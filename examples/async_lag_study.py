"""Policy-lag study: reproduce the paper's central finding in one run.

Sweeps the degree of asynchronicity (policy-buffer capacity K) for VACO
and PPO on two environments and prints a compact table of final
normalized scores — the essence of Fig. 3 — plus the measured backward
lag (mean TV between the actor mixture and pi_T at collection time).

    PYTHONPATH=src python examples/async_lag_study.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl  # noqa: E402

ENVS = ["pendulum", "pointmass"]
CAPS = [1, 4, 16]
ALGS = ["vaco", "ppo"]


def main() -> None:
    raw = {}
    for alg in ALGS:
        for cap in CAPS:
            scores = []
            for env in ENVS:
                res = run_async_rl(AsyncRLRunConfig(
                    env_name=env, algorithm=alg, buffer_capacity=cap,
                    n_actors=16, rollout_steps=96, total_phases=14,
                    seed=0))
                scores.append(np.mean(res.returns[-3:]))
            raw[(alg, cap)] = np.asarray(scores)

    # min-max normalize per env across everything.
    allv = np.stack(list(raw.values()))       # [cells, envs]
    lo, hi = allv.min(axis=0), allv.max(axis=0)
    rng = np.where(hi - lo < 1e-9, 1.0, hi - lo)

    print(f"\n{'':8s}" + "".join(f"K={c:<10d}" for c in CAPS))
    for alg in ALGS:
        cells = []
        for cap in CAPS:
            normed = (raw[(alg, cap)] - lo) / rng
            cells.append(f"{normed.mean():.3f}     ")
        print(f"{alg:8s}" + "".join(cells))
    print("\n(normalized mean final return; rows=algorithm, "
          "cols=degree of asynchronicity. The paper's claim: the "
          "VACO row decays more slowly left to right.)")


if __name__ == "__main__":
    main()
