"""One runtime, three lag regimes — the unified actor-learner subsystem.

Runs the same pendulum learner through every lag regime of the async
runtime (`repro.runtime`): the paper's two phase-locked protocols and a
genuinely concurrent producer thread, all publishing/sampling through the
same versioned PolicyStore and consuming from the same staleness-tagged
TrajectoryQueue.  Also demonstrates admission control at the queue
boundary: a max-lag eviction pass and a TV-gated pass (Eq. 8 lifted from
the minibatch to the queue).

    PYTHONPATH=src python examples/async_runtime.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.train.runner_rl import (  # noqa: E402
    AsyncRLRunConfig,
    run_async_rl,
)

# The classic-RL regimes; the fourth ("threaded_engine") drives the
# continuous-batching LLM serve engine instead of an env producer — see
# tests/test_serve_engine.py and repro.launch.serve --engine continuous.
ENV_REGIMES = ("backward_mixture", "forward_n", "threaded")

PHASES = 8
BASE = dict(env_name="pendulum", algorithm="vaco", buffer_capacity=4,
            n_actors=8, rollout_steps=48, total_phases=PHASES, seed=0)


def _summary(name: str, res, dt: float) -> None:
    q = res.runtime_stats["queue"]
    print(f"  {name:18s} phases={len(res.returns):2d} "
          f"final_return={res.returns[-1]:8.1f} "
          f"mean_lag={res.runtime_stats['mean_lag']:.2f} "
          f"max_lag={res.runtime_stats['max_lag']} "
          f"admitted={q['admitted']} dropped={q['dropped']} "
          f"({dt:.1f}s)")
    print(f"  {'':18s} lag histogram: {q['lag_histogram']}")


def main() -> None:
    print("=== three lag regimes, one PolicyStore/TrajectoryQueue API ===\n")
    for regime in ENV_REGIMES:
        t0 = time.time()
        res = run_async_rl(AsyncRLRunConfig(
            **BASE, runtime=regime, forward_n=4, get_timeout=60.0))
        _summary(regime, res, time.time() - t0)
    print()

    print("=== admission control at the queue boundary ===\n")
    t0 = time.time()
    res = run_async_rl(AsyncRLRunConfig(
        **BASE, runtime="threaded", admission="max_lag", max_lag=1,
        get_timeout=60.0))
    _summary("threaded+max_lag", res, time.time() - t0)

    t0 = time.time()
    res = run_async_rl(AsyncRLRunConfig(
        **BASE, runtime="threaded", admission="tv_gate",
        admission_mode="downweight", get_timeout=60.0))
    _summary("threaded+tv_gate", res, time.time() - t0)
    q = res.runtime_stats["queue"]
    print(f"  {'':18s} downweighted={q['downweighted']} "
          f"(items over delta/2 admitted at reduced weight)")
    print("\n(The same store/queue also drives the RLVR trainer — see "
          "repro.train.trainer_rlvr and `--runtime` on "
          "repro.launch.train.)")


if __name__ == "__main__":
    main()
