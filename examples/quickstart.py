"""Quickstart: VACO vs PPO under backward policy lag, in ~2 minutes.

Runs the simulated-asynchronous setup (Fig. 1 left) on the pure-JAX
pendulum with a policy buffer of K=8 stale policies, and prints the
eval-return trajectories plus the final-policy TV divergence — VACO's TV
should sit at its delta/2 = 0.1 constraint while improving return.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl  # noqa: E402


def main() -> None:
    print("=== VACO vs PPO under backward policy lag (K=8) ===\n")
    for alg in ("vaco", "ppo"):
        cfg = AsyncRLRunConfig(
            env_name="pendulum",
            algorithm=alg,
            buffer_capacity=8,     # 8 stale policies in the actor mixture
            n_actors=16,
            rollout_steps=96,
            total_phases=12,
            seed=0,
        )
        res = run_async_rl(cfg)
        curve = " -> ".join(f"{r:.0f}" for r in res.returns[::3])
        print(f"{alg:5s} eval return: {curve}")
        print(f"      final TV vs behavior data: {res.final_tv:.4f}"
              + ("  (VACO constraint delta/2 = 0.100)"
                 if alg == "vaco" else ""))
        print()


if __name__ == "__main__":
    main()
