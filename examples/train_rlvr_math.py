"""End-to-end RLVR driver (§5.2 protocol, deliverable b).

Trains a ~1-100M-class model of the paper's own family (qwen2.5-0.5b
shape, reduced) on the synthetic verifiable-math task for a few hundred
steps:

  1. supervised warm-start (creates the "base model" — no HF downloads
     offline);
  2. GRPO+VACO forward-lag loop: generate N minibatches per frozen
     policy, train N updates, track eval accuracy + TV + filter rate.

    PYTHONPATH=src python examples/train_rlvr_math.py \\
        [--algorithm grpo_vaco] [--n-minibatches 4] [--phases 10]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.configs import reduced_config  # noqa: E402
from repro.data.mathgen import MathTaskDataset  # noqa: E402
from repro.data.tokenizer import get_tokenizer  # noqa: E402
from repro.models.registry import build  # noqa: E402
from repro.train.trainer_rlvr import (  # noqa: E402
    RLVRHyperparams,
    RLVRTrainer,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--algorithm", default="grpo_vaco",
                    choices=["grpo", "grpo_vaco"])
    ap.add_argument("--n-minibatches", type=int, default=4)
    ap.add_argument("--phases", type=int, default=10)
    ap.add_argument("--warmup-steps", type=int, default=250)
    ap.add_argument("--level", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    tok = get_tokenizer()
    cfg = reduced_config("qwen2.5-0.5b", vocab=tok.vocab_size).replace(
        value_head=False)
    bundle = build(cfg)
    print(f"model: {cfg.name} "
          f"({cfg.param_count()/1e6:.1f}M params, vocab {cfg.vocab_size})")

    ds = MathTaskDataset(prompt_len=24, level=args.level, seed=args.seed)
    hp = RLVRHyperparams(
        algorithm=args.algorithm,
        n_minibatches=args.n_minibatches,
        prompts_per_minibatch=8,
        completions_per_prompt=4,
        max_new_tokens=6,
        warmup_steps=args.warmup_steps,
        lr=3e-5,
    )
    trainer = RLVRTrainer(bundle, ds, hp, seed=args.seed)

    print("\n[1/2] supervised warm-start (base-model creation)...")
    loss = trainer.warmup()
    acc = trainer.evaluate(128)
    print(f"      warmup loss {loss:.4f}; eval exact-match {acc:.3f}")

    print(f"\n[2/2] RLVR ({args.algorithm}, forward lag N="
          f"{args.n_minibatches})...")
    for phase in range(args.phases):
        logs = trainer.train_phase()
        rew = np.mean([l.mean_reward for l in logs])
        tv = np.mean([l.tv for l in logs])
        filt = np.mean([l.frac_filtered for l in logs])
        line = (f"  phase {phase:2d}  reward={rew:.3f} "
                f"TV={tv:.4f} filter/clip={filt:.3f}")
        if (phase + 1) % 3 == 0 or phase == args.phases - 1:
            line += f"  eval_acc={trainer.evaluate(128):.3f}"
        print(line, flush=True)

    final = trainer.evaluate()
    print(f"\nfinal eval exact-match accuracy: {final:.3f}")


if __name__ == "__main__":
    main()
