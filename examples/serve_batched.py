"""Batched serving demo: the actor-side engine (prefill + KV-cache decode)
on a reduced assigned architecture, with verifier scoring.

Demonstrates the serve path that the dry-run lowers at production scale
(decode_32k / long_500k shapes):

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-12b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import reduced_config  # noqa: E402
from repro.data.mathgen import MathTaskDataset, verify  # noqa: E402
from repro.data.tokenizer import get_tokenizer  # noqa: E402
from repro.models.registry import build  # noqa: E402
from repro.rollout.sampler import generate  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b",
                    help="any assigned arch id (reduced variant is built)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    args = ap.parse_args()

    tok = get_tokenizer()
    cfg = reduced_config(args.arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"window={cfg.sliding_window}, arch_type={cfg.arch_type}")

    ds = MathTaskDataset(prompt_len=24, level=0)
    toks_np, prompts, answers = ds.sample_batch(args.batch)

    aux = {}
    for name, shape in bundle.aux_input_shapes.items():
        aux[name] = jnp.ones((args.batch,) + shape) * 0.01

    gen = jax.jit(lambda p, t, k: generate(
        bundle, p, t, k, max_new_tokens=args.max_new_tokens,
        temperature=0.8, top_p=0.95, aux=aux or None))
    key = jax.random.PRNGKey(1)
    res = gen(params, jnp.asarray(toks_np), key)
    jax.block_until_ready(res.tokens)
    t0 = time.time()
    res = gen(params, jnp.asarray(toks_np), key)
    jax.block_until_ready(res.tokens)
    dt = time.time() - t0
    n = args.batch * args.max_new_tokens
    print(f"{n} tokens in {dt*1e3:.0f} ms "
          f"({n/dt:.0f} tok/s, CPU host, jitted decode loop)\n")

    comp = np.asarray(res.completion)
    for i in range(min(4, args.batch)):
        text = tok.decode(comp[i])
        print(f"  prompt: {prompts[i]!r}")
        print(f"  output: {text!r}  "
              f"(reward={verify(text, answers[i])}, untrained model)\n")


if __name__ == "__main__":
    main()
