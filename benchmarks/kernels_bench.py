"""Kernel micro-benchmarks: Pallas (interpret) vs jnp-oracle timing +
derived HBM-traffic accounting for the fused-logprob win.

On this CPU host wall-clock comparisons of interpret-mode Pallas are not
meaningful as TPU predictions — the purpose here is (a) a perf harness
skeleton that runs identically on TPU, and (b) the *analytic* derived
columns (bytes moved) that do transfer.
"""
from __future__ import annotations

import time
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.vtrace_pallas import vtrace_pallas
from repro.kernels.fused_logprob_pallas import logprobs_pallas


def _time(fn: Callable, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_rows():
    rows = []
    key = jax.random.PRNGKey(0)

    # vtrace: oracle scan timing + derived bytes.
    B, T = 64, 512
    ks = jax.random.split(key, 5)
    lr = 0.3 * jax.random.normal(ks[0], (B, T))
    v = jax.random.normal(ks[1], (B, T))
    bv = jax.random.normal(ks[2], (B,))
    r = jax.random.normal(ks[3], (B, T))
    d = jnp.full((B, T), 0.99)
    f_ref = jax.jit(lambda *a: ref.ref_vtrace(*a))
    us = _time(f_ref, lr, v, bv, r, d)
    bytes_moved = 5 * B * T * 4 + 2 * B * T * 4
    rows.append(("vtrace_ref_scan_B64_T512", us, bytes_moved))

    # fused logprob vs unfused: derived HBM traffic at RLVR scale.
    N, V = 256, 4096
    logits = 4.0 * jax.random.normal(ks[4], (N, V))
    targets = jax.random.randint(ks[0], (N,), 0, V)
    f_unfused = jax.jit(lambda l, t: (
        ref.ref_logprobs_from_logits(l, t), ref.ref_entropy_from_logits(l)))
    us = _time(f_unfused, logits, targets)
    # unfused: read logits ~3x (lse, gather-softmax, entropy) + write N.
    rows.append(("logprob_unfused_N256_V4096", us, 3 * N * V * 4))
    us = _time(
        lambda l, t: logprobs_pallas(l, t, interpret=True), logits, targets)
    # fused kernel: read logits once, write 2N.
    rows.append(("logprob_fused_interp_N256_V4096", us, N * V * 4))

    # flash-attention derived: causal+SWA block skip fraction at gemma3
    # local-layer geometry (S=4096, W=1024, block 128): blocks computed /
    # total.
    S, W, BLK = 4096, 1024, 128
    nq = nk = S // BLK
    total = nq * nk
    computed = sum(
        1
        for iq in range(nq)
        for ik in range(nk)
        if ik * BLK <= iq * BLK + BLK - 1
        and (iq * BLK - (ik * BLK + BLK - 1)) < W
    )
    rows.append(("flash_swa_blocks_computed_frac_x1000",
                 0.0, computed * 1000 // total))
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for name, us, derived in bench_rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
