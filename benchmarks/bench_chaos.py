"""Chaos smoke: the serve-backed RLVR loop must survive a canned fault
plan — and the recovery must be *measured*, not assumed.

Two runs of the same tiny threaded serve-producer RLVR training from one
shared warm-started base policy:

* **baseline** — fault-free;
* **chaos** — under a canned plan covering every injection family: a
  producer crash (watchdog restarts it with backoff, the first
  recovered batch carries ``restart`` provenance and the outage-spanning
  lag), a decode-loop stall long enough to blow the per-request
  deadline (timed-out requests retire cleanly and free their pages), a
  NaN publish (quarantined by the finiteness guard, never served), a
  queue stall, and a poisoned learner step (skipped + rolled back).

The run must complete with no deadlock, zero leaked pages / refcounts /
threads at exit, the quarantined version never entering any served
minibatch, and the chaos run's final greedy eval within a band of the
fault-free run — all written as flat gate metrics for
``benchmarks.check_regression`` (``CHAOS_METRICS``).

Env-tunable thresholds (CI knobs; defaults fit a laptop-class host):
``CHAOS_DEADLINE_S`` (per-request budget, default 3.0),
``CHAOS_STALL_MS`` (decode stall, default 2.5x the deadline),
``CHAOS_REWARD_BAND`` (|chaos - baseline| eval band, default 0.4),
``CHAOS_JOIN_S`` (thread-join grace at shutdown, default 10).

    PYTHONPATH=src python -m benchmarks.bench_chaos --steps-small \\
        --out results/bench/BENCH_chaos.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time
from typing import Any, Dict, Optional, Set

import numpy as np

ENV = {
    "deadline_s": float(os.environ.get("CHAOS_DEADLINE_S", "3.0")),
    "stall_ms": float(os.environ.get("CHAOS_STALL_MS", "0")) or None,
    "reward_band": float(os.environ.get("CHAOS_REWARD_BAND", "0.4")),
    "join_s": float(os.environ.get("CHAOS_JOIN_S", "10")),
}


def canned_plan(*, stall_ms: float, deadline_s: float) -> str:
    """The gate's fault plan: >=1 producer crash, >=1 deadline blowout,
    >=1 NaN publish, plus a queue stall and a poisoned learner step."""
    return ";".join((
        # Crash the producer thread on its third minibatch.
        "producer_crash:at_step=2",
        # Stall the decode loop long enough that every in-flight
        # request's wall-clock budget expires (stall >> deadline).
        f"stall:at_step=12,ms={stall_ms:g}",
        # Poison the learner's 4th publish: warmup is publish #1, so
        # this lands mid-training and the guard must quarantine it.
        "nan_publish:at_publish=4",
        # A put-side hiccup: backpressure path, not a failure.
        "queue_stall:at_call=5,ms=120",
        # Poison the learner state after step 7: the finiteness guard
        # must skip the step and roll back to the last good state.
        "learner_nan:at_step=7",
    ))


def _make_parts(seed: int, warmup_steps: int):
    from repro.configs.base import ModelConfig
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer

    tok = get_tokenizer()
    cfg = ModelConfig(
        name="chaos", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=tok.vocab_size,
    )

    def make_ds() -> MathTaskDataset:
        return MathTaskDataset(prompt_len=16, level=0, pool_size=256,
                               seed=seed + 1)

    return cfg, make_ds


def _make_hp(*, warmup_steps: int, fault_plan: str,
             deadline_s: Optional[float], seed: int):
    from repro.train.trainer_rlvr import RLVRHyperparams

    return RLVRHyperparams(
        algorithm="grpo", lr=1e-3, n_minibatches=3,
        prompts_per_minibatch=4, completions_per_prompt=4,
        max_new_tokens=6, warmup_steps=warmup_steps,
        producer="serve", runtime="threaded", queue_maxsize=2,
        controller="pass_through", store_capacity=6,
        engine_max_batch=8, engine_num_blocks=48,
        get_timeout=120.0,
        fault_plan=fault_plan, fault_seed=seed,
        watchdog_restarts=3, watchdog_backoff_ms=40.0,
        request_deadline_s=deadline_s,
        finiteness_guard=True,
    )


def _run_one(
    bundle, make_ds, hp, warm_params, *, seed: int, phases: int,
    tracer=None,
) -> Dict[str, Any]:
    """One threaded training run from the shared warm start; returns the
    gate's per-run observables (reward, counters, leak audit)."""
    import jax.numpy as jnp

    from repro.train.trainer_rlvr import (
        RLVRTrainer,
        RLVRTrainState,
        adamw_init,
    )

    threads_before = {t.ident for t in threading.enumerate()}
    tr = RLVRTrainer(bundle, make_ds(), hp, seed=seed, tracer=tracer)
    tr.state = RLVRTrainState(
        params=warm_params, opt_state=adamw_init(warm_params),
        updates=jnp.zeros((), jnp.int32),
    )
    tr.store.publish(warm_params, event="chaos_base")

    # Quarantine-never-served audit: record every behavior version that
    # reaches the queue (the engine stamps per-token provenance; any
    # quarantined version appearing here means a poisoned snapshot got
    # served).
    served_versions: Set[int] = set()
    orig_put = tr.queue.put

    def audited_put(payload, **kw):
        versions = getattr(payload, "versions", None)
        if versions is not None:
            served_versions.update(
                int(v) for v in np.unique(np.asarray(versions)))
        return orig_put(payload, **kw)

    tr.queue.put = audited_put

    t0 = time.monotonic()
    res = tr.train(phases, eval_every=10**9)
    final_acc = tr.evaluate(128)
    wall_s = time.monotonic() - t0
    tr.close()

    # Leak audit — pages: every pool block must be free (or resident in
    # the prefix cache) once all requests have retired; threads: every
    # thread this run started must be gone after close().
    alloc = tr.engine.allocator
    leaked_pages = alloc.num_blocks - alloc.num_free
    deadline = time.monotonic() + ENV["join_s"]
    while time.monotonic() < deadline:
        alive = [t for t in threading.enumerate()
                 if t.ident not in threads_before and t.is_alive()]
        if not alive:
            break
        time.sleep(0.05)
    leaked_threads = len(alive)

    quarantined = sorted(tr.store.quarantined_versions())
    counters = tr.metrics.counter_values(
        "fault_injected_total", "watchdog_restart_total",
        "request_timeout_total", "publish_quarantined_total",
        "restart_admitted_total", "learner_nonfinite_total",
        "admission_fallback_total")

    def total(name: str) -> int:
        return int(sum(v for k, v in counters.items()
                       if k.split("{")[0] == name))

    return {
        "final_reward": float(final_acc),
        "updates": len(res.phase_logs),
        "mean_minibatch_reward": (
            float(np.mean([pl.mean_reward for pl in res.phase_logs]))
            if res.phase_logs else 0.0),
        "wall_s": wall_s,
        "producer_restarts": tr.regime.restarts,
        "engine_timeouts": int(tr.engine.stats.timeouts),
        "quarantined_versions": quarantined,
        "quarantine_served": len(served_versions
                                 & set(quarantined)),
        "leaked_pages": int(leaked_pages),
        "leaked_threads": int(leaked_threads),
        "counters": counters,
        "faults_fired": dict(tr.regime.injector.fired_counts()),
        "watchdog_restart_total": total("watchdog_restart_total"),
        "request_timeout_total": total("request_timeout_total"),
        "publish_quarantined_total": total("publish_quarantined_total"),
        "restart_admitted_total": total("restart_admitted_total"),
        "learner_nonfinite_total": total("learner_nonfinite_total"),
        "runtime_stats": res.runtime_stats,
    }


def run_chaos(*, phases: int = 5, warmup_steps: int = 80,
              seed: int = 0) -> Dict[str, Any]:
    from repro.models.registry import build
    from repro.obs.tracer import make_tracer
    from repro.train.trainer_rlvr import RLVRTrainer

    sys.path.insert(0, os.path.dirname(__file__))
    from trace_report import fault_report

    cfg, make_ds = _make_parts(seed, warmup_steps)
    bundle = build(cfg)

    # Shared warm start (and the process's jit warm-up): both runs train
    # from identical params, so reward deltas are chaos-induced.
    warm_hp = _make_hp(warmup_steps=warmup_steps, fault_plan="",
                       deadline_s=None, seed=seed)
    warm_tr = RLVRTrainer(bundle, make_ds(), warm_hp, seed=seed)
    warm_tr.warmup()
    warm_params = warm_tr.state.params
    warm_tr.close()

    baseline = _run_one(
        bundle, make_ds,
        _make_hp(warmup_steps=warmup_steps, fault_plan="",
                 deadline_s=None, seed=seed),
        warm_params, seed=seed, phases=phases)

    deadline_s = ENV["deadline_s"]
    stall_ms = ENV["stall_ms"] or deadline_s * 2.5e3
    plan = canned_plan(stall_ms=stall_ms, deadline_s=deadline_s)
    tracer = make_tracer("spans")
    chaos = _run_one(
        bundle, make_ds,
        _make_hp(warmup_steps=warmup_steps, fault_plan=plan,
                 deadline_s=deadline_s, seed=seed),
        warm_params, seed=seed, phases=phases, tracer=tracer)

    events = [
        {"ph": ev.ph, "name": ev.name, "ts": ev.ts, "pid": ev.pid,
         "tid": ev.tid, "args": ev.args, "id": ev.id}
        for ev in tracer.events()
    ]
    recovery = fault_report(events)

    reward_delta = abs(chaos["final_reward"] - baseline["final_reward"])
    restarts = chaos["watchdog_restart_total"]
    recovered = [r for r in recovery["restarts"]
                 if r.get("recovery_ms") is not None]
    return {
        "benchmark": "chaos",
        "config": {
            "phases": phases, "warmup_steps": warmup_steps,
            "seed": seed, "fault_plan": plan,
            "request_deadline_s": deadline_s, "stall_ms": stall_ms,
            "reward_band": ENV["reward_band"],
        },
        "baseline": baseline,
        "chaos": chaos,
        "recovery": recovery,
        # --- flat gate metrics (benchmarks.check_regression) ---
        # completed: both runs consumed their full update budget minus
        # at most the guard-skipped steps — nothing deadlocked.
        "completed": float(
            baseline["updates"] == phases * 3
            and chaos["updates"] >= phases * 3 - 2),
        "reward_delta": reward_delta,
        "reward_band_ok": float(reward_delta <= ENV["reward_band"]),
        "leaked_pages": float(chaos["leaked_pages"]
                              + baseline["leaked_pages"]),
        "leaked_threads": float(chaos["leaked_threads"]
                                + baseline["leaked_threads"]),
        "quarantine_served": float(chaos["quarantine_served"]),
        "faults": {
            "producer_crash": float(
                chaos["faults_fired"].get("producer_crash", 0)),
            "nan_publish": float(
                chaos["faults_fired"].get("nan_publish", 0)),
            "request_timeouts": float(chaos["request_timeout_total"]),
            "watchdog_restarts": float(restarts),
            "restart_admitted": float(chaos["restart_admitted_total"]),
            "learner_nonfinite": float(
                chaos["learner_nonfinite_total"]),
            "recovery_measured": float(
                1.0 if (restarts == 0 or recovered) else 0.0),
        },
    }


def print_chaos(doc: Dict[str, Any]) -> None:
    base, chaos = doc["baseline"], doc["chaos"]
    print(f"\nchaos smoke (plan: {doc['config']['fault_plan']})")
    print(f"  {'':<26}{'baseline':>10}{'chaos':>10}")
    for key in ("final_reward", "mean_minibatch_reward", "updates",
                "wall_s", "engine_timeouts", "producer_restarts",
                "leaked_pages", "leaked_threads"):
        b, c = base[key], chaos[key]
        fmt = (lambda v: f"{v:>10.3f}" if isinstance(v, float)
               else f"{v:>10}")
        print(f"  {key:<26}{fmt(b)}{fmt(c)}")
    print(f"  faults fired: {chaos['faults_fired']}")
    print(f"  quarantined versions: {chaos['quarantined_versions']} "
          f"(served: {chaos['quarantine_served']})")
    rec = [r for r in doc["recovery"]["restarts"]
           if r.get("recovery_ms") is not None]
    for r in rec:
        print(f"  restart attempt {r['attempt']}: recovered in "
              f"{r['recovery_ms']:.1f} ms, admitted lag "
              f"{r['admitted_lag_oldest']} oldest / "
              f"{r['admitted_lag_newest']} newest")
    print(f"  timeout retirements by state: "
          f"{doc['recovery']['timeout_retirements']}")
    print(f"  reward |delta| {doc['reward_delta']:.3f} "
          f"(band {doc['config']['reward_band']}): "
          f"{'OK' if doc['reward_band_ok'] else 'OUT OF BAND'}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--phases", type=int, default=8)
    ap.add_argument("--warmup-steps", type=int, default=120)
    ap.add_argument("--steps-small", action="store_true",
                    help="CI-smoke scale (fewer phases, shorter warmup); "
                         "the committed baseline and the fresh CI run "
                         "must agree on this flag")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write a BENCH_chaos.json artifact for the CI "
                         "regression gate")
    args = ap.parse_args()
    if args.steps_small:
        doc = run_chaos(phases=5, warmup_steps=80, seed=args.seed)
    else:
        doc = run_chaos(phases=args.phases,
                        warmup_steps=args.warmup_steps, seed=args.seed)
    print_chaos(doc)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
