"""Serve throughput: continuous batching vs the phase-locked batch loop.

The same FIFO request stream — mixed per-request completion budgets,
the regime where phase-locked batching wastes the most decode work —
is served two ways:

* **phase_locked** — requests are grouped FIFO into fixed batches of
  ``max_batch``; each batch runs ``rollout.sampler.generate`` for the
  *longest* member's budget, so short rows idle-decode PAD until the
  slowest finishes, and the next batch waits behind them.
* **continuous** — the ``repro.serve`` engine admits/retires requests
  between decode steps over the paged KV cache; a retiring short
  request immediately frees its slot (and pages) for the next waiting
  request.

Reported per mode: useful tokens/sec (only mask-valid tokens count) and
p50/p99 *request latency* (submit -> last token, queueing included).
Results land in a machine-readable ``BENCH_serve.json``.

    PYTHONPATH=src python -m benchmarks.bench_serve [--steps 6] \\
        [--out results/bench/BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np


def run(
    *,
    n_requests: int = 12,
    max_batch: int = 4,
    lengths: tuple = (2, 4, 8, 48),
    block_size: int = 8,
    num_blocks: int = 48,
    prompt_len: int = 32,
    decode_chunk: int = 8,
    arch: str = "qwen2.5-0.5b",
    temperature: float = 1.0,
    seed: int = 0,
    repeats: int = 3,
) -> Dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.models.registry import build
    from repro.rollout.sampler import generate
    from repro.serve import ServeEngine

    tok = get_tokenizer()
    cfg = reduced_config(arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))
    ds = MathTaskDataset(prompt_len=prompt_len, level=0, seed=seed + 1)
    toks_np, _, _ = ds.sample_batch(n_requests)
    budgets = [lengths[i % len(lengths)] for i in range(n_requests)]
    max_seq_len = prompt_len + max(lengths) + block_size

    # -- phase-locked: FIFO batches, everyone decodes the batch max ----------
    gen_fns = {}

    def _run_static() -> Dict:
        t0 = time.perf_counter()
        useful = 0.0
        latencies = []
        elapsed = 0.0
        for lo in range(0, n_requests, max_batch):
            rows = toks_np[lo:lo + max_batch]
            batch_budgets = budgets[lo:lo + max_batch]
            n_new = max(batch_budgets)
            key = (rows.shape[0], n_new)
            fn = gen_fns.get(key)
            if fn is None:
                fn = gen_fns[key] = jax.jit(
                    lambda p, t, k, n=n_new: generate(
                        bundle, p, t, k, max_new_tokens=n,
                        temperature=temperature))
            res = fn(params, jnp.asarray(rows),
                     jax.random.fold_in(jax.random.PRNGKey(seed + 2), lo))
            jax.block_until_ready(res.tokens)
            mask = np.asarray(res.mask)
            # a row's useful tokens are capped by its own budget
            for i, b in enumerate(batch_budgets):
                useful += float(mask[i, :b].sum())
            elapsed = time.perf_counter() - t0
            latencies.extend([elapsed] * rows.shape[0])   # batch waits whole
        return {"wall_s": elapsed, "useful_tokens": useful,
                "latencies_s": latencies}

    # -- continuous: one engine, requests stream through slots ---------------
    engine = ServeEngine(
        bundle, params, num_blocks=num_blocks, block_size=block_size,
        max_batch=max_batch, max_seq_len=max_seq_len,
        decode_chunk=decode_chunk, temperature=temperature, seed=seed + 2)

    def _run_continuous() -> Dict:
        # The engine (and its jit caches) is reused across repeats, so
        # every stat must be a per-run delta of its cumulative counter.
        before = dict(engine.stats.__dict__)
        t0 = time.perf_counter()
        for i in range(n_requests):
            row = toks_np[i]
            engine.submit(row[row != tok.pad_id], budgets[i])
        trajs = engine.run()
        wall = time.perf_counter() - t0
        d = {k: engine.stats.__dict__[k] - v for k, v in before.items()}
        return {
            "wall_s": wall,
            "useful_tokens": float(d["tokens_out"]),
            "latencies_s": [t.latency_s for t in trajs],
            "mean_occupancy": (
                d["occupancy_sum"] / d["decode_steps"]
                if d["decode_steps"] else 0.0
            ),
            "preemptions": d["preemptions"],
        }

    def _summarize(raw: Dict) -> Dict:
        lat = np.asarray(raw["latencies_s"]) * 1e3
        out = {
            "tokens_per_s": raw["useful_tokens"] / raw["wall_s"],
            "useful_tokens": raw["useful_tokens"],
            "wall_s": raw["wall_s"],
            "latency_p50_ms": float(np.percentile(lat, 50)),
            "latency_p99_ms": float(np.percentile(lat, 99)),
        }
        for k in ("mean_occupancy", "preemptions"):
            if k in raw:
                out[k] = raw[k]
        return out

    def _best_of(fn) -> Dict:
        """Warm once, then best-of-`repeats` by wall time (standard
        noise suppression: the minimum is the least-perturbed run)."""
        fn()
        runs = [fn() for _ in range(max(repeats, 1))]
        return _summarize(min(runs, key=lambda r: r["wall_s"]))

    static = _best_of(_run_static)
    continuous = _best_of(_run_continuous)
    return {
        "config": {
            "arch": arch, "n_requests": n_requests, "max_batch": max_batch,
            "lengths": list(lengths), "block_size": block_size,
            "num_blocks": num_blocks, "prompt_len": prompt_len,
            "decode_chunk": decode_chunk,
            "temperature": temperature, "seed": seed,
        },
        "phase_locked": static,
        "continuous": continuous,
        "speedup_tokens_per_s": (
            continuous["tokens_per_s"] / static["tokens_per_s"]
            if static["tokens_per_s"] else 0.0
        ),
    }


def run_pool_sweep(
    *,
    block_counts: tuple = (16, 32, 64, 128, 256),
    block_size: int = 8,
    max_batch: int = 2,
    prompt_len: int = 8,
    budget: int = 56,
    decode_chunk: int = 8,
    arch: str = "qwen2.5-0.5b",
    seed: int = 0,
    repeats: int = 8,
) -> Dict:
    """Per-decode-step cost vs pool size at *equal work*.

    Every pool size serves the identical request stream (sized to fit
    the smallest pool), so the only variable is ``num_blocks``.  With
    the in-place paged pool the per-step cost must be ~flat — the old
    scan-carried pool rewrote all ``[L, KV, NB, BS, Dh]`` bytes per step
    and grew ~linearly (128 blocks measured ~2.7x over 16 at equal
    work).  ``cost_ratio`` (max/min per-step ms across the sweep) is the
    number the CI regression gate enforces.

    The workload is decode-dominated by construction (long budgets,
    short prompts, few prefills) so the per-step number measures the
    decode dispatch, not admission overhead.
    """
    import jax

    from repro.configs import reduced_config
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.models.registry import build
    from repro.serve import ServeEngine

    import jax.numpy as jnp
    import numpy as np

    tok = get_tokenizer()
    cfg = reduced_config(arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))
    ds = MathTaskDataset(prompt_len=prompt_len, level=0, seed=seed + 1)
    toks_np, _, _ = ds.sample_batch(max_batch)
    prompts = [row[row != tok.pad_id] for row in toks_np]
    blocks_per_req = -(-(prompt_len + budget) // block_size)
    assert max_batch * blocks_per_req <= min(block_counts), (
        "workload must fit the smallest pool so work is equal across "
        "the sweep")

    # Long timing windows (~0.3s each): per-dispatch cost here is under
    # a millisecond, and OS scheduler noise at the 100ms scale otherwise
    # dominates the very flatness this sweep exists to measure.
    dispatches = 40

    class _Lane:
        """One pool size's frozen decode state, timeable on demand."""

        def __init__(self, nb: int) -> None:
            self.nb = nb
            self.engine = ServeEngine(
                bundle, params, num_blocks=nb, block_size=block_size,
                max_batch=max_batch, max_seq_len=prompt_len + budget,
                decode_chunk=decode_chunk, temperature=1.0, seed=seed + 2)
            for p in prompts:
                self.engine.submit(p, budget)
            self.engine.step()   # admit + prefill + first chunk
            # Frozen mid-sequence state: same tokens/tables/pos/active
            # for every pool size, and attention only reads owned pages
            # — identical work per timed call by construction, with
            # scheduler/prefill churn excluded.
            e = self.engine
            self.args = (
                jnp.asarray(e._last_tok), jnp.asarray(e._tables),
                jnp.asarray(e._pos), jnp.asarray(e._active),
                jnp.full((max_batch,), budget, jnp.int32),
                jax.random.PRNGKey(seed + 3))
            self.pages = e.pages

        def time_once(self) -> float:
            token, tables, pos, active, remaining, key = self.args
            t0 = time.perf_counter()
            for _ in range(dispatches):
                _, _, _, self.pages = self.engine._decode(
                    self.engine.params, token, self.pages, tables, pos,
                    active, remaining, key)
            jax.tree.map(np.asarray, self.pages)   # block until ready
            return (time.perf_counter() - t0) / dispatches

    lanes = [_Lane(nb) for nb in block_counts]
    for lane in lanes:
        lane.time_once()                           # compile/warm
    # Round-robin the pool sizes within each repeat: slow drift of the
    # host (thermal/turbo, background load) then lands on every pool
    # size equally instead of accumulating into a fake num_blocks slope.
    samples = {lane.nb: [] for lane in lanes}
    for _ in range(max(repeats, 1)):
        for lane in lanes:
            samples[lane.nb].append(lane.time_once())
    # Median, not min: the sweep compares pool sizes against each other,
    # and a single turbo-burst (or stalled) sample at one size would
    # skew the ratio in a way min-of-noise suppression can't fix.
    per_step_ms = {
        str(nb): float(np.median(ts)) / decode_chunk * 1e3
        for nb, ts in samples.items()
    }

    # The enforced flatness number comes from a linear fit over the
    # whole sweep, not max/min of the raw points: a single noisy pool
    # size then shifts the ratio by its leverage in the fit instead of
    # defining it outright.  An O(num_blocks) decode step has a strong
    # slope and still fits to ~2x+; the in-place pool fits to ~1.0x.
    counts = np.asarray(block_counts, np.float64)
    costs = np.asarray([per_step_ms[str(nb)] for nb in block_counts])
    slope, intercept = np.polyfit(counts, costs, 1)
    lo = intercept + slope * counts.min()
    hi = intercept + slope * counts.max()
    fitted = hi / lo if lo > 0 else float(max(costs) / min(costs))
    return {
        "config": {
            "arch": arch, "block_counts": list(block_counts),
            "block_size": block_size, "max_batch": max_batch,
            "prompt_len": prompt_len, "budget": budget,
            "decode_chunk": decode_chunk, "seed": seed,
        },
        "per_step_ms": per_step_ms,
        "cost_ratio": float(max(fitted, 1.0)),
        "cost_ratio_maxmin": float(max(costs) / min(costs)),
    }


def write_json(res: Dict, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(res, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=6,
                    help="workload scale: n_requests = 2 * steps")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    # Size the pool to the live working set: the pages pytree is carried
    # through the per-step jit, so an oversized pool taxes every step.
    ap.add_argument("--num-blocks", type=int, default=48)
    ap.add_argument("--lengths", default="2,4,8,48")
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--arch", default="qwen2.5-0.5b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sweep-blocks", action="store_true",
                    help="also sweep pool sizes and report per-step "
                         "decode cost vs num_blocks (the in-place pool "
                         "must be ~flat)")
    ap.add_argument("--sweep-block-counts", default="16,32,64,128,256")
    ap.add_argument("--out", default="results/bench/BENCH_serve.json")
    args = ap.parse_args()
    res = run(
        n_requests=max(2 * args.steps, 2),
        max_batch=args.max_batch,
        lengths=tuple(int(x) for x in args.lengths.split(",")),
        block_size=args.block_size,
        num_blocks=args.num_blocks,
        decode_chunk=args.decode_chunk,
        arch=args.arch,
        seed=args.seed,
    )
    for mode in ("phase_locked", "continuous"):
        m = res[mode]
        print(f"{mode:13s} {m['tokens_per_s']:8.1f} tok/s  "
              f"p50 {m['latency_p50_ms']:7.1f} ms  "
              f"p99 {m['latency_p99_ms']:7.1f} ms")
    print(f"{'speedup':13s} {res['speedup_tokens_per_s']:8.2f}x (tok/s)")
    if args.sweep_blocks:
        counts = tuple(
            int(x) for x in args.sweep_block_counts.split(","))
        # The sweep owns its workload shape (decode-dominated, sized to
        # fit the smallest pool) — only arch/seed follow the main bench.
        sweep = run_pool_sweep(
            block_counts=counts, arch=args.arch, seed=args.seed)
        res["pool_sweep"] = sweep
        for nb in counts:
            print(f"pool {nb:4d} blocks  "
                  f"{sweep['per_step_ms'][str(nb)]:7.3f} ms/step")
        print(f"{'sweep ratio':13s} {sweep['cost_ratio']:8.2f}x "
              f"(fitted {min(counts)}->{max(counts)}-block per-step "
              f"cost, 1.0 = flat; raw max/min "
              f"{sweep['cost_ratio_maxmin']:.2f}x)")
    if args.out:
        write_json(res, args.out)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
