"""Serve throughput: continuous batching vs the phase-locked batch loop.

The same FIFO request stream — mixed per-request completion budgets,
the regime where phase-locked batching wastes the most decode work —
is served two ways:

* **phase_locked** — requests are grouped FIFO into fixed batches of
  ``max_batch``; each batch runs ``rollout.sampler.generate`` for the
  *longest* member's budget, so short rows idle-decode PAD until the
  slowest finishes, and the next batch waits behind them.
* **continuous** — the ``repro.serve`` engine admits/retires requests
  between decode steps over the paged KV cache; a retiring short
  request immediately frees its slot (and pages) for the next waiting
  request.

Reported per mode: useful tokens/sec (only mask-valid tokens count) and
p50/p99 *request latency* (submit -> last token, queueing included).
Results land in a machine-readable ``BENCH_serve.json``.

    PYTHONPATH=src python -m benchmarks.bench_serve [--steps 6] \\
        [--out results/bench/BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import numpy as np


def run(
    *,
    n_requests: int = 12,
    max_batch: int = 4,
    lengths: tuple = (2, 4, 8, 48),
    block_size: int = 8,
    num_blocks: int = 48,
    prompt_len: int = 32,
    decode_chunk: int = 8,
    arch: str = "qwen2.5-0.5b",
    temperature: float = 1.0,
    seed: int = 0,
    repeats: int = 3,
) -> Dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import reduced_config
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.metrics.runtime_metrics import (
        serve_latency_counts,
        serve_latency_stats,
    )
    from repro.models.registry import build
    from repro.rollout.sampler import generate
    from repro.serve import ServeEngine

    tok = get_tokenizer()
    cfg = reduced_config(arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))
    ds = MathTaskDataset(prompt_len=prompt_len, level=0, seed=seed + 1)
    toks_np, _, _ = ds.sample_batch(n_requests)
    budgets = [lengths[i % len(lengths)] for i in range(n_requests)]
    max_seq_len = prompt_len + max(lengths) + block_size

    # -- phase-locked: FIFO batches, everyone decodes the batch max ----------
    gen_fns = {}

    def _run_static() -> Dict:
        t0 = time.perf_counter()
        useful = 0.0
        latencies = []
        elapsed = 0.0
        for lo in range(0, n_requests, max_batch):
            rows = toks_np[lo:lo + max_batch]
            batch_budgets = budgets[lo:lo + max_batch]
            n_new = max(batch_budgets)
            key = (rows.shape[0], n_new)
            fn = gen_fns.get(key)
            if fn is None:
                fn = gen_fns[key] = jax.jit(
                    lambda p, t, k, n=n_new: generate(
                        bundle, p, t, k, max_new_tokens=n,
                        temperature=temperature))
            res = fn(params, jnp.asarray(rows),
                     jax.random.fold_in(jax.random.PRNGKey(seed + 2), lo))
            jax.block_until_ready(res.tokens)
            mask = np.asarray(res.mask)
            # a row's useful tokens are capped by its own budget
            for i, b in enumerate(batch_budgets):
                useful += float(mask[i, :b].sum())
            elapsed = time.perf_counter() - t0
            latencies.extend([elapsed] * rows.shape[0])   # batch waits whole
        return {"wall_s": elapsed, "useful_tokens": useful,
                "latencies_s": latencies}

    # -- continuous: one engine, requests stream through slots ---------------
    engine = ServeEngine(
        bundle, params, num_blocks=num_blocks, block_size=block_size,
        max_batch=max_batch, max_seq_len=max_seq_len,
        decode_chunk=decode_chunk, temperature=temperature, seed=seed + 2)

    def _run_continuous() -> Dict:
        # The engine (and its jit caches) is reused across repeats, so
        # every stat must be a per-run delta of its cumulative counter —
        # including the latency columns, which come from the engine's
        # own registry histograms via a windowed read (same numbers the
        # live telemetry reports; benchmarks can't disagree with it).
        before = dict(engine.stats.__dict__)
        starts = serve_latency_counts(engine.metrics)
        t0 = time.perf_counter()
        for i in range(n_requests):
            row = toks_np[i]
            engine.submit(row[row != tok.pad_id], budgets[i])
        engine.run()
        wall = time.perf_counter() - t0
        d = {k: engine.stats.__dict__[k] - v for k, v in before.items()}
        lat = serve_latency_stats(engine.metrics, starts)
        return {
            "wall_s": wall,
            "useful_tokens": float(d["tokens_out"]),
            "latency_p50_ms": lat["request_latency_p50_ms"],
            "latency_p99_ms": lat["request_latency_p99_ms"],
            "ttft_p50_ms": lat["ttft_p50_ms"],
            "ttft_p99_ms": lat["ttft_p99_ms"],
            "inter_token_p50_ms": lat["inter_token_p50_ms"],
            "queue_wait_p50_ms": lat["queue_wait_p50_ms"],
            "mean_occupancy": (
                d["occupancy_sum"] / d["decode_steps"]
                if d["decode_steps"] else 0.0
            ),
            "preemptions": d["preemptions"],
        }

    def _summarize(raw: Dict) -> Dict:
        out = {
            "tokens_per_s": raw["useful_tokens"] / raw["wall_s"],
            "useful_tokens": raw["useful_tokens"],
            "wall_s": raw["wall_s"],
        }
        if "latencies_s" in raw:    # phase-locked: no engine registry
            lat = np.asarray(raw["latencies_s"]) * 1e3
            out["latency_p50_ms"] = float(np.percentile(lat, 50))
            out["latency_p99_ms"] = float(np.percentile(lat, 99))
        for k in ("latency_p50_ms", "latency_p99_ms", "ttft_p50_ms",
                  "ttft_p99_ms", "inter_token_p50_ms",
                  "queue_wait_p50_ms", "mean_occupancy", "preemptions"):
            if k in raw:
                out[k] = raw[k]
        return out

    def _best_of(fn) -> Dict:
        """Warm once, then best-of-`repeats` by wall time (standard
        noise suppression: the minimum is the least-perturbed run)."""
        fn()
        runs = [fn() for _ in range(max(repeats, 1))]
        return _summarize(min(runs, key=lambda r: r["wall_s"]))

    static = _best_of(_run_static)
    continuous = _best_of(_run_continuous)
    return {
        "config": {
            "arch": arch, "n_requests": n_requests, "max_batch": max_batch,
            "lengths": list(lengths), "block_size": block_size,
            "num_blocks": num_blocks, "prompt_len": prompt_len,
            "decode_chunk": decode_chunk,
            "temperature": temperature, "seed": seed,
        },
        "phase_locked": static,
        "continuous": continuous,
        "speedup_tokens_per_s": (
            continuous["tokens_per_s"] / static["tokens_per_s"]
            if static["tokens_per_s"] else 0.0
        ),
    }


def run_pool_sweep(
    *,
    block_counts: tuple = (16, 32, 64, 128, 256),
    block_size: int = 8,
    max_batch: int = 2,
    prompt_len: int = 8,
    budget: int = 56,
    decode_chunk: int = 8,
    arch: str = "qwen2.5-0.5b",
    seed: int = 0,
    repeats: int = 8,
) -> Dict:
    """Per-decode-step cost vs pool size at *equal work*.

    Every pool size serves the identical request stream (sized to fit
    the smallest pool), so the only variable is ``num_blocks``.  With
    the in-place paged pool the per-step cost must be ~flat — the old
    scan-carried pool rewrote all ``[L, KV, NB, BS, Dh]`` bytes per step
    and grew ~linearly (128 blocks measured ~2.7x over 16 at equal
    work).  ``cost_ratio`` (max/min per-step ms across the sweep) is the
    number the CI regression gate enforces.

    The workload is decode-dominated by construction (long budgets,
    short prompts, few prefills) so the per-step number measures the
    decode dispatch, not admission overhead.
    """
    import jax

    from repro.configs import reduced_config
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.models.registry import build
    from repro.serve import ServeEngine

    import jax.numpy as jnp
    import numpy as np

    tok = get_tokenizer()
    cfg = reduced_config(arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))
    ds = MathTaskDataset(prompt_len=prompt_len, level=0, seed=seed + 1)
    toks_np, _, _ = ds.sample_batch(max_batch)
    prompts = [row[row != tok.pad_id] for row in toks_np]
    blocks_per_req = -(-(prompt_len + budget) // block_size)
    assert max_batch * blocks_per_req <= min(block_counts), (
        "workload must fit the smallest pool so work is equal across "
        "the sweep")

    # Long timing windows (~0.3s each): per-dispatch cost here is under
    # a millisecond, and OS scheduler noise at the 100ms scale otherwise
    # dominates the very flatness this sweep exists to measure.
    dispatches = 40

    class _Lane:
        """One pool size's frozen decode state, timeable on demand."""

        def __init__(self, nb: int) -> None:
            self.nb = nb
            self.engine = ServeEngine(
                bundle, params, num_blocks=nb, block_size=block_size,
                max_batch=max_batch, max_seq_len=prompt_len + budget,
                decode_chunk=decode_chunk, temperature=1.0, seed=seed + 2)
            for p in prompts:
                self.engine.submit(p, budget)
            self.engine.step()   # admit + prefill + first chunk
            # Frozen mid-sequence state: same tokens/tables/pos/active
            # for every pool size, and attention only reads owned pages
            # — identical work per timed call by construction, with
            # scheduler/prefill churn excluded.
            e = self.engine
            self.args = (
                jnp.asarray(e._last_tok), jnp.asarray(e._tables),
                jnp.asarray(e._pos), jnp.asarray(e._active),
                jnp.full((max_batch,), budget, jnp.int32),
                jnp.asarray(e._slot_shard),
                jax.random.PRNGKey(seed + 3))
            self.pages = e.pages

        def time_once(self) -> float:
            (token, tables, pos, active, remaining, slot_shard,
             key) = self.args
            t0 = time.perf_counter()
            for _ in range(dispatches):
                _, _, _, self.pages = self.engine._decode(
                    self.engine.params, token, self.pages, tables, pos,
                    active, remaining, slot_shard, key)
            jax.tree.map(np.asarray, self.pages)   # block until ready
            return (time.perf_counter() - t0) / dispatches

    lanes = [_Lane(nb) for nb in block_counts]
    for lane in lanes:
        lane.time_once()                           # compile/warm
    # Round-robin the pool sizes within each repeat: slow drift of the
    # host (thermal/turbo, background load) then lands on every pool
    # size equally instead of accumulating into a fake num_blocks slope.
    samples = {lane.nb: [] for lane in lanes}
    for _ in range(max(repeats, 1)):
        for lane in lanes:
            samples[lane.nb].append(lane.time_once())
    # Median, not min: the sweep compares pool sizes against each other,
    # and a single turbo-burst (or stalled) sample at one size would
    # skew the ratio in a way min-of-noise suppression can't fix.
    per_step_ms = {
        str(nb): float(np.median(ts)) / decode_chunk * 1e3
        for nb, ts in samples.items()
    }

    # The enforced flatness number comes from a linear fit over the
    # whole sweep, not max/min of the raw points: a single noisy pool
    # size then shifts the ratio by its leverage in the fit instead of
    # defining it outright.  An O(num_blocks) decode step has a strong
    # slope and still fits to ~2x+; the in-place pool fits to ~1.0x.
    counts = np.asarray(block_counts, np.float64)
    costs = np.asarray([per_step_ms[str(nb)] for nb in block_counts])
    slope, intercept = np.polyfit(counts, costs, 1)
    lo = intercept + slope * counts.min()
    hi = intercept + slope * counts.max()
    fitted = hi / lo if lo > 0 else float(max(costs) / min(costs))
    return {
        "config": {
            "arch": arch, "block_counts": list(block_counts),
            "block_size": block_size, "max_batch": max_batch,
            "prompt_len": prompt_len, "budget": budget,
            "decode_chunk": decode_chunk, "seed": seed,
        },
        "per_step_ms": per_step_ms,
        "cost_ratio": float(max(fitted, 1.0)),
        "cost_ratio_maxmin": float(max(costs) / min(costs)),
    }


def run_speculative(
    *,
    k: int = 4,
    n_requests: int = 16,
    max_batch: int = 4,
    budget: int = 32,
    block_size: int = 8,
    num_blocks: int = 48,
    prompt_len: int = 32,
    decode_chunk: int = 8,
    arch: str = "qwen2.5-0.5b",
    seed: int = 0,
    repeats: int = 5,
) -> Dict:
    """Speculative vs plain continuous decode at a *cooperative* draft.

    The draft is the benchmark's replay **oracle**: a zero-cost host
    callable that proposes the continuation a prior plain greedy run of
    the same engine produced (both arms are greedy and share params, so
    the verifier re-derives exactly those tokens and acceptance sits at
    ~1).  That makes this the acceptance-rate *ceiling* instrument: it
    isolates what the single-dispatch multi-token verify path buys over
    per-token chunked decode — one k-query model evaluation per k
    emitted tokens instead of k sequential in-scan evaluations — with
    draft cost and draft quality taken out of the picture.  Production
    drafts (``--draft version:-n`` self-speculation, a small registry
    model) pay real draft cost and their acceptance is a *measured*
    property; this number is the mechanism's upper bound and the one CI
    gates (hard floor 1.2x at k=4).
    """
    import jax

    from repro.configs import reduced_config
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.models.registry import build
    from repro.serve import ServeEngine

    tok = get_tokenizer()
    cfg = reduced_config(arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))
    ds = MathTaskDataset(prompt_len=prompt_len, level=0, seed=seed + 1)
    toks_np, _, _ = ds.sample_batch(n_requests)
    prompts = [row[row != tok.pad_id] for row in toks_np]
    max_seq_len = prompt_len + budget + block_size

    def _mk(spec_k, draft):
        return ServeEngine(
            bundle, params, num_blocks=num_blocks, block_size=block_size,
            max_batch=max_batch, max_seq_len=max_seq_len,
            decode_chunk=decode_chunk, temperature=1e-4, seed=seed + 2,
            speculate_k=spec_k, draft=draft)

    def _run(engine, on_submit=None) -> Dict:
        before = dict(engine.stats.__dict__)
        t0 = time.perf_counter()
        for i, p in enumerate(prompts):
            req = engine.submit(p, budget)
            if on_submit is not None:
                on_submit(i, req)
        trajs = engine.run()
        wall = time.perf_counter() - t0
        d = {key: engine.stats.__dict__[key] - v
             for key, v in before.items()}
        return {"wall_s": wall, "tokens": d["tokens_out"],
                "drafted": d.get("drafted_tokens", 0),
                "accepted": d.get("accepted_tokens", 0), "trajs": trajs}

    plain = _mk(0, None)
    warm = _run(plain)                      # compile + oracle source
    continuations = [np.asarray(t.tokens, np.int32)
                     for t in sorted(warm["trajs"],
                                     key=lambda t: t.request_id)]

    cont_by_id: Dict[int, np.ndarray] = {}

    def oracle(req, kk):
        cont = cont_by_id.get(req.request_id)
        if cont is None:
            return np.zeros((kk,), np.int32)
        m = len(req.tokens)
        return cont[m:m + kk]

    spec = _mk(k, oracle)
    seed_oracle = lambda i, req: cont_by_id.setdefault(  # noqa: E731
        req.request_id, continuations[i])
    _run(spec, seed_oracle)                 # compile/warm

    # Arms alternate within each repeat and the gated speedup is the
    # MEDIAN of per-pair ratios: host drift (scheduler contention,
    # turbo) lands on both arms of a pair ~equally instead of silently
    # deflating whichever arm it happened to hit, which is what a
    # best-of-per-arm split measurement is vulnerable to.
    pairs = []
    for _ in range(max(repeats, 1)):
        p_run = _run(plain)
        s_run = _run(spec, seed_oracle)
        pairs.append((p_run, s_run))
    ratios = [
        (s_["tokens"] / s_["wall_s"]) / (p_["tokens"] / p_["wall_s"])
        for p_, s_ in pairs
    ]
    p = min((p_ for p_, _ in pairs), key=lambda r: r["wall_s"])
    s = min((s_ for _, s_ in pairs), key=lambda r: r["wall_s"])
    plain_tps = p["tokens"] / p["wall_s"]
    spec_tps = s["tokens"] / s["wall_s"]
    return {
        "config": {
            "arch": arch, "k": k, "n_requests": n_requests,
            "max_batch": max_batch, "budget": budget,
            "block_size": block_size, "num_blocks": num_blocks,
            "prompt_len": prompt_len, "decode_chunk": decode_chunk,
            "seed": seed, "draft": "oracle",
        },
        "plain_tokens_per_s": plain_tps,
        "tokens_per_s": spec_tps,
        "speedup_vs_plain": float(np.median(ratios)),
        "acceptance_rate": (
            s["accepted"] / s["drafted"] if s["drafted"] else 0.0),
        "drafted": s["drafted"],
        "accepted": s["accepted"],
        "emitted": s["tokens"],
    }


def run_burst(
    *,
    burst: int = 8,
    prompt_len: int = 32,
    budget: int = 8,
    max_batch: int = 4,
    block_size: int = 8,
    num_blocks: int = 64,
    decode_chunk: int = 4,
    arch: str = "qwen2.5-0.5b",
    seed: int = 0,
    repeats: int = 3,
    long_prompt_len: int = 192,
    long_burst: int = 16,
    long_budget: int = 16,
    flight: int = 2,
    flight_budget: int = 64,
    prefill_chunk: int = 32,
    dispatch_budget: int = 520,
) -> Dict:
    """Prefill-burst micro-benches: admission latency + decode stalls.

    **Legacy lane** (both arms pin ``chunked_prefill=False``): all
    ``burst`` requests arrive at once with identical (padded) prompt
    length — the regime where per-request prefill dispatches hurt most.
    Reported per mode (batched vs per-request prefill): **admission
    latency** p50/p99 (submit -> first emitted token, queueing included
    — the engine registry's TTFT histogram, read windowed) and prefill
    dispatch counts.  ``admission_speedup`` (unbatched p50 /
    batched p50) is machine-normalized: both sides ran on this host.

    **Long-prompt lane** (``out["long"]``): ``flight`` short-prompt
    requests reach steady decode, then ``long_burst`` long-prompt
    requests arrive at once.  Measured per arm — chunked ragged prefill
    (the default engine) vs the deprecated monolithic path
    (``chunked_prefill=False``) — is the **p99 inter-token gap of the
    already-in-flight requests** from the burst's submission until they
    finish: under monolithic prefill every long prompt blocks the
    decode loop for a full-prompt dispatch, while chunked prefill tiles
    it under ``dispatch_budget`` tokens per round with decode rows
    riding along.  Gaps are host-measured per engine round (wall time
    between successive rounds in which the request emitted), identically
    on both arms.  ``inflight_p99_improvement`` (monolithic p99 /
    chunked p99) and ``tokens_per_s_ratio`` (chunked / monolithic burst
    throughput — the "no win by throttling" guard) are medians of
    paired per-repeat ratios, machine-normalized by construction.
    """
    import jax

    from repro.configs import reduced_config
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.metrics.runtime_metrics import (
        serve_latency_counts,
        serve_latency_stats,
    )
    from repro.models.registry import build
    from repro.serve import ServeEngine

    tok = get_tokenizer()
    cfg = reduced_config(arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))
    ds = MathTaskDataset(prompt_len=prompt_len, level=0, seed=seed + 1)
    toks_np, _, _ = ds.sample_batch(burst)
    # Full fixed-length rows: identical padded length by construction.
    rows = [np.asarray(r, np.int32) for r in toks_np]
    max_seq_len = prompt_len + budget + block_size

    def _legacy(**kw):
        # Both legacy arms exercise the deprecated monolithic-prefill
        # path on purpose; silence its DeprecationWarning here.
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return ServeEngine(chunked_prefill=False, **kw)

    def _run(engine) -> Dict:
        before = dict(engine.stats.__dict__)
        starts = serve_latency_counts(engine.metrics)
        t0 = time.monotonic()
        for r in rows:
            engine.submit(r, budget)
        engine.run()
        wall = time.monotonic() - t0
        d = {key: engine.stats.__dict__[key] - v
             for key, v in before.items()}
        # Admission latency == submit -> first token == the engine's
        # own TTFT histogram, windowed to this run.
        lat = serve_latency_stats(engine.metrics, starts)
        return {
            "wall_s": wall,
            "admission_p50_ms": lat["ttft_p50_ms"],
            "admission_p99_ms": lat["ttft_p99_ms"],
            "prefill_dispatches": d["prefill_dispatches"],
            "prefills": d["prefills"],
        }

    out: Dict = {
        "config": {
            "arch": arch, "burst": burst, "prompt_len": prompt_len,
            "budget": budget, "max_batch": max_batch,
            "block_size": block_size, "num_blocks": num_blocks,
            "decode_chunk": decode_chunk, "seed": seed,
        },
    }
    for label, batched in (("batched", True), ("unbatched", False)):
        engine = _legacy(
            bundle=bundle, params=params, num_blocks=num_blocks,
            block_size=block_size, max_batch=max_batch,
            max_seq_len=max_seq_len, decode_chunk=decode_chunk,
            temperature=1e-4, seed=seed + 2, batch_prefill=batched)
        _run(engine)                        # compile/warm
        runs = [_run(engine) for _ in range(max(repeats, 1))]
        out[label] = min(runs, key=lambda r: r["admission_p50_ms"])
    out["admission_speedup"] = (
        out["unbatched"]["admission_p50_ms"]
        / out["batched"]["admission_p50_ms"]
        if out["batched"]["admission_p50_ms"] else 0.0
    )

    # ---- long-prompt lane: in-flight inter-token p99 during the burst
    ds_long = MathTaskDataset(
        prompt_len=long_prompt_len, level=0, seed=seed + 3)
    long_np, _, _ = ds_long.sample_batch(long_burst)
    long_rows = [np.asarray(r, np.int32) for r in long_np]
    flight_rows = [np.asarray(r, np.int32) for r in rows[:flight]]
    long_seq_len = long_prompt_len + long_budget + block_size
    # Slots for every flight + every long request at once: the stall
    # contrast is sharpest when the whole burst is resident (monolithic
    # prefills it as one giant dispatch; chunked tiles all of it under
    # the budget with the flight rows riding along every round).
    long_max_batch = flight + long_burst
    pages_per = -(-long_seq_len // block_size)
    long_blocks = max(num_blocks, 2 * long_max_batch * pages_per)

    def _mk_long(chunked: bool):
        # decode_chunk pinned to 1 on BOTH arms: the lane isolates the
        # prefill *scheduling* policy at fixed decode granularity.  A
        # multi-token decode chunk would let the monolithic arm bank 4
        # tokens per round between its prefill stalls — hiding exactly
        # the stall the lane exists to measure — while the chunked arm
        # is 1-token-per-round during tiling by construction.
        kw = dict(
            bundle=bundle, params=params, num_blocks=long_blocks,
            block_size=block_size, max_batch=long_max_batch,
            max_seq_len=long_seq_len, decode_chunk=1,
            temperature=1e-4, seed=seed + 4)
        if chunked:
            return ServeEngine(prefill_chunk=prefill_chunk,
                               dispatch_budget=dispatch_budget, **kw)
        return _legacy(**kw)

    def _run_long(engine) -> Dict:
        in_flight = [engine.submit(p, flight_budget)
                     for p in flight_rows]
        # Let the in-flight requests finish prefill and settle into
        # steady decode before the burst lands.
        for _ in range(4):
            engine.step()
        tokens0 = engine.stats.tokens_out
        now = time.perf_counter()
        t0 = now
        last_emit = {r.request_id: now for r in in_flight}
        counts = {r.request_id: len(r.tokens) for r in in_flight}
        gaps: List[float] = []
        for p in long_rows:
            engine.submit(p, long_budget)
        while engine.has_work:
            engine.step()
            now = time.perf_counter()
            for r in in_flight:
                n = len(r.tokens)
                if n > counts[r.request_id]:
                    # The client-visible stall: wall time since this
                    # request last produced anything, regardless of how
                    # many tokens the round then delivered at once.
                    gaps.append(now - last_emit[r.request_id])
                    counts[r.request_id] = n
                    last_emit[r.request_id] = now
        wall = time.perf_counter() - t0
        tokens = engine.stats.tokens_out - tokens0
        return {
            "wall_s": wall,
            "tokens": int(tokens),
            "tokens_per_s": tokens / wall if wall > 0 else 0.0,
            "inflight_gaps": len(gaps),
            "inflight_p50_ms": float(np.percentile(gaps, 50)) * 1e3,
            "inflight_p99_ms": float(np.percentile(gaps, 99)) * 1e3,
        }

    chunked_eng = _mk_long(True)
    mono_eng = _mk_long(False)
    _run_long(chunked_eng), _run_long(mono_eng)     # compile/warm
    # Arms alternate within each repeat.  The p99 improvement is the
    # MEDIAN of per-pair ratios (host drift lands on both arms of a
    # pair); the throughput ratio instead compares each arm's BEST
    # (least-perturbed) wall time — the workload is identical on both
    # arms, so best-of-N wall is the standard noise floor and a single
    # slow repeat can't fake a throughput regression.
    long_pairs = [(_run_long(mono_eng), _run_long(chunked_eng))
                  for _ in range(max(repeats, 5))]
    p99_ratios = [m["inflight_p99_ms"] / c["inflight_p99_ms"]
                  for m, c in long_pairs if c["inflight_p99_ms"] > 0]
    best_mono = min(m["wall_s"] for m, _ in long_pairs)
    best_chunked = min(c["wall_s"] for _, c in long_pairs)
    out["long"] = {
        "config": {
            "long_prompt_len": long_prompt_len, "long_burst": long_burst,
            "long_budget": long_budget, "flight": flight,
            "flight_budget": flight_budget,
            "prefill_chunk": prefill_chunk,
            "dispatch_budget": dispatch_budget,
            "max_batch": long_max_batch,
            "decode_chunk": 1,
            "num_blocks": long_blocks,
        },
        "monolithic": min((m for m, _ in long_pairs),
                          key=lambda r: r["inflight_p99_ms"]),
        "chunked": min((c for _, c in long_pairs),
                       key=lambda r: r["inflight_p99_ms"]),
        "inflight_p99_improvement": float(np.median(p99_ratios))
        if p99_ratios else 0.0,
        "tokens_per_s_ratio": (best_mono / best_chunked
                               if best_chunked > 0 else 0.0),
    }
    return out


def run_tracing(
    *,
    n_requests: int = 12,
    max_batch: int = 4,
    lengths: tuple = (2, 4, 8, 48),
    block_size: int = 8,
    num_blocks: int = 48,
    prompt_len: int = 32,
    decode_chunk: int = 8,
    arch: str = "qwen2.5-0.5b",
    seed: int = 0,
    repeats: int = 3,
) -> Dict:
    """Span-tracing overhead: tokens/s with the tracer on vs off.

    The same request stream is served by two engines — one carrying a
    live ``obs.Tracer`` at ``spans`` detail (lifecycle + dispatch spans
    + counter tracks) and one at the ``--trace-detail off`` default
    (``NULL_TRACER``; the zero-cost path every production run without
    ``--trace`` takes).  ``overhead_ratio`` is the MEDIAN of paired
    per-repeat tokens/s ratios (traced / untraced), so host drift lands
    on both arms; ~1.0 means tracing is effectively free at serve
    granularity, and the CI gate puts a generous hard floor under it so
    only a pathological hot-path regression (e.g. tracing work no
    longer gated on ``tracer.enabled``) trips.  ``full``-detail adds a
    per-emitted-token instant and is reported for color, ungated.
    """
    import jax

    from repro.configs import reduced_config
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.obs.tracer import Tracer
    from repro.serve import ServeEngine
    from repro.models.registry import build

    tok = get_tokenizer()
    cfg = reduced_config(arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))
    ds = MathTaskDataset(prompt_len=prompt_len, level=0, seed=seed + 1)
    toks_np, _, _ = ds.sample_batch(n_requests)
    prompts = [row[row != tok.pad_id] for row in toks_np]
    budgets = [lengths[i % len(lengths)] for i in range(n_requests)]
    max_seq_len = prompt_len + max(lengths) + block_size

    def _mk(tracer):
        return ServeEngine(
            bundle, params, num_blocks=num_blocks, block_size=block_size,
            max_batch=max_batch, max_seq_len=max_seq_len,
            decode_chunk=decode_chunk, temperature=1.0, seed=seed + 2,
            tracer=tracer)

    def _run(engine, tracer=None) -> Dict:
        if tracer is not None:
            tracer.clear()
        before = engine.stats.tokens_out
        t0 = time.perf_counter()
        for p, b in zip(prompts, budgets):
            engine.submit(p, b)
        engine.run()
        wall = time.perf_counter() - t0
        return {"wall_s": wall,
                "tokens": engine.stats.tokens_out - before,
                "events": len(tracer) if tracer is not None else 0}

    off = _mk(None)
    spans_tracer = Tracer(detail="spans")
    spans = _mk(spans_tracer)
    full_tracer = Tracer(detail="full")
    full = _mk(full_tracer)
    _run(off), _run(spans, spans_tracer), _run(full, full_tracer)  # warm
    # Paired per-repeat ratios (median): drift hits all arms equally.
    triples = [(_run(off), _run(spans, spans_tracer),
                _run(full, full_tracer))
               for _ in range(max(repeats, 1))]
    spans_ratios = [
        (s["tokens"] / s["wall_s"]) / (o["tokens"] / o["wall_s"])
        for o, s, _ in triples
    ]
    full_ratios = [
        (f["tokens"] / f["wall_s"]) / (o["tokens"] / o["wall_s"])
        for o, _, f in triples
    ]
    o_best = min((o for o, _, _ in triples), key=lambda r: r["wall_s"])
    s_best = min((s for _, s, _ in triples), key=lambda r: r["wall_s"])
    return {
        "config": {
            "arch": arch, "n_requests": n_requests,
            "max_batch": max_batch, "lengths": list(lengths),
            "block_size": block_size, "num_blocks": num_blocks,
            "prompt_len": prompt_len, "decode_chunk": decode_chunk,
            "seed": seed,
        },
        "tokens_per_s_off": o_best["tokens"] / o_best["wall_s"],
        "tokens_per_s_spans": s_best["tokens"] / s_best["wall_s"],
        "overhead_ratio": float(np.median(spans_ratios)),
        "overhead_ratio_full": float(np.median(full_ratios)),
        "events_per_run": int(triples[-1][1]["events"]),
        "token_events_per_run": int(triples[-1][2]["events"]),
    }


def run_sharded(
    *,
    data: int = 2,
    n_requests: int = 12,
    max_batch: int = 4,
    lengths: tuple = (2, 4, 8, 48),
    block_size: int = 8,
    num_blocks: int = 64,
    prompt_len: int = 32,
    decode_chunk: int = 8,
    arch: str = "qwen2.5-0.5b",
    seed: int = 0,
    repeats: int = 3,
) -> Dict:
    """Mesh-sharded vs single-device continuous serve, same stream.

    Correctness instrument first, throughput second: on forced
    multi-device CPU the shards are fake (one physical core pool runs
    all of them plus the psum recombines), so ``speedup_vs_single`` is
    NOT expected to exceed 1 — the gate only keeps it from collapsing,
    while ``token_exact`` (greedy sharded output == single-device
    output, every request) is the hard acceptance bar.  On real
    accelerators the same path turns the NB-sharded pool into
    multi-device decode capacity.
    """
    import jax

    from repro.configs import reduced_config
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.launch.mesh import make_debug_mesh
    from repro.serve import ServeEngine
    from repro.models.registry import build

    n_dev = len(jax.devices())
    if n_dev < data:
        return {"skipped": f"host has {n_dev} devices, wants {data} "
                           "(set XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=N)"}
    mesh = make_debug_mesh(data=data)
    tok = get_tokenizer()
    cfg = reduced_config(arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))
    ds = MathTaskDataset(prompt_len=prompt_len, level=0, seed=seed + 1)
    toks_np, _, _ = ds.sample_batch(n_requests)
    prompts = [row[row != tok.pad_id] for row in toks_np]
    budgets = [lengths[i % len(lengths)] for i in range(n_requests)]
    max_seq_len = prompt_len + max(lengths) + block_size

    def _mk(m):
        return ServeEngine(
            bundle, params, num_blocks=num_blocks, block_size=block_size,
            max_batch=max_batch, max_seq_len=max_seq_len,
            decode_chunk=decode_chunk, temperature=1e-4, seed=seed + 2,
            mesh=m)

    def _run(engine) -> Dict:
        before = dict(engine.stats.__dict__)
        t0 = time.perf_counter()
        for p, b in zip(prompts, budgets):
            engine.submit(p, b)
        trajs = engine.run()
        wall = time.perf_counter() - t0
        d = {k: engine.stats.__dict__[k] - v for k, v in before.items()}
        toks = [t.tokens for t in sorted(trajs,
                                         key=lambda t: t.request_id)]
        return {"wall_s": wall, "tokens": d["tokens_out"], "out": toks}

    single, sharded = _mk(None), _mk(mesh)
    warm_single, warm_sharded = _run(single), _run(sharded)
    exact = len(warm_single["out"]) == len(warm_sharded["out"]) and all(
        np.array_equal(a, b)
        for a, b in zip(warm_single["out"], warm_sharded["out"]))
    # Paired per-repeat ratios (median): host drift hits both arms.
    pairs = [(_run(single), _run(sharded))
             for _ in range(max(repeats, 1))]
    ratios = [
        (h["tokens"] / h["wall_s"]) / (s["tokens"] / s["wall_s"])
        for s, h in pairs
    ]
    s_best = min((s for s, _ in pairs), key=lambda r: r["wall_s"])
    h_best = min((h for _, h in pairs), key=lambda r: r["wall_s"])
    return {
        "config": {
            "arch": arch, "data": data, "n_requests": n_requests,
            "max_batch": max_batch, "lengths": list(lengths),
            "block_size": block_size, "num_blocks": num_blocks,
            "prompt_len": prompt_len, "decode_chunk": decode_chunk,
            "seed": seed,
        },
        "num_shards": data,
        "token_exact": 1.0 if exact else 0.0,
        "single_tokens_per_s": s_best["tokens"] / s_best["wall_s"],
        "tokens_per_s": h_best["tokens"] / h_best["wall_s"],
        "speedup_vs_single": float(np.median(ratios)),
    }


def run_best_of(
    *,
    best_of: int = 4,
    n_prompts: int = 2,
    max_batch: int = 4,
    gen_len: int = 16,
    block_size: int = 8,
    num_blocks: int = 64,
    prompt_len: int = 32,
    decode_chunk: int = 8,
    arch: str = "qwen2.5-0.5b",
    seed: int = 0,
    repeats: int = 3,
) -> Dict:
    """Best-of-N prefill sharing: prefix-cached vs unshared engines.

    The same stream — ``n_prompts`` distinct prompts, each submitted
    ``best_of`` times (the best-of-N sampling shape) — is served twice:
    once on a plain engine that prefills every copy densely, once on a
    prefix-cached engine that shares the matched prefix pages and
    prefills only the unmatched suffix.

    ``prefill_cost_ratio`` is the machine-independent gate: computed
    prefill KV rows (``stats.prefill_tokens``) unshared / shared —
    deterministic for a fixed workload, so ``check_regression`` can put
    a hard floor under it (N dense prefills collapse to ~1).
    ``token_exact`` (greedy shared output == unshared output for every
    request id) is the correctness bar; tokens/s is paired and reported
    for color but host drift makes it the softer signal.
    """
    import jax

    from repro.configs import reduced_config
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.metrics.runtime_metrics import collect_serve_stats
    from repro.serve import ServeEngine
    from repro.models.registry import build

    tok = get_tokenizer()
    cfg = reduced_config(arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(seed))
    ds = MathTaskDataset(prompt_len=prompt_len, level=0, seed=seed + 1)
    toks_np, _, _ = ds.sample_batch(n_prompts)
    prompts = [row[row != tok.pad_id] for row in toks_np]
    max_seq_len = prompt_len + gen_len + block_size

    def _mk(prefix: bool) -> ServeEngine:
        return ServeEngine(
            bundle, params, num_blocks=num_blocks, block_size=block_size,
            max_batch=max_batch, max_seq_len=max_seq_len,
            decode_chunk=decode_chunk, temperature=1e-4, seed=seed + 2,
            prefix_cache=prefix)

    def _run(engine) -> Dict:
        before = dict(engine.stats.__dict__)
        t0 = time.perf_counter()
        rid = 0
        for p in prompts:
            for _ in range(best_of):
                engine.submit(p, gen_len, request_id=f"bo{rid}")
                rid += 1
        trajs = engine.run()
        wall = time.perf_counter() - t0
        d = {k: engine.stats.__dict__[k] - v for k, v in before.items()}
        out = {t.request_id: t.tokens for t in trajs}
        return {"wall_s": wall, "tokens": d["tokens_out"],
                "prefill_tokens": d["prefill_tokens"],
                "cow_copies": d.get("cow_copies", 0), "out": out}

    dense, shared = _mk(False), _mk(True)
    warm_dense, warm_shared = _run(dense), _run(shared)
    exact = (set(warm_dense["out"]) == set(warm_shared["out"]) and all(
        np.array_equal(warm_dense["out"][r], warm_shared["out"][r])
        for r in warm_dense["out"]))
    # Prefill cost is deterministic — take it from the warm pair.
    cost_ratio = (warm_dense["prefill_tokens"]
                  / max(warm_shared["prefill_tokens"], 1))
    # Paired per-repeat tokens/s ratios (median): drift hits both arms.
    # The first repeat is a throwaway — re-serving against a warm cache
    # changes the suffix lengths, so it compiles fresh prefill shapes.
    pairs = [(_run(dense), _run(shared))
             for _ in range(max(repeats, 1) + 1)][1:]
    ratios = [
        (s["tokens"] / s["wall_s"]) / (d["tokens"] / d["wall_s"])
        for d, s in pairs
    ]
    d_best = min((d for d, _ in pairs), key=lambda r: r["wall_s"])
    s_best = min((s for _, s in pairs), key=lambda r: r["wall_s"])
    stats = collect_serve_stats(shared)
    return {
        "config": {
            "arch": arch, "best_of": best_of, "n_prompts": n_prompts,
            "max_batch": max_batch, "gen_len": gen_len,
            "block_size": block_size, "num_blocks": num_blocks,
            "prompt_len": prompt_len, "decode_chunk": decode_chunk,
            "seed": seed,
        },
        "token_exact": 1.0 if exact else 0.0,
        "prefill_cost_ratio": float(cost_ratio),
        "unshared_prefill_tokens": int(warm_dense["prefill_tokens"]),
        "shared_prefill_tokens": int(warm_shared["prefill_tokens"]),
        "cow_copies": int(warm_shared["cow_copies"]),
        "prefix_hit_rate": stats["prefix_hit_rate"],
        "prefix_token_hit_rate": stats["prefix_token_hit_rate"],
        "unshared_tokens_per_s": d_best["tokens"] / d_best["wall_s"],
        "tokens_per_s": s_best["tokens"] / s_best["wall_s"],
        "speedup_vs_unshared": float(np.median(ratios)),
    }


def write_json(res: Dict, path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(res, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=6,
                    help="workload scale: n_requests = 2 * steps")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    # Size the pool to the live working set: the pages pytree is carried
    # through the per-step jit, so an oversized pool taxes every step.
    ap.add_argument("--num-blocks", type=int, default=48)
    ap.add_argument("--lengths", default="2,4,8,48")
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--arch", default="qwen2.5-0.5b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sweep-blocks", action="store_true",
                    help="also sweep pool sizes and report per-step "
                         "decode cost vs num_blocks (the in-place pool "
                         "must be ~flat)")
    ap.add_argument("--sweep-block-counts", default="16,32,64,128,256")
    ap.add_argument("--speculate", type=int, default=4,
                    help="speculative-decode bench draft length k "
                         "(oracle cooperative draft; 0 disables)")
    ap.add_argument("--burst", type=int, default=8,
                    help="batched-prefill bench: same-length requests "
                         "submitted at once (0 disables)")
    ap.add_argument("--best-of", type=int, default=4,
                    help="best-of-N prefill-sharing bench: each prompt "
                         "submitted N times to a prefix-cached vs plain "
                         "engine; reports the deterministic prefill cost "
                         "ratio and greedy token-exactness (0 disables)")
    ap.add_argument("--sharded", type=int, default=0,
                    help="mesh-sharded serve bench over N data shards "
                         "(0 disables; needs N devices — on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N): records sharded-vs-single tokens/s "
                         "and greedy token-exactness")
    ap.add_argument("--tracing", type=int, default=1,
                    help="tracing-overhead bench: paired tokens/s with "
                         "a spans-detail tracer vs off (0 disables)")
    ap.add_argument("--out", default="results/bench/BENCH_serve.json")
    args = ap.parse_args()
    res = run(
        n_requests=max(2 * args.steps, 2),
        max_batch=args.max_batch,
        lengths=tuple(int(x) for x in args.lengths.split(",")),
        block_size=args.block_size,
        num_blocks=args.num_blocks,
        decode_chunk=args.decode_chunk,
        arch=args.arch,
        seed=args.seed,
    )
    for mode in ("phase_locked", "continuous"):
        m = res[mode]
        print(f"{mode:13s} {m['tokens_per_s']:8.1f} tok/s  "
              f"p50 {m['latency_p50_ms']:7.1f} ms  "
              f"p99 {m['latency_p99_ms']:7.1f} ms")
    print(f"{'speedup':13s} {res['speedup_tokens_per_s']:8.2f}x (tok/s)")
    if args.sweep_blocks:
        counts = tuple(
            int(x) for x in args.sweep_block_counts.split(","))
        # The sweep owns its workload shape (decode-dominated, sized to
        # fit the smallest pool) — only arch/seed follow the main bench.
        sweep = run_pool_sweep(
            block_counts=counts, arch=args.arch, seed=args.seed)
        res["pool_sweep"] = sweep
        for nb in counts:
            print(f"pool {nb:4d} blocks  "
                  f"{sweep['per_step_ms'][str(nb)]:7.3f} ms/step")
        print(f"{'sweep ratio':13s} {sweep['cost_ratio']:8.2f}x "
              f"(fitted {min(counts)}->{max(counts)}-block per-step "
              f"cost, 1.0 = flat; raw max/min "
              f"{sweep['cost_ratio_maxmin']:.2f}x)")
    if args.speculate:
        spec = run_speculative(
            k=args.speculate, arch=args.arch, seed=args.seed)
        res["speculative"] = spec
        print(f"{'speculative':13s} {spec['tokens_per_s']:8.1f} tok/s  "
              f"vs plain {spec['plain_tokens_per_s']:8.1f} "
              f"({spec['speedup_vs_plain']:.2f}x at k={args.speculate}, "
              f"acceptance {spec['acceptance_rate']:.2f}, oracle draft)")
    if args.sharded:
        sh = run_sharded(data=args.sharded, arch=args.arch,
                         seed=args.seed)
        res["sharded"] = sh
        if "skipped" in sh:
            print(f"{'sharded':13s} skipped: {sh['skipped']}")
        else:
            print(f"{'sharded':13s} {sh['tokens_per_s']:8.1f} tok/s over "
                  f"{sh['num_shards']} shards vs "
                  f"{sh['single_tokens_per_s']:8.1f} single "
                  f"({sh['speedup_vs_single']:.2f}x, token_exact="
                  f"{int(sh['token_exact'])})")
    if args.best_of:
        bo = run_best_of(best_of=args.best_of, arch=args.arch,
                         seed=args.seed)
        res["best_of"] = bo
        print(f"{'best-of':13s} prefill {bo['unshared_prefill_tokens']} "
              f"-> {bo['shared_prefill_tokens']} KV rows "
              f"({bo['prefill_cost_ratio']:.2f}x cheaper at "
              f"N={args.best_of}, cow {bo['cow_copies']}, "
              f"token_exact={int(bo['token_exact'])}, "
              f"tok/s {bo['speedup_vs_unshared']:.2f}x)")
    if args.tracing:
        tr = run_tracing(arch=args.arch, seed=args.seed)
        res["tracing"] = tr
        print(f"{'tracing':13s} {tr['tokens_per_s_spans']:8.1f} tok/s "
              f"spans vs {tr['tokens_per_s_off']:8.1f} off "
              f"({tr['overhead_ratio']:.2f}x, full "
              f"{tr['overhead_ratio_full']:.2f}x, "
              f"{tr['events_per_run']} events/run)")
    if args.burst:
        burst = run_burst(burst=args.burst, arch=args.arch,
                          seed=args.seed)
        res["burst"] = burst
        print(f"{'burst':13s} admission p50 "
              f"{burst['batched']['admission_p50_ms']:.1f} ms batched "
              f"({burst['batched']['prefill_dispatches']} dispatches) vs "
              f"{burst['unbatched']['admission_p50_ms']:.1f} ms "
              f"per-request ({burst['unbatched']['prefill_dispatches']}) "
              f"-> {burst['admission_speedup']:.2f}x")
        lane = burst["long"]
        print(f"{'burst/long':13s} in-flight inter-token p99 "
              f"{lane['chunked']['inflight_p99_ms']:.1f} ms chunked vs "
              f"{lane['monolithic']['inflight_p99_ms']:.1f} ms monolithic"
              f" -> {lane['inflight_p99_improvement']:.2f}x better "
              f"(tokens/s ratio {lane['tokens_per_s_ratio']:.2f})")
    if args.out:
        write_json(res, args.out)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
