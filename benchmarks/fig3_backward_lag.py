"""Fig. 3 reproduction: backward policy lag vs aggregate performance.

Runs the simulated-async grid (envs x algorithms x buffer capacities x
seeds), min-max normalizes per task across algorithms, and reports
Median / IQM / Mean / Optimality-Gap with stratified-bootstrap 95% CIs —
the paper's exact evaluation protocol at CPU scale.

Paper claim validated: VACO's aggregates degrade *less* than
PPO/PPO-KL/SPO as the policy-buffer capacity (degree of asynchronicity)
grows.

Scale knobs (paper -> here): 500 envs -> 16, 1000-step rollouts -> 96,
100M steps -> ~50k per run, 10 seeds -> 3 (override with --seeds).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List

import numpy as np

from repro.metrics.aggregate import aggregate_metrics
from repro.train.runner_rl import run_grid

DEFAULT_ENVS = ["pendulum", "cartpole_swingup", "acrobot", "pointmass",
                "reacher"]
DEFAULT_ALGS = ["vaco", "ppo", "ppo_kl", "spo", "impala"]


def run(
    envs: List[str],
    algorithms: List[str],
    capacities: List[int],
    seeds: List[int],
    n_actors: int = 16,
    rollout_steps: int = 96,
    phases: int = 20,
) -> Dict:
    t0 = time.time()
    grid = run_grid(
        envs, algorithms, capacities, seeds,
        n_actors=n_actors, rollout_steps=rollout_steps,
        total_phases=phases,
    )
    results = {}
    for cap in capacities:
        scores_by_alg = {alg: grid[alg][cap] for alg in algorithms}
        agg = aggregate_metrics(scores_by_alg, n_boot=500)
        results[f"K={cap}"] = {
            alg: {m: [round(x, 4) for x in v] for m, v in per.items()}
            for alg, per in agg.items()
        }
    results["_raw"] = {
        alg: {str(cap): grid[alg][cap].tolist() for cap in capacities}
        for alg in algorithms
    }
    results["_seconds"] = round(time.time() - t0, 1)
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--envs", nargs="+", default=DEFAULT_ENVS)
    ap.add_argument("--algorithms", nargs="+", default=DEFAULT_ALGS)
    ap.add_argument("--capacities", nargs="+", type=int,
                    default=[1, 4, 16])
    ap.add_argument("--seeds", nargs="+", type=int, default=[0, 1, 2])
    ap.add_argument("--phases", type=int, default=20)
    ap.add_argument("--n-actors", type=int, default=16)
    ap.add_argument("--rollout-steps", type=int, default=96)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    res = run(args.envs, args.algorithms, args.capacities, args.seeds,
              n_actors=args.n_actors, rollout_steps=args.rollout_steps,
              phases=args.phases)

    for cap_key, per_alg in res.items():
        if cap_key.startswith("_"):
            continue
        print(f"\n== {cap_key} (normalized aggregates, 95% CI) ==")
        for alg, metrics in per_alg.items():
            iqm = metrics["iqm"]
            gap = metrics["optimality_gap"]
            print(f"  {alg:8s} IQM={iqm[0]:.3f} [{iqm[1]:.3f},{iqm[2]:.3f}]"
                  f"  OptGap={gap[0]:.3f} [{gap[1]:.3f},{gap[2]:.3f}]")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)
        print(f"\nwrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
