"""Offline lag-attribution report over a serve/runtime trace.

Reads a trace written by ``--trace`` (either the Perfetto ``.json`` or
the flat ``.jsonl`` form — ``repro.obs.perfetto.load_trace_events``
auto-detects) and prints where each request's wall-clock went and how
stale the tokens it emitted were:

* **time-in-state per request** — waiting vs running milliseconds from
  the async ``b``/``e`` lifecycle spans (a preempted request re-enters
  ``waiting``, so its waiting column shows the cost of every eviction);
* **lag-at-emission histogram** — per emitted token, how many publishes
  the engine's weights lagged the store (needs ``--trace-detail full``,
  which stamps one ``token`` instant per emission);
* **swap-to-first-stale-token** — for every in-flight weight swap, the
  latency until the first token actually sampled from the new version.

``--check`` validates the trace instead: the file must load, every
sync ``B`` must close with a matching ``E`` (well-nested per track),
and every async ``b`` must close with its ``e``.  Exit status is
nonzero on any imbalance — CI runs this against a fresh
``launch.serve --trace`` artifact.

``--faults`` switches to the fault/recovery view: injected-fault
counts, each watchdog restart's latency to the first restart-flagged
admission (the recovery lag spike, measured), timeout retirements
grouped by the request state they were caught in, and degradation
events (publish quarantines, admission fallbacks, non-finite learner
steps, speculation auto-disables).

  PYTHONPATH=src python benchmarks/trace_report.py out.json
  PYTHONPATH=src python benchmarks/trace_report.py out.json --check
  PYTHONPATH=src python benchmarks/trace_report.py chaos.jsonl --faults
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict
from typing import Any, Dict, List, Tuple

sys.path.insert(0, "src")

from repro.obs.perfetto import load_trace_events  # noqa: E402


def check_balance(events: List[Dict[str, Any]]) -> List[str]:
    """Return a list of imbalance descriptions (empty = balanced)."""
    errors: List[str] = []
    stacks: Dict[Tuple[Any, Any], List[str]] = defaultdict(list)
    open_async: Dict[Tuple[str, Any], int] = defaultdict(int)
    for ev in events:
        ph = ev.get("ph")
        name = ev.get("name", "?")
        if ph == "B":
            stacks[(ev.get("pid"), ev.get("tid"))].append(name)
        elif ph == "E":
            stack = stacks[(ev.get("pid"), ev.get("tid"))]
            if not stack:
                errors.append(f"E {name!r} with no open span on track "
                              f"({ev.get('pid')}, {ev.get('tid')})")
            elif stack[-1] != name:
                errors.append(f"E {name!r} closes {stack[-1]!r} "
                              f"(bad nesting)")
                stack.pop()
            else:
                stack.pop()
        elif ph == "b":
            open_async[(name, ev.get("id"))] += 1
        elif ph == "e":
            key = (name, ev.get("id"))
            if open_async[key] <= 0:
                errors.append(f"e {name!r} id={ev.get('id')} never opened")
            else:
                open_async[key] -= 1
    for (pid, tid), stack in stacks.items():
        for name in stack:
            errors.append(f"B {name!r} on ({pid}, {tid}) never closed")
    for (name, aid), n in open_async.items():
        if n:
            errors.append(f"b {name!r} id={aid} left open ({n}x)")
    return errors


def _lifecycle_durations(events: List[Dict[str, Any]]
                         ) -> Dict[int, Dict[str, float]]:
    """Per-request {state: total µs} from the async waiting/running spans."""
    acc: Dict[int, Dict[str, float]] = defaultdict(
        lambda: defaultdict(float))
    opened: Dict[Tuple[str, int], float] = {}
    for ev in events:
        name = ev.get("name")
        if name not in ("waiting", "running"):
            continue
        key = (name, ev.get("id"))
        if ev.get("ph") == "b":
            opened[key] = ev["ts"]
        elif ev.get("ph") == "e" and key in opened:
            acc[ev.get("id")][name] += ev["ts"] - opened.pop(key)
    return {rid: dict(states) for rid, states in acc.items()}


def _preemptions(events: List[Dict[str, Any]]) -> Dict[int, int]:
    out: Dict[int, int] = defaultdict(int)
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") == "preempt":
            rid = (ev.get("args") or {}).get("rid")
            if rid is not None:
                out[rid] += 1
    return out


def _token_instants(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    return [ev for ev in events
            if ev.get("ph") == "i" and ev.get("name") == "token"]


def report(events: List[Dict[str, Any]]) -> None:
    durations = _lifecycle_durations(events)
    preempts = _preemptions(events)
    tokens = _token_instants(events)
    tokens_by_rid: Dict[int, int] = defaultdict(int)
    for ev in tokens:
        tokens_by_rid[(ev.get("args") or {}).get("rid")] += 1

    print("time in state per request (ms):")
    print(f"  {'rid':>4} {'waiting':>9} {'running':>9} {'total':>9} "
          f"{'preempts':>8} {'tokens':>7}")
    for rid in sorted(durations):
        states = durations[rid]
        wait = states.get("waiting", 0.0) / 1e3
        run = states.get("running", 0.0) / 1e3
        tok = tokens_by_rid.get(rid, 0)
        print(f"  {rid:>4} {wait:>9.1f} {run:>9.1f} {wait + run:>9.1f} "
              f"{preempts.get(rid, 0):>8} "
              f"{tok if tok else '-':>7}")
    if not durations:
        print("  (no request lifecycle spans in trace)")

    if tokens:
        hist: Dict[int, int] = defaultdict(int)
        for ev in tokens:
            hist[int((ev.get("args") or {}).get("lag", 0))] += 1
        total = sum(hist.values())
        print(f"lag at emission ({total} tokens):")
        for lag in sorted(hist):
            n = hist[lag]
            bar = "#" * max(1, round(40 * n / total))
            print(f"  lag {lag:>3}: {n:>6} ({n / total:>6.1%}) {bar}")
    else:
        print("lag at emission: no per-token events "
              "(re-run with --trace-detail full)")

    swaps = [ev for ev in events
             if ev.get("ph") == "i" and ev.get("name") == "swap"]
    if swaps:
        print("swap -> first token from the new version:")
        for sw in swaps:
            new_v = (sw.get("args") or {}).get("new")
            first = next(
                (t for t in tokens
                 if t["ts"] >= sw["ts"]
                 and (t.get("args") or {}).get("v") == new_v), None)
            if first is None:
                print(f"  v{(sw.get('args') or {}).get('old')}->v{new_v}: "
                      f"no token from v{new_v} in trace")
            else:
                dt = (first["ts"] - sw["ts"]) / 1e3
                print(f"  v{(sw.get('args') or {}).get('old')}->v{new_v}: "
                      f"{dt:.1f} ms (rid "
                      f"{(first.get('args') or {}).get('rid')})")
    else:
        print("swaps: none in trace")


def _instants(events: List[Dict[str, Any]], name: str
              ) -> List[Dict[str, Any]]:
    return [ev for ev in events
            if ev.get("ph") == "i" and ev.get("name") == name]


def fault_report(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Fault/recovery digest of a trace (the ``--faults`` view, and what
    ``bench_chaos`` reads for its recovery-latency assertion).

    * ``faults``: injected-fault counts keyed ``kind@site``;
    * ``restarts``: one row per watchdog restart with the latency from
      the restart instant to the *next* ``restart_admitted`` instant —
      the restart -> first-fresh-admission recovery time, including the
      admitted item's lag columns (the measured recovery lag spike);
    * ``timeout_retirements``: deadline-expired requests grouped by the
      state they were caught in (``running`` vs ``waiting``);
    * ``quarantines`` / ``fallbacks`` / ``nonfinite_steps`` /
      ``spec_autodisables``: degradation-event counts.
    """
    faults: Dict[str, int] = defaultdict(int)
    for ev in _instants(events, "fault"):
        a = ev.get("args") or {}
        faults[f"{a.get('kind', '?')}@{a.get('site', '?')}"] += 1

    admissions = _instants(events, "restart_admitted")
    restarts: List[Dict[str, Any]] = []
    for rs in _instants(events, "watchdog_restart"):
        a = rs.get("args") or {}
        first = next((ad for ad in admissions if ad["ts"] >= rs["ts"]),
                     None)
        row: Dict[str, Any] = {
            "producer": rs.get("tid"),
            "attempt": a.get("attempt"),
            "backoff_s": a.get("delay_s"),
            "error": a.get("error"),
        }
        if first is not None:
            fa = first.get("args") or {}
            row.update(
                recovery_ms=(first["ts"] - rs["ts"]) / 1e6,
                admitted_lag=fa.get("lag"),
                admitted_lag_oldest=fa.get("lag_oldest"),
                admitted_lag_newest=fa.get("lag_newest"),
            )
        restarts.append(row)

    by_state: Dict[str, int] = defaultdict(int)
    for ev in _instants(events, "retire"):
        a = ev.get("args") or {}
        if a.get("reason") == "timeout":
            by_state[a.get("state", "?")] += 1

    return {
        "faults": dict(faults),
        "restarts": restarts,
        "timeout_retirements": dict(by_state),
        "quarantines": len(_instants(events, "publish_quarantine")),
        "fallbacks": len(_instants(events, "admission_fallback")),
        "nonfinite_steps": len(_instants(events, "learner_nonfinite")),
        "spec_autodisables": len(_instants(events, "spec_autodisable")),
    }


def print_fault_report(fr: Dict[str, Any]) -> None:
    print("injected faults:")
    if fr["faults"]:
        for key in sorted(fr["faults"]):
            print(f"  {key:<32} {fr['faults'][key]}")
    else:
        print("  (none in trace)")
    print("watchdog restarts -> first fresh admission:")
    if fr["restarts"]:
        for row in fr["restarts"]:
            rec = row.get("recovery_ms")
            tail = ("no restart-flagged admission in trace"
                    if rec is None else
                    f"recovered in {rec:.1f} ms (admitted lag "
                    f"{row.get('admitted_lag_oldest')} oldest / "
                    f"{row.get('admitted_lag_newest')} newest)")
            print(f"  {row['producer']} attempt {row['attempt']} "
                  f"(backoff {row['backoff_s']}s): {tail}")
    else:
        print("  (no restarts in trace)")
    print("timeout retirements by request state:")
    if fr["timeout_retirements"]:
        for state, n in sorted(fr["timeout_retirements"].items()):
            print(f"  {state:<10} {n}")
    else:
        print("  (none in trace)")
    print(f"publish quarantines: {fr['quarantines']}, admission "
          f"fallbacks: {fr['fallbacks']}, non-finite learner steps: "
          f"{fr['nonfinite_steps']}, speculation auto-disables: "
          f"{fr['spec_autodisables']}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="trace file (.json Perfetto or .jsonl)")
    ap.add_argument("--check", action="store_true",
                    help="validate only: file loads and all spans are "
                         "balanced; nonzero exit on any imbalance")
    ap.add_argument("--faults", action="store_true",
                    help="fault/recovery view: injected faults, watchdog "
                         "restart -> first-fresh-admission latency, "
                         "timeout retirements per request state, "
                         "degradation events")
    args = ap.parse_args(argv)

    try:
        events = load_trace_events(args.trace)
    except Exception as e:                      # malformed file: fail loud
        print(f"FAIL: cannot load {args.trace}: {e}")
        return 2
    errors = check_balance(events)
    if args.check:
        if errors:
            print(f"FAIL: {len(errors)} span imbalance(s) in "
                  f"{args.trace}:")
            for err in errors[:20]:
                print(f"  {err}")
            return 1
        print(f"OK: {args.trace}: {len(events)} events, spans balanced")
        return 0
    if errors:
        print(f"warning: {len(errors)} span imbalance(s) — "
              "partial trace? (ring eviction or truncated run)")
    if args.faults:
        print_fault_report(fault_report(events))
        return 0
    report(events)
    return 0


if __name__ == "__main__":
    sys.exit(main())
