"""Fig. 12 reproduction: VACO with vs without advantage realignment.

"Without realignment" replaces the V-trace advantage (w.r.t. pi_T) by
plain GAE on the behavioral data while keeping the TV filter — isolating
the contribution of the realignment term.  Paper finding: realignment
offers better robustness to off-policy data on average.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict

import numpy as np

from repro.metrics.aggregate import iqm
from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl
from repro.train.trainer_rl import RLHyperparams


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--envs", nargs="+",
                    default=["pendulum", "pointmass", "reacher"])
    ap.add_argument("--capacities", nargs="+", type=int, default=[4, 16])
    ap.add_argument("--seeds", nargs="+", type=int, default=[0, 1])
    ap.add_argument("--phases", type=int, default=16)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    variants = {
        "vaco(realigned)": {"realign": True},
        "vaco(no-realign)": {"realign": False},
    }
    report: Dict[str, Dict] = {}
    all_scores = {}
    for name, opts in variants.items():
        per_cap = {}
        for cap in args.capacities:
            scores = np.zeros((len(args.envs), len(args.seeds)))
            for i, env in enumerate(args.envs):
                for j, seed in enumerate(args.seeds):
                    hp = RLHyperparams(realign=opts["realign"])
                    res = run_async_rl(AsyncRLRunConfig(
                        env_name=env, algorithm="vaco",
                        buffer_capacity=cap, total_phases=args.phases,
                        seed=seed, hp=hp))
                    scores[i, j] = float(np.mean(res.returns[-3:]))
            per_cap[cap] = scores
        all_scores[name] = per_cap

    for cap in args.capacities:
        stacked = np.stack([all_scores[n][cap] for n in variants])
        lo, hi = stacked.min(), stacked.max()
        rng = (hi - lo) or 1.0
        print(f"== K={cap} ==")
        report[f"K={cap}"] = {}
        for name in variants:
            normed = (all_scores[name][cap] - lo) / rng
            val = iqm(normed)
            report[f"K={cap}"][name] = round(val, 4)
            print(f"  {name:18s} IQM={val:.3f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
