"""Runtime throughput: threaded producer vs phase-locked collection.

The point of the async runtime is overlap — the producer generates the
next trajectory while the learner is still updating on the previous one.
This benchmark runs the identical workload (same env, actors, steps,
algorithm, phase count) under the phase-locked ``backward_mixture``
regime and the concurrent ``threaded`` regime and reports environment
steps per second for each plus the overlap speedup.

    PYTHONPATH=src python -m benchmarks.bench_runtime [--phases N]
"""
from __future__ import annotations

import argparse
import contextlib
import tempfile
import time
from typing import Dict

import jax

from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl


@contextlib.contextmanager
def _compilation_cache():
    """Persist XLA executables so the warm run actually warms the timed
    run: each run_async_rl builds fresh jit wrappers (whose per-wrapper
    caches are useless across calls), but the persistent cache is keyed
    on the HLO fingerprint and is shared.  Restores the global config on
    exit so later benchmarks in the same process measure under the
    default (non-persisting) conditions."""
    names = ("jax_compilation_cache_dir",
             "jax_persistent_cache_min_compile_time_secs")
    try:
        saved = {n: getattr(jax.config, n) for n in names}
        jax.config.update(names[0], tempfile.mkdtemp())
        jax.config.update(names[1], 0.0)
    except Exception:
        yield  # older jax: timings will include trace+compile
        return
    try:
        yield
    finally:
        for n, v in saved.items():
            jax.config.update(n, v)


def run(
    *,
    phases: int = 8,
    n_actors: int = 8,
    rollout_steps: int = 64,
    algorithm: str = "vaco",
    seed: int = 0,
) -> Dict[str, float]:
    """Returns {regime: env_steps_per_sec} plus the threaded speedup."""
    out: Dict[str, float] = {}
    with _compilation_cache():
        for regime in ("backward_mixture", "threaded"):
            cfg = AsyncRLRunConfig(
                env_name="pendulum", algorithm=algorithm,
                buffer_capacity=4, n_actors=n_actors,
                rollout_steps=rollout_steps, total_phases=phases,
                seed=seed, runtime=regime, get_timeout=120.0,
            )
            # Warm run populates the persistent executable cache, so the
            # timed run re-traces but skips XLA compilation.
            run_async_rl(AsyncRLRunConfig(**{**cfg.__dict__,
                                             "total_phases": 2}))
            t0 = time.perf_counter()
            res = run_async_rl(cfg)
            dt = time.perf_counter() - t0
            # Consumed items come from the queue's own counters (the
            # same snapshot live telemetry reports): `admitted` counts
            # gate-passing pops, i.e. exactly the items the learner
            # stepped on (a threaded producer may leave extras buffered
            # in `depth`; those did no learner work).
            qs = res.runtime_stats["queue"]
            consumed = qs["admitted"]
            if consumed != len(res.returns):
                print(f"warning: queue says {consumed} consumed items, "
                      f"learner logged {len(res.returns)} phases")
                consumed = len(res.returns)
            env_steps = consumed * n_actors * rollout_steps
            out[regime] = env_steps / dt
    out["threaded_speedup"] = (
        out["threaded"] / out["backward_mixture"]
        if out["backward_mixture"] else 0.0
    )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--phases", type=int, default=8)
    ap.add_argument("--n-actors", type=int, default=8)
    ap.add_argument("--rollout-steps", type=int, default=64)
    ap.add_argument("--algorithm", default="vaco")
    ap.add_argument("--out", default=None,
                    help="write a BENCH_runtime.json artifact (same "
                         "shape as benchmarks.run's) for the CI "
                         "regression gate")
    args = ap.parse_args()
    res = run(phases=args.phases, n_actors=args.n_actors,
              rollout_steps=args.rollout_steps, algorithm=args.algorithm)
    for k, v in res.items():
        unit = "x" if k == "threaded_speedup" else " env steps/s"
        print(f"{k:18s} {v:10.1f}{unit}")
    if args.out:
        import json
        import os

        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            # Absolute env-steps/s are workload-dependent: the committed
            # baseline and CI's fresh run must use the same config for
            # the regression diff to mean anything.
            json.dump({"benchmark": "runtime_throughput",
                       "config": {"phases": args.phases,
                                  "n_actors": args.n_actors,
                                  "rollout_steps": args.rollout_steps,
                                  "algorithm": args.algorithm},
                       "env_steps_per_s": res}, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
