"""Runtime throughput: threaded producer vs phase-locked collection.

The point of the async runtime is overlap — the producer generates the
next trajectory while the learner is still updating on the previous one.
This benchmark runs the identical workload (same env, actors, steps,
algorithm, phase count) under the phase-locked ``backward_mixture``
regime and the concurrent ``threaded`` regime and reports environment
steps per second for each plus the overlap speedup.

``--lag-sweep`` adds the lag-controller sweep: every registered
controller (pass_through, max_lag, tv_gate, gac, stable_async, asympo)
runs the serve-backed RLVR trainer — real engine rollouts with
per-token {version, log_beta} provenance — under scripted lag regimes
{fresh, forced max lag}, from one shared warm-started base policy, and
the final greedy eval accuracy plus the queue's drop/downweight rates
land in a per-controller reward-vs-lag table.  The derived
``tv_gate_advantage_at_max_lag`` / ``drop_rate_at_max_lag`` numbers are
what CI's regression gate enforces.

    PYTHONPATH=src python -m benchmarks.bench_runtime [--phases N]
    PYTHONPATH=src python -m benchmarks.bench_runtime --lag-sweep \\
        --steps-small --out results/bench/BENCH_runtime.json
"""
from __future__ import annotations

import argparse
import contextlib
import tempfile
import time
from typing import Any, Dict

import jax

from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl


@contextlib.contextmanager
def _compilation_cache():
    """Persist XLA executables so the warm run actually warms the timed
    run: each run_async_rl builds fresh jit wrappers (whose per-wrapper
    caches are useless across calls), but the persistent cache is keyed
    on the HLO fingerprint and is shared.  Restores the global config on
    exit so later benchmarks in the same process measure under the
    default (non-persisting) conditions."""
    names = ("jax_compilation_cache_dir",
             "jax_persistent_cache_min_compile_time_secs")
    try:
        saved = {n: getattr(jax.config, n) for n in names}
        jax.config.update(names[0], tempfile.mkdtemp())
        jax.config.update(names[1], 0.0)
    except Exception:
        yield  # older jax: timings will include trace+compile
        return
    try:
        yield
    finally:
        for n, v in saved.items():
            jax.config.update(n, v)


def run(
    *,
    phases: int = 8,
    n_actors: int = 8,
    rollout_steps: int = 64,
    algorithm: str = "vaco",
    seed: int = 0,
) -> Dict[str, float]:
    """Returns {regime: env_steps_per_sec} plus the threaded speedup."""
    out: Dict[str, float] = {}
    with _compilation_cache():
        for regime in ("backward_mixture", "threaded"):
            cfg = AsyncRLRunConfig(
                env_name="pendulum", algorithm=algorithm,
                buffer_capacity=4, n_actors=n_actors,
                rollout_steps=rollout_steps, total_phases=phases,
                seed=seed, runtime=regime, get_timeout=120.0,
            )
            # Warm run populates the persistent executable cache, so the
            # timed run re-traces but skips XLA compilation.
            run_async_rl(AsyncRLRunConfig(**{**cfg.__dict__,
                                             "total_phases": 2}))
            t0 = time.perf_counter()
            res = run_async_rl(cfg)
            dt = time.perf_counter() - t0
            # Consumed items come from the queue's own counters (the
            # same snapshot live telemetry reports): `admitted` counts
            # gate-passing pops, i.e. exactly the items the learner
            # stepped on (a threaded producer may leave extras buffered
            # in `depth`; those did no learner work).
            qs = res.runtime_stats["queue"]
            consumed = qs["admitted"]
            if consumed != len(res.returns):
                print(f"warning: queue says {consumed} consumed items, "
                      f"learner logged {len(res.returns)} phases")
                consumed = len(res.returns)
            env_steps = consumed * n_actors * rollout_steps
            out[regime] = env_steps / dt
    out["threaded_speedup"] = (
        out["threaded"] / out["backward_mixture"]
        if out["backward_mixture"] else 0.0
    )
    return out


# Controller spec per sweep column.  max_lag's threshold sits below the
# forced lag so the stale regime is an all-drop column (drop-rate 1.0 —
# one of the gate's sanity bands); tv_gate runs downweight mode so it
# keeps consuming at max lag and the reward comparison vs pass_through
# is like-for-like in update count.
LAG_SWEEP_CONTROLLERS = (
    ("pass_through", "pass_through"),
    ("max_lag", "max_lag:max_lag=2"),
    ("tv_gate", "tv_gate:delta=0.05,mode=downweight"),
    ("gac", "gac:cos_min=0.25"),
    ("stable_async", "stable_async:c_max=2.0,var_max=0.5"),
    ("asympo", "asympo:pos_decay=0.8"),
)


def run_lag_sweep(
    *,
    phases: int = 8,
    warmup_steps: int = 120,
    max_lag: int = 3,
    seed: int = 0,
) -> Dict[str, Any]:
    """Final-reward-vs-lag for every lag controller, serve-produced.

    One tiny model is warm-started once (supervised format warmup);
    every (controller, lag) cell then trains from an identical copy of
    that base policy — same params, fresh optimizer moments, same
    pre-ramped PolicyStore — so the cells differ *only* in the
    controller and the scripted lag.  The store ring is pre-ramped with
    ``max_lag + 1`` publishes of the warm params so the forced-lag
    regime is at full staleness from the first minibatch (no warm-up
    ramp diluting the drop-rate columns).
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import ModelConfig
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.models.registry import build
    from repro.train.trainer_rlvr import (
        RLVRHyperparams,
        RLVRTrainer,
        RLVRTrainState,
        adamw_init,
    )

    tok = get_tokenizer()
    cfg = ModelConfig(
        name="lag-sweep", arch_type="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=tok.vocab_size,
    )
    bundle = build(cfg)

    def make_hp(spec: str, lag: int) -> RLVRHyperparams:
        # Plain GRPO (no in-loss VACO filter): the admission controller
        # is the *only* staleness defence, so the sweep measures the
        # controllers, not the loss.  lr is ~10x the trainer default —
        # large enough that full-weight stale updates measurably damage
        # the warm-started policy within the sweep's update budget.
        return RLVRHyperparams(
            algorithm="grpo", lr=1e-3, n_minibatches=3,
            prompts_per_minibatch=4, completions_per_prompt=4,
            max_new_tokens=6, warmup_steps=warmup_steps,
            producer="serve", controller=spec, forced_lag=lag,
            store_capacity=max_lag + 1, max_refills=4,
            engine_max_batch=8, engine_num_blocks=48,
        )

    def make_ds() -> MathTaskDataset:
        return MathTaskDataset(prompt_len=16, level=0, pool_size=256,
                               seed=seed + 1)

    # Shared warmup: one supervised run produces the base policy every
    # sweep cell starts from.
    warm_tr = RLVRTrainer(bundle, make_ds(),
                          make_hp("pass_through", 0), seed=seed)
    warm_tr.warmup()
    warm_params = warm_tr.state.params
    base_acc = warm_tr.evaluate(128)

    lags = (0, max_lag)
    table: Dict[str, Dict[str, Any]] = {}
    for name, spec in LAG_SWEEP_CONTROLLERS:
        table[name] = {"spec": spec}
        for lag in lags:
            tr = RLVRTrainer(bundle, make_ds(), make_hp(spec, lag),
                             seed=seed)
            tr.state = RLVRTrainState(
                params=warm_params,
                opt_state=adamw_init(warm_params),
                updates=jnp.zeros((), jnp.int32),
            )
            # Pre-ramp the snapshot ring: resolve_lagged(-max_lag) hits
            # a real (identical) snapshot from the very first minibatch.
            for _ in range(max_lag + 1):
                tr.store.publish(warm_params, event="lag_sweep_preramp")
            res = tr.train(phases, eval_every=10**9)
            qs = res.runtime_stats["queue"]
            decided = qs["admitted"] + qs["dropped"]
            table[name][f"lag{lag}"] = {
                "final_reward": (res.eval_accuracy[-1]
                                 if res.eval_accuracy else None),
                "updates": len(res.phase_logs),
                "mean_minibatch_reward": (
                    float(np.mean([pl.mean_reward
                                   for pl in res.phase_logs]))
                    if res.phase_logs else None),
                "drop_rate": (qs["dropped"] / decided if decided else 0.0),
                "downweight_rate": (
                    qs["downweighted"] / decided if decided else 0.0),
                "drops_by_reason": qs["drops_by_reason"],
                "downweights_by_reason": qs["downweights_by_reason"],
            }

    def reward(name: str, lag: int) -> float:
        r = table[name][f"lag{lag}"]["final_reward"]
        return 0.0 if r is None else float(r)

    out: Dict[str, Any] = {
        "config": {"phases": phases, "warmup_steps": warmup_steps,
                   "max_lag": max_lag, "seed": seed,
                   "base_accuracy": base_acc},
        "controllers": table,
        # CI-gated deriveds: the Eq. 8 gate must not lose reward vs
        # ungated consumption of max-lag data, pass_through must never
        # drop, and the lag-2 eviction gate must drop (all of) the
        # forced-lag-3 stream.
        "tv_gate_advantage_at_max_lag": (
            reward("tv_gate", max_lag) - reward("pass_through", max_lag)),
        "drop_rate_at_max_lag": {
            name: table[name][f"lag{max_lag}"]["drop_rate"]
            for name, _ in LAG_SWEEP_CONTROLLERS
        },
    }
    return out


def print_lag_sweep(sweep: Dict[str, Any]) -> None:
    cfg = sweep["config"]
    lags = (0, cfg["max_lag"])
    print(f"\nlag sweep (base accuracy {cfg['base_accuracy']:.3f}, "
          f"forced lag {cfg['max_lag']}):")
    hdr = f"{'controller':<14}" + "".join(
        f"  reward@lag{lag}  drop@lag{lag}  dwgt@lag{lag}" for lag in lags)
    print(hdr)
    for name in sweep["controllers"]:
        row = f"{name:<14}"
        for lag in lags:
            cell = sweep["controllers"][name][f"lag{lag}"]
            r = cell["final_reward"]
            row += (f"  {'--' if r is None else f'{r:10.3f}':>11}"
                    f"  {cell['drop_rate']:10.2f}"
                    f"  {cell['downweight_rate']:9.2f}")
        print(row)
    print(f"tv_gate advantage at max lag: "
          f"{sweep['tv_gate_advantage_at_max_lag']:+.3f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--phases", type=int, default=8)
    ap.add_argument("--n-actors", type=int, default=8)
    ap.add_argument("--rollout-steps", type=int, default=64)
    ap.add_argument("--algorithm", default="vaco")
    ap.add_argument("--lag-sweep", action="store_true",
                    help="also run every lag controller through the "
                         "serve-backed RLVR trainer across lag regimes "
                         "(reward-vs-lag + drop-rate table)")
    ap.add_argument("--steps-small", action="store_true",
                    help="lag sweep at CI-smoke scale (fewer phases / "
                         "shorter warmup); the committed baseline and "
                         "the fresh CI run must agree on this flag")
    ap.add_argument("--sweep-seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write a BENCH_runtime.json artifact (same "
                         "shape as benchmarks.run's) for the CI "
                         "regression gate")
    args = ap.parse_args()
    res = run(phases=args.phases, n_actors=args.n_actors,
              rollout_steps=args.rollout_steps, algorithm=args.algorithm)
    for k, v in res.items():
        unit = "x" if k == "threaded_speedup" else " env steps/s"
        print(f"{k:18s} {v:10.1f}{unit}")
    sweep = None
    if args.lag_sweep:
        if args.steps_small:
            sweep = run_lag_sweep(phases=5, warmup_steps=80,
                                  seed=args.sweep_seed)
        else:
            sweep = run_lag_sweep(seed=args.sweep_seed)
        print_lag_sweep(sweep)
    if args.out:
        import json
        import os

        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        doc = {"benchmark": "runtime_throughput",
               "config": {"phases": args.phases,
                          "n_actors": args.n_actors,
                          "rollout_steps": args.rollout_steps,
                          "algorithm": args.algorithm},
               "env_steps_per_s": res}
        if sweep is not None:
            doc["lag_sweep"] = sweep
        with open(args.out, "w") as f:
            # Absolute env-steps/s are workload-dependent: the committed
            # baseline and CI's fresh run must use the same config for
            # the regression diff to mean anything.
            json.dump(doc, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
