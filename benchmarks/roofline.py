"""Roofline analysis from the dry-run artifacts (harness deliverable g).

For every (arch x shape x mesh) record produced by launch/dryrun.py this
computes the three per-step roofline terms on TPU v5e:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s          [s]
    memory term     = HLO_bytes_per_device / HBM_bw               [s]
    collective term = collective_bytes_per_device / link_bw       [s]

(the dry-run's cost_analysis numbers are already per-device under SPMD —
verified in tests/test_hlo_analysis.py), identifies the dominant term,
and reports MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) against
the compiled HLO FLOPs to expose remat/redundancy waste.

Usage:
    PYTHONPATH=src python -m benchmarks.roofline results/dryrun_*.json \\
        [--markdown]
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float          # 6*N(active)*D tokens processed
    hlo_total_flops: float      # per-device * n_devices
    useful_fraction: float      # model_flops / hlo_total_flops
    note: str = ""


def tokens_processed(arch: str, shape_name: str) -> float:
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch * 1.0  # decode: one token per stream


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    n = cfg.active_param_count()
    toks = tokens_processed(arch, shape_name)
    kind = INPUT_SHAPES[shape_name].kind
    # 6ND for training (fwd 2ND + bwd 4ND); 2ND for inference.
    per_tok = 6.0 * n if kind == "train" else 2.0 * n
    return per_tok * toks


def _n_devices(mesh: str) -> int:
    n = 1
    for part in mesh.split("x"):
        n *= int(part)
    return n


def analyze_records(records: List[dict]) -> List[RooflineRow]:
    rows: List[RooflineRow] = []
    for r in records:
        if r["status"] != "ok":
            continue
        compute_s = r["flops"] / PEAK_FLOPS_BF16
        memory_s = r["hbm_bytes"] / HBM_BW
        coll_s = r["collective_bytes_per_device"] / ICI_BW
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": coll_s}
        dominant = max(terms, key=terms.get)
        mf = model_flops(r["arch"], r["shape"])
        total_hlo = r["flops"] * _n_devices(r["mesh"])
        rows.append(RooflineRow(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
            dominant=dominant, model_flops=mf,
            hlo_total_flops=total_hlo,
            useful_fraction=mf / total_hlo if total_hlo else 0.0,
        ))
    return rows


_MOVE_HINTS = {
    "compute": ("compute-bound: raise MFU via flash-attention kernel "
                "(causal/SWA skip), drop remat recompute on cheap layers"),
    "memory": ("memory-bound: bf16 cache/activations, fuse logprob "
               "(Pallas), window-bound local-layer KV caches"),
    "collective": ("collective-bound: reshard (tensor-parallel where "
                   "divisible), overlap all-gather with compute, "
                   "reduce-scatter grads instead of all-reduce"),
}


def to_markdown(rows: List[RooflineRow]) -> str:
    out = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s)"
        " | dominant | MODEL_FLOPS | useful frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} | "
            f"{r.memory_s:.3e} | {r.collective_s:.3e} | **{r.dominant}** |"
            f" {r.model_flops:.3e} | {r.useful_fraction:.2f} |"
        )
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("records", nargs="+", help="dryrun JSON file(s)")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args(argv)

    records: List[dict] = []
    for path in args.records:
        with open(path) as f:
            records.extend(json.load(f))
    rows = analyze_records(records)

    if args.markdown:
        print(to_markdown(rows))
    else:
        print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
              "model_flops,useful_fraction")
        for r in rows:
            print(f"{r.arch},{r.shape},{r.mesh},{r.compute_s:.4e},"
                  f"{r.memory_s:.4e},{r.collective_s:.4e},{r.dominant},"
                  f"{r.model_flops:.4e},{r.useful_fraction:.3f}")
    # dominant-term summary + hints
    print()
    for kind in ("compute", "memory", "collective"):
        n = sum(1 for r in rows if r.dominant == kind)
        if n:
            print(f"# {n:2d} combos {kind}-bound -> {_MOVE_HINTS[kind]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
