"""Fig. 11 reproduction: final-policy TV divergence tracking.

Measures the TV divergence between the end-of-phase policy and its
behavior data for VACO vs PPO(-KL) across environments and
asynchronicity levels.  Paper claim: VACO maintains the SAME TV level
(the delta/2 constraint) everywhere — predictable from the threshold —
while PPO's achieved TV varies and is not predictable from the clip
ratio.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict

import numpy as np

from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl
from repro.train.trainer_rl import RLHyperparams


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--envs", nargs="+",
                    default=["pendulum", "pointmass", "reacher"])
    ap.add_argument("--capacities", nargs="+", type=int, default=[1, 8])
    ap.add_argument("--seeds", nargs="+", type=int, default=[0, 1])
    ap.add_argument("--phases", type=int, default=12)
    ap.add_argument("--delta", type=float, default=0.2)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    report: Dict[str, Dict] = {}
    for alg in ("vaco", "ppo", "ppo_kl"):
        report[alg] = {}
        for cap in args.capacities:
            tvs = []
            for env in args.envs:
                for seed in args.seeds:
                    res = run_async_rl(AsyncRLRunConfig(
                        env_name=env, algorithm=alg, buffer_capacity=cap,
                        total_phases=args.phases, seed=seed,
                        hp=RLHyperparams(delta=args.delta)))
                    tvs.append(res.final_tv)
            report[alg][f"K={cap}"] = {
                "mean_tv": round(float(np.mean(tvs)), 4),
                "std_tv": round(float(np.std(tvs)), 4),
            }
            print(f"{alg:8s} K={cap:3d} final TV = "
                  f"{np.mean(tvs):.4f} +- {np.std(tvs):.4f} "
                  f"(VACO target delta/2 = {args.delta/2:.3f})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
