"""Fig. 7/8 reproduction: ablation on the TV threshold delta.

Runs VACO across delta values under a fixed degree of asynchronicity and
reports final normalized aggregates + AUC.  Paper claim: VACO is robust
to aggressive (small) delta values — constrained optimization avoids the
policy collapse that aggressive clipping induces in PPO.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

import numpy as np

from repro.metrics.aggregate import iqm
from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl
from repro.train.trainer_rl import RLHyperparams


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--deltas", nargs="+", type=float,
                    default=[0.05, 0.1, 0.2, 0.4])
    ap.add_argument("--envs", nargs="+",
                    default=["pendulum", "pointmass"])
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--seeds", nargs="+", type=int, default=[0, 1])
    ap.add_argument("--phases", type=int, default=16)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    report: Dict[str, Dict] = {}
    all_scores = {}
    for delta in args.deltas:
        scores = np.zeros((len(args.envs), len(args.seeds)))
        tvs = []
        for i, env in enumerate(args.envs):
            for j, seed in enumerate(args.seeds):
                res = run_async_rl(AsyncRLRunConfig(
                    env_name=env, algorithm="vaco",
                    buffer_capacity=args.capacity, total_phases=args.phases,
                    seed=seed, hp=RLHyperparams(delta=delta)))
                scores[i, j] = float(np.mean(res.returns[-3:]))
                tvs.append(res.final_tv)
        all_scores[delta] = scores
        report[f"delta={delta}"] = {
            "mean_final_tv": round(float(np.mean(tvs)), 4),
            "raw_scores": scores.tolist(),
        }
    # min-max normalize across deltas, report IQM per delta.
    stacked = np.stack(list(all_scores.values()))
    lo, hi = stacked.min(), stacked.max()
    rng = (hi - lo) or 1.0
    for delta in args.deltas:
        normed = (all_scores[delta] - lo) / rng
        report[f"delta={delta}"]["iqm"] = round(iqm(normed), 4)
        print(f"delta={delta:5.2f} IQM={report[f'delta={delta}']['iqm']:.3f}"
              f" final_TV={report[f'delta={delta}']['mean_final_tv']:.4f}"
              f" (constraint delta/2={delta/2:.3f})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
