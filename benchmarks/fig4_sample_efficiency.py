"""Fig. 4 reproduction: IQM learning curves + area-under-curve.

Tracks the normalized-return IQM across training phases for each
algorithm x asynchronicity level, plus the AUC sample-efficiency summary
(Fig. 4 bottom-right).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

import numpy as np

from repro.metrics.aggregate import auc, iqm, minmax_normalize
from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl

DEFAULT_ENVS = ["pendulum", "cartpole_swingup", "acrobot"]
DEFAULT_ALGS = ["vaco", "ppo", "spo", "impala"]


def run_curves(
    envs: List[str], algorithms: List[str], capacity: int,
    seeds: List[int], phases: int, **kw,
) -> Dict[str, np.ndarray]:
    """Returns {alg: [envs, seeds, phases] return curves}."""
    out = {}
    for alg in algorithms:
        curves = np.zeros((len(envs), len(seeds), phases))
        for i, env in enumerate(envs):
            for j, seed in enumerate(seeds):
                res = run_async_rl(AsyncRLRunConfig(
                    env_name=env, algorithm=alg, buffer_capacity=capacity,
                    total_phases=phases, seed=seed, **kw))
                curves[i, j] = np.asarray(res.returns)
        out[alg] = curves
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--envs", nargs="+", default=DEFAULT_ENVS)
    ap.add_argument("--algorithms", nargs="+", default=DEFAULT_ALGS)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--seeds", nargs="+", type=int, default=[0, 1])
    ap.add_argument("--phases", type=int, default=16)
    ap.add_argument("--n-actors", type=int, default=16)
    ap.add_argument("--rollout-steps", type=int, default=96)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    curves = run_curves(args.envs, args.algorithms, args.capacity,
                        args.seeds, args.phases,
                        n_actors=args.n_actors,
                        rollout_steps=args.rollout_steps)
    # normalize per env across algorithms using the final-phase spread.
    flat = {a: c.reshape(len(args.envs), -1) for a, c in curves.items()}
    lo = np.min(np.stack([v for v in flat.values()]), axis=(0, 2))
    hi = np.max(np.stack([v for v in flat.values()]), axis=(0, 2))
    rng = np.where(hi - lo < 1e-9, 1.0, hi - lo)

    print(f"== IQM learning curves (K={args.capacity}) ==")
    report = {}
    for alg, c in curves.items():
        normed = (c - lo[:, None, None]) / rng[:, None, None]
        curve_iqm = [
            iqm(normed[:, :, t]) for t in range(args.phases)
        ]
        auc_val = float(np.mean(curve_iqm))
        report[alg] = {"iqm_curve": [round(x, 4) for x in curve_iqm],
                       "auc": round(auc_val, 4)}
        spark = "".join(
            " .:-=+*#%@"[min(9, int(v * 10))] for v in curve_iqm)
        print(f"  {alg:8s} AUC={auc_val:.3f}  |{spark}|")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
