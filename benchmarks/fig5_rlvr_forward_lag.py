"""Fig. 5 reproduction: RLVR forward policy lag — GRPO (PPO-clip) vs
GRPO+VACO.

Protocol (§5.2): warm-start a base model on synthetic verifiable math,
then for each N in --minibatches run the generate-N/train-N loop and
record (top) eval exact-match accuracy vs N, and (bottom) the PPO clip
fraction vs the VACO filter rate per staleness level.

Paper claims validated:
  * eval accuracy degrades from N=1 as forward lag increases (both),
    VACO retaining more;
  * PPO clips constantly and proportionally to lag; VACO filters rarely
    at low lag and selectively-but-heavily when triggered.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List

import numpy as np

from repro.configs import reduced_config
from repro.data.mathgen import MathTaskDataset
from repro.data.tokenizer import get_tokenizer
from repro.models.registry import build
from repro.train.trainer_rlvr import RLVRHyperparams, RLVRTrainer


def run_one(arch: str, algorithm: str, n_minibatches: int, *,
            phases: int, seed: int, level: int,
            warmup_steps: int) -> Dict:
    tok = get_tokenizer()
    cfg = reduced_config(arch, vocab=tok.vocab_size).replace(
        value_head=False)
    bundle = build(cfg)
    ds = MathTaskDataset(prompt_len=24, level=level, seed=seed)
    hp = RLVRHyperparams(
        algorithm=algorithm, n_minibatches=n_minibatches,
        prompts_per_minibatch=8, completions_per_prompt=4,
        max_new_tokens=6, warmup_steps=warmup_steps, lr=3e-5,
    )
    tr = RLVRTrainer(bundle, ds, hp, seed=seed)
    tr.warmup()
    acc0 = tr.evaluate(128)
    res = tr.train(phases, eval_every=max(phases, 1))
    # filter/clip rate by staleness
    by_stale: Dict[int, List[float]] = {}
    tv_by_stale: Dict[int, List[float]] = {}
    for log in res.phase_logs:
        by_stale.setdefault(log.staleness, []).append(log.frac_filtered)
        tv_by_stale.setdefault(log.staleness, []).append(log.tv)
    return {
        "acc_after_warmup": acc0,
        "acc_final": res.eval_accuracy[-1] if res.eval_accuracy else None,
        "mean_reward_last": float(np.mean(
            [l.mean_reward for l in res.phase_logs[-n_minibatches:]])),
        "filter_rate_by_staleness": {
            str(k): round(float(np.mean(v)), 4)
            for k, v in sorted(by_stale.items())},
        "tv_by_staleness": {
            str(k): round(float(np.mean(v)), 4)
            for k, v in sorted(tv_by_stale.items())},
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-0.5b")
    ap.add_argument("--minibatches", nargs="+", type=int,
                    default=[1, 2, 4, 8])
    ap.add_argument("--seeds", nargs="+", type=int, default=[0])
    ap.add_argument("--phases", type=int, default=6)
    ap.add_argument("--level", type=int, default=0)
    ap.add_argument("--warmup-steps", type=int, default=150)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    report: Dict[str, Dict] = {}
    for alg in ("grpo", "grpo_vaco"):
        report[alg] = {}
        for n in args.minibatches:
            accs, rates = [], []
            per_seed = []
            for seed in args.seeds:
                r = run_one(args.arch, alg, n, phases=args.phases,
                            seed=seed, level=args.level,
                            warmup_steps=args.warmup_steps)
                per_seed.append(r)
                accs.append(r["acc_final"])
            report[alg][f"N={n}"] = {
                "acc_final_mean": round(float(np.mean(accs)), 4),
                "per_seed": per_seed,
            }
            print(f"{alg:10s} N={n:2d} acc={np.mean(accs):.3f} "
                  f"filter/clip={per_seed[0]['filter_rate_by_staleness']}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
