"""Fig. 9/10 reproduction: ablation on the V-trace rho_bar threshold.

rho_bar controls the fixed point of the realignment target (App. B.5 /
Espeholt et al. 2018).  Paper finding (confirming IMPALA): rho_bar = 1
outperforms larger values.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict

import numpy as np

from repro.metrics.aggregate import iqm
from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl
from repro.train.trainer_rl import RLHyperparams


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rhos", nargs="+", type=float,
                    default=[1.0, 2.0, 8.0])
    ap.add_argument("--envs", nargs="+",
                    default=["pendulum", "pointmass"])
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--seeds", nargs="+", type=int, default=[0, 1])
    ap.add_argument("--phases", type=int, default=16)
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    report: Dict[str, Dict] = {}
    all_scores = {}
    for rho in args.rhos:
        scores = np.zeros((len(args.envs), len(args.seeds)))
        for i, env in enumerate(args.envs):
            for j, seed in enumerate(args.seeds):
                res = run_async_rl(AsyncRLRunConfig(
                    env_name=env, algorithm="vaco",
                    buffer_capacity=args.capacity,
                    total_phases=args.phases, seed=seed,
                    hp=RLHyperparams(rho_bar=rho, c_bar=min(rho, 1.0))))
                scores[i, j] = float(np.mean(res.returns[-3:]))
        all_scores[rho] = scores
        report[f"rho={rho}"] = {"raw_scores": scores.tolist()}
    stacked = np.stack(list(all_scores.values()))
    lo, hi = stacked.min(), stacked.max()
    rng = (hi - lo) or 1.0
    for rho in args.rhos:
        normed = (all_scores[rho] - lo) / rng
        report[f"rho={rho}"]["iqm"] = round(iqm(normed), 4)
        print(f"rho_bar={rho:5.1f} IQM={report[f'rho={rho}']['iqm']:.3f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
