"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).  Each
figure-level benchmark runs a CPU-scaled version of the paper's protocol
(full-scale knobs are exposed by the individual modules' CLIs);
``us_per_call`` is the wall time of the benchmark body, ``derived`` the
figure's headline metric.

The serve/runtime throughput benchmarks additionally write
machine-readable ``BENCH_serve.json`` / ``BENCH_runtime.json`` into
``--out-dir`` (default ``results/bench``); CI uploads the directory as
an artifact so regressions are diffable across runs.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--out-dir DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def _row(name: str, us: float, derived) -> None:
    print(f"{name},{us:.0f},{derived}", flush=True)


def bench_fig3_backward_lag(fast: bool) -> None:
    from benchmarks.fig3_backward_lag import run

    t0 = time.perf_counter()
    res = run(
        envs=["pendulum", "pointmass"] if fast else
             ["pendulum", "pointmass", "reacher"],
        algorithms=["vaco", "ppo"] if fast else
                   ["vaco", "ppo", "spo", "impala"],
        capacities=[1, 8],
        seeds=[0] if fast else [0, 1],
        n_actors=8 if fast else 16,
        rollout_steps=64 if fast else 96,
        phases=8 if fast else 16,
    )
    us = (time.perf_counter() - t0) * 1e6
    for cap_key in ("K=1", "K=8"):
        vaco_iqm = res[cap_key]["vaco"]["iqm"][0]
        ppo_iqm = res[cap_key]["ppo"]["iqm"][0]
        _row(f"fig3_backward_lag[{cap_key}]", us,
             f"vaco_iqm={vaco_iqm:.3f};ppo_iqm={ppo_iqm:.3f}")


def bench_fig4_sample_efficiency(fast: bool) -> None:
    from benchmarks.fig4_sample_efficiency import run_curves
    from repro.metrics.aggregate import iqm

    t0 = time.perf_counter()
    curves = run_curves(
        ["pendulum"], ["vaco", "ppo"], capacity=8,
        seeds=[0], phases=6 if fast else 12,
        n_actors=8, rollout_steps=64,
    )
    us = (time.perf_counter() - t0) * 1e6
    aucs = {a: float(np.mean(c)) for a, c in curves.items()}
    _row("fig4_sample_efficiency_auc", us,
         ";".join(f"{a}={v:.1f}" for a, v in aucs.items()))


def bench_fig5_rlvr(fast: bool) -> None:
    from benchmarks.fig5_rlvr_forward_lag import run_one

    for alg in ("grpo", "grpo_vaco"):
        t0 = time.perf_counter()
        r = run_one(
            "qwen2.5-0.5b", alg, n_minibatches=2 if fast else 4,
            phases=2 if fast else 4, seed=0, level=0,
            warmup_steps=60 if fast else 150,
        )
        us = (time.perf_counter() - t0) * 1e6
        _row(f"fig5_rlvr[{alg}]", us,
             f"acc={r['acc_final']:.3f};"
             f"rate_by_lag={r['filter_rate_by_staleness']}")


def bench_fig11_tv(fast: bool) -> None:
    from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl

    t0 = time.perf_counter()
    tvs = {}
    for alg in ("vaco", "ppo"):
        res = run_async_rl(AsyncRLRunConfig(
            env_name="pendulum", algorithm=alg, buffer_capacity=8,
            n_actors=8, rollout_steps=64, total_phases=6))
        tvs[alg] = res.final_tv
    us = (time.perf_counter() - t0) * 1e6
    _row("fig11_tv_tracking", us,
         ";".join(f"{a}_tv={v:.4f}" for a, v in tvs.items())
         + ";vaco_target=0.100")


def _write_artifact(out_dir: str, name: str, payload) -> None:
    """Machine-readable benchmark artifact (CI uploads the directory)."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)


def bench_runtime_throughput(fast: bool, out_dir: str) -> None:
    """Threaded vs phase-locked actor-learner throughput."""
    from benchmarks.bench_runtime import run

    t0 = time.perf_counter()
    res = run(
        phases=4 if fast else 8,
        n_actors=4 if fast else 8,
        rollout_steps=32 if fast else 64,
    )
    us = (time.perf_counter() - t0) * 1e6
    _row("runtime_throughput", us,
         f"phase_locked={res['backward_mixture']:.0f}sps;"
         f"threaded={res['threaded']:.0f}sps;"
         f"speedup={res['threaded_speedup']:.2f}x")
    _write_artifact(out_dir, "BENCH_runtime.json", {
        "benchmark": "runtime_throughput",
        "us_per_call": us,
        "env_steps_per_s": res,
    })


def bench_serve_throughput(fast: bool, out_dir: str) -> None:
    """Continuous batching vs phase-locked serve at mixed lengths."""
    from benchmarks.bench_serve import run

    t0 = time.perf_counter()
    res = run(
        n_requests=12 if fast else 24,
        max_batch=4,
        lengths=(2, 4, 8, 48),
    )
    us = (time.perf_counter() - t0) * 1e6
    _row("serve_throughput", us,
         f"phase_locked={res['phase_locked']['tokens_per_s']:.0f}tps;"
         f"continuous={res['continuous']['tokens_per_s']:.0f}tps;"
         f"speedup={res['speedup_tokens_per_s']:.2f}x;"
         f"p99_ms={res['continuous']['latency_p99_ms']:.1f}")
    _write_artifact(out_dir, "BENCH_serve.json",
                    dict(res, benchmark="serve_throughput",
                         us_per_call=us))


def bench_theory() -> None:
    """Appendix B numerical validation (tabular MDP) as a benchmark."""
    t0 = time.perf_counter()
    import subprocess
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "tests/test_theory.py", "-q",
         "--no-header", "-x"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    us = (time.perf_counter() - t0) * 1e6
    ok = "passed" in r.stdout and "failed" not in r.stdout
    _row("appendixB_theory_validation", us, f"all_pass={ok}")


def bench_kernels() -> None:
    from benchmarks.kernels_bench import bench_rows

    for name, us, derived in bench_rows():
        _row(f"kernel[{name}]", us, derived)


def bench_roofline() -> None:
    """Summarize dry-run roofline terms if results exist."""
    path = "results/dryrun_singlepod.json"
    if not os.path.exists(path):
        _row("roofline_summary", 0, "skipped(no results/dryrun_*.json)")
        return
    t0 = time.perf_counter()
    from benchmarks.roofline import analyze_records

    with open(path) as f:
        rows = analyze_records(json.load(f))
    us = (time.perf_counter() - t0) * 1e6
    n_by = {}
    for r in rows:
        n_by[r.dominant] = n_by.get(r.dominant, 0) + 1
    _row("roofline_summary", us,
         f"combos={len(rows)};" +
         ";".join(f"{k}_bound={v}" for k, v in sorted(n_by.items())))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="smaller grids (CI-sized)")
    ap.add_argument("--out-dir", default="results/bench",
                    help="where BENCH_*.json artifacts are written")
    args, _ = ap.parse_known_args()
    fast = args.fast or os.environ.get("REPRO_BENCH_FAST", "1") == "1"

    print("name,us_per_call,derived")
    bench_kernels()
    bench_theory()
    bench_serve_throughput(fast, args.out_dir)
    bench_runtime_throughput(fast, args.out_dir)
    bench_fig11_tv(fast)
    bench_fig4_sample_efficiency(fast)
    bench_fig3_backward_lag(fast)
    bench_fig5_rlvr(fast)
    bench_roofline()


if __name__ == "__main__":
    main()
