"""CI benchmark-regression gate: diff fresh bench JSON against baselines.

``bench-smoke`` produces machine-readable ``BENCH_serve.json`` /
``BENCH_runtime.json``; this script compares them against the committed
baselines under ``results/`` and exits non-zero on a regression — the
benchmarks are *enforced*, not just uploaded.

Two classes of metric, because CI runners are not the machine the
baseline was measured on:

* **relative** metrics are machine-speed-normalized by construction
  (continuous-vs-phase-locked speedup, the pool-size-sweep cost ratio,
  threaded-vs-phase-locked overlap): both sides of the ratio ran on the
  same box, so a slow runner cancels out.  These get the strict default
  tolerance (``--tol``, 15%): a >15% drop means the *code* regressed.
* **absolute** metrics (tokens/s, env steps/s) move with the host; they
  get their own ``--abs-tol`` so CI can widen it for noisy shared
  runners while local runs keep it tight.

``pool_sweep.cost_ratio`` additionally carries a *hard cap* (1.2): the
in-place paged pool's per-step decode cost must stay ~flat in
``num_blocks`` regardless of what the baseline says — this is the
acceptance bar for the aliasing work and the backstop against both
baseline drift and a reverted aliased path.

Self-test (wired into CI): ``--synthetic-slowdown 0.2 --expect-fail``
degrades every fresh metric by 20% after loading and asserts the gate
*fails* — proving the gate can actually catch the regression it exists
for, on every run.

    PYTHONPATH=src python -m benchmarks.check_regression \\
        --serve-baseline results/BENCH_serve.json \\
        --serve-fresh results/bench/BENCH_serve.json \\
        --runtime-baseline results/BENCH_runtime.json \\
        --runtime-fresh results/bench/BENCH_runtime.json
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class Metric:
    path: str                     # dotted path into the bench JSON
    higher_is_better: bool
    relative: bool                # machine-speed-normalized metric
    hard_max: Optional[float] = None   # absolute cap (lower-is-better)
    hard_min: Optional[float] = None   # absolute floor (higher-is-better)
    cap_only: bool = False        # skip the baseline diff, cap suffices


SERVE_METRICS = (
    Metric("continuous.tokens_per_s", True, False),
    Metric("phase_locked.tokens_per_s", True, False),
    Metric("speedup_tokens_per_s", True, True),
    # The PR-3 acceptance bar: per-step decode cost flat in pool
    # size.  Cap-only: a healthy in-place pool fits to ~1.0x and an
    # O(pool) one to ~2x+, so the absolute 1.2 cap is the whole test —
    # a baseline-relative band around 1.0 would only add noise flakes.
    Metric("pool_sweep.cost_ratio", False, True, hard_max=1.2,
           cap_only=True),
    # Speculative decode (PR-4 acceptance bar, floor recalibrated in
    # PR 7): at the cooperative (oracle) draft and k=4 the multi-token
    # verify buys tokens/s by amortizing per-dispatch overhead — so the
    # win is host-dependent: ~1.4x where dispatch overhead dominates,
    # ~1.05-1.1x on fast hosts where jit compute dominates (verified by
    # re-running the pre-instrumentation code side by side).  The hard
    # floor is therefore a collapse backstop only — speculation must
    # never be meaningfully *slower* than plain chunked decode — while
    # the relative band vs the committed baseline catches code-level
    # drift.  The speedup is a median of paired same-host ratios, so
    # it is machine-normalized by construction.
    Metric("speculative.speedup_vs_plain", True, True, hard_min=0.8),
    # Acceptance rate at the oracle draft is a pure-correctness number
    # (it only drops if verify/accept logic changes): machine-free,
    # gated on the relative band.
    Metric("speculative.acceptance_rate", True, True),
    Metric("speculative.tokens_per_s", True, False),
    # Batched prefill: admission-latency win of stacking same-length
    # admissions into one dispatch (both sides measured on this host —
    # both arms run the deprecated monolithic path on purpose).
    Metric("burst.admission_speedup", True, True),
    Metric("burst.batched.admission_p50_ms", False, False),
    # Chunked ragged prefill (PR-10 acceptance bars).  The long-prompt
    # burst lane measures the p99 inter-token gap of already-decoding
    # requests while long prompts prefill: chunked tiling under the
    # dispatch budget must cut that tail >= 2x vs the monolithic path
    # (median of paired same-host ratios, machine-normalized — the
    # hard floor is the acceptance bar, the relative band catches
    # drift from the committed baseline).  tokens_per_s_ratio is the
    # "no win by throttling" guard: chunked may not buy its latency
    # tail by giving up more than 15% of burst throughput (being
    # faster is fine, so a floor, not a band).
    Metric("burst.long.inflight_p99_improvement", True, True,
           hard_min=2.0),
    Metric("burst.long.tokens_per_s_ratio", True, True, hard_min=0.85,
           cap_only=True),
    # Prefix caching (PR-6 acceptance bar): at best-of N=4, computed
    # prefill KV rows (prefix-cached vs dense) must drop >= 2x — the
    # ratio counts token rows, not wall time, so it is deterministic
    # for the fixed workload and gets a hard floor with no baseline
    # band.  Token-exactness is the correctness bar: greedy shared
    # output must equal the unshared engine's, every request.
    Metric("best_of.prefill_cost_ratio", True, True, hard_min=2.0,
           cap_only=True),
    Metric("best_of.token_exact", True, True, hard_min=1.0,
           cap_only=True),
    # Observability (PR-7): span tracing must stay off the hot path.
    # The ratio is tokens/s traced (spans detail) / untraced, a median
    # of paired same-host runs — healthy instrumentation sits ~1.0.
    # Cap-only with a deliberately generous floor: the number is noisy
    # at smoke scale, and the gate exists to catch a pathological
    # regression (per-event work no longer gated on tracer.enabled),
    # not 5% drift.  The tracing-*off* path needs no extra gate: it IS
    # continuous.tokens_per_s, which the absolute band above covers.
    Metric("tracing.overhead_ratio", True, True, hard_min=0.5,
           cap_only=True),
)

RUNTIME_METRICS = (
    Metric("env_steps_per_s.backward_mixture", True, False),
    Metric("env_steps_per_s.threaded", True, False),
    Metric("env_steps_per_s.threaded_speedup", True, True),
    # Lag-controller sweep (PR-8 acceptance bars), all cap-only: the
    # sweep is deterministic at fixed seed (phase-locked serve producer,
    # greedy eval), so the direction bands are the whole test and a
    # baseline-relative band would only add flakes.
    #
    # The Eq. 8 TV gate must not *lose* final reward vs ungated
    # consumption of the same max-lag stream — the paper's claim, as a
    # floor at 0 (measured margin at the smoke config: ~ +0.16).
    Metric("lag_sweep.tv_gate_advantage_at_max_lag", True, True,
           hard_min=0.0, cap_only=True),
    # Sanity bands on the sweep's extreme columns: pass_through must
    # never drop, and a lag-2 eviction gate must drop the entire
    # forced-lag-3 stream (the pre-ramped store makes staleness exact
    # from the first minibatch).
    Metric("lag_sweep.drop_rate_at_max_lag.pass_through", False, True,
           hard_max=0.0, cap_only=True),
    Metric("lag_sweep.drop_rate_at_max_lag.max_lag", True, True,
           hard_min=0.99, cap_only=True),
)

# Chaos smoke (PR-9 acceptance bars), all cap-only: the run is
# fault-injected and threaded, so no throughput baseline makes sense —
# the gates are structural.  Completion is the no-deadlock bar; the
# leak audits must be exactly zero (a leaked page or producer thread is
# a bug regardless of scale); a quarantined (NaN-poisoned) version must
# never appear in served provenance; every canned fault family must
# actually have fired (otherwise the chaos run silently tested
# nothing); the watchdog restart must be *measured* — a
# restart-flagged admission with its recovery latency in the trace —
# and the chaos run's final reward must sit within the band of the
# fault-free twin (the band itself is env-tunable in the bench,
# CHAOS_REWARD_BAND).
CHAOS_METRICS = (
    Metric("completed", True, True, hard_min=1.0, cap_only=True),
    Metric("leaked_pages", False, True, hard_max=0.0, cap_only=True),
    Metric("leaked_threads", False, True, hard_max=0.0, cap_only=True),
    Metric("quarantine_served", False, True, hard_max=0.0,
           cap_only=True),
    Metric("reward_band_ok", True, True, hard_min=1.0, cap_only=True),
    Metric("faults.producer_crash", True, True, hard_min=1.0,
           cap_only=True),
    Metric("faults.nan_publish", True, True, hard_min=1.0,
           cap_only=True),
    Metric("faults.request_timeouts", True, True, hard_min=1.0,
           cap_only=True),
    Metric("faults.watchdog_restarts", True, True, hard_min=1.0,
           cap_only=True),
    Metric("faults.restart_admitted", True, True, hard_min=1.0,
           cap_only=True),
    Metric("faults.learner_nonfinite", True, True, hard_min=1.0,
           cap_only=True),
    Metric("faults.recovery_measured", True, True, hard_min=1.0,
           cap_only=True),
)

# Sharded-serve job (forced multi-device CPU).  CPU sharding is a
# correctness instrument, not a speedup: token_exact is the hard bar
# (greedy sharded output == single-device output — 1.0 or the gate
# fails, no baseline needed), while the sharded-vs-single throughput
# ratio only gets the (wide, CI-set) relative band so a collapse —
# e.g. an accidental full-pool re-materialization per shard step —
# still trips.
SHARDED_METRICS = (
    Metric("sharded.token_exact", True, True, hard_min=1.0,
           cap_only=True),
    Metric("sharded.speedup_vs_single", True, True),
    Metric("sharded.tokens_per_s", True, False),
)


def _lookup(doc: Dict, path: str) -> Optional[float]:
    node = doc
    for part in path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return float(node) if isinstance(node, (int, float)) else None


def _apply_slowdown(doc: Dict, metrics: Tuple[Metric, ...],
                    slowdown: float) -> None:
    """Degrade every metric by `slowdown` in its bad direction, in place."""
    for m in metrics:
        node = doc
        parts = m.path.split(".")
        for part in parts[:-1]:
            node = node.get(part, {}) if isinstance(node, dict) else {}
        leaf = parts[-1]
        if isinstance(node, dict) and isinstance(
                node.get(leaf), (int, float)):
            if m.higher_is_better:
                node[leaf] = node[leaf] * (1.0 - slowdown)
            else:
                node[leaf] = node[leaf] / (1.0 - slowdown)


def check_pair(
    name: str,
    baseline: Dict,
    fresh: Dict,
    metrics: Tuple[Metric, ...],
    *,
    tol: float,
    abs_tol: float,
) -> List[str]:
    """Returns failure messages (empty = pass)."""
    failures: List[str] = []
    for m in metrics:
        base = _lookup(baseline, m.path)
        new = _lookup(fresh, m.path)
        if new is None:
            failures.append(
                f"{name}:{m.path}: missing from fresh results "
                "(benchmark stopped reporting it)")
            continue
        if m.hard_max is not None:
            # `not (<=)` so a NaN metric fails the cap instead of
            # vacuously passing it.
            if not (new <= m.hard_max):
                failures.append(
                    f"{name}:{m.path}: {new:.3f} exceeds hard cap "
                    f"{m.hard_max:.3f}")
            elif m.cap_only:
                print(f"  ✓ {name}:{m.path} [cap {m.hard_max:.2f}]: "
                      f"{new:.3f}")
        if m.hard_min is not None:
            if not (new >= m.hard_min):
                failures.append(
                    f"{name}:{m.path}: {new:.3f} below hard floor "
                    f"{m.hard_min:.3f}")
            else:
                print(f"  ✓ {name}:{m.path} [floor {m.hard_min:.2f}]: "
                      f"{new:.3f}")
        if m.cap_only:
            continue
        if base is None:
            print(f"  ~ {name}:{m.path}: no baseline, "
                  f"fresh={new:.3f} (hard caps only)")
            continue
        t = tol if m.relative else abs_tol
        if m.higher_is_better:
            floor = base * (1.0 - t)
            ok = new >= floor
            verdict = f"{new:.3f} vs baseline {base:.3f} (floor {floor:.3f})"
        else:
            ceil = base * (1.0 + t)
            ok = new <= ceil
            verdict = f"{new:.3f} vs baseline {base:.3f} (ceil {ceil:.3f})"
        kind = "rel" if m.relative else "abs"
        if ok:
            print(f"  ✓ {name}:{m.path} [{kind} ±{t:.0%}]: {verdict}")
        else:
            failures.append(
                f"{name}:{m.path} [{kind} ±{t:.0%}]: REGRESSION {verdict}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--serve-baseline", default=None)
    ap.add_argument("--serve-fresh", default=None)
    ap.add_argument("--runtime-baseline", default=None)
    ap.add_argument("--runtime-fresh", default=None)
    ap.add_argument("--sharded-baseline", default=None)
    ap.add_argument("--sharded-fresh", default=None)
    ap.add_argument("--chaos-baseline", default=None)
    ap.add_argument("--chaos-fresh", default=None)
    ap.add_argument("--tol", type=float, default=0.15,
                    help="tolerance for machine-normalized (relative) "
                         "metrics; >15%% drop fails")
    ap.add_argument("--abs-tol", type=float, default=0.15,
                    help="tolerance for absolute throughput metrics "
                         "(widen on shared CI runners)")
    ap.add_argument("--synthetic-slowdown", type=float, default=None,
                    help="degrade every fresh metric by this fraction "
                         "after loading (gate self-test)")
    ap.add_argument("--expect-fail", action="store_true",
                    help="exit 0 iff the gate FAILED (self-test mode)")
    args = ap.parse_args(argv)

    pairs = []
    if args.serve_fresh:
        pairs.append(("serve", args.serve_baseline, args.serve_fresh,
                      SERVE_METRICS))
    if args.runtime_fresh:
        pairs.append(("runtime", args.runtime_baseline, args.runtime_fresh,
                      RUNTIME_METRICS))
    if args.sharded_fresh:
        pairs.append(("sharded", args.sharded_baseline, args.sharded_fresh,
                      SHARDED_METRICS))
    if args.chaos_fresh:
        pairs.append(("chaos", args.chaos_baseline, args.chaos_fresh,
                      CHAOS_METRICS))
    if not pairs:
        ap.error("nothing to check: pass --serve-fresh, --runtime-fresh, "
                 "--sharded-fresh and/or --chaos-fresh")

    failures: List[str] = []
    for name, base_path, fresh_path, metrics in pairs:
        with open(fresh_path) as f:
            fresh = json.load(f)
        if base_path:
            with open(base_path) as f:
                baseline = json.load(f)
        else:
            baseline = {}
        if args.synthetic_slowdown:
            _apply_slowdown(fresh, metrics, args.synthetic_slowdown)
            print(f"[self-test] degraded fresh {name} metrics by "
                  f"{args.synthetic_slowdown:.0%}")
        failures.extend(check_pair(
            name, baseline, fresh, metrics,
            tol=args.tol, abs_tol=args.abs_tol))

    failed = bool(failures)
    for msg in failures:
        print(f"  ✗ {msg}")
    if args.expect_fail:
        if failed:
            print("gate self-test OK: synthetic regression was caught")
            return 0
        print("gate self-test FAILED: regression slipped through")
        return 1
    print("benchmark regression gate:",
          "FAIL" if failed else "PASS")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
