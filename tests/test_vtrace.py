"""V-trace advantage realignment: scan vs O(T^2) oracle, GAE identity,
IMPALA pg-advantage, and fixed-point behaviour on a tabular MDP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.vtrace import (
    naive_vtrace,
    vtrace,
    vtrace_impala_pg_advantage,
)
from repro.core.gae import gae


def _random_inputs(key, B=4, T=13):
    ks = jax.random.split(key, 5)
    log_ratios = 0.5 * jax.random.normal(ks[0], (B, T))
    values = jax.random.normal(ks[1], (B, T))
    bootstrap = jax.random.normal(ks[2], (B,))
    rewards = jax.random.normal(ks[3], (B, T))
    dones = jax.random.bernoulli(ks[4], 0.1, (B, T))
    discounts = 0.99 * (1.0 - dones.astype(jnp.float32))
    return log_ratios, values, bootstrap, rewards, discounts


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("rho_bar,c_bar,lam", [(1.0, 1.0, 1.0),
                                               (2.0, 1.0, 0.95),
                                               (1e9, 1e9, 1.0)])
def test_scan_matches_naive(seed, rho_bar, c_bar, lam):
    lr, v, bv, r, d = _random_inputs(jax.random.PRNGKey(seed))
    fast = vtrace(log_ratios=lr, values=v, bootstrap_value=bv, rewards=r,
                  discounts=d, rho_bar=rho_bar, c_bar=c_bar, lam=lam)
    slow = naive_vtrace(log_ratios=lr, values=v, bootstrap_value=bv,
                        rewards=r, discounts=d, rho_bar=rho_bar,
                        c_bar=c_bar, lam=lam)
    np.testing.assert_allclose(fast.vs, slow.vs, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(fast.advantages, slow.advantages,
                               rtol=1e-5, atol=1e-5)


def test_on_policy_reduces_to_gae():
    """log_ratios == 0 and unclipped rho/c: V-trace == GAE targets."""
    _, v, bv, r, d = _random_inputs(jax.random.PRNGKey(3))
    lam = 0.95
    out = vtrace(log_ratios=jnp.zeros_like(v), values=v, bootstrap_value=bv,
                 rewards=r, discounts=d, rho_bar=1e9, c_bar=1e9, lam=lam)
    ref = gae(values=v, bootstrap_value=bv, rewards=r, discounts=d, lam=lam)
    np.testing.assert_allclose(out.vs, ref.returns, rtol=1e-5, atol=1e-5)


def test_on_policy_advantage_is_one_step_td_of_vs():
    """Eq. 15: A = r + gamma*v_{t+1} - V(s_t)."""
    lr, v, bv, r, d = _random_inputs(jax.random.PRNGKey(4))
    out = vtrace(log_ratios=lr, values=v, bootstrap_value=bv, rewards=r,
                 discounts=d)
    vs_tp1 = jnp.concatenate([out.vs[:, 1:], bv[:, None]], axis=1)
    np.testing.assert_allclose(out.advantages, r + d * vs_tp1 - v,
                               rtol=1e-6, atol=1e-6)


def test_rho_clipping_monotone():
    """Lower rho_bar shrinks |correction| towards the raw values."""
    lr, v, bv, r, d = _random_inputs(jax.random.PRNGKey(5))
    lr = jnp.abs(lr) + 0.5  # ratios well above 1 so clipping binds
    small = vtrace(log_ratios=lr, values=v, bootstrap_value=bv, rewards=r,
                   discounts=d, rho_bar=0.5, c_bar=0.5)
    large = vtrace(log_ratios=lr, values=v, bootstrap_value=bv, rewards=r,
                   discounts=d, rho_bar=4.0, c_bar=4.0)
    assert float(jnp.mean(jnp.abs(small.vs - v))) <= float(
        jnp.mean(jnp.abs(large.vs - v))) + 1e-6


def test_impala_pg_advantage_shape_and_onpolicy_match():
    lr, v, bv, r, d = _random_inputs(jax.random.PRNGKey(6))
    out = vtrace(log_ratios=jnp.zeros_like(lr), values=v, bootstrap_value=bv,
                 rewards=r, discounts=d)
    pg = vtrace_impala_pg_advantage(
        out, rewards=r, discounts=d, values=v, bootstrap_value=bv,
        log_ratios=jnp.zeros_like(lr))
    assert pg.shape == r.shape
    # On-policy: rho == 1, so pg advantage == A_vtrace.
    np.testing.assert_allclose(pg, out.advantages, rtol=1e-6, atol=1e-6)


def test_jit_and_grad_safety():
    lr, v, bv, r, d = _random_inputs(jax.random.PRNGKey(7))

    @jax.jit
    def f(values):
        out = vtrace(log_ratios=lr, values=values, bootstrap_value=bv,
                     rewards=r, discounts=d)
        return jnp.sum(out.vs)

    g = jax.grad(f)(v)
    assert g.shape == v.shape
    assert bool(jnp.all(jnp.isfinite(g)))
