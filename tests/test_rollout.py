"""Rollout/serving layer: generation semantics, async engines, policy
buffer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.policy_lag import (
    buffer_init,
    buffer_latest,
    buffer_push,
    buffer_sample,
)
from repro.data.mathgen import MathTaskDataset
from repro.data.tokenizer import EOS, PAD, get_tokenizer
from repro.envs import make_pendulum, wrap_autoreset
from repro.models.mlp_policy import act, mlp_policy_init
from repro.models.registry import build
from repro.rollout.async_engine import (
    ForwardLagGenerator,
    SimulatedAsyncActors,
)
from repro.rollout.sampler import generate, score_tokens

TOK = get_tokenizer()
CFG = ModelConfig(
    name="roll-test", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=TOK.vocab_size,
)
BUNDLE = build(CFG)
PARAMS = BUNDLE.init(jax.random.PRNGKey(0))


def _prompt(b=3, p=10):
    row = TOK.pad_to(TOK.encode("1+2=?#"), p, left=True)
    return jnp.asarray(np.stack([row] * b))


def test_generate_shapes_and_determinism():
    f = jax.jit(lambda pr, k: generate(BUNDLE, PARAMS, pr, k,
                                       max_new_tokens=6))
    r1 = f(_prompt(), jax.random.PRNGKey(1))
    r2 = f(_prompt(), jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(r1.completion),
                                  np.asarray(r2.completion))
    r3 = f(_prompt(), jax.random.PRNGKey(2))
    assert not np.array_equal(np.asarray(r1.completion),
                              np.asarray(r3.completion))


def test_generate_score_consistency():
    """The behavior logprobs recorded at sampling == teacher-forced
    rescoring under the same params (the beta == pi_serve invariant that
    removes the paper's vllm/transformers mismatch)."""
    res = jax.jit(lambda pr, k: generate(BUNDLE, PARAMS, pr, k,
                                         max_new_tokens=8))(
        _prompt(), jax.random.PRNGKey(3))
    logp, ent, _ = score_tokens(BUNDLE, PARAMS, res.tokens, prompt_len=10)
    diff = np.abs(np.asarray(logp - res.log_beta)) * np.asarray(res.mask)
    assert diff.max() < 2e-4
    assert bool(jnp.all(ent >= 0))


def test_generate_eos_masks_tail():
    """After EOS the mask is zero and PAD is emitted."""
    # Force EOS by biasing the embedding-tied head? Simpler: run many
    # tokens; untrained model rarely emits EOS, so synthesize directly:
    comp = jnp.asarray([[5, EOS, 7, 8]])
    # the invariant tested: mask semantics in GenerationResult are
    # enforced by the scan — emulate via a tiny vocab-weighted model is
    # overkill; instead check the engine's mask bookkeeping over 64 tokens.
    res = jax.jit(lambda pr, k: generate(BUNDLE, PARAMS, pr, k,
                                         max_new_tokens=64,
                                         temperature=2.0))(
        _prompt(1, 8), jax.random.PRNGKey(9))
    m = np.asarray(res.mask[0])
    c = np.asarray(res.completion[0])
    if EOS in c.tolist():
        t = c.tolist().index(EOS)
        assert m[t] == 1.0            # EOS token itself is scored
        assert (m[t + 1:] == 0).all()  # nothing after
        assert (c[t + 1:] == PAD).all()


def test_generate_eos_on_first_decode_step_single_token_mask():
    """A request whose *first* decode step emits EOS must come back as a
    well-formed single-token result: mask [1, 0, ...], PAD completion
    tail, and exact zeros in log_beta/values beyond the scored EOS —
    per-request consumers (the serve engine's tokenwise provenance) read
    these vectors without re-applying the batch mask."""
    import dataclasses

    def bias_eos(out):
        return out._replace(logits=out.logits.at[..., EOS].add(1e4))

    # Force EOS from the *prefill* logits only: the dead-row decode
    # steps that follow sample from ordinary (unforced) distributions,
    # which is exactly where garbage log-probs used to leak in.
    forced = dataclasses.replace(
        BUNDLE,
        forward=lambda *a, **k: bias_eos(BUNDLE.forward(*a, **k)),
    )
    for n in (1, 6):
        res = jax.jit(lambda pr, k: generate(
            forced, PARAMS, pr, k, max_new_tokens=n))(
            _prompt(2, 8), jax.random.PRNGKey(4))
        comp = np.asarray(res.completion)
        assert (comp[:, 0] == EOS).all()
        np.testing.assert_array_equal(
            np.asarray(res.mask), [[1.0] + [0.0] * (n - 1)] * 2)
        np.testing.assert_array_equal(comp[:, 1:], PAD)
        # exact zeros (not just masked garbage) beyond the scored token
        np.testing.assert_array_equal(np.asarray(res.log_beta[:, 1:]), 0.0)
        np.testing.assert_array_equal(np.asarray(res.values[:, 1:]), 0.0)
        assert np.isfinite(np.asarray(res.log_beta)).all()


def test_top_p_restricts_support():
    logits = jnp.asarray([[0.0, 0.1, 5.0, 5.1]])
    from repro.rollout.sampler import _top_p_filter

    filtered = _top_p_filter(logits, 0.9)
    assert np.isneginf(np.asarray(filtered)[0, :2]).all()
    assert np.isfinite(np.asarray(filtered)[0, 2:]).all()
    # top_p=1 is a no-op
    np.testing.assert_array_equal(
        np.asarray(_top_p_filter(logits, 1.0)), np.asarray(logits))


def test_policy_buffer_fifo_and_mixture():
    params = {"w": jnp.zeros((2,))}
    buf = buffer_init(params, capacity=3)
    assert int(buf.count) == 1
    for i in range(1, 5):
        buf = buffer_push(buf, {"w": jnp.full((2,), float(i))})
    assert int(buf.count) == 3
    # latest is w=4; buffer holds {2,3,4}
    np.testing.assert_allclose(np.asarray(buffer_latest(buf)["w"]), 4.0)
    sampled, slots = buffer_sample(buf, jax.random.PRNGKey(0), 256)
    vals = np.asarray(sampled["w"][:, 0])
    assert set(np.unique(vals)) == {2.0, 3.0, 4.0}


def test_simulated_async_actors_mixture_changes_with_capacity():
    env = wrap_autoreset(make_pendulum())
    params = mlp_policy_init(jax.random.PRNGKey(0), env.obs_dim,
                             env.act_dim)
    actors = SimulatedAsyncActors(
        env, act, params, n_actors=8, buffer_capacity=4,
        rollout_steps=16, seed=0)
    # push three distinct policies
    for i in range(3):
        p2 = jax.tree.map(lambda x: x + 0.1 * (i + 1), params)
        actors.push_policy(p2)
    batch, slots = actors.collect()
    assert batch.obs.shape == (8, 16, 3)
    assert len(np.unique(np.asarray(slots))) > 1  # a genuine mixture


def test_forward_lag_generator_staleness_labels():
    ds = MathTaskDataset(prompt_len=12, level=0, pool_size=128)
    gen = ForwardLagGenerator(
        BUNDLE, ds, n_minibatches=3, prompts_per_minibatch=2,
        completions_per_prompt=2, max_new_tokens=4)
    batches = gen.generate_phase(PARAMS)
    assert [b.staleness for b in batches] == [0, 1, 2]
    for b in batches:
        assert b.gen.tokens.shape == (4, 16)
        assert b.rewards.shape == (4,)
        assert set(np.unique(np.asarray(b.rewards))) <= {0.0, 1.0}
