"""Mesh-sharded serve path: shard_map kernel parity vs the single-device
oracles, in-place pool updates (buffer donation) under shard_map, the
placement-aware scheduler, and full-engine token-exactness — greedy,
speculative and preemption-churned — against the single-device engine.

Needs a multi-device host: CI runs this suite under
``XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_PLATFORMS=cpu``
(the sharded-serve job); on a 1-device host everything here skips, so
tier-1 collection is unaffected.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.tokenizer import get_tokenizer
from repro.distributed.sharding import paged_pool_sharding, replicated
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.launch.mesh import make_debug_mesh, parse_mesh_spec
from repro.models.registry import build
from repro.models.transformer import write_prefill_batch_to_pages
from repro.runtime import PolicyStore
from repro.serve import ServeEngine, ShardedBlockAllocator, make_allocator

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="sharded-serve suite needs >= 2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

TOK = get_tokenizer()
CFG = ModelConfig(
    name="sharded-test", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=TOK.vocab_size,
)
BUNDLE = build(CFG)
PARAMS = BUNDLE.init(jax.random.PRNGKey(0))
PROMPTS = [np.asarray(TOK.encode(p), np.int32)
           for p in ("1+2=?#", "3*4=?#", "10-7=?#", "6/2=?#")]
BUDGETS = [5, 9, 13, 7]


def _mesh(data=2):
    return make_debug_mesh(data=data)


# --- shard_map kernel parity vs the single-device oracles -------------------


def _ragged_sharded_case(seed, *, shards, per_shard, bs, b, kv, d, h,
                         t=1):
    """A ragged batch whose per-slot pages cross page (and shard-table)
    boundaries: each slot lives on one shard, owns a random *permuted*
    set of that shard's pages, and has its own context length (0 = an
    inactive slot — included on purpose)."""
    rng = np.random.default_rng(seed)
    nb = shards * per_shard
    m = per_shard                                    # table width
    k_pages = rng.normal(size=(kv, nb, bs, d)).astype(np.float32)
    v_pages = rng.normal(size=(kv, nb, bs, d)).astype(np.float32)
    local_tables = np.stack(
        [rng.permutation(per_shard)[:m] for _ in range(b)]).astype(np.int32)
    slot_shard = (rng.permutation(b) % shards).astype(np.int32)
    lens = rng.integers(0, m * bs + 1, size=(b,)).astype(np.int32)
    lens[0] = 0                                       # pinned inactive slot
    lens[1] = per_shard * bs                          # full table, crosses
    # Global ids: shard-local id + shard offset (the single-device view).
    global_tables = local_tables + slot_shard[:, None] * per_shard
    q = rng.normal(size=(b, t, h, d)).astype(np.float32)
    return (k_pages, v_pages, local_tables, global_tables, slot_shard,
            lens, q)


@pytest.mark.parametrize("mode", ["reference", "pallas_interpret"])
@pytest.mark.parametrize("window", [None, 5])
def test_sharded_paged_attention_parity(mode, window):
    mesh = _mesh(2)
    (k_pages, v_pages, local_t, global_t, ss, lens, q) = \
        _ragged_sharded_case(0, shards=2, per_shard=4, bs=4, b=5, kv=2,
                             d=8, h=4)
    q1 = q[:, 0]
    want = kops.paged_attention(
        q1, k_pages, v_pages, global_t, lens, window=window, mode=mode)
    got = kops.paged_attention(
        q1, k_pages, v_pages, local_t, lens, window=window, mode=mode,
        mesh=mesh, slot_shard=jnp.asarray(ss))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("mode", ["reference", "pallas_interpret"])
def test_sharded_paged_attention_multi_parity(mode):
    mesh = _mesh(2)
    (k_pages, v_pages, local_t, global_t, ss, lens, q) = \
        _ragged_sharded_case(1, shards=2, per_shard=4, bs=4, b=4, kv=2,
                             d=8, h=4, t=3)
    lens = np.maximum(lens, 0)
    lens[lens > 0] = np.maximum(lens[lens > 0], 3)   # room for the chunk
    want = kops.paged_attention_multi(
        q, k_pages, v_pages, global_t, lens, mode=mode)
    got = kops.paged_attention_multi(
        q, k_pages, v_pages, local_t, lens, mode=mode,
        mesh=mesh, slot_shard=jnp.asarray(ss))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("mode", ["reference", "pallas_interpret"])
def test_sharded_paged_kv_write_parity(mode):
    """Row writes land on the right page of the right shard — including
    a masked (inactive) slot that must write nothing anywhere."""
    mesh = _mesh(2)
    rng = np.random.default_rng(2)
    L, kv, per_shard, bs, d, b = 2, 2, 4, 4, 8, 5
    nb = 2 * per_shard
    pool = rng.normal(size=(L, kv, nb, bs, d)).astype(np.float32)
    k_rows = rng.normal(size=(b, kv, d)).astype(np.float32)
    v_rows = rng.normal(size=(b, kv, d)).astype(np.float32)
    local_idx = rng.integers(0, per_shard, size=(b,)).astype(np.int32)
    ss = (np.arange(b) % 2).astype(np.int32)
    offset = rng.integers(0, bs, size=(b,)).astype(np.int32)
    active = np.array([True, True, False, True, True])
    global_idx = local_idx + ss * per_shard
    want_k, want_v = kops.paged_kv_write(
        pool[0:1] * 0 + pool, pool.copy(), k_rows, v_rows, global_idx,
        offset, active, layer=1, mode=mode)
    got_k, got_v = kops.paged_kv_write(
        jnp.asarray(pool), jnp.asarray(pool), k_rows, v_rows, local_idx,
        offset, active, layer=1, mode=mode,
        mesh=mesh, slot_shard=jnp.asarray(ss))
    np.testing.assert_allclose(np.asarray(got_k), np.asarray(want_k),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               atol=1e-6)


def test_sharded_kv_write_donation_in_place():
    """The aliased in-place pool update survives sharding: donated
    NB-sharded pools are updated buffer-in-place on every shard (the
    acceptance bar for shard_map not re-materializing the pool)."""
    mesh = _mesh(2)
    L, kv, nb, bs, d, b = 2, 2, 8, 4, 8, 3
    sharding = paged_pool_sharding(mesh)
    k_pool = jax.device_put(jnp.zeros((L, kv, nb, bs, d)), sharding)
    v_pool = jax.device_put(jnp.zeros((L, kv, nb, bs, d)), sharding)
    k_ptrs = [s.data.unsafe_buffer_pointer()
              for s in k_pool.addressable_shards]

    fn = jax.jit(
        lambda kp, vp, kr, vr, pi, off, act, ss: kops.paged_kv_write(
            kp, vp, kr, vr, pi, off, act, layer=0,
            mesh=mesh, slot_shard=ss),
        donate_argnums=(0, 1))
    k2, v2 = fn(k_pool, v_pool,
                jnp.ones((b, kv, d)), jnp.ones((b, kv, d)),
                jnp.arange(b, dtype=jnp.int32),
                jnp.zeros((b,), jnp.int32),
                jnp.ones((b,), bool),
                jnp.asarray([0, 1, 0], jnp.int32))
    assert [s.data.unsafe_buffer_pointer()
            for s in k2.addressable_shards] == k_ptrs
    assert k2.sharding.is_equivalent_to(sharding, k2.ndim)


def test_sharded_prefill_batch_write_parity():
    """write_prefill_batch_to_pages places each request's rows on its
    home shard only, matching the single-device writer on the global
    view."""
    mesh = _mesh(2)
    rng = np.random.default_rng(3)
    L, kv, per_shard, bs, d, n, p = 2, 2, 4, 4, 8, 3, 10
    nb = 2 * per_shard
    cache_k = rng.normal(size=(L, n, p, kv, d)).astype(np.float32)
    cache_v = rng.normal(size=(L, n, p, kv, d)).astype(np.float32)
    m = -(-p // bs)
    local_blocks = np.stack(
        [rng.permutation(per_shard)[:m] for _ in range(n)]).astype(np.int32)
    home = np.asarray([0, 1, 1], np.int32)
    plens = np.asarray([10, 7, 4], np.int32)
    global_blocks = local_blocks + home[:, None] * per_shard
    zero = {"k_pages": jnp.zeros((L, kv, nb, bs, d)),
            "v_pages": jnp.zeros((L, kv, nb, bs, d))}
    want = write_prefill_batch_to_pages(
        cache_k, cache_v, zero, jnp.asarray(global_blocks),
        jnp.asarray(plens))
    got = write_prefill_batch_to_pages(
        cache_k, cache_v,
        jax.device_put(zero, paged_pool_sharding(mesh)),
        jnp.asarray(local_blocks), jnp.asarray(plens),
        jnp.asarray(home), mesh=mesh)
    np.testing.assert_allclose(np.asarray(got["k_pages"]),
                               np.asarray(want["k_pages"]), atol=1e-6)
    np.testing.assert_allclose(np.asarray(got["v_pages"]),
                               np.asarray(want["v_pages"]), atol=1e-6)


# --- allocator + placement ---------------------------------------------------


def test_sharded_allocator_per_shard_free_lists():
    a = ShardedBlockAllocator(num_blocks=16, block_size=4, num_shards=4)
    assert a.num_free == 16 and a.shard_num_blocks == 4
    got = a.allocate(3, shard=2)
    assert all(0 <= b < 4 for b in got)       # shard-local ids
    assert a.free_by_shard() == [4, 4, 1, 4]
    assert not a.can_allocate(2, shard=2) and a.can_allocate(2, shard=0)
    a.release(got, shard=2)
    assert a.free_by_shard() == [4, 4, 4, 4]
    with pytest.raises(ValueError):
        ShardedBlockAllocator(num_blocks=10, block_size=4, num_shards=4)
    assert make_allocator(8, 4, 1).num_shards == 1


def test_scheduler_balances_live_slots_per_shard():
    """Placement spreads admissions across shards instead of piling
    onto shard 0; pages come off each request's home-shard free list."""
    mesh = _mesh(2)
    eng = ServeEngine(BUNDLE, PARAMS, num_blocks=32, block_size=4,
                      max_batch=4, max_seq_len=64, temperature=1e-4,
                      seed=0, mesh=mesh)
    for r, n in zip(PROMPTS, BUDGETS):
        eng.submit(r, n)
    eng.step()
    shards = sorted(r.shard for r in eng.scheduler.running)
    assert shards == [0, 0, 1, 1]
    from repro.metrics.runtime_metrics import collect_serve_stats

    stats = collect_serve_stats(eng)
    assert stats["num_shards"] == 2
    assert stats["live_slots_by_shard"] == [2, 2]
    assert sum(stats["pool_free_by_shard"]) == stats["pool_blocks_free"]


# --- full-engine token-exactness vs the single-device engine ----------------


def _run_engine(mesh, *, num_blocks=32, decode_chunk=2, max_batch=3,
                **kw):
    eng = ServeEngine(BUNDLE, PARAMS, num_blocks=num_blocks, block_size=4,
                      max_batch=max_batch, max_seq_len=64,
                      temperature=1e-4, seed=0,
                      decode_chunk=decode_chunk, mesh=mesh, **kw)
    reqs = [eng.submit(r, n) for r, n in zip(PROMPTS, BUDGETS)]
    trajs = {t.request_id: t for t in eng.run(max_steps=600)}
    return [trajs[r.request_id].tokens for r in reqs], eng


@pytest.mark.parametrize("data", [2, 4])
def test_sharded_engine_token_exact_greedy(data):
    """ISSUE acceptance bar: with a data-sharded mesh on forced
    multi-device CPU, greedy serve output is token-exact vs the
    single-device engine at mixed lengths."""
    if len(jax.devices()) < data:
        pytest.skip(f"needs {data} devices")
    single, _ = _run_engine(None)
    sharded, eng = _run_engine(_mesh(data))
    for s, h in zip(single, sharded):
        np.testing.assert_array_equal(s, h)
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_sharded_engine_speculative_token_exact():
    """Speculation over sharded pools (draft pool shards like the
    verifier pool): token-exact with both the sharded and single-device
    non-speculative engines."""
    single, _ = _run_engine(None)
    spec, eng = _run_engine(_mesh(2), speculate_k=3,
                            draft=("params", PARAMS))
    for s, h in zip(single, spec):
        np.testing.assert_array_equal(s, h)
    stats = eng.stats.as_dict()
    assert stats["drafted_tokens"] > 0
    assert stats["acceptance_rate"] > 0.5     # same-params draft


def test_sharded_engine_preemption_token_exact():
    """A pool under pressure preempts on the starved request's own
    shard; recompute re-prefill over the sharded pool must not change
    a single emitted token."""
    single, _ = _run_engine(None, num_blocks=12, decode_chunk=1)
    sharded, eng = _run_engine(_mesh(2), num_blocks=12, decode_chunk=1)
    assert eng.stats.preemptions > 0
    for s, h in zip(single, sharded):
        np.testing.assert_array_equal(s, h)
    assert eng.allocator.num_free == 12


def test_sharded_engine_inflight_swap_provenance():
    """In-flight weight swap over a mesh: the PolicyStore publishes
    replicated params and per-token version provenance stays intact."""
    mesh = _mesh(2)
    store = PolicyStore(PARAMS, capacity=4, sharding=replicated(mesh))
    eng = ServeEngine(BUNDLE, store=store, num_blocks=32, block_size=4,
                      max_batch=2, max_seq_len=64, temperature=1.0,
                      seed=3, mesh=mesh)
    eng.submit(PROMPTS[0], 12)
    for _ in range(5):
        assert not eng.step()
    store.publish(jax.tree.map(lambda x: x + 0.01, PARAMS))
    traj = eng.run(max_steps=200)[0]
    assert eng.stats.swaps == 1
    v = traj.versions
    assert v[0] == 0 and v[-1] == 1
    dv = np.diff(v)
    assert (dv >= 0).all() and dv.sum() == 1


# --- launcher plumbing -------------------------------------------------------


def test_parse_mesh_spec():
    assert parse_mesh_spec("data=4") == {"data": 4, "model": 1}
    assert parse_mesh_spec("data=2,model=2") == {"data": 2, "model": 2}
    with pytest.raises(ValueError):
        parse_mesh_spec("rows=3")
    with pytest.raises(ValueError):
        parse_mesh_spec("data=x")
    with pytest.raises(ValueError):
        parse_mesh_spec("data=0")


def test_launcher_serves_sharded(capsys):
    """--mesh data=2 end to end through the CLI (versioned runtime)."""
    from repro.launch.serve import main

    rc = main(["--engine", "continuous", "--mesh", "data=2",
               "--requests", "4", "--mixed-lengths", "2,4",
               "--max-batch", "2", "--runtime", "versioned"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "sharded over 2 shards" in out
    assert "serving over mesh" in out
