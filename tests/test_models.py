"""Model-layer correctness: forward/decode agreement, masks, MoE routing,
recurrent-state handoff — across all backbone families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.models.registry import build

KEY = jax.random.PRNGKey(0)

DENSE = ModelConfig(
    name="t-dense", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=97, qkv_bias=True,
)
CASES = {
    "dense": DENSE,
    "swa": DENSE.replace(name="t-swa", sliding_window=4, global_every=2),
    "mqa_softcap": DENSE.replace(name="t-mqa", n_kv_heads=1,
                                 logit_softcap=30.0, tie_embeddings=True),
    "moe": DENSE.replace(name="t-moe", moe=MoEConfig(
        n_experts=4, top_k=2, d_ff_expert=32, n_shared_experts=1,
        capacity_factor=2.0)),
    "hybrid": DENSE.replace(name="t-hyb", hybrid_attn_ssm=True,
                            ssm=SSMConfig(state_dim=8), sliding_window=4,
                            global_every=2),
    "rwkv": ModelConfig(
        name="t-rwkv", arch_type="ssm", n_layers=2, d_model=128, n_heads=2,
        n_kv_heads=2, d_ff=256, vocab_size=97, attn_free=True,
        tie_embeddings=True),
}


def _rand_tokens(key, b, s, vocab):
    return jax.random.randint(key, (b, s), 3, vocab)


@pytest.mark.parametrize("case", list(CASES))
def test_decode_matches_forward(case):
    """Token-by-token decode from an empty cache must reproduce the
    teacher-forced forward logits (validates cache writes, RoPE offsets,
    SSM/WKV state handoff, sliding-window decode masks)."""
    cfg = CASES[case]
    bundle = build(cfg)
    params = bundle.init(KEY)
    b, s = 2, 10
    toks = _rand_tokens(jax.random.PRNGKey(1), b, s, cfg.vocab_size)

    full = bundle.forward(params, toks)

    cache = bundle.init_cache(params, b, 16)
    got = []
    for t in range(s):
        out, cache = bundle.decode_step(params, toks[:, t], cache)
        got.append(out.logits)
    got = jnp.stack(got, axis=1)  # [B, S, V]

    np.testing.assert_allclose(
        np.asarray(got), np.asarray(full.logits), rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= top_k the dispatch keeps every token."""
    cfg = CASES["moe"]
    bundle = build(cfg)
    params = bundle.init(KEY)
    toks = _rand_tokens(jax.random.PRNGKey(2), 2, 12, cfg.vocab_size)
    out = bundle.forward(params, toks)
    assert bool(jnp.all(jnp.isfinite(out.logits)))
    assert float(out.aux_loss) > 0.0  # load-balance aux is live


def test_causality():
    """Future-token perturbation cannot change past logits."""
    cfg = CASES["dense"]
    bundle = build(cfg)
    params = bundle.init(KEY)
    toks = _rand_tokens(jax.random.PRNGKey(3), 1, 8, cfg.vocab_size)
    base = bundle.forward(params, toks).logits
    toks2 = toks.at[0, 6].set((toks[0, 6] + 1) % cfg.vocab_size)
    pert = bundle.forward(params, toks2).logits
    np.testing.assert_allclose(np.asarray(base[0, :6]),
                               np.asarray(pert[0, :6]), rtol=1e-5,
                               atol=1e-5)
    assert not np.allclose(np.asarray(base[0, 6:]), np.asarray(pert[0, 6:]))


def test_sliding_window_blocks_long_range():
    """A token beyond the window cannot influence the current logit in a
    single local-attention layer model."""
    cfg = DENSE.replace(name="t-swa1", n_layers=1, sliding_window=3,
                        global_every=10**6)  # all layers local
    bundle = build(cfg)
    params = bundle.init(KEY)
    toks = _rand_tokens(jax.random.PRNGKey(4), 1, 9, cfg.vocab_size)
    base = bundle.forward(params, toks).logits
    # Perturb position 0; window=3 means position 8 sees keys {6,7,8}.
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    pert = bundle.forward(params, toks2).logits
    np.testing.assert_allclose(np.asarray(base[0, -1]),
                               np.asarray(pert[0, -1]), rtol=1e-5,
                               atol=1e-5)


def test_prefix_lm_bidirectional_prefix():
    """VLM prefix tokens attend bidirectionally: perturbing a *later*
    prefix patch changes the hidden state of earlier positions' logits."""
    cfg = DENSE.replace(name="t-vlm", vision_prefix_len=4, prefix_lm=True)
    bundle = build(cfg)
    params = bundle.init(KEY)
    toks = _rand_tokens(jax.random.PRNGKey(5), 1, 6, cfg.vocab_size)
    emb = jax.random.normal(jax.random.PRNGKey(6), (1, 4, 1152))
    base = bundle.forward(params, toks, prefix_embeds=emb).logits
    emb2 = emb.at[0, 3].add(1.0)
    pert = bundle.forward(params, toks, prefix_embeds=emb2).logits
    # First text logit is affected by the last patch (prefix visible).
    assert not np.allclose(np.asarray(base[0, 0]), np.asarray(pert[0, 0]))


def test_whisper_cross_attention_sees_frames():
    cfg = ModelConfig(
        name="t-whisper", arch_type="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=97,
        encoder_layers=2, encoder_seq_len=10, activation="gelu")
    bundle = build(cfg)
    params = bundle.init(KEY)
    toks = _rand_tokens(jax.random.PRNGKey(7), 2, 6, cfg.vocab_size)
    frames = jax.random.normal(jax.random.PRNGKey(8), (2, 10, 64))
    base = bundle.forward(params, toks, frames=frames).logits
    pert = bundle.forward(params, toks, frames=frames + 0.5).logits
    assert not np.allclose(np.asarray(base), np.asarray(pert))
    # decode path agrees with forward
    cache = bundle.init_cache(params, 2, 8, frames=frames)
    got = []
    for t in range(6):
        out, cache = bundle.decode_step(params, toks[:, t], cache)
        got.append(out.logits)
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=2e-4, atol=2e-4)


def test_param_count_close_to_analytic():
    cfg = CASES["dense"]
    bundle = build(cfg)
    params = bundle.init(KEY)
    actual = sum(x.size for x in jax.tree.leaves(params))
    analytic = cfg.param_count()
    assert abs(actual - analytic) / actual < 0.05


def test_grad_flows_through_everything():
    cfg = CASES["hybrid"]
    bundle = build(cfg)
    params = bundle.init(KEY)
    toks = _rand_tokens(jax.random.PRNGKey(9), 2, 8, cfg.vocab_size)

    def loss(p):
        out = bundle.forward(p, toks)
        return jnp.mean(jax.nn.logsumexp(out.logits, -1)) + out.aux_loss

    g = jax.grad(loss)(params)
    norms = [float(jnp.linalg.norm(x)) for x in jax.tree.leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    # At least 90% of leaves receive gradient signal.
    nonzero = sum(1 for n in norms if n > 0)
    assert nonzero / len(norms) > 0.9
