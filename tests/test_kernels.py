"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention_pallas import flash_attention
from repro.kernels.fused_logprob_pallas import logprobs_pallas
from repro.kernels.paged_attention_pallas import (
    paged_attention,
    paged_attention_multi,
    paged_attention_varlen,
)
from repro.kernels.vtrace_pallas import vtrace_pallas
from repro.kernels.wkv6_pallas import wkv6_pallas
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# vtrace
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,t", [(1, 5), (4, 13), (8, 64), (13, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vtrace_kernel_sweep(b, t, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, b * t), 5)
    lr = (0.5 * jax.random.normal(ks[0], (b, t))).astype(dtype)
    v = jax.random.normal(ks[1], (b, t)).astype(dtype)
    bv = jax.random.normal(ks[2], (b,)).astype(dtype)
    r = jax.random.normal(ks[3], (b, t)).astype(dtype)
    d = 0.99 * (1 - jax.random.bernoulli(ks[4], 0.1, (b, t)).astype(
        jnp.float32)).astype(dtype)
    vs, adv = vtrace_pallas(lr, v, bv, r, d, interpret=True)
    vs_r, adv_r = ref.ref_vtrace(
        lr.astype(jnp.float32), v.astype(jnp.float32),
        bv.astype(jnp.float32), r.astype(jnp.float32),
        d.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(vs, vs_r, rtol=tol, atol=tol)
    np.testing.assert_allclose(adv, adv_r, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "s,h,kv,d,window",
    [(64, 4, 2, 32, None), (100, 4, 1, 16, None), (128, 8, 8, 64, 32),
     (96, 4, 2, 32, 16), (65, 2, 2, 8, 7)],
)
def test_flash_attention_sweep(s, h, kv, d, window):
    ks = jax.random.split(jax.random.fold_in(KEY, s + h + d), 3)
    q = jax.random.normal(ks[0], (2, s, h, d))
    k = jax.random.normal(ks[1], (2, s, kv, d))
    v = jax.random.normal(ks[2], (2, s, kv, d))
    out = flash_attention(q, k, v, window=window, block_q=32, block_k=32,
                          interpret=True)
    want = ref.ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_flash_attention_bf16(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 64, 2, 32)).astype(dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    want = ref.ref_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                             v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# paged decode attention (serve engine)
# ---------------------------------------------------------------------------


def _ragged_tables(rng, b, num_blocks, max_blocks, block_size,
                   full_lens=False):
    """Shuffled distinct page assignments + ragged context lengths."""
    perm = rng.permutation(num_blocks)
    tables = np.zeros((b, max_blocks), np.int32)
    lens = np.zeros((b,), np.int32)
    nxt = 0
    for i in range(b):
        n_pages = int(rng.integers(1, max_blocks + 1))
        if nxt + n_pages > num_blocks:
            n_pages = num_blocks - nxt
        tables[i, :n_pages] = perm[nxt:nxt + n_pages]
        nxt += n_pages
        hi = n_pages * block_size
        lens[i] = hi if full_lens else int(rng.integers(1, hi + 1))
    return tables, lens


@pytest.mark.parametrize(
    "b,h,kv,d,bs,window",
    [(4, 4, 2, 16, 8, None), (3, 4, 4, 32, 4, None), (2, 8, 2, 16, 8, 5),
     (5, 2, 1, 8, 16, None), (4, 4, 2, 16, 8, 12)],
)
def test_paged_attention_ragged_sweep(b, h, kv, d, bs, window):
    """Pallas kernel vs jnp oracle on shuffled, ragged block tables."""
    rng = np.random.default_rng(b * 31 + h)
    num_blocks, max_blocks = 24, 4
    ks = jax.random.split(jax.random.fold_in(KEY, b * h * d), 3)
    q = jax.random.normal(ks[0], (b, h, d))
    kp = jax.random.normal(ks[1], (kv, num_blocks, bs, d))
    vp = jax.random.normal(ks[2], (kv, num_blocks, bs, d))
    tables, lens = _ragged_tables(rng, b, num_blocks, max_blocks, bs)
    out = paged_attention(q, kp, vp, jnp.asarray(tables),
                          jnp.asarray(lens), window=window, interpret=True)
    want = ref.ref_paged_attention(q, kp, vp, jnp.asarray(tables),
                                   jnp.asarray(lens), window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_inactive_slot_zero_output():
    """context_len 0 (an empty serve slot) must yield exactly zero."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 4, 16))
    kp = jax.random.normal(ks[1], (2, 8, 4, 16))
    vp = jax.random.normal(ks[2], (2, 8, 4, 16))
    tables = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    lens = jnp.asarray([0, 6], jnp.int32)
    for fn in (
        lambda: paged_attention(q, kp, vp, tables, lens, interpret=True),
        lambda: ref.ref_paged_attention(q, kp, vp, tables, lens),
    ):
        out = np.asarray(fn())
        np.testing.assert_array_equal(out[0], 0.0)
        assert np.abs(out[1]).max() > 0


def test_paged_attention_matches_dense_attention():
    """A contiguous single-request table == plain causal attention on
    the last query position (the dense/paged equivalence the serve
    engine relies on)."""
    s, h, kv, d, bs = 12, 4, 2, 16, 4
    ks = jax.random.split(KEY, 3)
    q_full = jax.random.normal(ks[0], (1, s, h, d))
    k_full = jax.random.normal(ks[1], (1, s, kv, d))
    v_full = jax.random.normal(ks[2], (1, s, kv, d))
    want = ref.ref_attention(q_full, k_full, v_full, causal=True)[0, -1]
    # pack rows 0..s-1 into contiguous pages
    kp = jnp.zeros((kv, 4, bs, d))
    vp = jnp.zeros((kv, 4, bs, d))
    kp = kp.at[:, :3].set(
        k_full[0].transpose(1, 0, 2).reshape(kv, 3, bs, d))
    vp = vp.at[:, :3].set(
        v_full[0].transpose(1, 0, 2).reshape(kv, 3, bs, d))
    tables = jnp.asarray([[0, 1, 2]], jnp.int32)
    lens = jnp.asarray([s], jnp.int32)
    got = ref.ref_paged_attention(q_full[:, -1], kp, vp, tables, lens)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged attention, varlen (one kernel family: prefill/decode/verify)
# ---------------------------------------------------------------------------


def _varlen_rows(rng, lens, t):
    """Random per-slot ``(row_start, row_len)`` inside each context.

    Mixes the three call shapes the serve engine issues: decode rows
    (``row_len == 1``), ragged tiles (``1 < row_len <= t``) and dead
    slots (``row_len == 0``) — plus a ``row_start`` anywhere in the
    written context, as chunked prefill resumes mid-prompt."""
    b = len(lens)
    row_start = np.zeros((b,), np.int32)
    row_len = np.zeros((b,), np.int32)
    for i in range(b):
        kind = i % 3
        if kind == 0 and lens[i] >= 1:          # decode shape
            row_len[i] = 1
        elif kind == 1:                          # dead slot
            row_len[i] = 0
        else:                                    # ragged tile
            row_len[i] = int(rng.integers(1, min(t, lens[i]) + 1))
        row_start[i] = int(rng.integers(0, lens[i] - row_len[i] + 1))
    return row_start, row_len


@pytest.mark.parametrize(
    "b,t,h,kv,d,bs,window",
    [(4, 4, 4, 2, 16, 8, None), (3, 8, 4, 4, 32, 4, None),
     (5, 3, 2, 1, 8, 16, None), (2, 6, 8, 2, 16, 8, 5),
     (4, 5, 4, 2, 16, 8, 12), (6, 2, 2, 2, 8, 4, None)],
)
def test_paged_attention_varlen_ragged_sweep(b, t, h, kv, d, bs, window):
    """Varlen Pallas kernel (interpret) vs the jnp oracle on shuffled
    tables with mixed decode/tile/dead rows at ragged offsets."""
    rng = np.random.default_rng(b * 131 + t * 7 + h)
    num_blocks, max_blocks = 24, 4
    ks = jax.random.split(jax.random.fold_in(KEY, b * t * h + d), 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    kp = jax.random.normal(ks[1], (kv, num_blocks, bs, d))
    vp = jax.random.normal(ks[2], (kv, num_blocks, bs, d))
    tables, lens = _ragged_tables(rng, b, num_blocks, max_blocks, bs)
    row_start, row_len = _varlen_rows(rng, lens, t)
    got = paged_attention_varlen(
        q, kp, vp, jnp.asarray(tables), jnp.asarray(row_start),
        jnp.asarray(row_len), window=window, interpret=True)
    want = ref.ref_paged_attention_varlen(
        q, kp, vp, jnp.asarray(tables), jnp.asarray(row_start),
        jnp.asarray(row_len), window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # Padding rows and dead slots must be exactly zero, not just close.
    got_np = np.asarray(got)
    for i in range(b):
        np.testing.assert_array_equal(got_np[i, row_len[i]:], 0.0)


def test_paged_attention_varlen_subsumes_decode_and_verify():
    """The three serve call shapes are one kernel: ``row_len == 1``
    reproduces single-token decode and full-tail ``row_len == k``
    reproduces the speculative-verify (multi) shape, numerically
    identical to the dedicated entry points."""
    rng = np.random.default_rng(7)
    b, t, h, kv, d, bs = 4, 4, 4, 2, 16, 8
    num_blocks, max_blocks = 24, 4
    ks = jax.random.split(jax.random.fold_in(KEY, 977), 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    kp = jax.random.normal(ks[1], (kv, num_blocks, bs, d))
    vp = jax.random.normal(ks[2], (kv, num_blocks, bs, d))
    tables, lens = _ragged_tables(rng, b, num_blocks, max_blocks, bs)
    tables, lens = jnp.asarray(tables), jnp.asarray(lens)

    # decode: the varlen row (row_start = ctx-1, row_len = 1) vs the
    # single-token kernel on the same contexts.
    dec = paged_attention_varlen(
        q[:, :1], kp, vp, tables, lens - 1, jnp.ones((b,), jnp.int32),
        interpret=True)
    want_dec = paged_attention(q[:, 0], kp, vp, tables, lens,
                               interpret=True)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(want_dec),
                               rtol=2e-5, atol=2e-5)

    # verify: the fixed-T wrapper is literally the varlen kernel with
    # (ctx-T, T) rows — including its treatment of inactive slots.
    lens_inact = lens.at[1].set(0)
    multi = paged_attention_multi(q, kp, vp, tables, lens_inact,
                                  interpret=True)
    active = lens_inact > 0
    var = paged_attention_varlen(
        q, kp, vp, tables,
        jnp.where(active, lens_inact - t, 0),
        jnp.where(active, t, 0), interpret=True)
    np.testing.assert_allclose(np.asarray(multi), np.asarray(var),
                               rtol=0, atol=0)
    np.testing.assert_array_equal(np.asarray(multi[1]), 0.0)


def test_ops_varlen_dispatch_modes_agree():
    """reference and pallas_interpret modes of the ops-layer varlen
    entry agree on ragged mixed-shape rows."""
    rng = np.random.default_rng(13)
    b, t, h, kv, d, bs = 5, 3, 4, 2, 16, 4
    num_blocks, max_blocks = 16, 4
    ks = jax.random.split(jax.random.fold_in(KEY, 1933), 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    kp = jax.random.normal(ks[1], (kv, num_blocks, bs, d))
    vp = jax.random.normal(ks[2], (kv, num_blocks, bs, d))
    tables, lens = _ragged_tables(rng, b, num_blocks, max_blocks, bs)
    row_start, row_len = _varlen_rows(rng, lens, t)
    args = (q, kp, vp, jnp.asarray(tables), jnp.asarray(row_start),
            jnp.asarray(row_len))
    a = ops.paged_attention_varlen(*args, mode="reference")
    bI = ops.paged_attention_varlen(*args, mode="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(bI),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "s,h,kd,vd,chunk",
    [(32, 2, 16, 16, 8), (50, 3, 32, 32, 16), (64, 2, 64, 64, 64),
     (17, 1, 8, 8, 4)],
)
def test_wkv6_sweep(s, h, kd, vd, chunk):
    ks = jax.random.split(jax.random.fold_in(KEY, s * h + kd), 6)
    r = jax.random.normal(ks[0], (2, s, h, kd))
    k = jax.random.normal(ks[1], (2, s, h, kd))
    v = jax.random.normal(ks[2], (2, s, h, vd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (2, s, h, kd))) * 0.8 + 0.1
    u = 0.3 * jax.random.normal(ks[4], (h, kd))
    s0 = jax.random.normal(ks[5], (2, h, kd, vd))
    y, sf = wkv6_pallas(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    yr, sr = ref.ref_wkv6(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr),
                               rtol=3e-4, atol=3e-4)


def test_wkv6_extreme_decay_stable():
    """Aggressive decays (w -> 0) must not overflow the chunked form —
    the TPU adaptation's exponent differences are all <= 0."""
    s, h, kd, vd = 32, 1, 16, 16
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (1, s, h, kd))
    k = jax.random.normal(ks[1], (1, s, h, kd))
    v = jax.random.normal(ks[2], (1, s, h, vd))
    w = jnp.full((1, s, h, kd), 1e-6)  # near-total forgetting
    u = jnp.zeros((h, kd))
    y, sf = wkv6_pallas(r, k, v, w, u, chunk=16, interpret=True)
    yr, sr = ref.ref_wkv6(r, k, v, w, u)
    assert bool(jnp.all(jnp.isfinite(y)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# fused logprob
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,vocab,bn,bv",
    [(16, 54, 8, 32), (7, 1000, 8, 256), (64, 2048, 8, 512),
     (3, 131, 4, 64)],
)
def test_logprob_kernel_sweep(n, vocab, bn, bv):
    ks = jax.random.split(jax.random.fold_in(KEY, n * vocab), 2)
    logits = 4.0 * jax.random.normal(ks[0], (n, vocab))
    targets = jax.random.randint(ks[1], (n,), 0, vocab)
    logp, ent = logprobs_pallas(logits, targets, block_n=bn, block_v=bv,
                                interpret=True)
    logp_r = ref.ref_logprobs_from_logits(logits, targets)
    ent_r = ref.ref_entropy_from_logits(logits)
    np.testing.assert_allclose(np.asarray(logp), np.asarray(logp_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ent), np.asarray(ent_r),
                               rtol=1e-4, atol=1e-4)


def test_logprob_kernel_bf16_logits():
    ks = jax.random.split(KEY, 2)
    logits = (4.0 * jax.random.normal(ks[0], (32, 512))).astype(jnp.bfloat16)
    targets = jax.random.randint(ks[1], (32,), 0, 512)
    logp, _ = logprobs_pallas(logits, targets, interpret=True)
    logp_r = ref.ref_logprobs_from_logits(logits, targets)
    np.testing.assert_allclose(np.asarray(logp), np.asarray(logp_r),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------


def test_ops_dispatch_modes_agree():
    ks = jax.random.split(KEY, 5)
    lr = 0.3 * jax.random.normal(ks[0], (4, 16))
    v = jax.random.normal(ks[1], (4, 16))
    bv = jax.random.normal(ks[2], (4,))
    r = jax.random.normal(ks[3], (4, 16))
    d = jnp.full((4, 16), 0.99)
    a = ops.vtrace(lr, v, bv, r, d, mode="reference")
    b = ops.vtrace(lr, v, bv, r, d, mode="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]),
                               rtol=1e-5, atol=1e-5)

    logits = jax.random.normal(ks[4], (2, 8, 64))
    tgts = jax.random.randint(ks[0], (2, 8), 0, 64)
    la, ea = ops.logprobs_from_logits(logits, tgts, mode="reference")
    lb, eb = ops.logprobs_from_logits(logits, tgts, mode="pallas_interpret")
    assert la.shape == (2, 8)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ea), np.asarray(eb),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# selective-SSM scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "s,i,n,blk",
    [(16, 32, 8, 16), (33, 100, 16, 64), (64, 128, 16, 64), (7, 8, 4, 8)],
)
def test_ssm_scan_sweep(s, i, n, blk):
    from repro.kernels.ssm_scan_pallas import ssm_scan_pallas

    ks = jax.random.split(jax.random.fold_in(KEY, s * i), 6)
    u = jax.random.normal(ks[0], (2, s, i))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (2, s, i)))
    bt = jax.random.normal(ks[2], (2, s, n))
    ct = jax.random.normal(ks[3], (2, s, n))
    a = -jnp.exp(jax.random.normal(ks[4], (i, n)))
    h0 = jax.random.normal(ks[5], (2, i, n))
    y, hT = ssm_scan_pallas(u, dt, bt, ct, a, h0, block_i=blk,
                            interpret=True)
    y_r, h_r = ref.ref_ssm_scan(u, dt, bt, ct, a, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h_r),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_ops_dispatch():
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (1, 8, 16))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 8, 16)))
    bt = jax.random.normal(ks[2], (1, 8, 4))
    ct = jax.random.normal(ks[3], (1, 8, 4))
    a = -jnp.exp(jax.random.normal(ks[4], (16, 4)))
    y1, h1 = ops.ssm_scan(u, dt, bt, ct, a, mode="reference")
    y2, h2 = ops.ssm_scan(u, dt, bt, ct, a, mode="pallas_interpret")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
