"""Optional-import shim for ``hypothesis``.

The property tests use only ``given``/``settings``/``st.integers``/
``st.floats``.  When hypothesis is installed this module re-exports the
real API; when it is absent the decorated tests skip cleanly at run time
instead of breaking collection of the whole file (the non-property tests
in the same modules still run).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the dep
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipped():
                pytest.skip("hypothesis not installed")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
