"""Observability: span tracer, metrics registry, Perfetto export,
trace_report validation, and the MetricLogger sink."""
import importlib.util
import json
import pathlib
import threading

import pytest

from repro.metrics.logging import MetricLogger, read_jsonl
from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_TRACER,
    TraceEvent,
    Tracer,
    events_to_trace_json,
    export_perfetto,
    export_trace_jsonl,
    load_trace_events,
    make_tracer,
    trace_annotation,
)


def _load_trace_report():
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "benchmarks" / "trace_report.py")
    spec = importlib.util.spec_from_file_location("trace_report", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


trace_report = _load_trace_report()


# --- tracer -----------------------------------------------------------------


def test_tracer_sync_spans_and_instants():
    tr = Tracer(detail="spans")
    with tr.span("step", tid="engine", n=3):
        tr.instant("swap", tid="engine", old=0, new=1)
    evs = tr.events()
    assert [e.ph for e in evs] == ["B", "i", "E"]
    assert evs[0].args == {"n": 3}
    assert evs[0].ts <= evs[1].ts <= evs[2].ts
    assert all(e.pid == "serve" and e.tid == "engine" for e in evs)


def test_tracer_async_spans_carry_id():
    tr = Tracer(detail="spans")
    tr.async_begin("waiting", 7)
    tr.async_end("waiting", 7)
    b, e = tr.events()
    assert (b.ph, e.ph) == ("b", "e")
    assert b.id == e.id == 7


def test_tracer_ring_evicts_and_counts_drops():
    tr = Tracer(capacity=4, detail="spans")
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr) == 4
    assert tr.dropped == 6
    assert [e.name for e in tr.events()] == ["e6", "e7", "e8", "e9"]
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_detail_levels():
    assert make_tracer("off") is NULL_TRACER
    assert make_tracer("spans").full is False
    assert make_tracer("full").full is True
    with pytest.raises(ValueError):
        make_tracer("verbose")
    with pytest.raises(ValueError):
        Tracer(detail="off")


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False and NULL_TRACER.full is False
    with NULL_TRACER.span("x", big_arg=list(range(100))):
        NULL_TRACER.instant("y")
        NULL_TRACER.counter("z", v=1.0)
        NULL_TRACER.async_begin("w", 1)
        NULL_TRACER.async_end("w", 1)
    assert len(NULL_TRACER) == 0


def test_tracer_to_trace_ns_matches_now():
    import time

    tr = Tracer(detail="spans")
    mono = time.monotonic()
    assert abs(tr.to_trace_ns(mono) - tr.now()) < 50_000_000  # 50ms slack


def test_tracer_threaded_appends_all_land():
    tr = Tracer(capacity=1 << 14, detail="spans")

    def work(tid):
        for _ in range(500):
            tr.instant("tick", tid=f"t{tid}")

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr) == 2000 and tr.dropped == 0


# --- registry ---------------------------------------------------------------


def test_registry_instruments_get_or_create_with_labels():
    reg = MetricsRegistry()
    c = reg.counter("drops", reason="tv_gate")
    c.inc()
    c.inc(2.0)
    assert reg.counter("drops", reason="tv_gate") is c
    assert reg.counter("drops", reason="max_lag") is not c
    with pytest.raises(ValueError):
        c.inc(-1.0)
    g = reg.gauge("depth")
    g.set(4)
    g.dec()
    assert g.value == 3.0
    snap = reg.snapshot()
    assert snap["counters"]["drops{reason=tv_gate}"] == 3.0
    assert snap["gauges"]["depth"] == 3.0


def test_histogram_exact_and_windowed_percentiles():
    h = Histogram()
    for v in range(1, 101):           # 1..100
        h.observe(float(v))
    assert h.percentiles()["p50"] == 50.0
    assert h.percentiles()["p99"] == 99.0
    start = h.count
    for v in (1000.0, 2000.0, 3000.0):
        h.observe(v)
    win = h.percentiles(start=start)
    assert win["p50"] == 2000.0       # only post-start samples
    s = h.summary(start=start)
    assert s["count"] == 3 and s["mean"] == 2000.0
    assert Histogram().percentiles()["p50"] == 0.0  # empty: zeros, no raise


def test_histogram_bounded_retention():
    h = Histogram(max_samples=8)
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100 and len(h.samples) == 8
    assert h.percentiles()["p50"] == 95.0   # window = last 8 (92..99)


def test_registry_producers_merge_and_replace():
    reg = MetricsRegistry()
    reg.register_producer("serve", lambda: {"tokens": 5})
    assert reg.snapshot()["serve"] == {"tokens": 5}
    reg.register_producer("serve", lambda: {"tokens": 9})  # replace
    assert reg.snapshot()["serve"] == {"tokens": 9}
    reg.unregister_producer("serve")
    assert "serve" not in reg.snapshot()


def test_registry_export_jsonl_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("n").inc(3)
    path = str(tmp_path / "m.jsonl")
    reg.export_jsonl(path, step=1)
    reg.export_jsonl(path, step=2)
    rows = read_jsonl(path)
    assert [r["step"] for r in rows] == [1, 2]
    assert rows[0]["counters"]["n"] == 3.0


# --- perfetto export --------------------------------------------------------


def _sample_tracer():
    tr = Tracer(detail="full")
    tr.async_begin("waiting", 0)
    tr.async_end("waiting", 0)
    tr.async_begin("running", 0)
    with tr.span("decode", tid="engine", chunk=4):
        tr.instant("token", tid="tokens", rid=0, v=1, lag=0, tok=42)
    tr.counter("pool_free", free=12.0)
    tr.async_end("running", 0)
    return tr


def test_events_to_trace_json_shape():
    doc = events_to_trace_json(_sample_tracer())
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    names = {(e["name"], e["args"]["name"]) for e in meta}
    assert ("process_name", "serve") in names
    assert ("thread_name", "engine") in names
    body = [e for e in evs if e["ph"] != "M"]
    for e in body:
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    asy = [e for e in body if e["ph"] in ("b", "e")]
    assert all(e["cat"] == "request" and e["id"] == 0 for e in asy)
    inst = next(e for e in body if e["ph"] == "i")
    assert inst["s"] == "t"
    json.dumps(doc)                   # JSON-serializable end to end


def test_export_roundtrip_both_formats(tmp_path):
    tr = _sample_tracer()
    jpath, lpath = str(tmp_path / "t.json"), str(tmp_path / "t.jsonl")
    n_json = export_perfetto(tr, jpath)
    n_jsonl = export_trace_jsonl(tr, lpath)
    assert n_json == n_jsonl == len(tr.events())
    from_json = load_trace_events(jpath)
    from_jsonl = load_trace_events(lpath)
    assert len(from_json) == len(from_jsonl) == n_json
    # same phases and (µs) timestamps from either format
    assert [e["ph"] for e in from_json] == [e["ph"] for e in from_jsonl]
    for a, b in zip(from_json, from_jsonl):
        assert a["ts"] == pytest.approx(b["ts"], abs=1e-6)


def test_trace_annotation_is_usable_context():
    with trace_annotation("serve.decode"):
        pass                          # jax present or not: must not raise


# --- trace_report validation ------------------------------------------------


def test_check_balance_accepts_balanced():
    doc = events_to_trace_json(_sample_tracer())
    evs = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    assert trace_report.check_balance(evs) == []


def test_check_balance_rejects_imbalance():
    tr = Tracer(detail="spans")
    tr.begin("decode")                         # never closed
    tr.async_begin("running", 3)               # never closed
    errors = trace_report.check_balance(
        [e for e in events_to_trace_json(tr)["traceEvents"]
         if e["ph"] != "M"])
    assert len(errors) == 2
    assert any("never closed" in e for e in errors)
    assert any("left open" in e for e in errors)


def test_check_balance_rejects_bad_nesting():
    evs = [
        {"ph": "B", "name": "a", "pid": 1, "tid": 1, "ts": 0},
        {"ph": "B", "name": "b", "pid": 1, "tid": 1, "ts": 1},
        {"ph": "E", "name": "a", "pid": 1, "tid": 1, "ts": 2},
        {"ph": "E", "name": "b", "pid": 1, "tid": 1, "ts": 3},
    ]
    assert trace_report.check_balance(evs)


def test_trace_report_cli_check(tmp_path, capsys):
    path = str(tmp_path / "ok.json")
    export_perfetto(_sample_tracer(), path)
    assert trace_report.main([path, "--check"]) == 0
    bad = Tracer(detail="spans")
    bad.begin("oops")
    bad_path = str(tmp_path / "bad.json")
    export_perfetto(bad, bad_path)
    assert trace_report.main([bad_path, "--check"]) == 1
    garbage = tmp_path / "garbage.json"
    garbage.write_text("{not json")
    assert trace_report.main([str(garbage), "--check"]) == 2


def test_trace_report_prints_lag_and_states(tmp_path, capsys):
    path = str(tmp_path / "t.json")
    export_perfetto(_sample_tracer(), path)
    assert trace_report.main([path]) == 0
    out = capsys.readouterr().out
    assert "time in state per request" in out
    assert "lag   0:" in out


# --- MetricLogger sink ------------------------------------------------------


def test_metric_logger_context_manager_and_rows(tmp_path):
    path = str(tmp_path / "log.jsonl")
    with MetricLogger(path) as log:
        log.log(0, loss=1.5, note="warm")
        log.log(1, loss=1.25)
    rows = read_jsonl(path)
    assert [r["step"] for r in rows] == [0, 1]
    assert rows[0]["loss"] == 1.5 and rows[0]["note"] == "warm"
    with MetricLogger(path) as log:   # append mode: old rows survive
        log.log(2, loss=1.0)
    assert len(read_jsonl(path)) == 3


def test_metric_logger_registry_sink(tmp_path):
    reg = MetricsRegistry()
    reg.counter("tokens").inc(7)
    reg.register_producer("serve", lambda: {"swaps": 2})
    path = str(tmp_path / "reg.jsonl")
    with MetricLogger(path, registry=reg) as log:
        row = log.log_registry(5, phase="a")
    assert row["serve"] == {"swaps": 2} and row["phase"] == "a"
    on_disk = read_jsonl(path)[0]
    assert on_disk["counters"]["tokens"] == 7.0
    assert on_disk["step"] == 5
    with MetricLogger(path) as log:
        with pytest.raises(ValueError):
            log.log_registry(0)


def test_metric_logger_close_idempotent(tmp_path):
    log = MetricLogger(str(tmp_path / "x.jsonl"))
    log.log(0, a=1)
    log.close()
    log.close()                       # second close is a no-op
    log.log(1, a=2)                   # post-close writes are dropped
    assert len(read_jsonl(str(tmp_path / "x.jsonl"))) == 1
