"""Chunked ragged prefill: token-exact equivalence vs the deprecated
monolithic path (plain, prefix-cached, speculative, every chunk/budget
shape), the legacy shim's DeprecationWarning, exactly-once page and
prefix-refcount release on preemption/deadline expiry mid-chunk, and
the TTFT queue-vs-prefill histogram split."""
import warnings

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.tokenizer import get_tokenizer
from repro.metrics.runtime_metrics import collect_serve_stats
from repro.serve import ServeEngine

from repro.models.registry import build

TOK = get_tokenizer()
CFG = ModelConfig(
    name="chunked-test", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=TOK.vocab_size,
)
BUNDLE = build(CFG)
PARAMS = BUNDLE.init(jax.random.PRNGKey(0))

PROMPTS = [np.asarray(TOK.encode(p), np.int32)
           for p in ("12+345=?#", "998-76=?#", "7*8=?#")]
BUDGETS = [6, 9, 4]


def _engine(**kw):
    defaults = dict(num_blocks=64, block_size=4, max_batch=3,
                    max_seq_len=64, temperature=1e-4, seed=0)
    defaults.update(kw)
    params = defaults.pop("params", PARAMS)
    return ServeEngine(BUNDLE, params, **defaults)


def _legacy(**kw):
    with pytest.warns(DeprecationWarning):
        return _engine(chunked_prefill=False, **kw)


def _serve(eng, prompts=PROMPTS, budgets=BUDGETS):
    for i, (p, b) in enumerate(zip(prompts, budgets)):
        eng.submit(p, b, request_id=f"r{i}")
    return {t.request_id: np.asarray(t.tokens)
            for t in eng.run(max_steps=600)}


# --- token-exact equivalence (tentpole acceptance) ---------------------------


@pytest.mark.parametrize("prefill_chunk,dispatch_budget",
                         [(1, 2), (2, 3), (4, 4), (16, 32), (64, 64)])
def test_chunked_matches_monolithic_token_exact(prefill_chunk,
                                                dispatch_budget):
    """Greedy output is bit-identical across every tile/budget shape —
    including a 1-token chunk (maximal interleave) and a chunk larger
    than any prompt (single-tile prefill)."""
    want = _serve(_legacy())
    got = _serve(_engine(prefill_chunk=prefill_chunk,
                         dispatch_budget=dispatch_budget))
    assert set(want) == set(got)
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])


def test_chunked_prefix_cache_token_exact():
    """Chunked tiles re-match through the prefix cache (gated on the
    owner's tiles landing) without changing a single greedy token."""
    kw = dict(prefix_cache=True, max_batch=4)
    want = {}
    eng_legacy = _legacy(**kw)
    for i, p in enumerate(PROMPTS[:2]):
        for j in range(3):
            eng_legacy.submit(p, 8, request_id=f"r{i}.{j}")
    want = {t.request_id: np.asarray(t.tokens)
            for t in eng_legacy.run(max_steps=600)}

    eng = _engine(prefill_chunk=2, dispatch_budget=6, **kw)
    for i, p in enumerate(PROMPTS[:2]):
        for j in range(3):
            eng.submit(p, 8, request_id=f"r{i}.{j}")
    got = {t.request_id: np.asarray(t.tokens)
           for t in eng.run(max_steps=600)}
    assert set(want) == set(got)
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    assert eng.scheduler.prefix_hits > 0
    # every reference dropped exactly once on retire
    assert eng.allocator.num_free == eng.allocator.num_blocks


@pytest.mark.parametrize("chunked_kw", [
    dict(prefill_chunk=2, dispatch_budget=4),
    dict(prefill_chunk=8, dispatch_budget=16),
])
def test_chunked_speculative_token_exact(chunked_kw):
    """Speculative rounds only run once no prefill is pending, so the
    chunked engine must reproduce the legacy speculative stream."""
    kw = dict(speculate_k=3, draft=("params", PARAMS))
    want = _serve(_legacy(**kw))
    eng = _engine(**kw, **chunked_kw)
    got = _serve(eng)
    assert set(want) == set(got)
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])


def test_chunked_is_default_and_monolithic_deprecated():
    with warnings.catch_warnings():
        warnings.simplefilter("error")      # default path must not warn
        eng = _engine()
    assert eng.chunked_prefill
    with pytest.warns(DeprecationWarning, match="chunked_prefill"):
        legacy = _engine(chunked_prefill=False)
    assert not legacy.chunked_prefill


def test_prefill_dispatches_respect_budget():
    """A tight dispatch budget splits prompts into many small rounds;
    a huge one prefills each admission wave in O(1) dispatches."""
    tight = _engine(prefill_chunk=2, dispatch_budget=4)
    _serve(tight)
    loose = _engine(prefill_chunk=64, dispatch_budget=256)
    _serve(loose)
    assert tight.stats.prefill_dispatches > loose.stats.prefill_dispatches
    # both computed every prompt row exactly once
    total = sum(len(p) for p in PROMPTS)
    assert tight.stats.prefill_tokens == total
    assert loose.stats.prefill_tokens == total


# --- mid-chunk aborts: exactly-once release ----------------------------------


def _long_prompt(n=40):
    row = np.asarray(TOK.encode("123+456=?#"), np.int32)
    return np.tile(row, -(-n // len(row)))[:n]


def test_preemption_mid_chunk_releases_pages_exactly_once():
    """Preempting a request between tiles must release its pages once
    (the hardened allocator raises on double-free) and re-admission
    must reproduce the untouched engine's greedy tokens."""
    prompt = _long_prompt()
    eng = _engine(prefill_chunk=4, dispatch_budget=4, max_batch=2,
                  num_blocks=32, block_size=4, max_seq_len=64)
    req = eng.submit(prompt, 5, request_id="victim")
    eng.step()                    # admission + first tile only
    assert not req.prefill_done
    assert 0 < req.num_prefilled < len(prompt)
    eng.scheduler._preempt(req)   # mid-chunk eviction
    assert req.num_prefilled == 0 and req.blocks == []
    (traj,) = eng.run(max_steps=400)

    want = _serve(_engine(), prompts=[prompt], budgets=[5])
    np.testing.assert_array_equal(traj.tokens, want["r0"])
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_deadline_expiry_mid_chunk_releases_pages_exactly_once():
    """A deadline firing between tiles retires the half-prefilled
    request through the one retire path: pages back exactly once, a
    timeout trajectory out, and the pool fully free."""
    prompt = _long_prompt()
    eng = _engine(prefill_chunk=4, dispatch_budget=4, max_batch=2,
                  num_blocks=32, block_size=4, max_seq_len=64,
                  request_deadline_s=30.0)
    req = eng.submit(prompt, 5, request_id="late")
    eng.step()
    assert not req.prefill_done and req.num_prefilled > 0
    # jump the scheduler's clock past the deadline
    eng.scheduler._clock = lambda: req.submit_time + 31.0
    out = eng.run(max_steps=50)
    assert [t.finish_reason for t in out] == ["timeout"]
    assert eng.allocator.num_free == eng.allocator.num_blocks
    assert eng.scheduler.timeouts_by_state.get("running") == 1


def test_prefix_abort_mid_chunk_unregisters_uncomputed_pages():
    """With the prefix cache on, a mid-chunk abort must unregister the
    pages whose rows were never computed — a later identical prompt
    must not match garbage and must still produce exact tokens."""
    prompt = _long_prompt()
    eng = _engine(prefill_chunk=4, dispatch_budget=4, max_batch=2,
                  num_blocks=32, block_size=4, max_seq_len=64,
                  prefix_cache=True)
    req = eng.submit(prompt, 5, request_id="aborted")
    eng.step()
    assert not req.prefill_done
    eng.scheduler._preempt(req)
    got = {t.request_id: np.asarray(t.tokens)
           for t in eng.run(max_steps=400)}
    eng.submit(prompt, 5, request_id="retry")
    got.update({t.request_id: np.asarray(t.tokens)
                for t in eng.run(max_steps=400)})

    want = _serve(_engine(), prompts=[prompt], budgets=[5])
    np.testing.assert_array_equal(got["aborted"], want["r0"])
    np.testing.assert_array_equal(got["retry"], want["r0"])
    assert eng.allocator.num_free == eng.allocator.num_blocks


# --- TTFT decomposition (observability satellite) ----------------------------


def test_ttft_splits_into_queue_and_prefill_histograms():
    eng = _engine(prefill_chunk=2, dispatch_budget=4)
    _serve(eng)
    stats = collect_serve_stats(eng)
    n = stats["ttft_count"]
    assert n == len(PROMPTS)
    # one (queue, prefill) observation per first token, ms keys present
    assert stats["ttft_queue_count"] == n
    assert stats["ttft_prefill_count"] == n
    for key in ("ttft_queue_p50_ms", "ttft_queue_p99_ms",
                "ttft_prefill_p50_ms", "ttft_prefill_p99_ms"):
        assert stats[key] >= 0.0
    # the split decomposes the mean exactly: ttft = queue + prefill
    np.testing.assert_allclose(
        stats["ttft_mean_ms"],
        stats["ttft_queue_mean_ms"] + stats["ttft_prefill_mean_ms"],
        rtol=1e-6, atol=1e-3)
