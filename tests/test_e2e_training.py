"""End-to-end behaviour: training actually improves the objective, and the
paper's qualitative claims hold at test scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.mathgen import MathTaskDataset
from repro.data.tokenizer import get_tokenizer
from repro.models.registry import build
from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl
from repro.train.trainer_rlvr import RLVRHyperparams, RLVRTrainer


@pytest.mark.slow
@pytest.mark.flaky
def test_vaco_improves_pendulum_under_lag():
    """VACO must improve eval return on pendulum with backward lag K=4.

    Quarantined (`flaky`): the +100 margin is host-sensitive — the same
    seed clears it on some BLAS/CPU stacks and lands at ~+40 on others,
    which used to kill the whole tier-1 `-x` run before the serve and
    kernel suites even collected.  The deterministic smoke below keeps
    the qualitative claim (training improves, finite) in tier-1; this
    strict variant still runs under `-m flaky`.
    """
    res = run_async_rl(AsyncRLRunConfig(
        env_name="pendulum", algorithm="vaco", buffer_capacity=4,
        n_actors=16, rollout_steps=96, total_phases=14, seed=0))
    early = np.mean(res.returns[:2])
    late = np.mean(res.returns[-3:])
    assert late > early + 100.0, (early, late)


def test_vaco_pendulum_under_lag_improves_deterministic():
    """Seeded tier-1 replacement for the strict +100-margin variant:
    the same VACO run must improve at all (direction, not magnitude —
    robust to per-host numeric drift) and stay finite throughout."""
    res = run_async_rl(AsyncRLRunConfig(
        env_name="pendulum", algorithm="vaco", buffer_capacity=4,
        n_actors=16, rollout_steps=96, total_phases=14, seed=0))
    returns = np.asarray(res.returns, np.float64)
    assert np.isfinite(returns).all()
    early = np.mean(returns[:2])
    late = np.mean(returns[-3:])
    assert late > early, (early, late)


@pytest.mark.slow
def test_vaco_tv_respects_constraint():
    """Final-policy TV stays at/below delta/2 within tolerance (Fig. 11)."""
    res = run_async_rl(AsyncRLRunConfig(
        env_name="pendulum", algorithm="vaco", buffer_capacity=8,
        n_actors=8, rollout_steps=64, total_phases=8, seed=0))
    assert res.final_tv < 0.2 / 2.0 + 0.05


@pytest.mark.slow
def test_rlvr_warmup_reaches_nontrivial_accuracy():
    tok = get_tokenizer()
    cfg = ModelConfig(
        name="e2e-rlvr", arch_type="dense", n_layers=2, d_model=96,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab_size=tok.vocab_size,
        tie_embeddings=True, value_head=False)
    ds = MathTaskDataset(prompt_len=16, level=0, pool_size=1024)
    hp = RLVRHyperparams(algorithm="grpo_vaco", n_minibatches=2,
                         prompts_per_minibatch=8, completions_per_prompt=4,
                         max_new_tokens=6, warmup_steps=80)
    tr = RLVRTrainer(build(cfg), ds, hp, seed=0)
    tr.warmup()
    acc = tr.evaluate(128)
    assert acc > 0.3, acc
    # one RL phase must keep params finite and produce staleness-ordered TV
    logs = tr.train_phase()
    tvs = [l.tv for l in logs]
    assert all(np.isfinite(tvs))
    assert tvs[0] <= tvs[-1] + 1e-3  # forward lag grows TV within a phase


def test_checkpoint_resume_bitexact():
    """Save/restore mid-training resumes to identical parameters."""
    from repro.checkpoint import load_checkpoint, save_checkpoint
    from repro.train.trainer_rl import (
        RLHyperparams, init_train_state, make_train_phase)
    from repro.envs import make_pendulum, wrap_autoreset
    from repro.models.mlp_policy import act, mlp_policy_init
    from repro.rollout.async_engine import SimulatedAsyncActors
    import tempfile

    env = wrap_autoreset(make_pendulum())
    params = mlp_policy_init(jax.random.PRNGKey(0), env.obs_dim,
                             env.act_dim)
    state = init_train_state(params)
    actors = SimulatedAsyncActors(env, act, params, n_actors=4,
                                  buffer_capacity=2, rollout_steps=32,
                                  seed=0)
    phase = make_train_phase(RLHyperparams(num_minibatches=4,
                                           num_epochs=2))
    batch, _ = actors.collect()
    state, _ = phase(state, batch, jax.random.PRNGKey(1))

    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 1, state.params)
        restored, step, _ = load_checkpoint(path, state.params)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
