"""Loss-layer semantics: PPO clipping, SPO penalty, GRPO groups, VACO
gradient behaviour, IMPALA estimator wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import (
    GRPOConfig,
    IMPALAConfig,
    PPOConfig,
    SPOConfig,
    VACOConfig,
    group_advantages,
    grpo_token_loss,
    impala_total_loss,
    ppo_policy_loss,
    spo_total_loss,
    vaco_policy_loss,
    vaco_total_loss,
)

KEY = jax.random.PRNGKey(0)


def test_ppo_clip_zeroes_gradient_outside_range():
    """Samples with ratio beyond 1+clip and positive advantage contribute
    no gradient."""
    log_beta = jnp.zeros((4,))
    adv = jnp.ones((4,))
    cfg = PPOConfig(clip_low=0.2, clip_high=0.2)

    def loss(log_pi):
        l, _ = ppo_policy_loss(log_pi=log_pi, log_beta=log_beta,
                               advantages=adv, cfg=cfg)
        return l

    log_pi = jnp.asarray([0.0, 0.1, 0.5, 1.0])  # ratios 1, 1.1, 1.65, 2.7
    g = jax.grad(loss)(log_pi)
    assert g[0] != 0.0 and g[1] != 0.0
    assert g[2] == 0.0 and g[3] == 0.0


def test_ppo_asymmetric_clip():
    """DAPO clip-higher: ratio 1.25 is NOT clipped with clip_high=0.272
    but IS with clip_high=0.2."""
    log_pi = jnp.asarray([jnp.log(1.25)])
    adv = jnp.ones((1,))

    def grad_for(high):
        cfg = PPOConfig(clip_low=0.2, clip_high=high)
        return jax.grad(lambda lp: ppo_policy_loss(
            log_pi=lp, log_beta=jnp.zeros((1,)), advantages=adv,
            cfg=cfg)[0])(log_pi)

    assert float(grad_for(0.272)[0]) != 0.0
    assert float(grad_for(0.2)[0]) == 0.0


def test_spo_penalty_pulls_ratio_to_one():
    log_pi = jnp.asarray([0.5, -0.5])
    cfg = SPOConfig(penalty_coef=100.0)
    g = jax.grad(lambda lp: spo_total_loss(
        log_pi=lp, log_beta=jnp.zeros((2,)),
        advantages=jnp.zeros((2,)), values=jnp.zeros((2,)),
        value_targets=jnp.zeros((2,)), entropy=jnp.zeros((2,)),
        cfg=cfg)[0])(log_pi)
    # gradient descent (-g) moves log-ratios toward 0
    assert float(g[0]) > 0.0 and float(g[1]) < 0.0


def test_group_advantages_zero_mean_per_group():
    rewards = jnp.asarray([1.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 0.0])
    adv = group_advantages(rewards, group_size=4)
    a = np.asarray(adv).reshape(2, 4)
    np.testing.assert_allclose(a.mean(axis=1), 0.0, atol=1e-6)
    # all-same-reward group gets ~zero advantage (std -> eps)
    adv2 = group_advantages(jnp.ones((4,)), group_size=4)
    np.testing.assert_allclose(np.asarray(adv2), 0.0, atol=1e-4)


def test_grpo_token_loss_switches_mechanism():
    log_pi = 0.4 * jax.random.normal(KEY, (2, 8))
    log_beta = jnp.zeros((2, 8))
    adv = jnp.asarray([1.0, -1.0])
    mask = jnp.ones((2, 8))
    _, aux_clip = grpo_token_loss(
        log_pi=log_pi, log_beta=log_beta, advantages=adv, token_mask=mask,
        cfg=GRPOConfig(use_vaco=False))
    assert "clip_frac" in aux_clip
    _, aux_vaco = grpo_token_loss(
        log_pi=log_pi, log_beta=log_beta, advantages=adv, token_mask=mask,
        cfg=GRPOConfig(use_vaco=True, delta=0.01))
    assert "frac_filtered" in aux_vaco


def test_vaco_respects_token_mask():
    """Masked (padding) tokens contribute neither loss nor gradient."""
    log_beta = jnp.zeros((8,))
    adv = jnp.ones((8,))
    mask = jnp.asarray([1.0] * 4 + [0.0] * 4)
    cfg = VACOConfig(delta=1e9)

    def loss(log_pi):
        l, _ = vaco_policy_loss(log_pi=log_pi, log_beta=log_beta,
                                advantages=adv, cfg=cfg, valid_mask=mask)
        return l

    lp = 0.3 * jax.random.normal(KEY, (8,))
    g = jax.grad(loss)(lp)
    assert bool(jnp.all(g[4:] == 0.0))
    assert bool(jnp.any(g[:4] != 0.0))


def test_vaco_total_loss_trains_value_head():
    values = jnp.asarray([0.0, 1.0])
    targets = jnp.asarray([1.0, 1.0])
    loss, aux = vaco_total_loss(
        log_pi=jnp.zeros((2,)), log_beta=jnp.zeros((2,)),
        advantages=jnp.zeros((2,)), values=values, value_targets=targets,
        cfg=VACOConfig())
    np.testing.assert_allclose(float(aux["value_loss"]), 0.25, rtol=1e-6)


def test_impala_loss_is_plain_pg():
    """IMPALA policy loss gradient == -E[pg_adv * grad log_pi]."""
    lp = 0.2 * jax.random.normal(KEY, (16,))
    pg_adv = jax.random.normal(jax.random.PRNGKey(1), (16,))

    g = jax.grad(lambda x: impala_total_loss(
        log_pi=x, log_beta=jnp.zeros((16,)), pg_advantages=pg_adv,
        values=jnp.zeros((16,)), value_targets=jnp.zeros((16,)),
        entropy=jnp.zeros((16,)), cfg=IMPALAConfig(value_coef=0.0))[0])(lp)
    np.testing.assert_allclose(np.asarray(g), -np.asarray(pg_adv) / 16,
                               rtol=1e-5, atol=1e-6)


def test_filter_vs_clip_distinct_behaviour_under_lag():
    """Fig. 5's mechanism contrast: at small TV, PPO already clips some
    samples while VACO filters none; at large TV, VACO filters a sizable
    fraction."""
    k1, k2 = jax.random.split(KEY)
    adv = jax.random.normal(k2, (1, 512))
    mask = jnp.ones((1, 512))
    zeros = jnp.zeros((1, 512))

    # Mild lag (TV ~ 0.06 < delta/2 = 0.1): a heavy-tailed ratio spread
    # already trips PPO's clip on outliers, while VACO filters nothing.
    mild = 0.15 * jax.random.normal(k1, (1, 512))
    _, aux_v = grpo_token_loss(
        log_pi=mild, log_beta=zeros, advantages=adv,
        token_mask=mask, cfg=GRPOConfig(use_vaco=True, delta=0.2))
    _, aux_p = grpo_token_loss(
        log_pi=mild, log_beta=zeros, advantages=adv,
        token_mask=mask, cfg=GRPOConfig(use_vaco=False))
    assert float(aux_v["frac_filtered"]) == 0.0
    assert float(aux_p["clip_frac"]) > 0.0

    big = 0.8 * jax.random.normal(k1, (1, 512))    # heavy lag
    _, aux_v2 = grpo_token_loss(
        log_pi=big, log_beta=zeros, advantages=adv,
        token_mask=mask, cfg=GRPOConfig(use_vaco=True, delta=0.2))
    assert float(aux_v2["frac_filtered"]) > 0.2
