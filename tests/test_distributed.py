"""Distribution-layer tests: sharding rules, HLO collective parser, and a
tiny-mesh pjit of the real train/serve steps on the host's devices.

(These run with 1 CPU device — mesh (1,1) — so they validate the
*plumbing*: spec construction, divisibility fallbacks, lowering of the
sharded step functions.  The production 16x16 / 2x16x16 lowering proof is
launch/dryrun.py, exercised separately because it needs
xla_force_host_platform_device_count=512 before jax init.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import INPUT_SHAPES, reduced_config
from repro.configs.base import InputShape
from repro.distributed.sharding import (
    ShardingPolicy,
    batch_sharding,
    cache_shardings,
    paged_pool_sharding,
    param_spec,
    params_shardings,
    shard_paged_pool,
)
from repro.launch.mesh import make_debug_mesh
from repro.models.registry import build
from repro.utils.hlo import collective_bytes


# --- param_spec rules --------------------------------------------------------


def _mesh16():
    """Abstract 16x16 mesh over fake devices (no allocation: specs only)."""
    devs = np.array(jax.devices() * 256)[:256].reshape(16, 16)
    return Mesh(devs, ("data", "model"))


def test_param_spec_shards_largest_divisible_dim():
    mesh = _mesh16()
    pol = ShardingPolicy()
    # [5120, 13824]: both divisible, largest (13824) gets 'model'.
    spec = param_spec("['mlp']['up']['w']", (5120, 13824), mesh, pol)
    assert spec == P(None, "model")
    # hymba-style odd head count folded into 1600: divisible.
    spec = param_spec("['attn']['wq']['w']", (1600, 1600), mesh, pol)
    assert spec == P("model", None)
    # indivisible everything -> replicate.
    spec = param_spec("['x']['w']", (25, 7), mesh, pol)
    assert spec == P()


def test_param_spec_skips_stacked_layer_axis():
    mesh = _mesh16()
    spec = param_spec("['layers']['mlp']['w']", (48, 5120, 13824), mesh,
                      ShardingPolicy())
    assert spec[0] is None  # the scan axis is never sharded


def test_param_spec_expert_parallel():
    mesh = _mesh16()
    spec = param_spec("['layers']['moe']['gate_w']", (61, 384, 7168, 2048),
                      mesh, ShardingPolicy())
    assert spec == P(None, "model", None, None)  # expert axis


def test_param_spec_tensor_mode():
    mesh = _mesh16()
    pol = ShardingPolicy(weight_mode="tensor")
    up = param_spec("['mlp']['up']['w']", (5120, 13824), mesh, pol)
    down = param_spec("['mlp']['down']['w']", (13824, 5120), mesh, pol)
    assert up == P(None, "model")    # column-parallel
    assert down == P("model", None)  # row-parallel


def test_batch_sharding_fallbacks():
    mesh = _mesh16()
    assert batch_sharding(mesh, 256, 2).spec == P("data", None)
    assert batch_sharding(mesh, 1, 2).spec == P(None, None)


def test_cache_shardings_seq_vs_batch():
    mesh = _mesh16()
    cache = {
        "pos": jax.ShapeDtypeStruct((128,), jnp.int32),
        "k": jax.ShapeDtypeStruct((32, 128, 32768, 8, 128), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((32, 128, 32768, 8, 128), jnp.bfloat16),
    }
    sh = cache_shardings(cache, mesh, shard_seq=False)
    assert sh["k"].spec == P(None, "data", None, None, None)
    long_cache = {
        "pos": jax.ShapeDtypeStruct((1,), jnp.int32),
        "k": jax.ShapeDtypeStruct((32, 1, 524288, 8, 128), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((32, 1, 524288, 8, 128), jnp.bfloat16),
    }
    sh = cache_shardings(long_cache, mesh, shard_seq=True)
    assert sh["k"].spec == P(None, None, "data", None, None)


def test_paged_pool_sharding_spec():
    """The serve pool shards its NB (page) axis over 'data' — the axis
    PR 2's [L, KV, NB, BS, Dh] layout was chosen to split on."""
    mesh = _mesh16()
    assert paged_pool_sharding(mesh).spec == P(
        None, None, "data", None, None)
    pool = {"k_pages": jnp.zeros((2, 2, 8, 4, 8)),
            "v_pages": jnp.zeros((2, 2, 8, 4, 8))}
    assert shard_paged_pool(pool, None) is pool   # mesh=None: identity


# --- HLO collective parser ---------------------------------------------------


def test_collective_parser_counts_known_ops():
    hlo = """
  %ag = bf16[8,128,512]{2,1,0} all-gather(%x), replica_groups={}
  %ar = f32[256]{0} all-reduce(%y), to_apply=%add
  %rs = f32[16,32]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = bf16[4,64]{1,0} all-to-all(%w), dimensions={0}
  %cp = f32[8]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %ag2 = bf16[2,2]{1,0} all-gather-start(%q)
"""
    stats = collective_bytes(hlo)
    assert stats.count_by_kind["all-gather"] == 1  # -start excluded
    assert stats.bytes_by_kind["all-gather"] == 8 * 128 * 512 * 2
    assert stats.bytes_by_kind["all-reduce"] == 256 * 4
    assert stats.bytes_by_kind["reduce-scatter"] == 16 * 32 * 4
    assert stats.bytes_by_kind["all-to-all"] == 4 * 64 * 2
    assert stats.bytes_by_kind["collective-permute"] == 8 * 4
    assert stats.total_count == 5


def test_collective_parser_on_real_lowering():
    """An actually-sharded matmul must show an all-reduce in its HLO."""
    mesh = _mesh16()
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 256), jnp.float32)
    from jax.sharding import NamedSharding

    with mesh:
        comp = jax.jit(
            lambda x, y: x @ y,
            in_shardings=(NamedSharding(mesh, P(None, "model")),
                          NamedSharding(mesh, P("model", None))),
        ).lower(a, b).compile()
    stats = collective_bytes(comp.as_text())
    assert stats.total_count >= 1
    assert stats.total_bytes > 0


# --- tiny-mesh end-to-end lowering ------------------------------------------


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "kimi-k2-1t-a32b",
                                  "rwkv6-1.6b"])
def test_reduced_train_step_lowers_on_debug_mesh(arch):
    from repro.launch import steps as steps_mod

    cfg = reduced_config(arch)
    bundle = build(cfg)
    mesh = make_debug_mesh()
    shape = InputShape("tiny_train", seq_len=32, global_batch=4,
                       kind="train")
    params_abs = steps_mod.abstract_params(bundle, dtype=jnp.float32)
    opt_abs = steps_mod.abstract_opt_state(params_abs)
    batch = steps_mod.train_batch_specs(bundle, shape, prompt_len=16)
    step = steps_mod.make_train_step(bundle, prompt_len=16)
    with mesh:
        compiled = jax.jit(step).lower(params_abs, opt_abs, batch).compile()
    assert compiled.cost_analysis() is not None
