"""Fault injection & supervision: plan grammar, deterministic firing,
publish quarantine, seeded backoff, watchdog restarts with measured
restart provenance, request-deadline expiry without double-release, and
the graceful-degradation paths (admission fallback, spec auto-disable,
signal-flush handlers)."""
import signal
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs.registry import MetricsRegistry
from repro.resilience import (
    BackoffPolicy,
    FaultInjector,
    Heartbeat,
    InjectedFault,
    NULL_INJECTOR,
    RestartContext,
    SupervisionError,
    install_flush_handlers,
    parse_fault_plan,
    supervise,
    tree_all_finite,
)
from repro.runtime import (
    PolicyStore,
    QuarantinedVersionError,
    TrajectoryQueue,
    make_regime,
)
from repro.runtime.admission import AdmissionPolicy
from repro.serve import (
    BlockAllocator,
    ContinuousBatchingScheduler,
    Request,
    RequestState,
    ServeEngine,
)


def _params(v: float):
    return {"w": jnp.full((2,), float(v))}


# --- fault plan grammar -----------------------------------------------------


def test_parse_fault_plan_grammar():
    events = parse_fault_plan(
        "producer_crash:at_step=2;stall:slot=0,ms=200,count=3;"
        "nan_publish:at_publish=3,p=0.5")
    assert [e.kind for e in events] == [
        "producer_crash", "stall", "nan_publish"]
    assert events[0].params == {"at_step": 2}
    assert events[1].count == 3 and events[1].params["ms"] == 200
    assert events[2].p == 0.5
    assert parse_fault_plan("") == [] and parse_fault_plan(None) == []
    # list-of-chunks form (launcher flags pass lists)
    assert len(parse_fault_plan(["stall:ms=1", "stall:ms=2;stall:ms=3"])) == 3


def test_parse_fault_plan_rejects_bad_specs():
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_fault_plan("meteor_strike:at_step=1")
    with pytest.raises(ValueError, match="unknown option"):
        parse_fault_plan("producer_crash:at_publish=1")
    with pytest.raises(ValueError, match="key=value"):
        parse_fault_plan("stall:ms")


def test_injector_matching_and_exhaustion():
    reg = MetricsRegistry()
    inj = FaultInjector("producer_crash:at_step=2", registry=reg)
    assert inj.active and not NULL_INJECTOR.active
    inj.crash_if("producer", at_step=0)        # no match
    inj.crash_if("publish", at_step=2)         # wrong site
    with pytest.raises(InjectedFault):
        inj.crash_if("producer", at_step=2)
    inj.crash_if("producer", at_step=2)        # count=1: exhausted
    assert inj.fired_counts() == {"producer_crash": 1}
    assert reg.counter_values("fault_injected_total") == {
        "fault_injected_total{kind=producer_crash,site=producer}": 1.0}


def test_injector_missing_context_key_never_wildcards():
    inj = FaultInjector("stall:slot=3,ms=50", sleep=lambda s: None)
    # engine reports at_step but not slot -> must not fire
    assert inj.stall("engine_step", at_step=3) == 0.0
    assert inj.stall("engine_step", slot=3) == 0.05


def test_injector_probabilistic_firing_is_seed_deterministic():
    plan = "queue_stall:ms=1,p=0.5,count=100"

    def fired(seed):
        inj = FaultInjector(plan, seed=seed, sleep=lambda s: None)
        for call in range(40):
            inj.stall("queue_get", at_call=call)
        return inj.fired_counts().get("queue_stall", 0)

    a, b, c = fired(0), fired(0), fired(1)
    assert a == b                      # same seed -> identical replay
    assert 0 < a < 40                  # actually probabilistic
    assert c != a                      # seed moves the draw


def test_injector_poison_nans_first_leaf_only():
    inj = FaultInjector("learner_nan:at_step=7")
    params = {"a": jnp.ones((2,)), "b": jnp.ones((3,))}
    out, poisoned = inj.poison("learner_step", params, at_step=1)
    assert not poisoned and out is params
    out, poisoned = inj.poison("learner_step", params, at_step=7)
    assert poisoned
    assert not tree_all_finite(out)
    assert bool(jnp.all(jnp.isfinite(out["b"])))


# --- publish quarantine -----------------------------------------------------


def test_nan_publish_quarantined_never_served():
    reg = MetricsRegistry()
    inj = FaultInjector("nan_publish:at_publish=2", registry=reg)
    store = PolicyStore(_params(0.0), capacity=4, injector=inj,
                        guard_finite=True, registry=reg)
    assert store.publish(_params(1.0)) == 1
    poisoned_v = store.publish(_params(2.0))   # injector NaNs this one
    assert poisoned_v == 2                      # version still consumed
    assert store.quarantined_versions() == [2]
    assert store.meta(2).meta["quarantined"] is True
    # latest()/resolve_lagged() skip it; get() refuses it
    params, v = store.latest()
    assert v == 1
    np.testing.assert_allclose(np.asarray(params["w"]), 1.0)
    assert store.resolve_lagged(0) == 1
    with pytest.raises(QuarantinedVersionError):
        store.get(2)
    assert 2 not in store.retained_versions()
    assert store.publish(_params(3.0)) == 3     # recovery: next one serves
    assert store.latest()[1] == 3
    assert reg.counter_values("publish_quarantined_total") == {
        "publish_quarantined_total": 1.0}


def test_posthoc_quarantine_guards_reads():
    store = PolicyStore(_params(0.0), capacity=4, guard_finite=True)
    store.publish(_params(1.0))
    store.publish(_params(2.0))
    store.quarantine(2)
    assert store.latest()[1] == 1
    with pytest.raises(QuarantinedVersionError):
        store.get(2)
    with pytest.raises(KeyError):
        store.quarantine(99)                    # never published


def test_guard_finite_catches_organic_nans():
    store = PolicyStore(_params(0.0), capacity=4, guard_finite=True)
    v = store.publish({"w": jnp.array([1.0, jnp.nan])})
    assert store.quarantined_versions() == [v]
    assert store.latest()[1] == 0


# --- backoff + supervision --------------------------------------------------


def test_backoff_schedule_is_seed_deterministic_and_bounded():
    p = BackoffPolicy(base_ms=50, factor=2.0, max_ms=130, jitter=0.25,
                      max_restarts=4, seed=7)
    s1, s2 = p.schedule(), p.schedule()
    assert s1 == s2 and len(s1) == 4
    same = BackoffPolicy(base_ms=50, factor=2.0, max_ms=130, jitter=0.25,
                         max_restarts=4, seed=7)
    assert same.schedule() == s1                # pure function of fields
    other = BackoffPolicy(base_ms=50, factor=2.0, max_ms=130, jitter=0.25,
                          max_restarts=4, seed=8)
    assert other.schedule() != s1
    for i, d in enumerate(s1):
        base = min(130.0, 50.0 * 2.0 ** i) / 1e3
        assert base <= d <= base * 1.25         # jitter only inflates
    assert isinstance(s1[0], float)


def test_supervise_restarts_then_succeeds():
    reg = MetricsRegistry()
    attempts = []

    def run(ctx: RestartContext):
        attempts.append(ctx.attempt)
        if ctx.attempt < 2:
            raise RuntimeError(f"boom {ctx.attempt}")

    policy = BackoffPolicy(base_ms=1, max_ms=2, max_restarts=3, seed=0)
    restarts = supervise(run, policy=policy, name="p0", registry=reg)
    assert restarts == 2 and attempts == [0, 1, 2]
    assert reg.counter_values("watchdog_restart_total") == {
        "watchdog_restart_total{producer=p0}": 2.0}


def test_supervise_budget_exhaustion_raises():
    def run(ctx):
        raise ValueError("always")

    policy = BackoffPolicy(base_ms=1, max_ms=1, max_restarts=2, seed=0)
    with pytest.raises(SupervisionError) as ei:
        supervise(run, policy=policy, name="p1")
    assert ei.value.restarts == 2
    assert isinstance(ei.value.last_error, ValueError)


def test_supervise_clean_exits_do_not_consume_restarts():
    class Done(Exception):
        pass

    def run(ctx):
        raise Done()

    restarts = supervise(
        run, policy=BackoffPolicy(base_ms=1, max_restarts=3),
        clean_exits=(Done,))
    assert restarts == 0


def test_heartbeat_staleness_with_fake_clock():
    now = [0.0]
    hb = Heartbeat(timeout_s=1.0, clock=lambda: now[0])
    assert not hb.stale()
    now[0] = 2.0
    assert hb.stale()
    hb.beat()
    assert not hb.stale() and hb.beats == 1


# --- restart provenance through the threaded regime -------------------------


def test_threaded_regime_restart_provenance_and_lag_spike():
    """A crashed-and-restarted producer's first admitted batch carries
    restart provenance and the outage's lag spike, measured at
    admission (restart_admitted_total) rather than bypassing it."""
    reg = MetricsRegistry()
    inj = FaultInjector("producer_crash:at_step=2", registry=reg)
    store = PolicyStore(_params(0.0), capacity=8)
    queue = TrajectoryQueue(maxsize=1, registry=reg, injector=inj)
    regime = make_regime(
        "threaded", store, queue,
        lambda params: float(params["w"][0]),
        max_items=4, injector=inj,
        supervisor=BackoffPolicy(base_ms=250, jitter=0.0, max_restarts=2,
                                 seed=0))
    regime.start()
    try:
        first = queue.get(learner_version=store.version, timeout=30.0)
        assert first is not None and "restart" not in first.meta
        # The crash fires entering iteration 3 (produced == 2).  Wait for
        # the watchdog to log it, then publish during the 250 ms backoff:
        # the restarted producer's first batch must span the outage.
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            if reg.counter_values("watchdog_restart_total"):
                break
            time.sleep(0.005)
        assert reg.counter_values("watchdog_restart_total") == {
            "watchdog_restart_total{producer=threaded}": 1.0}
        second = queue.get(learner_version=store.version, timeout=30.0)
        assert second is not None and "restart" not in second.meta
        for v in (1.0, 2.0, 3.0):
            store.publish(_params(v))
        third = queue.get(learner_version=store.version, timeout=30.0)
        assert third is not None
        assert third.meta["restart"] is True
        assert third.meta["restart_attempt"] == 1
        # Oldest spans back to the pre-crash pin -> the full outage lag.
        assert third.lag_oldest >= 3
        assert third.lag_newest <= third.lag_oldest
        assert reg.counter_values("restart_admitted_total") == {
            "restart_admitted_total": 1.0}
        assert queue.get(learner_version=store.version, timeout=30.0) \
            is not None                       # 4th item: stream completes
    finally:
        regime.stop()
    assert regime.restarts == 1
    assert inj.fired_counts() == {"producer_crash": 1}


def test_threaded_regime_restart_budget_exhaustion_surfaces():
    inj = FaultInjector("producer_crash:at_step=0,count=10")
    store = PolicyStore(_params(0.0), capacity=2)
    queue = TrajectoryQueue()
    regime = make_regime(
        "threaded", store, queue, lambda p: 0.0, max_items=4,
        injector=inj,
        supervisor=BackoffPolicy(base_ms=1, max_ms=2, max_restarts=2,
                                 seed=0))
    regime.start()
    try:
        with pytest.raises(RuntimeError, match="producer crashed"):
            # Budget exhausted -> SupervisionError surfaces on the
            # consumer side instead of a silent hang.
            regime.next_item(store.version, timeout=30.0)
        assert isinstance(regime.error, SupervisionError)
    finally:
        regime.stop()


# --- request deadlines + double-release hardening ---------------------------


def _sched(num_blocks=8, block_size=4, max_batch=2, **kw):
    return ContinuousBatchingScheduler(
        BlockAllocator(num_blocks, block_size),
        max_batch=max_batch, max_blocks_per_request=8, **kw)


def test_scheduler_deadline_expiry_releases_pages():
    now = [0.0]
    reg = MetricsRegistry()
    s = _sched(request_deadline_s=2.0, clock=lambda: now[0], registry=reg)
    r_run = Request(prompt=np.zeros((6,), np.int32), max_new_tokens=4)
    r_wait = Request(prompt=np.zeros((6,), np.int32), max_new_tokens=4)
    r_slow = Request(prompt=np.zeros((6,), np.int32), max_new_tokens=4,
                     deadline_s=9.0)    # per-request override
    for r in (r_run, r_slow, r_wait):   # FIFO: r_run + r_slow get the
        s.submit(r)                     # 2 slots, r_wait stays queued
        r.submit_time = now[0]
    s.schedule()
    assert s.allocator.num_free < s.allocator.num_blocks
    assert s.expire() == []             # within budget
    now[0] = 3.0
    expired = s.expire()
    assert set(expired) == {r_run, r_wait}
    assert r_slow.state is not RequestState.FINISHED   # its budget is 9 s
    assert r_run.finish_reason == "timeout"
    assert s.timeouts == 2
    assert s.timeouts_by_state == {"running": 1, "waiting": 1}
    assert reg.counter_values("request_timeout_total") == {
        "request_timeout_total{state=running}": 1.0,
        "request_timeout_total{state=waiting}": 1.0,
    }
    s.retire(r_slow, "eos")
    assert s.allocator.num_free == s.allocator.num_blocks  # nothing leaked


def test_scheduler_timeout_preemption_race_releases_once():
    """A deadline retirement racing a preemption (or a second retire)
    must release pages exactly once — the regression the FINISHED
    guards exist for."""
    now = [0.0]
    s = _sched(request_deadline_s=1.0, clock=lambda: now[0])
    r = Request(prompt=np.zeros((6,), np.int32), max_new_tokens=4)
    s.submit(r)
    r.submit_time = 0.0
    s.schedule()
    held = s.allocator.num_blocks - s.allocator.num_free
    assert held > 0
    now[0] = 5.0
    assert s.expire() == [r]
    free_after = s.allocator.num_free
    assert free_after == s.allocator.num_blocks
    # the races: preempt-after-timeout and retire-after-retire
    s._preempt(r)
    s.retire(r, "eos")
    assert s.allocator.num_free == free_after      # no double release
    assert r.finish_reason == "timeout"            # first retirement wins
    assert r.state is RequestState.FINISHED
    assert s.expire() == []                        # FINISHED never re-expires


# --- graceful degradation ---------------------------------------------------


class _RaisingAdmission(AdmissionPolicy):
    name = "raising"

    def admit(self, item):
        raise RuntimeError("controller bug")


def test_queue_admission_fallback_on_raising_controller():
    reg = MetricsRegistry()
    q = TrajectoryQueue(admission=_RaisingAdmission(), registry=reg,
                        fallback_max_lag=2)
    for v in (0, 7):
        q.put(f"p{v}", behavior_version=v, learner_version=8)
    with pytest.warns(RuntimeWarning, match="falling back to max_lag:2"):
        item = q.get(learner_version=8, timeout=1.0)
    # fallback admission: lag-8 item dropped, lag-1 item admitted
    assert item is not None and item.behavior_version == 7
    counters = reg.counter_values("admission_fallback_total")
    assert counters == {
        "admission_fallback_total{controller=raising}": 2.0}
    assert q.stats().dropped == 1


def test_spec_autodisable_after_repeated_all_reject():
    eng = ServeEngine.__new__(ServeEngine)    # unit-test the policy alone
    eng.speculate_k = 4
    eng.spec_disable_after = 3
    eng.spec_disabled = False
    eng._all_reject_rounds = 0
    eng.stats = type("S", (), {"spec_autodisables": 0})()
    eng.metrics = MetricsRegistry()
    from repro.obs.tracer import NULL_TRACER
    eng.tracer = NULL_TRACER
    eng._note_spec_round(accepted=0, n_active=2)
    eng._note_spec_round(accepted=3, n_active=2)   # a hit resets the run
    for _ in range(3):
        eng._note_spec_round(accepted=0, n_active=2)
    assert eng.spec_disabled and eng._spec_k_active == 0
    assert eng.stats.spec_autodisables == 1
    eng._note_spec_round(accepted=0, n_active=2)   # latched: counted once
    assert eng.metrics.counter_values("spec_autodisable_total") == {
        "spec_autodisable_total": 1.0}
    eng._note_spec_round(accepted=0, n_active=0)   # idle rounds ignored


def test_install_flush_handlers_one_shot():
    flushed = []
    prev = install_flush_handlers(flushed.append, signals=(signal.SIGTERM,))
    try:
        with pytest.raises(SystemExit) as ei:
            signal.raise_signal(signal.SIGTERM)
        assert ei.value.code == 128 + signal.SIGTERM
        assert flushed == [signal.SIGTERM]
        # one-shot: the previous disposition is already back
        assert signal.getsignal(signal.SIGTERM) is prev[signal.SIGTERM]
    finally:
        for sig, handler in prev.items():
            signal.signal(sig, handler)


# --- resilience stats plumbing ----------------------------------------------


def test_collect_resilience_stats_rollup():
    from repro.metrics.runtime_metrics import collect_resilience_stats

    reg = MetricsRegistry()
    inj = FaultInjector("nan_publish:at_publish=1", registry=reg)
    store = PolicyStore(_params(0.0), capacity=2, injector=inj,
                        guard_finite=True, registry=reg)
    store.publish(_params(1.0))
    stats = collect_resilience_stats(reg, store=store, injector=inj)
    assert stats["quarantined_versions"] == [1]
    assert stats["faults_fired"] == {"nan_publish": 1}
    assert stats["counters"][
        "fault_injected_total{kind=nan_publish,site=publish}"] == 1.0
    assert stats["counters"]["publish_quarantined_total"] == 1.0


def test_counter_values_never_invokes_producers():
    reg = MetricsRegistry()
    reg.register_producer(
        "recursive", lambda: {"boom": reg.counter_values()})
    reg.counter("a_total").inc()
    assert reg.counter_values("a_total") == {"a_total": 1.0}
