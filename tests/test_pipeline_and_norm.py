"""Tests for the packing pipeline, prefetcher, obs/reward normalization,
and the TIS baseline loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.losses import TISConfig, tis_token_loss
from repro.data.mathgen import MathTaskDataset
from repro.data.pipeline import (
    PackedBatch,
    Prefetcher,
    pack_examples,
    packed_warmup_batches,
)
from repro.envs.normalize import (
    normalize,
    reward_norm_init,
    reward_norm_update,
    stat_init,
    stat_update,
)


# --- packing ----------------------------------------------------------------


def test_pack_examples_no_overlap_and_masks():
    examples = [([1, 2, 3], [4, 5]), ([6, 7], [8]), ([9], [10, 11, 12])]
    pb = pack_examples(examples, batch=2, length=8, pad_id=0)
    assert pb.n_examples == 3
    # every packed example's tokens appear contiguously with its seg id
    segs = set(np.unique(pb.segment_ids)) - {0}
    assert segs == {1, 2, 3}
    # loss mask only on answer positions
    assert pb.loss_mask.sum() == 2 + 1 + 3
    # mask implies non-padding
    assert ((pb.loss_mask > 0) <= (pb.segment_ids > 0)).all()


def test_pack_examples_skips_oversized():
    pb = pack_examples([(list(range(20)), [1])], batch=1, length=8)
    assert pb.n_examples == 0


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6), batch=st.integers(1, 4),
       length=st.integers(8, 64))
def test_pack_examples_properties(seed, batch, length):
    rng = np.random.default_rng(seed)
    examples = []
    for _ in range(rng.integers(1, 12)):
        lp = int(rng.integers(1, 10))
        la = int(rng.integers(1, 5))
        examples.append((list(rng.integers(1, 50, lp)),
                         list(rng.integers(1, 50, la))))
    pb = pack_examples(examples, batch, length)
    # padding is exactly where segment_ids == 0
    assert ((pb.tokens == 0) == (pb.segment_ids == 0)).all()
    # segments are row-local and contiguous
    for r in range(batch):
        row = pb.segment_ids[r]
        nz = row[row > 0]
        if nz.size:
            # contiguity: each segment id occupies one run
            changes = np.sum(np.diff(nz) != 0)
            assert changes == len(np.unique(nz)) - 1


def test_packed_warmup_batches_stream():
    ds = MathTaskDataset(prompt_len=24, level=0, pool_size=128)
    batches = list(packed_warmup_batches(ds, batch=2, length=64, steps=3))
    assert len(batches) == 3
    for pb in batches:
        assert pb.tokens.shape == (2, 64)
        assert pb.n_examples > 2  # packing actually packs


def test_prefetcher_preserves_order_and_errors():
    assert list(Prefetcher(iter(range(10)))) == list(range(10))

    def boom():
        yield 1
        raise ValueError("boom")

    it = Prefetcher(boom())
    assert next(it) == 1
    with pytest.raises(ValueError):
        list(it)


# --- normalization -----------------------------------------------------------


def test_running_stat_matches_numpy():
    rng = np.random.default_rng(0)
    data = rng.normal(3.0, 2.0, size=(1000, 4)).astype(np.float32)
    stat = stat_init(4)
    for chunk in np.split(data, 10):
        stat = stat_update(stat, jnp.asarray(chunk))
    np.testing.assert_allclose(np.asarray(stat.mean), data.mean(axis=0),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(stat.var), data.var(axis=0),
                               rtol=1e-2, atol=1e-2)
    normed = normalize(stat, jnp.asarray(data))
    assert abs(float(jnp.mean(normed))) < 0.05
    assert abs(float(jnp.std(normed)) - 1.0) < 0.05


def test_reward_norm_scales_and_resets():
    state = reward_norm_init(4)
    rewards = jnp.ones((4,)) * 5.0
    dones = jnp.zeros((4,))
    for _ in range(50):
        state, scaled = reward_norm_update(state, rewards, dones)
    assert float(jnp.mean(scaled)) < 5.0  # actually scaled down
    # done resets the running return
    state, _ = reward_norm_update(state, rewards, jnp.ones((4,)))
    state2, _ = reward_norm_update(state, rewards, dones)
    np.testing.assert_allclose(np.asarray(state2.ret),
                               0.99 * 0.0 + 5.0 + 0.99 * 5.0 - 5.0 + 0.0,
                               atol=5.0)  # loose: just finite & reset-ish
    assert bool(jnp.all(jnp.isfinite(state2.ret)))


# --- TIS ----------------------------------------------------------------------


def test_tis_truncation_and_gradient():
    log_beta = jnp.zeros((1, 4))
    adv = jnp.ones((1, 4))
    mask = jnp.ones((1, 4))
    log_pi = jnp.log(jnp.asarray([[0.5, 1.0, 1.9, 3.0]]))
    cfg = TISConfig(c_tis=2.0)

    loss, aux = tis_token_loss(
        log_pi=log_pi, log_beta=log_beta, advantages=adv,
        token_mask=mask, cfg=cfg)
    # value: mean of min(ratio, 2) * 1 = (0.5 + 1 + 1.9 + 2)/4
    np.testing.assert_allclose(float(loss), -(0.5 + 1.0 + 1.9 + 2.0) / 4,
                               rtol=1e-5)
    np.testing.assert_allclose(float(aux["trunc_frac"]), 0.25, rtol=1e-6)

    g = jax.grad(lambda lp: tis_token_loss(
        log_pi=lp, log_beta=log_beta, advantages=adv, token_mask=mask,
        cfg=cfg)[0])(log_pi)
    # truncated sample (ratio 3.0) contributes no gradient
    assert float(g[0, 3]) == 0.0
    assert float(g[0, 0]) != 0.0
