"""Gradient parity of the sharded-backward MoE einsums (custom_vjp) vs
plain einsums — guards hillclimb #2 iter 4 against silent grad drift."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models import moe as M

CFG = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16, n_shared_experts=1,
                capacity_factor=2.0)


def _loss(p, x):
    y, aux = M.moe_apply(p, x, CFG, "swiglu", group_size=12)
    return jnp.sum(y ** 2) + aux


def test_custom_vjp_matches_plain_einsum_grads(monkeypatch):
    p = M.moe_init(jax.random.PRNGKey(0), 32, CFG, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 32))
    g1 = jax.grad(_loss)(p, x)
    gx1 = jax.grad(_loss, argnums=1)(p, x)

    monkeypatch.setattr(
        M, "_dispatch_einsum",
        lambda d, xg: jnp.einsum("gsec,gsd->egcd", d, xg))
    monkeypatch.setattr(
        M, "_combine_einsum",
        lambda c, ob: jnp.einsum("gsec,egcd->gsd", c, ob))
    g2 = jax.grad(_loss)(p, x)
    gx2 = jax.grad(_loss, argnums=1)(p, x)

    flat1 = jax.tree_util.tree_flatten_with_path(g1)[0]
    flat2 = jax.tree_util.tree_flatten_with_path(g2)[0]
    for (k1, a), (k2, b) in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6, err_msg=str(k1))
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2),
                               rtol=1e-5, atol=1e-6)


def test_router_still_receives_gradient():
    p = M.moe_init(jax.random.PRNGKey(0), 32, CFG, "swiglu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 32))
    g = jax.grad(_loss)(p, x)
    assert float(jnp.linalg.norm(g["router"]["w"])) > 0.0


# --- dispatch/combine invariants (hypothesis) -------------------------------


import pytest
from _hypothesis_compat import given, settings, st


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6), sg=st.integers(4, 32),
       cf=st.floats(1.0, 2.5))
def test_dispatch_combine_invariants(seed, sg, cf):
    """For every (token, expert-choice): the dispatch one-hot routes each
    kept token-choice to exactly one capacity slot; combine weights are
    non-negative and sum to <= 1 per token (= 1 when nothing dropped)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs.base import MoEConfig
    from repro.models import moe as M
    from repro.models.layers import dense_apply

    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8,
                    capacity_factor=cf)
    key = jax.random.PRNGKey(seed)
    d = 16
    p = M.moe_init(key, d, cfg, "swiglu")
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, sg, d))

    # reproduce the routing internals at group_size = sg (single group)
    xf = x.reshape(-1, d)
    logits = dense_apply(p["router"], xf)
    gates, ids, probs = M._topk_routing(logits, cfg.top_k)
    n = xf.shape[0]
    cap = max(1, int(cfg.capacity_factor * sg * cfg.top_k / cfg.n_experts))

    onehot = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.int32)
    flat = onehot.reshape(1, n * cfg.top_k, cfg.n_experts)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat
    pos = jnp.sum(flat * pos_in_expert, axis=-1).reshape(1, n, cfg.top_k)
    keep = pos < cap
    cap_onehot = jax.nn.one_hot(
        jnp.where(keep, pos, cap), cap + 1, dtype=jnp.float32)[..., :cap]
    dispatch = jnp.einsum("gske,gskc->gsec",
                          onehot[None, ..., :].astype(jnp.float32)[0][None],
                          cap_onehot)
    combine = jnp.einsum(
        "gske,gskc,gsk->gsec",
        onehot[None].astype(jnp.float32), cap_onehot, gates[None])

    disp = np.asarray(dispatch[0])      # [S, E, C]
    comb = np.asarray(combine[0])

    # each (expert, slot) holds at most one token
    assert (disp.sum(axis=0) <= 1 + 1e-6).all()
    # each token occupies at most top_k slots total
    assert (disp.sum(axis=(1, 2)) <= cfg.top_k + 1e-6).all()
    # combine weights in [0, 1], per-token sum <= 1 (+fp)
    assert (comb >= -1e-7).all()
    per_tok = comb.sum(axis=(1, 2))
    assert (per_tok <= 1.0 + 1e-5).all()
    # when nothing was dropped, weights sum exactly to 1
    if bool(np.asarray(keep).all()):
        np.testing.assert_allclose(per_tok, 1.0, rtol=1e-5)
