"""Exact tabular-MDP validation of the paper's theoretical claims.

The paper argues on paper; here we check numerically on random MDPs:

* Lemma 3.1 (performance difference lemma) — exact equality.
* Theorem 3.2 — the D^± bounds actually bracket J(pi') - J(pi).
* Lemma 4.2 structure — at pi = pi_T the realigned surrogate and the
  epsilon term both vanish (zero backward lag), while the Lemma 4.1
  (PPO-style) surrogate is strictly penalized under mismatch.
* Theorem B.2 — the V-trace operator is a contraction whose fixed point is
  V_{pi_rho_bar}; rho_bar -> inf recovers V_pi.
"""
import numpy as np
import pytest

rng = np.random.default_rng(0)


def random_mdp(S=6, A=4, gamma=0.9, seed=0):
    r = np.random.default_rng(seed)
    P = r.dirichlet(np.ones(S), size=(S, A))         # [S, A, S]
    R = r.normal(size=(S, A))
    mu = r.dirichlet(np.ones(S))
    return P, R, mu, gamma


def random_policy(S, A, seed, temp=1.0):
    r = np.random.default_rng(seed)
    logits = r.normal(size=(S, A)) / temp
    e = np.exp(logits - logits.max(axis=1, keepdims=True))
    return e / e.sum(axis=1, keepdims=True)


def value_of(pi, P, R, gamma):
    S, A, _ = P.shape
    P_pi = np.einsum("sa,sab->sb", pi, P)
    r_pi = np.einsum("sa,sa->s", pi, R)
    V = np.linalg.solve(np.eye(S) - gamma * P_pi, r_pi)
    Q = R + gamma * np.einsum("sab,b->sa", P, V)
    return V, Q


def discounted_state_dist(pi, P, mu, gamma):
    S = P.shape[0]
    P_pi = np.einsum("sa,sab->sb", pi, P)
    d = (1.0 - gamma) * np.linalg.solve(np.eye(S) - gamma * P_pi.T, mu)
    return d


def J_of(pi, P, R, mu, gamma):
    V, _ = value_of(pi, P, R, gamma)
    return float(mu @ V)


def test_lemma_3_1_performance_difference_exact():
    P, R, mu, gamma = random_mdp(seed=1)
    pi = random_policy(6, 4, seed=2)
    pi2 = random_policy(6, 4, seed=3)
    V, Q = value_of(pi, P, R, gamma)
    A = Q - V[:, None]
    d2 = discounted_state_dist(pi2, P, mu, gamma)
    lhs = J_of(pi2, P, R, mu, gamma) - J_of(pi, P, R, mu, gamma)
    rhs = (1.0 / (1.0 - gamma)) * np.einsum("s,sa,sa->", d2, pi2, A)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-8, atol=1e-10)


def test_theorem_3_2_bounds_bracket():
    P, R, mu, gamma = random_mdp(seed=4)
    pi = random_policy(6, 4, seed=5)
    pi2 = random_policy(6, 4, seed=6, temp=2.0)
    V, Q = value_of(pi, P, R, gamma)
    A = Q - V[:, None]
    d = discounted_state_dist(pi, P, mu, gamma)
    # L_pi(pi') as in Eq. 5 (note: paper folds 1/(1-gamma) differently in
    # Thm 3.2; we use the explicit Eq. 30 decomposition).
    surrogate = np.einsum("s,sa,sa->", d, pi2, A)
    eps = np.max(np.abs(np.einsum("sa,sa->s", pi2, A)))
    tv = 0.5 * np.abs(pi2 - pi).sum(axis=1)
    penalty = (2.0 * gamma * eps / (1.0 - gamma)) * float(d @ tv)
    lhs = J_of(pi2, P, R, mu, gamma) - J_of(pi, P, R, mu, gamma)
    lo = (surrogate - penalty) / (1.0 - gamma)
    hi = (surrogate + penalty) / (1.0 - gamma)
    assert lo - 1e-9 <= lhs <= hi + 1e-9


def test_bounds_tight_at_equal_policies():
    P, R, mu, gamma = random_mdp(seed=7)
    pi = random_policy(6, 4, seed=8)
    V, Q = value_of(pi, P, R, gamma)
    A = Q - V[:, None]
    d = discounted_state_dist(pi, P, mu, gamma)
    surrogate = np.einsum("s,sa,sa->", d, pi, A)
    eps = np.max(np.abs(np.einsum("sa,sa->s", pi, A)))
    np.testing.assert_allclose(surrogate, 0.0, atol=1e-10)
    np.testing.assert_allclose(eps, 0.0, atol=1e-10)


def test_lemma_4_2_zero_backward_lag():
    """Realigned surrogate (A_{pi_T}) vanishes at pi = pi_T even under an
    off-policy state/action distribution beta_T — while the Lemma 4.1
    behavioral-advantage surrogate does not."""
    P, R, mu, gamma = random_mdp(seed=9)
    pi_T = random_policy(6, 4, seed=10)
    beta = random_policy(6, 4, seed=11)  # mixture stand-in, beta != pi_T
    d_b = discounted_state_dist(beta, P, mu, gamma)

    V_T, Q_T = value_of(pi_T, P, R, gamma)
    A_T = Q_T - V_T[:, None]
    # Realigned surrogate at pi = pi_T:
    #   E_{s~d^beta, a~beta}[ (pi_T/beta) A_{pi_T} ] = E_{a~pi_T}[A_{pi_T}] = 0
    realigned = np.einsum("s,sa,sa->", d_b, pi_T, A_T)
    np.testing.assert_allclose(realigned, 0.0, atol=1e-10)
    # epsilon^{pi_T} with realigned advantage is exactly 0 as well:
    eps = np.max(np.abs(np.einsum("sa,sa->s", pi_T, A_T)))
    np.testing.assert_allclose(eps, 0.0, atol=1e-10)

    # The behavioral (Lemma 4.1) surrogate generally is NOT zero:
    V_b, Q_b = value_of(beta, P, R, gamma)
    A_b = Q_b - V_b[:, None]
    behavioral = np.einsum("s,sa,sa->", d_b, pi_T, A_b)
    assert abs(behavioral) > 1e-6


def vtrace_operator(V, pi, beta, P, R, gamma, rho_bar, c_bar, iters=1):
    """Exact expected one-step V-trace backup (Eq. 37 in expectation)."""
    ratio = pi / beta
    rho = np.minimum(rho_bar, ratio)
    for _ in range(iters):
        TD = R + gamma * np.einsum("sab,b->sa", P, V) - V[:, None]
        V = V + np.einsum("sa,sa,sa->s", beta, rho, TD)
    return V


def test_theorem_b2_vtrace_fixed_point():
    P, R, mu, gamma = random_mdp(seed=12)
    pi = random_policy(6, 4, seed=13)
    beta = random_policy(6, 4, seed=14)

    for rho_bar in (1.0, 1e6):
        # pi_rho_bar from Eq. 38.
        unnorm = np.minimum(rho_bar * beta, pi)
        pi_rho = unnorm / unnorm.sum(axis=1, keepdims=True)
        V_target, _ = value_of(pi_rho, P, R, gamma)

        V = np.zeros(P.shape[0])
        for _ in range(3000):
            V = vtrace_operator(V, pi, beta, P, R, gamma, rho_bar, rho_bar)
        np.testing.assert_allclose(V, V_target, rtol=1e-5, atol=1e-6)


def test_vtrace_contraction_rate():
    """||R V1 - R V2||_inf <= eta ||V1 - V2||_inf with eta < 1."""
    P, R, mu, gamma = random_mdp(seed=15)
    pi = random_policy(6, 4, seed=16)
    beta = random_policy(6, 4, seed=17)
    r = np.random.default_rng(18)
    V1 = r.normal(size=6)
    V2 = r.normal(size=6)
    RV1 = vtrace_operator(V1.copy(), pi, beta, P, R, gamma, 1.0, 1.0)
    RV2 = vtrace_operator(V2.copy(), pi, beta, P, R, gamma, 1.0, 1.0)
    alpha = np.min(np.einsum("sa,sa->s", beta, np.minimum(1.0, pi / beta)))
    eta = 1.0 - (1.0 - gamma) * alpha
    assert np.max(np.abs(RV1 - RV2)) <= eta * np.max(np.abs(V1 - V2)) + 1e-12
    assert eta < 1.0
