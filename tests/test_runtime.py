"""Async runtime: policy store versioning, queue staleness tagging under
all three lag regimes, admission control exactness, and bit-for-bit
trainer equivalence of the refactored forward_n RLVR path."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy_lag import buffer_sample
from repro.runtime import (
    MaxLagEviction,
    PassThrough,
    PolicyStore,
    QueueClosed,
    StaleVersionError,
    TokenwiseTVGate,
    TrajectoryQueue,
    TVGatedAdmission,
    make_admission,
    make_regime,
)


def _params(v: float):
    return {"w": jnp.full((2,), float(v))}


# --- policy store -----------------------------------------------------------


def test_policy_store_version_monotonic_and_latest():
    store = PolicyStore(_params(0.0), capacity=3)
    assert store.version == 0
    versions = [store.publish(_params(i)) for i in (1.0, 2.0, 3.0)]
    assert versions == [1, 2, 3]
    params, v = store.latest()
    assert v == 3
    np.testing.assert_allclose(np.asarray(params["w"]), 3.0)


def test_policy_store_ring_eviction():
    store = PolicyStore(_params(0.0), capacity=2)
    store.publish(_params(1.0))
    store.publish(_params(2.0))          # evicts v0
    assert store.retained_versions() == [1, 2]
    np.testing.assert_allclose(np.asarray(store.get(1)["w"]), 1.0)
    np.testing.assert_allclose(np.asarray(store.get(2)["w"]), 2.0)
    with pytest.raises(StaleVersionError):
        store.get(0)
    with pytest.raises(KeyError):
        store.get(99)                    # never published


def test_policy_store_sample_maps_slots_to_versions():
    store = PolicyStore(_params(0.0), capacity=4)
    for i in (1.0, 2.0, 3.0):
        store.publish(_params(i), note=f"p{i}")
    params_b, versions = store.sample(jax.random.PRNGKey(0), 64)
    w = np.asarray(params_b["w"][:, 0])
    np.testing.assert_allclose(w, versions.astype(np.float64))
    assert set(versions.tolist()) <= {0, 1, 2, 3}
    assert store.meta(3).meta == {"note": "p3.0"}


def test_policy_store_snapshot_consistent_under_publishes():
    store = PolicyStore(_params(0.0), capacity=2)
    stop = threading.Event()

    def publisher():
        i = 1
        while not stop.is_set():
            store.publish(_params(i))
            i += 1

    t = threading.Thread(target=publisher, daemon=True)
    t.start()
    try:
        for _ in range(50):
            buffer, slot_versions, version = store.snapshot_state()
            # the latest slot of the snapshot maps to the snapshot version
            cap = buffer.capacity
            slot = (int(buffer.head) - 1) % cap
            assert int(slot_versions[slot]) == version
    finally:
        stop.set()
        t.join(timeout=10)


# --- queue + admission ------------------------------------------------------


def test_queue_stamps_versions_and_lag():
    q = TrajectoryQueue()
    q.put("a", behavior_version=3, learner_version=5)
    item = q.get(learner_version=7)
    assert (item.behavior_version, item.enqueue_learner_version,
            item.learner_version_at_consume) == (3, 5, 7)
    assert item.lag == 4
    assert q.stats().lag_histogram == {4: 1}


def test_queue_close_semantics():
    q = TrajectoryQueue()
    q.put("a", behavior_version=0, learner_version=0)
    q.close()
    assert q.get(learner_version=0).payload == "a"   # drains
    assert q.get(learner_version=0) is None          # then end-of-stream
    with pytest.raises(QueueClosed):
        q.put("b", behavior_version=0, learner_version=0)


def test_max_lag_eviction_drops_only_stale():
    q = TrajectoryQueue(admission=MaxLagEviction(max_lag=2))
    for v in range(5):
        q.put(f"p{v}", behavior_version=v, learner_version=5)
    # consumed at learner version 5: lags 5,4,3,2,1 -> first admitted has
    # lag 2 (items with lag > 2 dropped in FIFO order).
    item = q.get(learner_version=5)
    assert item.payload == "p3" and item.lag == 2
    stats = q.stats()
    assert stats.dropped == 3
    assert stats.drops_by_reason == {"max_lag": 3}


def test_tv_gate_drops_exactly_over_threshold():
    # payload IS the tv value; delta/2 = 0.1 is the admission boundary.
    gate = TVGatedAdmission(delta=0.2, tv_fn=lambda payload: payload)
    q = TrajectoryQueue(admission=gate)
    tvs = [0.05, 0.0999, 0.1, 0.100001, 0.3]
    for tv in tvs:
        q.put(tv, behavior_version=0, learner_version=0)
    q.close()  # drain-then-None
    admitted = []
    while (item := q.get(learner_version=1)) is not None:
        admitted.append(item)
    # exactly the tv <= delta/2 items pass, at full weight, tagged with tv
    assert [i.payload for i in admitted] == [0.05, 0.0999, 0.1]
    assert all(i.weight == 1.0 and i.tv == i.payload for i in admitted)
    stats = q.stats()
    assert stats.dropped == 2
    assert stats.drops_by_reason == {"tv_gate": 2}
    assert stats.admission_drop_rate == pytest.approx(2 / 5)


def test_tv_gate_downweight_mode():
    gate = TVGatedAdmission(delta=0.2, tv_fn=lambda p: p,
                            mode="downweight")
    q = TrajectoryQueue(admission=gate)
    q.put(0.4, behavior_version=0, learner_version=0)
    item = q.get(learner_version=0)
    assert item.weight == pytest.approx(0.1 / 0.4)
    assert q.stats().downweighted == 1


def test_empty_queue_pop_times_out_clean():
    """Popping an empty (open) queue returns None after the timeout and
    perturbs no counters."""
    q = TrajectoryQueue()
    t0 = time.monotonic()
    assert q.get(learner_version=0, timeout=0.05) is None
    assert time.monotonic() - t0 < 5.0
    stats = q.stats()
    assert (stats.puts, stats.admitted, stats.dropped) == (0, 0, 0)
    assert stats.lag_histogram == {}
    # zero timeout: immediate None, still no counters
    assert q.get(learner_version=0, timeout=0.0) is None
    assert q.stats().admitted == 0


def test_tv_gate_zero_weight_downweight_clamped_to_drop():
    """Downweighting must not admit dead data: tv -> inf yields weight 0
    (and near-inf yields weight < min_weight); both are dropped with a
    dedicated reason instead of training at weight ~0."""
    gate = TVGatedAdmission(delta=0.2, tv_fn=lambda p: p,
                            mode="downweight")
    q = TrajectoryQueue(admission=gate)
    for tv in (float("inf"), 1e9, 0.4):
        q.put(tv, behavior_version=0, learner_version=0)
    q.close()
    admitted = []
    while (item := q.get(learner_version=0)) is not None:
        admitted.append(item)
    # only the finite, >= min_weight item survives
    assert [i.payload for i in admitted] == [0.4]
    assert admitted[0].weight == pytest.approx(0.1 / 0.4)
    stats = q.stats()
    assert stats.drops_by_reason == {"tv_zero_weight": 2}
    assert stats.downweighted == 1


def test_max_lag_eviction_every_item_stale():
    """When every queued item is over-age the consumer sees a clean
    end-of-stream (None), with the drops fully accounted."""
    q = TrajectoryQueue(admission=MaxLagEviction(max_lag=1))
    for v in range(4):
        q.put(f"p{v}", behavior_version=v, learner_version=10)
    q.close()
    assert q.get(learner_version=10) is None     # all dropped, drained
    stats = q.stats()
    assert stats.admitted == 0 and stats.dropped == 4
    assert stats.drops_by_reason == {"max_lag": 4}
    assert stats.admission_drop_rate == 1.0
    assert stats.lag_histogram == {}             # nothing ever admitted


def test_max_lag_all_stale_phase_locked_regime_warns_and_stops():
    """A phase-locked regime whose producer only yields stale items must
    terminate (with a warning), not spin."""
    store = PolicyStore(_params(0.0), capacity=2)
    queue = TrajectoryQueue(admission=MaxLagEviction(max_lag=0))
    store.publish(_params(1.0))   # learner is at v1; producer serves v0

    regime = make_regime("forward_n", store, queue,
                         lambda params: float(params["w"][0]), forward_n=2)
    # items enqueue with behavior_version == fill-time latest (1), then
    # the learner moves ahead: every consume sees lag >= 1 > max_lag 0.
    with pytest.warns(RuntimeWarning, match="starved"):
        item = regime.next_item(learner_version=store.version + 1,
                                max_refills=3)
    assert item is None
    assert queue.stats().dropped > 0


def test_tokenwise_tv_gate_segments_and_weights():
    """Per-segment Eq. 8: only the stale segment is downweighted, and
    the scalar weight is the token-weighted mean of segment weights."""
    tv = np.asarray([0.01, 0.01, 0.3, 0.3, 0.3, 0.3])
    versions = np.asarray([0, 0, 1, 1, 1, 1])
    gate = TokenwiseTVGate(delta=0.2, token_tv_fn=lambda p: p,
                           mode="downweight")
    q = TrajectoryQueue(admission=gate)
    q.put((tv, versions), behavior_version=0, learner_version=1)
    item = q.get(learner_version=1)
    # segment 0 passes (w=1); segment 1 at tv .3 -> w = .1/.3
    want = (2 * 1.0 + 4 * (0.1 / 0.3)) / 6
    assert item.weight == pytest.approx(want)
    segs = item.meta["tv_segments"]
    assert [(s["version"], s["tokens"]) for s in segs] == [(0, 2), (1, 4)]
    assert segs[0]["weight"] == 1.0
    assert segs[1]["weight"] == pytest.approx(0.1 / 0.3)
    # drop mode: stale segment zeroed, weight = live fraction
    gate_d = TokenwiseTVGate(delta=0.2, token_tv_fn=lambda p: p,
                             mode="drop")

    class _I:
        payload, meta = (tv, versions), {}

    dec = gate_d.admit(_I())
    assert dec.admit and dec.weight == pytest.approx(2 / 6)
    # all segments hopeless -> dropped outright
    hopeless = (np.full((4,), 50.0), np.asarray([0, 0, 1, 1]))

    class _I2:
        payload, meta = hopeless, {}

    dec2 = gate_d.admit(_I2())
    assert not dec2.admit and dec2.reason == "tv_gate_tokenwise"


def test_tokenwise_tv_gate_empty_and_mismatched():
    gate = TokenwiseTVGate(delta=0.2, token_tv_fn=lambda p: p)

    class _I:
        def __init__(self, p):
            self.payload, self.meta = p, {}

    dec = gate.admit(_I((np.zeros((0,)), np.zeros((0,)))))
    assert dec.admit and dec.weight == 1.0    # empty trajectory: no-op
    with pytest.raises(ValueError, match="mismatch"):
        gate.admit(_I((np.zeros((3,)), np.zeros((2,)))))


def test_make_admission_factory():
    assert isinstance(make_admission("pass_through"), PassThrough)
    assert isinstance(make_admission("max_lag", max_lag=1), MaxLagEviction)
    assert isinstance(
        make_admission("tv_gate", delta=0.1, tv_fn=lambda p: 0.0),
        TVGatedAdmission)
    assert isinstance(
        make_admission("tv_gate_tokenwise", delta=0.1, tv_fn=lambda p: p,
                       mode="downweight"),
        TokenwiseTVGate)
    with pytest.raises(ValueError):
        make_admission("tv_gate")  # tv_fn required
    with pytest.raises(ValueError):
        make_admission("tv_gate_tokenwise")  # tv_fn required
    with pytest.raises(ValueError):
        make_admission("nope")


# --- staleness tagging under the three lag regimes --------------------------


def test_backward_mixture_regime_tags_oldest_sampled_version():
    store = PolicyStore(_params(0.0), capacity=4)
    for i in (1.0, 2.0, 3.0):
        store.publish(_params(i))
    queue = TrajectoryQueue()
    key = jax.random.PRNGKey(0)

    def producer(buffer):
        params_b, slots = buffer_sample(buffer, key, 32)
        return np.asarray(params_b["w"][:, 0]), slots

    regime = make_regime("backward_mixture", store, queue, producer)
    regime.fill()
    item = queue.get(learner_version=store.version)
    versions = np.asarray(item.meta["behavior_versions"])
    # payload weights w == version floats: the tag matches the content
    np.testing.assert_allclose(item.payload, versions.astype(np.float64))
    assert item.behavior_version == versions.min()
    assert item.lag == store.version - versions.min()


def test_forward_n_regime_linear_forward_lag():
    store = PolicyStore(_params(0.0), capacity=2)
    queue = TrajectoryQueue()
    regime = make_regime("forward_n", store, queue,
                         lambda params: float(params["w"][0]),
                         forward_n=3)
    regime.fill()
    lags = []
    for _ in range(3):
        item = queue.get(learner_version=store.version)
        assert item.behavior_version == 0          # frozen at fill time
        assert item.payload == 0.0                 # generated from v0
        lags.append(item.lag)
        store.publish(_params(store.version + 1.0))  # one learner update
    assert lags == [0, 1, 2]                       # the §5.2 protocol


def test_regime_restamps_payload_versions_to_frozen_params():
    """A payload's per-token `versions` record is overwritten by the
    regime from the (params, version) pair it actually handed the
    producer — a producer that reads the store mid-generation (after a
    concurrent publish) must not leak the newer version into the item."""
    from collections import namedtuple

    store = PolicyStore(_params(0.0), capacity=4)
    queue = TrajectoryQueue()
    Payload = namedtuple("Payload", ["tokens", "versions"])

    def producer(params):
        # Simulate a learner publish landing during generation: the
        # producer's own store read now returns the *newer* version.
        store.publish(_params(store.version + 1.0))
        return Payload(tokens=np.zeros((2, 3)),
                       versions=np.full((2, 3), store.version, np.int64))

    regime = make_regime("forward_n", store, queue, producer, forward_n=1)
    regime.fill()
    item = queue.get(learner_version=store.version)
    assert item.behavior_version == 0
    np.testing.assert_array_equal(item.payload.versions, 0)


def test_threaded_regime_concurrent_production_and_tags():
    store = PolicyStore(_params(0.0), capacity=2)
    queue = TrajectoryQueue(maxsize=2)

    def producer(params):
        time.sleep(0.005)
        return float(params["w"][0])

    regime = make_regime("threaded", store, queue, producer, max_items=6)
    regime.start()
    try:
        consumed = []
        while (item := queue.get(learner_version=store.version,
                                 timeout=30.0)) is not None:
            consumed.append(item)
            store.publish(_params(store.version + 1.0))
        assert len(consumed) == 6
        behavior = [i.behavior_version for i in consumed]
        assert behavior == sorted(behavior)        # producer tracks latest
        assert behavior[-1] > 0                    # saw learner progress
        assert all(i.lag >= 0 for i in consumed)
        assert queue.stats().puts == 6
    finally:
        regime.stop()


# --- trainer equivalence (refactored forward_n == legacy phase-locked) -----


@pytest.mark.slow
def test_rlvr_forward_n_matches_legacy_bit_for_bit():
    """The queue-driven forward_n RLVR phase reproduces the pre-refactor
    generate-N/train-N loop exactly (metrics and final params) at fixed
    seed."""
    from repro.configs.base import ModelConfig
    from repro.core.losses import group_advantages
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.models.registry import build
    from repro.optim import adamw_init
    from repro.rollout.async_engine import ForwardLagGenerator
    from repro.train.trainer_rlvr import (
        RLVRHyperparams,
        RLVRTrainer,
        RLVRTrainState,
        make_update_step,
    )

    tok = get_tokenizer()
    cfg = ModelConfig(
        name="rt-eq", arch_type="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=tok.vocab_size)
    bundle = build(cfg)
    hp = RLVRHyperparams(
        algorithm="grpo_vaco", n_minibatches=3, prompts_per_minibatch=4,
        completions_per_prompt=2, max_new_tokens=4, warmup_steps=0)

    # legacy phase-locked loop (pre-refactor protocol, reconstructed from
    # the same primitives):
    ds = MathTaskDataset(prompt_len=12, level=0, pool_size=256)
    params = bundle.init(jax.random.PRNGKey(0))
    state = RLVRTrainState(params=params, opt_state=adamw_init(params),
                           updates=jnp.zeros((), jnp.int32))
    gen = ForwardLagGenerator(
        bundle, ds, n_minibatches=3, prompts_per_minibatch=4,
        completions_per_prompt=2, max_new_tokens=4, seed=1)
    upd = make_update_step(bundle, hp, ds.prompt_len)
    legacy = []
    for _ in range(2):
        for b in gen.generate_phase(state.params):
            adv = group_advantages(b.rewards, 2)
            state, aux = upd(state, b.gen.tokens, b.gen.log_beta,
                             b.gen.mask, adv)
            legacy.append((float(jnp.mean(b.rewards)), float(aux["tv"]),
                           float(aux.get("frac_filtered", 0.0)),
                           b.staleness))

    # refactored runtime path (fresh dataset: same sampling RNG state)
    ds2 = MathTaskDataset(prompt_len=12, level=0, pool_size=256)
    tr = RLVRTrainer(bundle, ds2, hp, seed=0)
    new = []
    for _ in range(2):
        for log in tr.train_phase():
            new.append((log.mean_reward, log.tv, log.frac_filtered,
                        log.staleness))

    assert new == legacy
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(tr.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- supervised production (no-fault transparency) --------------------------


def test_threaded_regime_supervision_transparent_without_faults():
    """A supervisor on a healthy producer must be invisible: no restarts,
    no restart provenance, identical put/consume accounting."""
    from repro.resilience import BackoffPolicy

    store = PolicyStore(_params(0.0), capacity=2)
    queue = TrajectoryQueue(maxsize=2)
    regime = make_regime(
        "threaded", store, queue,
        lambda params: float(params["w"][0]), max_items=5,
        supervisor=BackoffPolicy(base_ms=1, max_restarts=3, seed=0))
    regime.start()
    try:
        consumed = []
        while (item := queue.get(learner_version=store.version,
                                 timeout=30.0)) is not None:
            consumed.append(item)
        assert len(consumed) == 5
        assert all("restart" not in i.meta for i in consumed)
        assert queue.stats().puts == 5
    finally:
        regime.stop()
    assert regime.restarts == 0 and regime.error is None
    assert queue.registry.counter_values("watchdog_restart_total") == {}
    assert queue.registry.counter_values("restart_admitted_total") == {}
