"""Dry-run smoke: execute launch/dryrun.py as a subprocess (it must set
XLA_FLAGS before jax init, so it cannot run in-process) for one cheap
combo per step kind, plus the skip policy and record schema."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(args, timeout=420):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=ENV, cwd=REPO, timeout=timeout,
    )


@pytest.mark.slow
def test_dryrun_decode_single_combo(tmp_path):
    out = tmp_path / "rec.json"
    r = _run(["--arch", "rwkv6-1.6b", "--shape", "decode_32k",
              "--out", str(out)])
    assert r.returncode == 0, r.stdout + r.stderr
    recs = json.loads(out.read_text())
    assert len(recs) == 1
    rec = recs[0]
    assert rec["status"] == "ok"
    assert rec["flops"] > 0 and rec["hbm_bytes"] > 0
    assert rec["mesh"] == "16x16"


@pytest.mark.slow
def test_dryrun_multipod_single_combo(tmp_path):
    out = tmp_path / "rec.json"
    r = _run(["--arch", "rwkv6-1.6b", "--shape", "decode_32k",
              "--multi-pod", "--no-extrapolate", "--out", str(out)])
    assert r.returncode == 0, r.stdout + r.stderr
    recs = json.loads(out.read_text())
    assert recs[0]["status"] == "ok"
    assert recs[0]["mesh"] == "2x16x16"


def test_skip_policy_matches_design():
    """Pure in-process check of the documented long_500k skip list."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.configs import get_config, list_archs

    skipped = {a for a in list_archs()
               if not get_config(a).is_subquadratic}
    assert skipped == {
        "qwen2.5-14b", "paligemma-3b", "granite-20b", "codeqwen1.5-7b",
        "whisper-large-v3", "kimi-k2-1t-a32b", "llama4-scout-17b-a16e",
    }


def test_grid_artifacts_are_complete():
    """The committed dry-run result files must cover the full 10x4 grid
    with zero failures on both meshes (regression guard on the
    deliverable)."""
    for name in ("dryrun_singlepod.json", "dryrun_multipod.json"):
        path = os.path.join(REPO, "results", name)
        if not os.path.exists(path):
            pytest.skip(f"{name} not generated on this host")
        recs = json.load(open(path))
        assert len(recs) == 40
        assert sum(r["status"] == "ok" for r in recs) == 33
        assert sum(r["status"] == "skipped" for r in recs) == 7
        assert sum(r["status"] == "failed" for r in recs) == 0
