"""In-place paged KV pool: kernel parity, aliased-vs-carried decode
bit-exactness (incl. preemption/retire churn), prefill tile writes, and
the donation/buffer-reuse contract the flat-in-num_blocks cost rests on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.tokenizer import get_tokenizer
from repro.kernels import ops, ref
from repro.kernels.paged_kv_write_pallas import paged_kv_write
from repro.models import transformer as tf_mod
from repro.models.registry import build
from repro.rollout.sampler import generate
from repro.serve import ServeEngine

KEY = jax.random.PRNGKey(0)
TOK = get_tokenizer()
CFG = ModelConfig(
    name="inplace-test", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=TOK.vocab_size,
)
BUNDLE = build(CFG)
PARAMS = BUNDLE.init(jax.random.PRNGKey(0))

PROMPTS = [np.asarray(TOK.encode(p), np.int32)
           for p in ("1+2=?#", "3*4=?#", "10-7=?#")]
BUDGETS = [5, 9, 13]


# --- paged_kv_write kernel vs oracle ----------------------------------------


@pytest.mark.parametrize(
    "layers,kv,nb,bs,d,b",
    [(2, 2, 8, 4, 16, 4), (3, 1, 12, 8, 32, 3), (1, 4, 6, 4, 8, 5)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_kv_write_kernel_parity(layers, kv, nb, bs, d, b, dtype):
    """Pallas (interpret) vs DUS oracle on random rows, ragged offsets,
    and inactive slots, at every layer index."""
    rng = np.random.default_rng(layers * nb + b)
    ks = jax.random.split(jax.random.fold_in(KEY, nb * d), 4)
    kp = jax.random.normal(ks[0], (layers, kv, nb, bs, d)).astype(dtype)
    vp = jax.random.normal(ks[1], (layers, kv, nb, bs, d)).astype(dtype)
    k_rows = jax.random.normal(ks[2], (b, kv, d))
    v_rows = jax.random.normal(ks[3], (b, kv, d))
    page_idx = jnp.asarray(
        rng.choice(nb, size=b, replace=False), jnp.int32)
    offset = jnp.asarray(rng.integers(0, bs, size=b), jnp.int32)
    active = jnp.asarray(rng.integers(0, 2, size=b).astype(bool))
    for layer in range(layers):
        got_k, got_v = paged_kv_write(
            kp, vp, k_rows, v_rows, page_idx, offset, active,
            layer=layer, interpret=True)
        want_k, want_v = ref.ref_paged_kv_write(
            kp, vp, k_rows, v_rows, page_idx, offset, active, layer=layer)
        np.testing.assert_array_equal(np.asarray(got_k, np.float32),
                                      np.asarray(want_k, np.float32))
        np.testing.assert_array_equal(np.asarray(got_v, np.float32),
                                      np.asarray(want_v, np.float32))


def test_paged_kv_write_drop_semantics():
    """Inactive slots must leave the pool untouched — even when their
    page_idx is garbage (the engine never reads it)."""
    kp = jnp.zeros((1, 2, 4, 4, 8))
    vp = jnp.zeros((1, 2, 4, 4, 8))
    rows = jnp.ones((2, 2, 8))
    page_idx = jnp.asarray([1, 9999], jnp.int32)   # slot 1 inactive
    offset = jnp.asarray([2, 0], jnp.int32)
    active = jnp.asarray([True, False])
    for impl in (
        lambda: ref.ref_paged_kv_write(
            kp, vp, rows, rows, page_idx, offset, active, layer=0),
        lambda: paged_kv_write(
            kp, vp, rows, rows, page_idx, offset,
            active, layer=0, interpret=True),
    ):
        nk, nv = impl()
        nk = np.array(nk)
        assert nk[0, :, 1, 2, :].min() == 1.0     # active slot landed
        nk[0, :, 1, 2, :] = 0.0
        np.testing.assert_array_equal(nk, 0.0)    # nothing else moved
        np.testing.assert_array_equal(
            np.asarray(nv)[0, :, 1, 2, :], 1.0)


def test_paged_kv_write_ops_dispatch_modes_agree():
    ks = jax.random.split(KEY, 4)
    kp = jax.random.normal(ks[0], (2, 2, 6, 4, 16))
    vp = jax.random.normal(ks[1], (2, 2, 6, 4, 16))
    k_rows = jax.random.normal(ks[2], (3, 2, 16))
    v_rows = jax.random.normal(ks[3], (3, 2, 16))
    page_idx = jnp.asarray([0, 3, 5], jnp.int32)
    offset = jnp.asarray([1, 0, 3], jnp.int32)
    active = jnp.asarray([True, True, False])
    a = ops.paged_kv_write(kp, vp, k_rows, v_rows, page_idx, offset,
                           active, layer=1, mode="reference")
    b = ops.paged_kv_write(kp, vp, k_rows, v_rows, page_idx, offset,
                           active, layer=1, mode="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


# --- aliased decode path vs the carried-pool oracle -------------------------


def _random_paged_state(rng, batch, num_blocks, max_blocks, block_size):
    """Disjoint per-slot block tables + ragged positions."""
    perm = rng.permutation(num_blocks)
    tables = np.zeros((batch, max_blocks), np.int32)
    pos = np.zeros((batch,), np.int32)
    for i in range(batch):
        tables[i] = perm[i * max_blocks:(i + 1) * max_blocks]
        pos[i] = int(rng.integers(0, max_blocks * block_size - 8))
    return jnp.asarray(tables), jnp.asarray(pos)


def test_decode_step_paged_matches_carried():
    """The hoisted/aliased decode step matches the legacy scan-carried
    step over a multi-step rollout — logits and every pool row — with a
    mid-run slot deactivation (retire churn).

    Tolerance is ulp-level, not bitwise: the carried path's layer body
    compiles inside a lax.scan (fused), the hoisted path dispatches the
    same ops standalone, and XLA's fusion changes rounding in the last
    bit.  Greedy *token* equality under churn is asserted bit-for-bit by
    the engine-level test below.
    """
    rng = np.random.default_rng(7)
    batch, num_blocks, max_blocks, block_size = 3, 12, 4, 4
    pages_a = tf_mod.init_paged_cache(CFG, num_blocks, block_size)
    pages_c = jax.tree.map(jnp.copy, pages_a)
    tables, pos = _random_paged_state(
        rng, batch, num_blocks, max_blocks, block_size)
    token = jnp.asarray(rng.integers(0, CFG.vocab_size, batch), jnp.int32)
    active = jnp.asarray([True, True, True])
    for step in range(6):
        if step == 3:
            active = jnp.asarray([True, False, True])   # slot 1 retires
        out_a, pages_a = tf_mod.decode_step_paged(
            PARAMS, CFG, token, pages_a, tables, pos, active)
        out_c, pages_c = tf_mod.decode_step_paged_carried(
            PARAMS, CFG, token, pages_c, tables, pos, active)
        np.testing.assert_allclose(np.asarray(out_a.logits),
                                   np.asarray(out_c.logits),
                                   rtol=2e-6, atol=2e-6)
        for leaf in ("k_pages", "v_pages"):
            np.testing.assert_allclose(np.asarray(pages_a[leaf]),
                                       np.asarray(pages_c[leaf]),
                                       rtol=2e-6, atol=2e-6)
        token = jnp.argmax(out_a.logits, axis=-1).astype(jnp.int32)
        pos = pos + active.astype(jnp.int32)


@pytest.mark.parametrize("decode_chunk", [1, 4])
def test_engine_aliased_matches_carried_under_preemption(
        monkeypatch, decode_chunk):
    """Full-engine bit-exactness: a pool too small for all requests
    forces preemption + recompute churn; the aliased path must emit
    token-for-token what the carried path emits (greedy, fixed seed),
    across multi-chunk decode."""
    def _run(impl):
        monkeypatch.setattr(tf_mod, "decode_step_paged", impl)
        eng = ServeEngine(
            BUNDLE, PARAMS, num_blocks=7, block_size=4, max_batch=3,
            max_seq_len=64, temperature=1e-4, seed=0,
            decode_chunk=decode_chunk)
        reqs = [eng.submit(r, n) for r, n in zip(PROMPTS, BUDGETS)]
        trajs = {t.request_id: t for t in eng.run(max_steps=400)}
        if decode_chunk == 1:
            # Multi-chunk lookahead reserves pages up front, so the
            # scheduler serializes instead of preempting there; the
            # chunk=1 case is the one that churns through preemption.
            assert eng.stats.preemptions > 0
        assert eng.allocator.num_free == 7
        return [trajs[r.request_id].tokens for r in reqs]

    aliased = _run(tf_mod.decode_step_paged)
    carried = _run(tf_mod.decode_step_paged_carried)
    for a, c in zip(aliased, carried):
        np.testing.assert_array_equal(a, c)


# --- prefill tile writes ----------------------------------------------------


def test_write_prefill_to_pages_matches_row_scatter():
    """The DUS-per-tile prefill write equals the row-scatter semantics:
    rows < prompt_len land at blocks[row // BS], everything else —
    including whatever lives in the pad slots' page 0 — is untouched."""
    layers, kv, nb, bs, d = 2, 2, 10, 4, 8
    p, plen = 12, 9
    ks = jax.random.split(KEY, 4)
    pages = {
        "k_pages": jax.random.normal(ks[0], (layers, kv, nb, bs, d)),
        "v_pages": jax.random.normal(ks[1], (layers, kv, nb, bs, d)),
    }
    cache_k = jax.random.normal(ks[2], (layers, 1, p, kv, d))
    cache_v = jax.random.normal(ks[3], (layers, 1, p, kv, d))
    blocks = jnp.asarray([7, 2, 5, 0, 0], jnp.int32)   # pads -> page 0
    got = tf_mod.write_prefill_to_pages(
        cache_k, cache_v, pages, blocks, jnp.int32(plen))
    want_k = np.asarray(pages["k_pages"]).copy()
    want_v = np.asarray(pages["v_pages"]).copy()
    rows_k = np.asarray(cache_k)[:, 0].transpose(0, 2, 1, 3)
    rows_v = np.asarray(cache_v)[:, 0].transpose(0, 2, 1, 3)
    for r in range(plen):
        want_k[:, :, int(blocks[r // bs]), r % bs, :] = rows_k[:, :, r, :]
        want_v[:, :, int(blocks[r // bs]), r % bs, :] = rows_v[:, :, r, :]
    np.testing.assert_array_equal(np.asarray(got["k_pages"]), want_k)
    np.testing.assert_array_equal(np.asarray(got["v_pages"]), want_v)


# --- donation / buffer reuse ------------------------------------------------


def test_engine_decode_donates_and_reuses_pool_buffer():
    """The decode dispatch must consume the pool it was handed
    (donate_argnums) and, on this single-device host, write the result
    into the *same* buffer — the no-copy property the flat-in-num_blocks
    per-step cost rests on."""
    eng = ServeEngine(BUNDLE, PARAMS, num_blocks=32, block_size=4,
                      max_batch=2, max_seq_len=64, temperature=1e-4,
                      seed=0)
    eng.submit(PROMPTS[0], 8)
    eng.step()                       # prefill + first chunk: all compiled
    before = eng.pages["k_pages"]
    ptr_before = before.unsafe_buffer_pointer()
    eng.step()
    assert before.is_deleted(), "pool was not donated into the dispatch"
    assert eng.pages["k_pages"].unsafe_buffer_pointer() == ptr_before, (
        "pool buffer was copied, not updated in place")


def test_released_pages_overwritten_not_stale():
    """Copy-free release means retired requests' rows stay in the pool
    until reused; a later request that inherits those pages must produce
    exactly the dense-path tokens (a stale-row read would corrupt its
    attention)."""
    def _greedy_reference(row, n):
        g = jax.jit(lambda p, t, k: generate(
            BUNDLE, p, t, k, max_new_tokens=n, temperature=1e-4))(
            PARAMS, jnp.asarray(row)[None], jax.random.PRNGKey(7))
        return np.asarray(g.completion[0])

    # Pool of exactly one request's working set: every admission reuses
    # the predecessor's just-released pages.
    eng = ServeEngine(BUNDLE, PARAMS, num_blocks=8, block_size=4,
                      max_batch=1, max_seq_len=32, temperature=1e-4,
                      seed=0)
    for prompt, budget in zip(PROMPTS, BUDGETS):
        want = _greedy_reference(prompt, budget)
        req = eng.submit(prompt, budget)
        traj = {t.request_id: t for t in eng.run(max_steps=200)}
        np.testing.assert_array_equal(traj[req.request_id].tokens, want)
        assert eng.allocator.num_free == 8     # all pages back in pool
