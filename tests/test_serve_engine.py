"""Serve subsystem: block allocator, continuous-batching scheduler,
paged-KV engine parity vs the dense generate loop, preemption
correctness, in-flight weight swap provenance, and tokenwise TV
admission over served trajectories."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.tokenizer import EOS, get_tokenizer
from repro.models.registry import build
from repro.rollout.sampler import generate, score_tokens
from repro.runtime import (
    PolicyStore,
    TokenwiseTVGate,
    TrajectoryQueue,
    TVGatedAdmission,
    make_regime,
)
from repro.serve import (
    BlockAllocator,
    ContinuousBatchingScheduler,
    OutOfBlocks,
    Request,
    ServeEngine,
)

TOK = get_tokenizer()
CFG = ModelConfig(
    name="serve-test", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=TOK.vocab_size,
)
BUNDLE = build(CFG)
PARAMS = BUNDLE.init(jax.random.PRNGKey(0))

PROMPTS = [np.asarray(TOK.encode(p), np.int32)
           for p in ("1+2=?#", "3*4=?#", "10-7=?#")]
BUDGETS = [5, 9, 13]


def _greedy_reference(params, row, n):
    g = jax.jit(lambda p, t, k: generate(
        BUNDLE, p, t, k, max_new_tokens=n, temperature=1e-4))(
        params, jnp.asarray(row)[None], jax.random.PRNGKey(7))
    return np.asarray(g.completion[0])


# --- allocator --------------------------------------------------------------


def test_allocator_free_list_and_reuse():
    a = BlockAllocator(num_blocks=4, block_size=8)
    assert a.num_free == 4
    b1 = a.allocate(3)
    assert a.num_free == 1 and len(set(b1)) == 3
    with pytest.raises(OutOfBlocks):
        a.allocate(2)
    a.release(b1[:2])               # copy-free release
    assert a.num_free == 3
    b2 = a.allocate(3)
    assert set(b2) & set(b1[:2])    # released pages are reused
    assert a.blocks_for(1) == 1 and a.blocks_for(8) == 1
    assert a.blocks_for(9) == 2


def test_allocator_padded_table_in_range():
    a = BlockAllocator(num_blocks=8, block_size=4)
    row = a.padded_table([5, 2], width=4)
    np.testing.assert_array_equal(row, [5, 2, 0, 0])
    with pytest.raises(ValueError):
        a.padded_table([1, 2, 3], width=2)


# --- scheduler --------------------------------------------------------------


def _sched(num_blocks=8, block_size=4, max_batch=2, max_blocks=8):
    return ContinuousBatchingScheduler(
        BlockAllocator(num_blocks, block_size),
        max_batch=max_batch, max_blocks_per_request=max_blocks)


def test_scheduler_admits_fifo_into_slots():
    s = _sched()
    reqs = [Request(prompt=np.zeros((6,), np.int32), max_new_tokens=4)
            for _ in range(3)]
    for r in reqs:
        s.submit(r)
    admitted, preempted = s.schedule()
    assert admitted == reqs[:2] and not preempted   # 2 slots
    assert [r.slot for r in admitted] == [0, 1]
    assert all(len(r.blocks) >= 2 for r in admitted)  # 7 rows -> 2 pages
    s.retire(reqs[0], "eos")
    admitted, _ = s.schedule()
    assert admitted == [reqs[2]] and reqs[2].slot == 0  # slot reused


def test_scheduler_rejects_impossible_request():
    s = _sched(num_blocks=2, block_size=4, max_blocks=2)
    with pytest.raises(ValueError):
        s.submit(Request(prompt=np.zeros((6,), np.int32),
                         max_new_tokens=8))   # 14 rows > 8-row pool


def test_scheduler_preempts_latest_admitted_on_pressure():
    s = _sched(num_blocks=4, block_size=4, max_batch=2)
    r1 = Request(prompt=np.zeros((4,), np.int32), max_new_tokens=9)
    r2 = Request(prompt=np.zeros((4,), np.int32), max_new_tokens=9)
    s.submit(r1), s.submit(r2)
    admitted, _ = s.schedule()
    assert admitted == [r1, r2]     # 2 pages each (5 rows)
    # r1 grows past its pages (9th row): pool dry -> r2 (latest) evicted
    r1.tokens.extend([5, 5, 5, 5, 5])
    admitted, preempted = s.schedule()
    assert preempted == [r2] and not admitted    # r1's extension won
    assert r2.state.value == "waiting" and r2.blocks == []
    assert r2.num_preemptions == 1
    assert s.waiting[0] is r2       # requeued at the front


# --- engine correctness -----------------------------------------------------


@pytest.mark.parametrize("decode_chunk", [1, 4])
def test_engine_matches_dense_generate_greedy(decode_chunk):
    """Continuous batching over the paged cache is token-exact vs the
    phase-locked dense loop under greedy decoding, at mixed lengths."""
    want = [_greedy_reference(PARAMS, r, n)
            for r, n in zip(PROMPTS, BUDGETS)]
    eng = ServeEngine(
        BUNDLE, PARAMS, num_blocks=32, block_size=4, max_batch=2,
        max_seq_len=64, temperature=1e-4, seed=0,
        decode_chunk=decode_chunk)
    reqs = [eng.submit(r, n) for r, n in zip(PROMPTS, BUDGETS)]
    trajs = {t.request_id: t for t in eng.run(max_steps=400)}
    for rq, w in zip(reqs, want):
        t = trajs[rq.request_id]
        np.testing.assert_array_equal(t.tokens, w)
        assert t.mask.tolist() == [1.0] * len(w)
        assert t.finish_reason in ("eos", "length")
    # every page returned to the pool, copy-free
    assert eng.allocator.num_free == eng.allocator.num_blocks
    assert eng.stats.finished == 3
    from repro.metrics.runtime_metrics import collect_serve_stats

    stats = collect_serve_stats(eng)
    assert stats["tokens_out"] == sum(BUDGETS)
    assert stats["pool_utilization"] == 0.0       # all freed
    assert stats["waiting"] == 0 and stats["running"] == 0
    assert 0.0 < stats["mean_occupancy"] <= 2.0   # max_batch slots


def test_engine_log_beta_matches_rescoring():
    """Recorded behavior log-probs == teacher-forced rescoring under the
    same params (the β == π_serve invariant, per request)."""
    eng = ServeEngine(BUNDLE, PARAMS, num_blocks=32, block_size=4,
                      max_batch=2, max_seq_len=64, temperature=1.0,
                      seed=5)
    eng.submit(PROMPTS[0], 8)
    t = eng.run(max_steps=100)[0]
    full = np.concatenate([t.prompt, t.tokens])
    logp, _, _ = score_tokens(BUNDLE, PARAMS, jnp.asarray(full)[None],
                              prompt_len=len(t.prompt))
    np.testing.assert_allclose(np.asarray(logp[0]), t.log_beta, atol=2e-4)


def test_engine_preemption_preserves_tokens():
    """A pool too small for all requests forces preemption; recompute
    re-prefill must not change any emitted token (greedy)."""
    want = [_greedy_reference(PARAMS, r, n)
            for r, n in zip(PROMPTS, BUDGETS)]
    eng = ServeEngine(BUNDLE, PARAMS, num_blocks=7, block_size=4,
                      max_batch=3, max_seq_len=64, temperature=1e-4,
                      seed=0)
    reqs = [eng.submit(r, n) for r, n in zip(PROMPTS, BUDGETS)]
    trajs = {t.request_id: t for t in eng.run(max_steps=400)}
    assert eng.stats.preemptions > 0
    for rq, w in zip(reqs, want):
        np.testing.assert_array_equal(trajs[rq.request_id].tokens, w)
    assert eng.allocator.num_free == 7


def test_engine_requires_paged_capable_arch():
    cfg = CFG.replace(name="rwkv-ish", attn_free=True)
    bundle = build(cfg)
    assert bundle.decode_step_paged is None
    with pytest.raises(ValueError, match="attn-free"):
        ServeEngine(bundle, PARAMS)


# --- sliding-window (gemma3-style) archs on the paged path ------------------


WIN_CFG = CFG.replace(name="serve-window-test", sliding_window=4,
                      global_every=2)   # layer 0 local(4), layer 1 global
WIN_BUNDLE = build(WIN_CFG)
WIN_PARAMS = WIN_BUNDLE.init(jax.random.PRNGKey(1))


def test_sliding_window_arch_is_paged_capable():
    """The per-layer window gate is lifted: gemma3-style local:global
    patterns run the paged path (prefix-LM/VLM and SSM state stay
    gated)."""
    assert WIN_BUNDLE.decode_step_paged is not None
    assert WIN_BUNDLE.decode_step_paged_multi is not None
    vlm = CFG.replace(name="vlm-ish", vision_prefix_len=16, prefix_lm=True)
    assert build(vlm).decode_step_paged is None


def test_engine_windowed_matches_dense_generate_greedy():
    """Paged serve over a sliding-window arch is token-exact vs the
    dense generate loop, with contexts well past the window so the
    local layers' masks actually bite."""
    budgets = [10, 14, 12]
    want = []
    for row, n in zip(PROMPTS, budgets):
        g = jax.jit(lambda p, t, k, n=n: generate(
            WIN_BUNDLE, p, t, k, max_new_tokens=n, temperature=1e-4))(
            WIN_PARAMS, jnp.asarray(row)[None], jax.random.PRNGKey(7))
        comp = np.asarray(g.completion[0])
        if (comp == EOS).any():       # engine retires at EOS; cut the pad
            comp = comp[: int(np.argmax(comp == EOS)) + 1]
        want.append(comp)
    eng = ServeEngine(
        WIN_BUNDLE, WIN_PARAMS, num_blocks=32, block_size=4, max_batch=2,
        max_seq_len=64, temperature=1e-4, seed=0, decode_chunk=2)
    reqs = [eng.submit(r, n) for r, n in zip(PROMPTS, budgets)]
    trajs = {t.request_id: t for t in eng.run(max_steps=400)}
    for rq, w in zip(reqs, want):
        np.testing.assert_array_equal(trajs[rq.request_id].tokens, w)


def test_engine_windowed_speculative_token_exact():
    """Multi-token verify carries the same per-layer windows: the spec
    engine on a windowed arch is token-exact with its own non-spec
    greedy output."""
    def _run(k):
        eng = ServeEngine(
            WIN_BUNDLE, WIN_PARAMS, num_blocks=32, block_size=4,
            max_batch=2, max_seq_len=64, temperature=1e-4, seed=0,
            speculate_k=k,
            draft=("params", WIN_PARAMS) if k else None)
        reqs = [eng.submit(r, 12) for r in PROMPTS]
        trajs = {t.request_id: t for t in eng.run(max_steps=400)}
        return [trajs[r.request_id].tokens for r in reqs]

    plain = _run(0)
    spec = _run(3)
    for p, s in zip(plain, spec):
        np.testing.assert_array_equal(p, s)


# --- in-flight weight swap (acceptance: per-token version provenance) -------


def _swap_trajectory(seed=3, swap_after=5, total=12):
    """Fixed-seed run with one learner publish mid-generation."""
    store = PolicyStore(PARAMS, capacity=4)
    eng = ServeEngine(BUNDLE, store=store, num_blocks=32, block_size=4,
                      max_batch=2, max_seq_len=64, temperature=1.0,
                      seed=seed)
    eng.submit(PROMPTS[0], total)
    for _ in range(swap_after):
        assert not eng.step()
    p2 = jax.tree.map(lambda x: x + 0.01, PARAMS)
    store.publish(p2)
    trajs = eng.run(max_steps=200)
    return trajs[0], p2, eng


def test_inflight_swap_versions_change_at_boundary():
    traj, _, eng = _swap_trajectory()
    v = traj.versions
    assert v[0] == 0 and v[-1] == 1          # straddles the publish
    dv = np.diff(v)
    assert (dv >= 0).all() and dv.sum() == 1  # one clean step boundary
    assert eng.stats.swaps == 1
    assert traj.behavior_version == 0         # oldest-version convention


def test_inflight_swap_tokenwise_gate_differs_from_whole_trajectory():
    """Eq. 8 per version segment weights the stale segment only; the
    whole-trajectory gate averages it away.  (Acceptance criterion.)"""
    traj, p2, _ = _swap_trajectory()
    full = np.concatenate([traj.prompt, traj.tokens])
    logp, _, _ = score_tokens(BUNDLE, p2, jnp.asarray(full)[None],
                              prompt_len=len(traj.prompt))
    tv_tokens = 0.5 * np.abs(
        np.exp(np.asarray(logp[0]) - traj.log_beta) - 1.0)
    # Threshold at the trajectory-mean TV: the whole-trajectory gate
    # sits exactly on its boundary (weight 1), while segmentwise the
    # pre-swap segment (scored under the *new* policy) differs from the
    # post-swap one, so one segment lands above the mean.
    delta = 2.0 * float(tv_tokens.mean())
    payload = (tv_tokens, traj.versions)

    class _Item:
        def __init__(self, p):
            self.payload, self.meta = p, {}

    tok_item, whole_item = _Item(payload), _Item(payload)
    tok_dec = TokenwiseTVGate(
        delta, lambda p: p, mode="downweight").admit(tok_item)
    whole_dec = TVGatedAdmission(
        delta, lambda p: float(np.mean(p[0])),
        mode="downweight").admit(whole_item)
    assert whole_dec.admit and whole_dec.weight == 1.0
    assert tok_dec.admit and tok_dec.weight != whole_dec.weight
    segs = tok_item.meta["tv_segments"]
    assert [s["version"] for s in segs] == [0, 1]
    assert sum(s["tokens"] for s in segs) == traj.num_tokens
    assert any(s["weight"] < 1.0 for s in segs)


# --- engine-driven threaded regime ------------------------------------------


def test_threaded_engine_regime_tags_per_token_versions():
    """The rewired threaded regime drives the engine; queue items carry
    the full per-token version vector and the oldest-version tag."""
    store = PolicyStore(PARAMS, capacity=4)
    queue = TrajectoryQueue(maxsize=4)
    eng = ServeEngine(BUNDLE, store=store, num_blocks=32, block_size=4,
                      max_batch=2, max_seq_len=64, temperature=1.0,
                      seed=11)
    stream = [(PROMPTS[i % 3], 6) for i in range(4)]
    it = iter(stream)
    regime = make_regime(
        "threaded_engine", store, queue,
        lambda: next(it, None), engine=eng, max_items=4)
    regime.start()
    # Publish while the engine is still warming up its first dispatch:
    # every trajectory must then see the swap (deterministically).
    store.publish(jax.tree.map(lambda x: x + 0.001, PARAMS))
    try:
        consumed = []
        while (item := queue.get(learner_version=store.version,
                                 timeout=30.0)) is not None:
            consumed.append(item)
            store.publish(jax.tree.map(
                lambda x: x + 0.001, store.latest()[0]))
        assert len(consumed) == 4
        for item in consumed:
            versions = item.meta["versions"]
            assert len(versions) == item.payload.num_tokens
            assert item.behavior_version == min(versions)
            assert item.lag >= 0
        # learner published while serving: some trajectory saw a
        # non-zero version (the engine swapped in-flight)
        assert max(max(i.meta["versions"]) for i in consumed) > 0
    finally:
        regime.stop()


def test_threaded_engine_regime_requires_shared_store():
    store, other = PolicyStore(PARAMS, 2), PolicyStore(PARAMS, 2)
    eng = ServeEngine(BUNDLE, store=other, num_blocks=8, block_size=4,
                      max_batch=1, max_seq_len=32)
    with pytest.raises(ValueError, match="share"):
        make_regime("threaded_engine", store, TrajectoryQueue(),
                    lambda: None, engine=eng)


# --- tracing provenance (acceptance: trace == ServeStats, spans balance) ----


from repro.metrics.runtime_metrics import collect_serve_stats  # noqa: E402
from repro.obs import Tracer  # noqa: E402


def _assert_balanced(events):
    """Every sync B nests and closes; every async b gets its e."""
    stacks = {}
    opens = {}
    for ev in events:
        key = (ev.pid, ev.tid)
        if ev.ph == "B":
            stacks.setdefault(key, []).append(ev.name)
        elif ev.ph == "E":
            assert stacks.get(key), f"E {ev.name} on empty track {key}"
            assert stacks[key][-1] == ev.name, (
                f"E {ev.name} closes {stacks[key][-1]}")
            stacks[key].pop()
        elif ev.ph == "b":
            opens[(ev.name, ev.id)] = opens.get((ev.name, ev.id), 0) + 1
        elif ev.ph == "e":
            assert opens.get((ev.name, ev.id), 0) > 0, (
                f"e {ev.name} id={ev.id} never opened")
            opens[(ev.name, ev.id)] -= 1
    assert all(not s for s in stacks.values()), f"left open: {stacks}"
    assert all(n == 0 for n in opens.values()), f"async open: {opens}"


def _token_events(tr):
    return [e for e in tr.events() if e.ph == "i" and e.name == "token"]


def test_tracing_matches_stats_under_preemption_churn():
    """Full-detail trace of the preemption-churn config: spans balance,
    and the per-token event stream reproduces every request's tokens,
    versions, and the engine's aggregate counters exactly."""
    tr = Tracer(detail="full")
    eng = ServeEngine(BUNDLE, PARAMS, num_blocks=7, block_size=4,
                      max_batch=3, max_seq_len=64, temperature=1e-4,
                      seed=0, tracer=tr)
    reqs = [eng.submit(r, n) for r, n in zip(PROMPTS, BUDGETS)]
    trajs = {t.request_id: t for t in eng.run(max_steps=400)}
    assert eng.stats.preemptions > 0
    evs = tr.events()
    _assert_balanced(evs)

    toks = _token_events(tr)
    assert len(toks) == eng.stats.tokens_out == sum(BUDGETS)
    by_rid = {}
    for ev in toks:
        by_rid.setdefault(ev.args["rid"], []).append(ev)
    assert set(by_rid) == {r.request_id for r in reqs}
    for rid, seq in by_rid.items():
        np.testing.assert_array_equal(
            [e.args["tok"] for e in seq], trajs[rid].tokens)
        np.testing.assert_array_equal(
            [e.args["v"] for e in seq], trajs[rid].versions)

    preempts = [e for e in evs if e.ph == "i" and e.name == "preempt"]
    assert len(preempts) == eng.stats.preemptions
    retires = [e for e in evs if e.ph == "i" and e.name == "retire"]
    assert len(retires) == len(reqs)
    assert {e.args["rid"] for e in retires} == set(by_rid)

    # Latency histograms saw every emission: one TTFT per request, one
    # inter-token gap per remaining token (preemption gaps included).
    stats = collect_serve_stats(eng)
    assert stats["ttft_count"] == len(reqs)
    assert stats["inter_token_count"] == eng.stats.tokens_out - len(reqs)
    assert stats["request_latency_count"] == len(reqs)
    assert stats["queue_wait_count"] >= len(reqs) + eng.stats.preemptions


def test_tracing_swap_provenance_matches_versions():
    """In-flight swap: the trace's swap instant and per-token version
    stream agree with the trajectory's recorded provenance, and the
    swap-to-first-stale-token histogram fires exactly once."""
    tr = Tracer(detail="full")
    store = PolicyStore(PARAMS, capacity=4)
    eng = ServeEngine(BUNDLE, store=store, num_blocks=32, block_size=4,
                      max_batch=2, max_seq_len=64, temperature=1.0,
                      seed=3, tracer=tr)
    eng.submit(PROMPTS[0], 12)
    for _ in range(5):
        assert not eng.step()
    store.publish(jax.tree.map(lambda x: x + 0.01, PARAMS))
    traj = eng.run(max_steps=200)[0]
    _assert_balanced(tr.events())

    swaps = [e for e in tr.events() if e.ph == "i" and e.name == "swap"]
    assert len(swaps) == 1 == eng.stats.swaps
    assert swaps[0].args == {"old": 0, "new": 1}
    toks = _token_events(tr)
    np.testing.assert_array_equal(
        [e.args["v"] for e in toks], traj.versions)
    assert traj.versions[0] == 0 and traj.versions[-1] == 1
    # every post-swap token was emitted after the swap instant
    first_new = next(e for e in toks if e.args["v"] == 1)
    assert first_new.ts >= swaps[0].ts
    assert collect_serve_stats(eng)["swap_to_stale_count"] == 1


def test_tracing_speculative_rollback_accounting():
    """Adversarial draft: rollback instants account for exactly the
    drafted-minus-accepted tokens ServeStats reports."""
    tr = Tracer(detail="full")
    bad_draft = lambda req, k: np.zeros((k,), np.int32)  # noqa: E731
    eng = ServeEngine(BUNDLE, PARAMS, num_blocks=32, block_size=4,
                      max_batch=2, max_seq_len=64, temperature=1e-4,
                      seed=0, speculate_k=3, draft=bad_draft, tracer=tr)
    for r in PROMPTS[:2]:
        eng.submit(r, 8)
    eng.run(max_steps=400)
    _assert_balanced(tr.events())
    assert eng.stats.drafted_tokens > 0
    rejected = sum(
        e.args["rejected"] for e in tr.events()
        if e.ph == "i" and e.name == "rollback")
    assert rejected == eng.stats.drafted_tokens - eng.stats.accepted_tokens
    assert rejected > 0
    # host-callable drafts don't dispatch a model, so no "draft" span —
    # but every speculative round runs the fused verify.
    verifies = [e for e in tr.events()
                if e.ph == "B" and e.name == "verify"]
    assert len(verifies) > 0


def test_tracing_off_emits_nothing_and_matches_traced_run():
    """NULL_TRACER (the default) records nothing, and tracing does not
    perturb generation: greedy outputs are identical with and without
    a full-detail tracer attached."""
    from repro.obs import NULL_TRACER

    def _run(tracer):
        eng = ServeEngine(BUNDLE, PARAMS, num_blocks=7, block_size=4,
                          max_batch=3, max_seq_len=64, temperature=1e-4,
                          seed=0, tracer=tracer)
        reqs = [eng.submit(r, n) for r, n in zip(PROMPTS, BUDGETS)]
        trajs = {t.request_id: t for t in eng.run(max_steps=400)}
        return [trajs[r.request_id].tokens for r in reqs]

    before = len(NULL_TRACER)
    plain = _run(None)
    assert len(NULL_TRACER) == before == 0
    tr = Tracer(detail="full")
    traced = _run(tr)
    for a, b in zip(plain, traced):
        np.testing.assert_array_equal(a, b)
