"""TV estimation + filtering: analytic properties, gradient semantics, and
hypothesis property tests on the controller invariant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.tv_filter import (
    apply_detach,
    exact_tv_decrease_check,
    tv_estimate,
    tv_filter_mask,
)
from repro.core.losses import VACOConfig, vaco_policy_loss
from repro.core.distributions import Categorical


def test_tv_estimate_on_policy_zero():
    assert float(tv_estimate(jnp.zeros((128,)))) == 0.0


def test_tv_estimate_matches_formula():
    lr = jnp.array([0.0, jnp.log(2.0), jnp.log(0.5)])
    # 0.5 * mean(|1-1|, |2-1|, |0.5-1|) = 0.5 * (0 + 1 + 0.5)/3 = 0.25
    np.testing.assert_allclose(float(tv_estimate(lr)), 0.25, rtol=1e-6)


def test_tv_estimate_is_unbiased_for_exact_tv():
    """Sampled estimator (Eq. 8) converges to exact D_TV for categoricals."""
    key = jax.random.PRNGKey(0)
    logits_b = jax.random.normal(key, (8,))
    logits_p = logits_b + 0.3 * jax.random.normal(jax.random.PRNGKey(1), (8,))
    beta = Categorical(logits_b)
    pi = Categorical(logits_p)
    exact = float(beta.tv(pi))
    keys = jax.random.split(jax.random.PRNGKey(3), 200_000)
    acts = jax.vmap(beta.sample)(keys)
    lr = pi.log_probs[acts] - beta.log_probs[acts]
    est = float(tv_estimate(lr))
    assert abs(est - exact) < 0.01


def test_filter_inactive_below_threshold():
    lr = 0.01 * jnp.ones((64,))
    adv = jnp.ones((64,))
    res = tv_filter_mask(log_ratios=lr, advantages=adv, delta=0.2)
    assert not bool(res.active)
    assert float(jnp.sum(res.detach_mask)) == 0.0


def test_filter_targets_exactly_tv_increasing_samples():
    key = jax.random.PRNGKey(1)
    lr = jax.random.normal(key, (256,))
    adv = jax.random.normal(jax.random.PRNGKey(2), (256,))
    res = tv_filter_mask(log_ratios=lr, advantages=adv, delta=0.0)
    assert bool(res.active)
    should = exact_tv_decrease_check(lr, adv) > 0
    np.testing.assert_array_equal(
        np.asarray(res.detach_mask > 0), np.asarray(should))


def test_detach_kills_gradient_only_on_masked():
    lr = jnp.array([0.5, -0.5, 0.2])
    mask = jnp.array([1.0, 0.0, 1.0])

    def f(x):
        return jnp.sum(jnp.exp(apply_detach(x, mask)))

    g = jax.grad(f)(lr)
    assert g[0] == 0.0 and g[2] == 0.0 and g[1] != 0.0


def test_vaco_loss_gradient_never_increases_tv_direction():
    """The signature property: with the filter on, the resulting update
    direction cannot have positive inner product with grad(TV) computed on
    the same minibatch (per-sample contributions all non-positive)."""
    key = jax.random.PRNGKey(3)
    # One logit parameter per sample: ratio_i = exp(theta_i - beta_i).
    theta = jax.random.normal(key, (512,))
    log_beta = jax.random.normal(jax.random.PRNGKey(4), (512,))
    adv = jax.random.normal(jax.random.PRNGKey(5), (512,))
    cfg = VACOConfig(delta=0.0)  # force the filter active

    def loss(th):
        l, _ = vaco_policy_loss(
            log_pi=th, log_beta=log_beta, advantages=adv, cfg=cfg)
        return l

    def tv(th):
        return tv_estimate(th - log_beta)

    g_loss = jax.grad(loss)(theta)
    g_tv = jax.grad(tv)(theta)
    # Gradient *descent* step direction is -g_loss; it must not align with
    # +g_tv on any sample: elementwise (-g_loss) * g_tv <= 0 up to fp noise.
    assert float(jnp.max((-g_loss) * g_tv)) <= 1e-7


@settings(max_examples=50, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    delta=st.floats(0.01, 1.0),
    n=st.integers(2, 300),
)
def test_property_filter_controller(seed, delta, n):
    """Hypothesis: (1) filter only activates when TV > delta/2; (2) detach
    mask is a subset of the TV-increasing set; (3) frac_filtered in [0,1];
    (4) masking respects the validity mask."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    lr = jax.random.normal(k1, (n,))
    adv = jax.random.normal(k2, (n,))
    valid = jax.random.bernoulli(k3, 0.8, (n,)).astype(jnp.float32)
    if float(jnp.sum(valid)) == 0.0:
        valid = jnp.ones((n,), jnp.float32)
    res = tv_filter_mask(
        log_ratios=lr, advantages=adv, delta=delta, valid_mask=valid)
    tv = float(tv_estimate(lr, valid))
    assert bool(res.active) == (tv > delta / 2.0)
    mask = np.asarray(res.detach_mask)
    assert ((mask == 0) | (mask == 1)).all()
    if bool(res.active):
        should = np.asarray(
            (exact_tv_decrease_check(lr, adv) > 0) & (valid > 0))
        assert (mask.astype(bool) <= should).all()  # subset
        assert (mask.astype(bool) == should).all()  # actually equal
    else:
        assert mask.sum() == 0
    assert 0.0 <= float(res.frac_filtered) <= 1.0
    assert (mask <= np.asarray(valid)).all()


def test_vaco_loss_value_unchanged_by_filter():
    """Detaching alters gradients, not the loss value."""
    lr = jax.random.normal(jax.random.PRNGKey(6), (128,))
    log_beta = jnp.zeros((128,))
    adv = jax.random.normal(jax.random.PRNGKey(7), (128,))
    l_on, _ = vaco_policy_loss(
        log_pi=lr, log_beta=log_beta, advantages=adv,
        cfg=VACOConfig(delta=0.0))
    l_off, _ = vaco_policy_loss(
        log_pi=lr, log_beta=log_beta, advantages=adv,
        cfg=VACOConfig(delta=1e9))
    np.testing.assert_allclose(float(l_on), float(l_off), rtol=1e-6)
