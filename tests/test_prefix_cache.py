"""Prefix sharing in the paged KV cache: hardened release, refcount
lifecycle + LRU eviction of zero-ref cached pages, chain-hash
content addressing, best-of-N token-exactness vs the unshared engine
(greedy, speculative, sharded), COW on mid-page divergence, preemption
churn, version-salt invalidation on in-flight weight swaps, and
sliding-window page reclamation."""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.tokenizer import get_tokenizer
from repro.models.registry import build
from repro.runtime import PolicyStore
from repro.serve import (
    BlockAllocator,
    OutOfBlocks,
    ServeEngine,
    ShardedBlockAllocator,
    prefix_key,
)

TOK = get_tokenizer()
CFG = ModelConfig(
    name="prefix-test", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=TOK.vocab_size,
)
BUNDLE = build(CFG)
PARAMS = BUNDLE.init(jax.random.PRNGKey(0))

PROMPTS = [np.asarray(TOK.encode(p), np.int32)
           for p in ("12+345=?#", "998-76=?#")]


def _engine(prefix, **kw):
    defaults = dict(num_blocks=64, block_size=4, max_batch=4,
                    max_seq_len=64, temperature=1e-4, seed=0)
    defaults.update(kw)
    return ServeEngine(BUNDLE, kw.pop("params", PARAMS),
                       prefix_cache=prefix, **defaults)


def _serve_best_of(eng, n=4, budget=8, prompts=PROMPTS):
    """Each prompt submitted `n` times; greedy -> identical siblings."""
    rid = 0
    for p in prompts:
        for _ in range(n):
            eng.submit(p, budget, request_id=f"r{rid}")
            rid += 1
    return {t.request_id: np.asarray(t.tokens)
            for t in eng.run(max_steps=600)}


# --- hardened release (satellite 1) -----------------------------------------


@pytest.mark.parametrize("sharded", [False, True])
def test_release_rejects_double_free_and_out_of_range(sharded):
    if sharded:
        a = ShardedBlockAllocator(8, 4, num_shards=2)
    else:
        a = BlockAllocator(8, 4)
    blocks = a.allocate(2)
    a.release(blocks)
    with pytest.raises(ValueError, match="double free"):
        a.release(blocks[:1])
    with pytest.raises(ValueError, match="out of range"):
        a.release([a.shard_num_blocks])
    with pytest.raises(ValueError, match="out of range"):
        a.release([-2])
    # the failed releases corrupted nothing
    assert a.num_free == a.num_blocks if not sharded else True
    got = a.allocate(a.shard_num_blocks)
    assert len(set(got)) == a.shard_num_blocks


def test_release_double_free_detected_for_shared_pages():
    a = BlockAllocator(4, 4, prefix_cache=True)
    (b,) = a.allocate(1)
    a.share(b)                       # ref 2
    a.release([b]), a.release([b])   # both owners drop
    with pytest.raises(ValueError, match="double free"):
        a.release([b])


# --- refcount lifecycle + evictable LRU -------------------------------------


def _key_for(ids, bs=4, salt=b"s"):
    return prefix_key(np.asarray(ids, np.int32), bs, salt)


def test_refcount_lifecycle_and_lru_eviction():
    a = BlockAllocator(4, 4, prefix_cache=True)
    key = _key_for(list(range(8)))           # 2 full pages
    blocks = a.allocate(2)
    a.register(key, blocks, 0)
    assert a.num_indexed > 0

    # release -> pages park on the evictable LRU, still matchable
    a.release(blocks)
    assert a.num_cached == 2 and a.num_free == 4
    m = a.lookup(key, limit=7)
    assert m.full_pages == blocks[:1]        # limit 7 caps at 1 full page
    m = a.lookup(key, limit=8)
    assert m.full_pages == blocks and m.matched_tokens == 8

    # share revives from the LRU: ref 0 -> 1, no longer evictable
    a.share(blocks[0])
    assert a.ref(blocks[0]) == 1 and a.num_cached == 1
    with pytest.raises(ValueError, match="cannot share"):
        a.share(a.allocate(2)[0] if False else 3)  # page 3 is free

    # pool pressure: free pages go first, then the LRU evicts the
    # remaining parked page and drops its index entries
    a.allocate(3)
    assert a.evictions == 1 and a.num_cached == 0
    # the evicted page's entries are gone; the pinned (live) page 0 is
    # still indexed and matchable
    m = a.lookup(key, limit=8)
    assert m.full_pages == blocks[:1] and m.matched_tokens == 4
    with pytest.raises(OutOfBlocks):
        a.allocate(1)                        # pinned share is not evictable


def test_flush_returns_cached_pages_to_free_list():
    a = BlockAllocator(4, 4, prefix_cache=True)
    key = _key_for(list(range(8)))
    blocks = a.allocate(2)
    a.register(key, blocks, 0)
    a.release(blocks)
    a.flush()
    assert a.num_cached == 0 and a.num_indexed == 0
    assert a.num_free == 4
    assert a.lookup(key, limit=8).matched_tokens == 0


# --- content addressing ------------------------------------------------------


def test_prefix_key_chain_and_salt():
    ids = list(range(10))
    k1 = _key_for(ids, bs=4, salt=b"v0")
    assert len(k1.chain) == 2 and k1.tail == (8, 9)
    # chain hash j certifies pages 0..j: a change in page 0 moves BOTH
    k2 = _key_for([99] + ids[1:], bs=4, salt=b"v0")
    assert k1.chain[0] != k2.chain[0] and k1.chain[1] != k2.chain[1]
    # same ids, different salt (policy version / arch) -> disjoint keys
    k3 = _key_for(ids, bs=4, salt=b"v1")
    assert k1.chain[0] != k3.chain[0] and k1.root != k3.root


def test_lookup_mid_page_divergence_yields_cow():
    a = BlockAllocator(8, 4, prefix_cache=True)
    key = _key_for([0, 1, 2, 3, 4, 5, 6, 7])
    blocks = a.allocate(2)
    a.register(key, blocks, 0)
    # diverges inside page 1 (two common rows) -> COW source match
    other = _key_for([0, 1, 2, 3, 4, 5, 99, 98])
    m = a.lookup(other, limit=7)
    assert m.full_pages == blocks[:1]
    assert m.cow_page == blocks[1] and m.cow_rows == 2
    assert m.matched_tokens == 6
    # a fully matching tail page is shared outright (no COW)
    m = a.lookup(key, limit=8)
    assert m.full_pages == blocks and m.cow_page is None


# --- engine: best-of-N exactness + prefill savings (tentpole) ---------------


@pytest.mark.parametrize("speculate", [0, 3])
def test_best_of_token_exact_and_prefill_savings(speculate):
    kw = {}
    if speculate:
        kw = dict(speculate_k=speculate, draft=("params", PARAMS))
    want = _serve_best_of(_engine(False, **kw))
    eng = _engine(True, **kw)
    got = _serve_best_of(eng)
    assert set(want) == set(got)
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    # N=4 dense prefills collapsed to ~1 per prompt + cheap suffixes
    assert eng.stats.prefill_tokens < sum(len(p) for p in PROMPTS) * 2
    assert eng.scheduler.prefix_hits > 0
    assert eng.stats.cow_copies > 0          # prompts diverge mid-page
    # every reference dropped on retire; evictable pages still count free
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_preemption_churn_token_exact():
    """A pool too small for the stream forces preempt/re-admit cycles;
    re-prefill through the cache (and eviction under pressure) must not
    change a single greedy token."""
    kw = dict(num_blocks=10, block_size=4, max_batch=3, max_seq_len=48)
    want = _serve_best_of(_engine(False, **kw), n=3, budget=10)
    eng = _engine(True, **kw)
    got = _serve_best_of(eng, n=3, budget=10)
    assert eng.stats.preemptions > 0
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    assert eng.allocator.num_free == eng.allocator.num_blocks


# --- version salt: in-flight weight swap invalidates ------------------------


def test_version_salt_invalidates_after_swap():
    store = PolicyStore(PARAMS, capacity=4)
    eng = ServeEngine(BUNDLE, store=store, num_blocks=64, block_size=4,
                      max_batch=2, max_seq_len=64, temperature=1e-4,
                      seed=0, prefix_cache=True)
    eng.submit(PROMPTS[0], 6, request_id="warm")
    eng.run(max_steps=200)
    hits_before = eng.scheduler.prefix_hits

    p2 = jax.tree.map(lambda x: x + 0.01, PARAMS)
    store.publish(p2)
    eng.submit(PROMPTS[0], 6, request_id="postswap")
    (traj,) = eng.run(max_steps=200)
    # v0-salted entries are unreachable under v1: no stale-KV sharing
    assert eng.scheduler.prefix_hits == hits_before

    fresh = ServeEngine(BUNDLE, p2, num_blocks=64, block_size=4,
                        max_batch=2, max_seq_len=64, temperature=1e-4,
                        seed=0)
    fresh.submit(PROMPTS[0], 6, request_id="postswap")
    (want,) = fresh.run(max_steps=200)
    np.testing.assert_array_equal(traj.tokens, want.tokens)


# --- sliding-window page reclamation (satellite 2) --------------------------


WIN_CFG = CFG.replace(name="prefix-window-test", sliding_window=6,
                      global_every=5)    # both layers windowed
WIN_BUNDLE = build(WIN_CFG)
WIN_PARAMS = WIN_BUNDLE.init(jax.random.PRNGKey(1))


def test_window_reclamation_token_exact():
    """All-windowed arch: pages entirely behind the widest window are
    released mid-flight; emitted tokens must match the non-reclaiming
    engine exactly (the freed rows were mask-invisible)."""
    def _run(reclaim):
        eng = ServeEngine(
            WIN_BUNDLE, WIN_PARAMS, num_blocks=32, block_size=4,
            max_batch=2, max_seq_len=64, temperature=1e-4, seed=0,
            window_reclaim=reclaim)
        for i, p in enumerate(PROMPTS):
            eng.submit(p, 14, request_id=f"w{i}")
        out = {t.request_id: np.asarray(t.tokens)
               for t in eng.run(max_steps=400)}
        return out, eng

    want, base = _run(False)
    got, eng = _run(True)
    assert base._reclaim_window is None and eng._reclaim_window == 6
    assert eng.scheduler.reclaimed_pages > 0
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    assert eng.allocator.num_free == eng.allocator.num_blocks
    # mixed local:global archs must NOT reclaim (global layers need all)
    mixed = ServeEngine(build(CFG.replace(name="mix", sliding_window=4,
                                          global_every=2)),
                        WIN_PARAMS, num_blocks=8, block_size=4,
                        max_batch=1, max_seq_len=32)
    assert mixed._reclaim_window is None


# --- sharded placement (CI: 8 fake CPU devices) -----------------------------


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices (set XLA_FLAGS="
                           "--xla_force_host_platform_device_count=8)")
@pytest.mark.parametrize("speculate", [0, 2])
def test_sharded_best_of_token_exact(speculate):
    from repro.launch.mesh import make_debug_mesh

    data = min(len(jax.devices()), 8)
    mesh = make_debug_mesh(data=data)
    kw = dict(num_blocks=8 * data, block_size=4, max_batch=4,
              max_seq_len=48)
    if speculate:
        kw.update(speculate_k=speculate, draft=("params", PARAMS))
    want = _serve_best_of(_engine(False, **kw), budget=6)
    eng = _engine(True, mesh=mesh, **kw)
    got = _serve_best_of(eng, budget=6)
    for rid in want:
        np.testing.assert_array_equal(got[rid], want[rid])
    # best-of siblings landed on the match's home shard and shared pages
    assert eng.scheduler.prefix_hits > 0
    assert eng.allocator.num_free == eng.allocator.num_blocks
    assert all(s.num_free == s.num_blocks for s in eng.allocator._shards)
