"""Substrate tests: optimizer, schedules, clipping, checkpoint, metrics,
tokenizer, math generator, envs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data.mathgen import (
    MathTaskDataset,
    extract_answer,
    sample_problem,
    verify,
)
from repro.data.tokenizer import get_tokenizer
from repro.envs import ENV_MAKERS, make_env, wrap_autoreset
from repro.metrics.aggregate import (
    aggregate_metrics,
    iqm,
    minmax_normalize,
    optimality_gap,
    stratified_bootstrap_ci,
)
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    linear_anneal,
    warmup_cosine,
)


# --- optimizer -------------------------------------------------------------


def test_adamw_matches_reference_impl():
    """Hand-rolled AdamW vs a literal numpy transcription of the paper
    update, 10 steps on a quadratic."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    state = adamw_init(params)
    w_np = np.asarray([1.0, -2.0, 3.0])
    m = np.zeros(3)
    v = np.zeros(3)
    for t in range(1, 11):
        g = {"w": 2.0 * params["w"]}  # grad of ||w||^2
        params, state = adamw_update(g, state, params, cfg)
        g_np = 2.0 * w_np
        m = 0.9 * m + 0.1 * g_np
        v = 0.999 * v + 0.001 * g_np * g_np
        mhat = m / (1 - 0.9**t)
        vhat = v / (1 - 0.999**t)
        w_np = w_np - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(params["w"]), w_np,
                               rtol=1e-4, atol=1e-5)


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.05)
    params = {"w": jnp.ones((8,)) * 5.0}
    state = adamw_init(params)
    for _ in range(500):
        g = {"w": 2.0 * params["w"]}
        params, state = adamw_update(g, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_weight_decay_decoupled():
    """AdamW decay shrinks params even with zero gradient."""
    cfg = AdamWConfig(lr=0.1, weight_decay=0.1)
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params)
    params, _ = adamw_update({"w": jnp.zeros((4,))}, state, params, cfg)
    assert float(params["w"][0]) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 10.0, rtol=1e-6)
    from repro.utils.tree import tree_global_norm
    np.testing.assert_allclose(float(tree_global_norm(clipped)), 1.0,
                               rtol=1e-5)
    # no-op below the bound
    clipped2, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), 3.0)


def test_schedules():
    lin = linear_anneal(100)
    assert float(lin(0)) == 1.0
    np.testing.assert_allclose(float(lin(50)), 0.5)
    cos = cosine_schedule(100)
    assert float(cos(0)) == 1.0 and float(cos(100)) < 1e-6
    wc = warmup_cosine(10, 110)
    assert float(wc(5)) == 0.5 and float(wc(10)) == 1.0


# --- checkpoint --------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "layers": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "step": jnp.asarray(7, jnp.int32),
        "nested": [jnp.ones((2,)), jnp.zeros((1,), jnp.bool_)],
    }
    path = save_checkpoint(str(tmp_path), 42, tree, meta={"arch": "t"})
    restored, step, meta = load_checkpoint(path, tree)
    assert step == 42 and meta["arch"] == "t"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.ones((3,))}
    path = save_checkpoint(str(tmp_path), 0, tree)
    with pytest.raises(ValueError):
        load_checkpoint(path, {"w": jnp.ones((4,))})


# --- metrics -----------------------------------------------------------------


def test_iqm_drops_tails():
    x = np.array([[0.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 100.0]])
    assert iqm(x) == 1.0


def test_minmax_normalize_bounds():
    scores = {"a": np.random.rand(3, 5), "b": np.random.rand(3, 5) * 2}
    normed = minmax_normalize(scores)
    allv = np.stack(list(normed.values()))
    assert allv.min() >= 0.0 and allv.max() <= 1.0 + 1e-12


def test_bootstrap_ci_contains_point():
    scores = np.random.default_rng(0).normal(0.5, 0.1, size=(4, 10))
    pt, lo, hi = stratified_bootstrap_ci(scores, iqm, n_boot=200)
    assert lo <= pt <= hi


def test_aggregate_metrics_full_table():
    rng = np.random.default_rng(1)
    table = aggregate_metrics(
        {"vaco": rng.random((3, 4)) + 0.5, "ppo": rng.random((3, 4))},
        n_boot=100,
    )
    assert set(table) == {"vaco", "ppo"}
    assert set(table["vaco"]) == {"median", "iqm", "mean", "optimality_gap"}


# --- tokenizer / mathgen -----------------------------------------------------


def test_tokenizer_roundtrip():
    tok = get_tokenizer()
    s = "12+(3*4)=?# answer 15"
    ids = tok.encode(s)
    assert tok.decode(ids) == s
    padded = tok.pad_to(ids, 64, left=True)
    assert padded.shape == (64,) and padded[0] == tok.pad_id


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10**6), level=st.integers(0, 3))
def test_mathgen_verifier_consistent(seed, level):
    rng = np.random.default_rng(seed)
    p = sample_problem(rng, level)
    assert verify(p.answer, p.answer) == 1.0
    assert verify("the answer is " + p.answer, p.answer) == 1.0
    wrong = str(int(p.answer) + 1)
    assert verify(wrong, p.answer) == 0.0
    assert extract_answer("no numbers here") is None


def test_dataset_eval_train_disjoint():
    ds = MathTaskDataset(pool_size=256, seed=3)
    evals = {p.prompt for p in ds.eval_set}
    trains = {p.prompt for p in ds.train_set}
    # may collide by template coincidence, but must not be identical sets
    assert len(evals & trains) < len(evals)


def test_supervised_batch_masks_answer_only():
    ds = MathTaskDataset(prompt_len=24, level=0, pool_size=64)
    toks, mask = ds.supervised_batch(4, completion_len=8)
    assert toks.shape == (4, 32) and mask.shape == (4, 32)
    assert mask.sum() > 0
    # mask only covers non-pad token positions
    assert ((mask > 0) <= (toks >= 0)).all()


# --- envs --------------------------------------------------------------------


@pytest.mark.parametrize("name", list(ENV_MAKERS))
def test_env_step_finite_and_jittable(name):
    env = wrap_autoreset(make_env(name))
    key = jax.random.PRNGKey(0)
    state = env.reset(key)
    obs = env.observe(state)
    assert obs.shape == (env.obs_dim,)

    @jax.jit
    def run(state, key):
        def body(carry, k):
            state = carry
            a = jnp.zeros((env.act_dim,))
            state, ts = env.step(state, a, k)
            return state, (ts.obs, ts.reward)
        return jax.lax.scan(body, state, jax.random.split(key, 50))

    state, (obses, rewards) = run(state, jax.random.PRNGKey(1))
    assert bool(jnp.all(jnp.isfinite(obses)))
    assert bool(jnp.all(jnp.isfinite(rewards)))


def test_autoreset_respects_time_limit():
    env = wrap_autoreset(make_env("pendulum", max_steps=10))
    state = env.reset(jax.random.PRNGKey(0))
    dones = []
    for i in range(25):
        state, ts = env.step(state, jnp.zeros((1,)),
                             jax.random.PRNGKey(i + 1))
        dones.append(bool(ts.done))
    assert dones[9] and dones[19]
    assert sum(dones) == 2
