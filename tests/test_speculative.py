"""Speculative decode over the paged cache: multi-token verify kernel
parity (interpret mode vs the jnp oracle), the accept/rollback rule,
engine token-exactness vs non-speculative greedy decode at every
acceptance rate, rollback under preemption churn, PolicyStore draft
pinning, and batched prefill admissions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.tokenizer import PAD, get_tokenizer
from repro.kernels import ref
from repro.kernels.paged_attention_pallas import (
    paged_attention,
    paged_attention_multi,
)
from repro.models.registry import build
from repro.rollout.sampler import generate, score_tokens, speculative_accept
from repro.runtime import PolicyStore
from repro.runtime.policy_store import StaleVersionError
from repro.serve import ServeEngine

TOK = get_tokenizer()
CFG = ModelConfig(
    name="spec-test", arch_type="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=TOK.vocab_size,
)
BUNDLE = build(CFG)
PARAMS = BUNDLE.init(jax.random.PRNGKey(0))
# A draft from a different init proposes junk -> acceptance ~0 (the
# adversarial end of the acceptance spectrum).
ADVERSARIAL_PARAMS = BUNDLE.init(jax.random.PRNGKey(99))

PROMPTS = [np.asarray(TOK.encode(p), np.int32)
           for p in ("1+2=?#", "3*4=?#", "10-7=?#")]
BUDGETS = [5, 9, 13]
KEY = jax.random.PRNGKey(0)


def _greedy_reference(params, row, n):
    g = jax.jit(lambda p, t, k: generate(
        BUNDLE, p, t, k, max_new_tokens=n, temperature=1e-4))(
        params, jnp.asarray(row)[None], jax.random.PRNGKey(7))
    return np.asarray(g.completion[0])


GREEDY_WANT = [_greedy_reference(PARAMS, r, n)
               for r, n in zip(PROMPTS, BUDGETS)]


# --- multi-token verify kernel: interpret-mode parity vs the oracle ---------


def _ragged_tables(rng, b, num_blocks, max_blocks, bs, t):
    tables = np.zeros((b, max_blocks), np.int32)
    lens = np.zeros((b,), np.int32)
    perm = rng.permutation(num_blocks)
    pi = 0
    for i in range(b):
        n = int(rng.integers(t, max_blocks * bs))
        lens[i] = n
        pages = -(-n // bs)
        tables[i, :pages] = perm[pi:pi + pages]
        pi += pages
    return jnp.asarray(tables), jnp.asarray(lens)


@pytest.mark.parametrize("b,t,h,kv,d,bs,window", [
    (2, 4, 4, 2, 16, 4, None),
    (3, 2, 2, 2, 8, 4, None),
    (2, 3, 4, 4, 16, 8, None),
    (2, 4, 4, 2, 16, 4, 6),
    (1, 5, 8, 2, 32, 4, None),
])
def test_paged_attention_multi_parity_sweep(b, t, h, kv, d, bs, window):
    """Pallas multi-query kernel (interpret) vs jnp oracle on shuffled,
    ragged block tables."""
    rng = np.random.default_rng(b * 17 + t)
    ks = jax.random.split(jax.random.fold_in(KEY, b * t * d), 3)
    q = jax.random.normal(ks[0], (b, t, h, d))
    kp = jax.random.normal(ks[1], (kv, 24, bs, d))
    vp = jax.random.normal(ks[2], (kv, 24, bs, d))
    tables, lens = _ragged_tables(rng, b, 24, 4, bs, t)
    out = paged_attention_multi(q, kp, vp, tables, lens,
                                window=window, interpret=True)
    want = ref.ref_paged_attention_multi(q, kp, vp, tables, lens,
                                         window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_multi_t1_reduces_to_single():
    """T=1 is exactly the plain decode kernel (oracle and Pallas)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 16))
    kp = jax.random.normal(ks[1], (2, 8, 4, 16))
    vp = jax.random.normal(ks[2], (2, 8, 4, 16))
    tables = jnp.asarray([[0, 1, 2], [3, 4, 5]], jnp.int32)
    lens = jnp.asarray([7, 3], jnp.int32)
    multi = paged_attention_multi(q, kp, vp, tables, lens, interpret=True)
    single = paged_attention(q[:, 0], kp, vp, tables, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(multi[:, 0]), np.asarray(single),
                               rtol=2e-5, atol=2e-5)


def test_paged_attention_multi_inactive_slot_zero():
    """context_len 0 (an empty serve slot) must yield exactly zero."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 3, 4, 16))
    kp = jax.random.normal(ks[1], (2, 8, 4, 16))
    vp = jax.random.normal(ks[2], (2, 8, 4, 16))
    tables = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    lens = jnp.asarray([0, 6], jnp.int32)
    for fn in (
        lambda: paged_attention_multi(q, kp, vp, tables, lens,
                                      interpret=True),
        lambda: ref.ref_paged_attention_multi(q, kp, vp, tables, lens),
    ):
        out = np.asarray(fn())
        np.testing.assert_array_equal(out[0], 0.0)
        assert np.abs(out[1]).max() > 0


def test_decode_step_paged_multi_matches_sequential_steps():
    """The fused T-token verify step == T sequential single-token paged
    decode steps, in logits and in the pool it leaves behind."""
    B, T, NB, BS, M = 2, 4, 16, 4, 6
    rng = np.random.default_rng(0)
    tables = np.zeros((B, M), np.int32)
    tables[0, :4] = [3, 7, 1, 9]
    tables[1, :4] = [2, 5, 8, 11]
    pos = jnp.asarray([5, 2], jnp.int32)
    active = jnp.asarray([True, True])
    cap = jnp.asarray([4 * BS, 4 * BS], jnp.int32)
    toks = rng.integers(0, CFG.vocab_size, (B, T)).astype(np.int32)

    seq_logits, p, pages = [], pos, BUNDLE.init_paged_cache(NB, BS)
    for t in range(T):
        out, pages = BUNDLE.decode_step_paged(
            PARAMS, jnp.asarray(toks[:, t]), pages, jnp.asarray(tables),
            p, active)
        seq_logits.append(out.logits)
        p = p + 1
    out_m, pages_m = BUNDLE.decode_step_paged_multi(
        PARAMS, jnp.asarray(toks), BUNDLE.init_paged_cache(NB, BS),
        jnp.asarray(tables), pos, active, cap)
    np.testing.assert_allclose(
        np.asarray(out_m.logits), np.asarray(jnp.stack(seq_logits, 1)),
        rtol=2e-5, atol=2e-5)
    for k in ("k_pages", "v_pages"):
        np.testing.assert_allclose(np.asarray(pages_m[k]),
                                   np.asarray(pages[k]),
                                   rtol=1e-6, atol=1e-6)


def test_decode_step_paged_multi_write_cap_drops_overflow():
    """Positions past a slot's allocated rows must not write anywhere —
    especially not into the table's in-range pad pages (page 0)."""
    B, T, NB, BS, M = 1, 4, 8, 4, 2
    tables = jnp.asarray([[3, 0]], jnp.int32)   # pad slot points at page 0
    pos = jnp.asarray([2], jnp.int32)
    active = jnp.asarray([True])
    cap = jnp.asarray([BS], jnp.int32)          # only page 3's 4 rows owned
    toks = jnp.asarray([[5, 6, 7, 8]], jnp.int32)
    pages = BUNDLE.init_paged_cache(NB, BS)
    _, pages = BUNDLE.decode_step_paged_multi(
        PARAMS, toks, pages, tables, pos, active, cap)
    # rows 2..3 land in page 3; rows 4..5 (>= cap) must be dropped
    assert np.abs(np.asarray(pages["k_pages"][:, :, 3])).max() > 0
    np.testing.assert_array_equal(np.asarray(pages["k_pages"][:, :, 0]), 0.0)
    np.testing.assert_array_equal(np.asarray(pages["v_pages"][:, :, 0]), 0.0)


# --- the accept rule --------------------------------------------------------


def _peaked(tokens, vocab, hi=8.0):
    """Logits strongly peaked on `tokens` ([B, K])."""
    return jnp.where(
        tokens[..., None] == jnp.arange(vocab), hi, 0.0)


def test_speculative_accept_greedy_accept_all():
    v = 11
    drafts = jnp.asarray([[3, 5, 7, 2]], jnp.int32)
    logits = _peaked(drafts, v)
    toks, lps, n_acc, n_emit = speculative_accept(
        logits, drafts, logits, KEY, temperature=1e-4)
    assert int(n_acc[0]) == 4 and int(n_emit[0]) == 4
    np.testing.assert_array_equal(np.asarray(toks[0]), [3, 5, 7, 2])
    assert np.asarray(lps[0]).max() <= 0.0


def test_speculative_accept_greedy_reject_first():
    """Adversarial draft: everything rejected, the correction is the
    verifier argmax, and the tail is PAD with log-prob exactly 0."""
    v = 11
    drafts = jnp.asarray([[3, 5, 7, 2]], jnp.int32)
    verifier = _peaked(jnp.asarray([[4, 6, 8, 1]], jnp.int32), v)
    toks, lps, n_acc, n_emit = speculative_accept(
        verifier, drafts, _peaked(drafts, v), KEY, temperature=1e-4)
    assert int(n_acc[0]) == 0 and int(n_emit[0]) == 1
    assert int(toks[0, 0]) == 4                 # verifier argmax
    np.testing.assert_array_equal(np.asarray(toks[0, 1:]), PAD)
    np.testing.assert_array_equal(np.asarray(lps[0, 1:]), 0.0)


def test_speculative_accept_greedy_partial_prefix():
    v = 11
    drafts = jnp.asarray([[3, 5, 7, 2]], jnp.int32)
    verifier = _peaked(jnp.asarray([[3, 5, 9, 2]], jnp.int32), v)
    toks, lps, n_acc, n_emit = speculative_accept(
        verifier, drafts, _peaked(drafts, v), KEY, temperature=1e-4)
    assert int(n_acc[0]) == 2 and int(n_emit[0]) == 3
    np.testing.assert_array_equal(np.asarray(toks[0, :3]), [3, 5, 9])
    np.testing.assert_array_equal(np.asarray(toks[0, 3:]), PAD)


def test_speculative_accept_identical_distributions_accept_all():
    """q == p accepts everything at ANY temperature (the ratio is 1)."""
    logits = jax.random.normal(KEY, (2, 3, 17))
    drafts = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    _, _, n_acc, _ = speculative_accept(
        logits, drafts, logits, jax.random.PRNGKey(5), temperature=1.0)
    np.testing.assert_array_equal(np.asarray(n_acc), 3)


def test_speculative_accept_onehot_draft_marginal_is_verifier():
    """A deterministic (one-hot) proposal still emits tokens distributed
    exactly as the verifier: empirically the first-position marginal
    matches softmax(p) to sampling error."""
    v = 5
    verifier = jnp.tile(
        jnp.asarray([[0.5, 1.5, -0.3, 0.2, -1.0]]), (1, 1, 1))
    p = np.asarray(jax.nn.softmax(verifier[0, 0]))
    drafts = jnp.asarray([[1]], jnp.int32)       # always propose token 1
    onehot = jnp.where(drafts[..., None] == jnp.arange(v), 0.0, -1e9)
    counts = np.zeros(v)
    n = 4000
    keys = jax.random.split(jax.random.PRNGKey(3), n)
    emit = jax.jit(jax.vmap(lambda k: speculative_accept(
        verifier, drafts, onehot, k, temperature=1.0)[0][0, 0]))(keys)
    for t in np.asarray(emit):
        counts[int(t)] += 1
    np.testing.assert_allclose(counts / n, p, atol=0.03)


# --- engine: token-exactness across the acceptance spectrum -----------------


@pytest.mark.parametrize("label,draft,k", [
    ("accept_all", ("params", PARAMS), 4),
    ("adversarial", ("params", ADVERSARIAL_PARAMS), 4),
    ("k1", ("params", PARAMS), 1),
    ("callable", lambda req, k: np.zeros(k, np.int32), 3),
])
def test_spec_engine_token_exact_vs_nonspec_greedy(label, draft, k):
    """Speculative greedy output == non-speculative greedy output at any
    acceptance rate (the tentpole correctness bar)."""
    eng = ServeEngine(
        BUNDLE, PARAMS, num_blocks=32, block_size=4, max_batch=2,
        max_seq_len=64, temperature=1e-4, seed=0,
        speculate_k=k, draft=draft)
    reqs = [eng.submit(r, n) for r, n in zip(PROMPTS, BUDGETS)]
    trajs = {t.request_id: t for t in eng.run(max_steps=400)}
    for rq, w in zip(reqs, GREEDY_WANT):
        t = trajs[rq.request_id]
        np.testing.assert_array_equal(t.tokens, w)
        assert t.mask.tolist() == [1.0] * len(w)
    stats = eng.stats.as_dict()
    if label == "accept_all":
        assert stats["acceptance_rate"] == 1.0
    if label == "adversarial":
        assert stats["acceptance_rate"] == 0.0
    assert eng.allocator.num_free == eng.allocator.num_blocks


@pytest.mark.parametrize("label,draft", [
    ("accept_all", ("params", PARAMS)),
    ("adversarial", ("params", ADVERSARIAL_PARAMS)),
])
def test_spec_engine_adaptive_k_token_exact_and_adapts(label, draft):
    """Draft-aware scheduling: adaptive k stays token-exact (the round
    length never touches correctness) and the chosen-k histogram moves
    the way the acceptance EMA says it should — pinned at the max for
    an accept-all draft, collapsing toward 1 for an adversarial one."""
    from repro.metrics.runtime_metrics import collect_serve_stats

    k_max = 4
    eng = ServeEngine(
        BUNDLE, PARAMS, num_blocks=32, block_size=4, max_batch=2,
        max_seq_len=64, temperature=1e-4, seed=0,
        speculate_k=k_max, draft=draft, speculate_adaptive=True)
    reqs = [eng.submit(r, n) for r, n in zip(PROMPTS, BUDGETS)]
    trajs = {t.request_id: t for t in eng.run(max_steps=400)}
    for rq, w in zip(reqs, GREEDY_WANT):
        np.testing.assert_array_equal(trajs[rq.request_id].tokens, w)
    stats = collect_serve_stats(eng)
    assert stats["speculate_adaptive"] is True
    hist = {int(k): v for k, v in stats["chosen_k_histogram"].items()}
    assert sum(hist.values()) == eng.stats.spec_rounds > 0
    if label == "accept_all":
        # Acceptance EMA stays 1.0 -> every round drafts the full k.
        assert set(hist) == {k_max}
    else:
        # Rejections drag the EMA down; later rounds must shrink k.
        assert min(hist) < k_max


def test_adaptive_k_ema_resets_on_admission():
    """A slot's acceptance EMA belongs to its occupant: once a request
    retires and a new one is admitted into the slot, the EMA restarts
    optimistic (k back at the max) instead of inheriting the previous
    occupant's rejections."""
    eng = ServeEngine(
        BUNDLE, PARAMS, num_blocks=32, block_size=4, max_batch=1,
        max_seq_len=64, temperature=1e-4, seed=0,
        speculate_k=4, draft=("params", ADVERSARIAL_PARAMS),
        speculate_adaptive=True)
    eng.submit(PROMPTS[0], BUDGETS[0])
    eng.run(max_steps=100)
    assert eng._accept_ema[0] < 1.0          # adversarial draft rejected
    before = eng._chosen_k_hist.snapshot().get(4, 0)
    eng.submit(PROMPTS[1], 4)
    eng.step()   # admission round: chunked prefill tiles, no spec yet
    eng.step()   # EMA was reset at admission -> this round drafts k=4
    assert eng._chosen_k_hist.snapshot().get(4, 0) == before + 1


def test_spec_engine_rollback_under_preemption_churn():
    """A pool too small for every request forces preemption mid-spec;
    re-prefill + pos-rewind rollback must not change a single token."""
    eng = ServeEngine(
        BUNDLE, PARAMS, num_blocks=6, block_size=4, max_batch=3,
        max_seq_len=64, temperature=1e-4, seed=0,
        speculate_k=3, draft=("params", PARAMS))
    reqs = [eng.submit(r, n) for r, n in zip(PROMPTS, BUDGETS)]
    trajs = {t.request_id: t for t in eng.run(max_steps=400)}
    assert eng.stats.preemptions > 0
    for rq, w in zip(reqs, GREEDY_WANT):
        np.testing.assert_array_equal(trajs[rq.request_id].tokens, w)
    assert eng.allocator.num_free == eng.allocator.num_blocks


def test_spec_engine_log_beta_matches_rescoring():
    """Per-token log_beta recorded by speculative serving == the
    verifier's teacher-forced rescoring (β stays the latest policy)."""
    eng = ServeEngine(
        BUNDLE, PARAMS, num_blocks=32, block_size=4, max_batch=2,
        max_seq_len=64, temperature=1.0, seed=5,
        speculate_k=3, draft=("params", PARAMS))
    eng.submit(PROMPTS[0], 8)
    t = eng.run(max_steps=100)[0]
    full = np.concatenate([t.prompt, t.tokens])
    logp, _, _ = score_tokens(BUNDLE, PARAMS, jnp.asarray(full)[None],
                              prompt_len=len(t.prompt))
    np.testing.assert_allclose(np.asarray(logp[0]), t.log_beta, atol=2e-4)


def test_spec_engine_selfspec_pins_and_swaps():
    """Self-speculation pins its draft version (survives ring eviction)
    and re-pins latest+offset after every verifier swap; serve stats
    expose acceptance rate + the draft-version lag histogram."""
    from repro.metrics.runtime_metrics import collect_serve_stats

    store = PolicyStore(PARAMS, capacity=2)
    store.publish(jax.tree.map(lambda x: x + 0.01, PARAMS))      # v1
    eng = ServeEngine(
        BUNDLE, store=store, num_blocks=32, block_size=4, max_batch=2,
        max_seq_len=64, temperature=1.0, seed=3,
        speculate_k=2, draft=("version", -1))
    assert (eng.version, eng.draft.version) == (1, 0)
    assert store.pinned_versions() == [0]
    eng.submit(PROMPTS[0], 12)
    for _ in range(3):
        eng.step()
    # Two publishes evict v0 from the capacity-2 ring; the pin keeps the
    # draft readable until the next swap re-pins v2 and releases v0.
    store.publish(jax.tree.map(lambda x: x + 0.01, store.latest()[0]))
    store.publish(jax.tree.map(lambda x: x + 0.01, store.latest()[0]))
    trajs = eng.run(max_steps=200)
    assert (eng.version, eng.draft.version) == (3, 2)
    assert store.pinned_versions() == [2]
    v = trajs[0].versions
    assert (np.diff(v) >= 0).all()
    stats = collect_serve_stats(eng)
    assert stats["drafted_tokens"] > 0
    assert 0.0 <= stats["acceptance_rate"] <= 1.0
    assert stats["draft_version"] == 2
    assert sum(stats["draft_version_lag_histogram"].values()) > 0


def test_policy_store_pin_release_refcount():
    store = PolicyStore(PARAMS, capacity=2)
    store.publish(jax.tree.map(lambda x: x + 1.0, PARAMS))       # v1
    store.pin(0)
    store.pin(0)                                                 # refcount 2
    store.publish(jax.tree.map(lambda x: x + 2.0, PARAMS))       # v2: v0 out
    assert store.retained_versions() == [1, 2]
    p0 = store.get(0)                                            # via pin
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(p0)[0]),
        np.asarray(jax.tree.leaves(PARAMS)[0]))
    store.release(0)
    p0 = store.get(0)                                            # still held
    store.release(0)
    with pytest.raises(StaleVersionError):
        store.get(0)
    with pytest.raises(KeyError):
        store.release(0)


def test_policy_store_resolve_lagged():
    store = PolicyStore(PARAMS, capacity=2)
    for _ in range(3):
        store.publish(PARAMS)                                    # v1..v3
    assert store.resolve_lagged(0) == 3
    assert store.resolve_lagged(-1) == 2
    assert store.resolve_lagged(-3) == 2     # v0 evicted -> oldest resident
    store.pin(2)
    store.publish(PARAMS)                                        # v4: ring 3,4
    assert store.resolve_lagged(-2) == 2     # pinned version is resident
    with pytest.raises(ValueError):
        store.resolve_lagged(1)


def test_policy_store_pin_lagged_atomic():
    """pin_lagged resolves AND pins in one lock hold (the engine's
    draft handoff path); the pinned version survives eviction."""
    store = PolicyStore(PARAMS, capacity=2)
    store.publish(PARAMS)                                        # v1
    params, version = store.pin_lagged(-1)
    assert version == 0 and store.pinned_versions() == [0]
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(params)[0]),
        np.asarray(jax.tree.leaves(PARAMS)[0]))
    store.publish(PARAMS)                                        # evicts v0
    _, again = store.pin_lagged(-10)     # clamps to pinned v0, refcount 2
    assert again == 0
    store.release(0)
    store.release(0)
    with pytest.raises(ValueError):
        store.pin_lagged(1)


def test_spec_engine_requires_multi_capable_arch():
    cfg = CFG.replace(name="rwkv-ish", attn_free=True)
    bundle = build(cfg)
    assert bundle.decode_step_paged_multi is None
    with pytest.raises(ValueError):
        ServeEngine(bundle, PARAMS, speculate_k=2)


# --- batched prefill --------------------------------------------------------


def test_batched_prefill_token_exact_and_one_dispatch():
    """A burst of same-padded-length admissions prefills in ONE dispatch
    and emits exactly the tokens the per-request path emits."""
    prompts = PROMPTS + [np.asarray(TOK.encode("9-5=?#"), np.int32)]
    outs, dispatches = {}, {}
    for bp in (True, False):
        with pytest.warns(DeprecationWarning):
            eng = ServeEngine(
                BUNDLE, PARAMS, num_blocks=32, block_size=8, max_batch=4,
                max_seq_len=64, temperature=1e-4, seed=0,
                batch_prefill=bp, chunked_prefill=False)
        reqs = [eng.submit(r, 6) for r in prompts]
        trajs = {t.request_id: t for t in eng.run(max_steps=200)}
        outs[bp] = [trajs[rq.request_id].tokens for rq in reqs]
        dispatches[bp] = eng.stats.prefill_dispatches
        assert eng.stats.prefills == 4
    assert dispatches[True] == 1 and dispatches[False] == 4
    for a, b in zip(outs[True], outs[False]):
        np.testing.assert_array_equal(a, b)


def test_batched_prefill_mixed_lengths_grouped_separately():
    """Different padded lengths cannot share a dispatch; each length
    class gets its own, and tokens still match the dense reference."""
    short = PROMPTS[0]                      # 6 ids -> pads to 8
    long = np.concatenate([PROMPTS[1]] * 2)  # 12 ids -> pads to 16
    with pytest.warns(DeprecationWarning):
        eng = ServeEngine(
            BUNDLE, PARAMS, num_blocks=32, block_size=8, max_batch=4,
            max_seq_len=64, temperature=1e-4, seed=0,
            chunked_prefill=False)
    r1 = eng.submit(short, 5)
    r2 = eng.submit(long, 5)
    trajs = {t.request_id: t for t in eng.run(max_steps=200)}
    assert eng.stats.prefill_dispatches == 2
    np.testing.assert_array_equal(
        trajs[r1.request_id].tokens, _greedy_reference(PARAMS, short, 5))
    np.testing.assert_array_equal(
        trajs[r2.request_id].tokens, _greedy_reference(PARAMS, long, 5))


def test_batched_prefill_records_first_token_latency():
    eng = ServeEngine(
        BUNDLE, PARAMS, num_blocks=32, block_size=8, max_batch=2,
        max_seq_len=64, temperature=1e-4, seed=0)
    reqs = [eng.submit(r, 4) for r in PROMPTS]
    eng.run(max_steps=200)
    for rq in reqs:
        assert rq.first_token_time is not None
        assert rq.first_token_time >= rq.submit_time


# --- speculation composes with the rest of the engine -----------------------


def test_spec_engine_with_batched_prefill_and_mixed_lengths():
    """Speculation + batched prefill + mixed budgets, all at once."""
    with pytest.warns(DeprecationWarning):
        eng = ServeEngine(
            BUNDLE, PARAMS, num_blocks=32, block_size=8, max_batch=3,
            max_seq_len=64, temperature=1e-4, seed=0,
            speculate_k=4, draft=("params", PARAMS),
            chunked_prefill=False)
    reqs = [eng.submit(r, n) for r, n in zip(PROMPTS, BUDGETS)]
    trajs = {t.request_id: t for t in eng.run(max_steps=400)}
    for rq, w in zip(reqs, GREEDY_WANT):
        np.testing.assert_array_equal(trajs[rq.request_id].tokens, w)
    assert eng.stats.prefill_dispatches < eng.stats.prefills
