"""Lag-controller zoo: spec grammar, registry, legacy-shim equivalence,
span-aware max-lag eviction, the per-token/gradient controller hooks
(gac, stable_async, asympo), and serve-produced provenance flowing into
the redesigned admission API."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (
    AsymPOController,
    GradientAlignmentController,
    MaxLagEviction,
    PassThrough,
    StableAsyncController,
    TrajectoryItem,
    TrajectoryQueue,
    TVGatedAdmission,
    available_controllers,
    make_admission,
    make_controller,
    parse_controller_spec,
    spec_from_legacy,
)
from repro.runtime.controllers import ControllerSpec


def _item(behavior=0, consume=None, newest=None, payload=None, **meta):
    it = TrajectoryItem(
        payload=payload, behavior_version=behavior,
        enqueue_learner_version=behavior if consume is None else consume,
        behavior_version_newest=newest, meta=dict(meta),
    )
    if consume is not None:
        it.learner_version_at_consume = consume
    return it


# --- spec grammar -----------------------------------------------------------


def test_parse_controller_spec_values_and_canonical():
    spec = parse_controller_spec(
        "tv_gate:delta=0.2,mode=downweight,min_weight=1e-3")
    assert spec.name == "tv_gate"
    assert spec.options == {
        "delta": 0.2, "mode": "downweight", "min_weight": 1e-3}
    # values parse int -> float -> bool -> str
    s2 = parse_controller_spec("max_lag:max_lag=4")
    assert s2.options == {"max_lag": 4}
    assert isinstance(s2.options["max_lag"], int)
    # canonical round-trips through the parser
    assert parse_controller_spec(spec.canonical()) == spec
    assert parse_controller_spec("pass_through").canonical() == \
        "pass_through"


def test_parse_controller_spec_rejects_garbage():
    with pytest.raises(ValueError, match="unknown controller"):
        parse_controller_spec("definitely_not_registered")
    with pytest.raises(ValueError):
        parse_controller_spec("tv_gate:delta")        # not key=value
    with pytest.raises(ValueError):
        parse_controller_spec("")
    # unknown option keys hard-error at build time, not silently ignored
    with pytest.raises(ValueError, match="unknown option"):
        make_controller(ControllerSpec("max_lag", (("bogus", 1),)))


def test_registry_lists_all_six_controllers():
    info = available_controllers()
    assert {"pass_through", "max_lag", "tv_gate", "tv_gate_tokenwise",
            "gac", "stable_async", "asympo"} <= set(info)
    # every registered controller documents itself
    assert all(info[k].description for k in info)


# --- legacy shim ------------------------------------------------------------


def test_spec_from_legacy_maps_the_admission_triple():
    assert spec_from_legacy("pass_through").canonical() == "pass_through"
    assert spec_from_legacy("max_lag", max_lag=7).canonical() == \
        "max_lag:max_lag=7"
    assert spec_from_legacy(
        "tv_gate", delta=0.1, mode="downweight").canonical() == \
        "tv_gate:delta=0.1,mode=downweight"
    with pytest.raises(ValueError, match="unknown admission policy"):
        spec_from_legacy("nope")


def test_make_admission_shim_warns_and_matches_spec_path():
    """The deprecated factory must produce a controller whose decision
    stream is identical to the redesigned spec path's, for every legacy
    policy name."""
    stream = [
        _item(behavior=v, consume=5, payload=float(tv))
        for v, tv in [(5, 0.01), (4, 0.09), (3, 0.11), (1, 0.4), (0, 2.0)]
    ]
    cases = [
        ("pass_through", "pass_through", {}),
        ("max_lag", "max_lag:max_lag=2", {"max_lag": 2}),
        ("tv_gate", "tv_gate:delta=0.2,mode=downweight",
         {"delta": 0.2, "mode": "downweight"}),
    ]
    tv_fn = lambda payload: payload                       # noqa: E731
    for legacy_name, spec_text, kwargs in cases:
        with pytest.warns(DeprecationWarning):
            shim = make_admission(legacy_name, tv_fn=tv_fn, **kwargs)
        spec = make_controller(parse_controller_spec(spec_text),
                               tv_fn=tv_fn)
        assert type(shim) is type(spec)
        for it in stream:
            assert shim.admit(it) == spec.admit(it), (
                f"{legacy_name}: shim and spec paths disagree on "
                f"lag={it.lag} tv={it.payload}")


def test_make_admission_shim_type_errors_preserved():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert isinstance(make_admission("pass_through"), PassThrough)
        assert isinstance(make_admission("max_lag"), MaxLagEviction)
        with pytest.raises(ValueError, match="requires a tv_fn"):
            make_admission("tv_gate")
        with pytest.raises(ValueError):
            make_admission("nope")


# --- span-aware max-lag eviction --------------------------------------------


def test_max_lag_span_gating_on_mixture_items():
    gate = MaxLagEviction(max_lag=2)
    # homogeneous fresh / stale: unchanged semantics
    assert gate.admit(_item(behavior=4, consume=5)).admit
    d = gate.admit(_item(behavior=0, consume=5))
    assert (d.admit, d.reason) == (False, "max_lag")
    # newest token over-age: the whole item is over-age
    d = gate.admit(_item(behavior=0, consume=9, newest=1))
    assert (d.admit, d.reason) == (False, "max_lag")
    # REGRESSION: a mixture straddling the cutoff (oldest over, newest
    # under) used to be dropped on its oldest version alone; now the
    # under-cutoff fraction is admitted as a downweight.
    d = gate.admit(_item(behavior=0, consume=3, newest=3))
    assert d.admit and d.reason == "max_lag_span"
    # linear interpolation over span {lag 3..0}: 3 of 4 lags <= 2
    assert d.weight == pytest.approx(3 / 4)
    # exact per-snapshot fractions when the producer recorded them
    d = gate.admit(_item(behavior=0, consume=3, newest=3,
                         behavior_versions=[0, 3, 3, 3]))
    assert d.admit and d.weight == pytest.approx(3 / 4)
    d = gate.admit(_item(behavior=0, consume=3, newest=3,
                         behavior_versions=[0, 0, 0, 3]))
    assert d.admit and d.weight == pytest.approx(1 / 4)


def test_trajectory_item_lag_span_fields():
    it = _item(behavior=2, consume=7, newest=6)
    assert (it.lag, it.lag_oldest, it.lag_newest) == (5, 5, 1)
    solo = _item(behavior=3, consume=7)
    assert (solo.lag_oldest, solo.lag_newest) == (4, 4)


# --- mandatory decision reasons ---------------------------------------------


def test_queue_rejects_empty_decision_reason():
    from repro.runtime import AdmissionDecision, LagController

    class Reasonless(LagController):
        name = "reasonless"

        def admit(self, item):
            return AdmissionDecision(admit=True)   # no reason

    q = TrajectoryQueue(admission=Reasonless())
    q.put("x", behavior_version=0, learner_version=0)
    with pytest.raises(ValueError, match="reasons are mandatory"):
        q.get(learner_version=0)


def test_queue_labelled_admission_counters():
    gate = TVGatedAdmission(delta=0.2, tv_fn=lambda p: p,
                            mode="downweight")
    q = TrajectoryQueue(admission=gate)
    for tv in (0.05, 0.4, 0.4):
        q.put(tv, behavior_version=0, learner_version=0)
    q.close()
    while q.get(learner_version=0) is not None:
        pass
    counters = q.admission_counters()
    assert counters == {
        "queue_admission_total{controller=tv_gate,"
        "outcome=admit,reason=admit}": 1,
        "queue_admission_total{controller=tv_gate,"
        "outcome=downweight,reason=tv_downweight}": 2,
    }
    stats = q.stats()
    assert stats.controller == "tv_gate"
    assert stats.downweights_by_reason == {"tv_downweight": 2}


# --- the new controllers ----------------------------------------------------


def test_gac_scales_misaligned_stale_gradients():
    ctrl = GradientAlignmentController(cos_min=0.5, fresh_lag=0,
                                       min_scale=0.0)
    g = {"w": jnp.ones((4,))}
    # fresh item sets the anchor, passes through untouched
    out, info = ctrl.transform_gradients(_item(behavior=5, consume=5), g)
    assert info == {"gac_cos": 1.0, "gac_scale": 1.0}
    np.testing.assert_array_equal(np.asarray(out["w"]), 1.0)
    # stale gradient opposing the anchor is zeroed (cos = -1 <= 0)
    opposed = {"w": -jnp.ones((4,))}
    out, info = ctrl.transform_gradients(
        _item(behavior=0, consume=5), opposed)
    assert info["gac_cos"] == pytest.approx(-1.0)
    assert info["gac_scale"] == 0.0
    np.testing.assert_allclose(np.asarray(out["w"]), 0.0)
    # stale but aligned passes at full scale
    out, info = ctrl.transform_gradients(_item(behavior=0, consume=5), g)
    assert info["gac_cos"] == pytest.approx(1.0)
    assert info["gac_scale"] == 1.0
    # partially aligned (0 < cos < cos_min) interpolates
    mixed = {"w": jnp.asarray([1.0, -1.0, 1.0, -1.0]) +
             jnp.asarray([0.5, 0.0, 0.0, 0.0])}
    _, info = ctrl.transform_gradients(_item(behavior=0, consume=5), mixed)
    assert 0.0 < info["gac_scale"] < 1.0


def test_stable_async_truncates_to_variance_budget():
    ctrl = StableAsyncController(c_max=4.0, c_min=1.0, var_max=0.1)
    B, S = 2, 5
    log_beta = np.zeros((B, S), np.float32)
    # one wildly off-policy token: untruncated ratio e^3 ~ 20
    log_pi = np.zeros((B, S), np.float32)
    log_pi[0, 0] = 3.0
    mask = np.ones((B, S), np.float32)
    item = _item(behavior=0, consume=3)
    w = ctrl.loss_weights(item, advantages=np.ones(B),
                          log_beta=log_beta, mask=mask, log_pi=log_pi)
    assert w.shape == (B, S)
    meta = item.meta["stable_async"]
    assert meta["var"] <= 0.1 + 1e-9
    # the off-policy token was truncated to c, everything else is ~1
    assert w[0, 0] == pytest.approx(meta["c"])
    np.testing.assert_allclose(w[1], 1.0)
    # on-policy data passes essentially unweighted at the loosest c
    item2 = _item(behavior=3, consume=3)
    w2 = ctrl.loss_weights(item2, advantages=np.ones(B),
                           log_beta=log_beta, mask=mask, log_pi=log_beta)
    np.testing.assert_allclose(w2, 1.0)
    assert item2.meta["stable_async"]["c"] == 4.0
    with pytest.raises(ValueError, match="needs_log_pi"):
        ctrl.loss_weights(item, advantages=np.ones(B),
                          log_beta=log_beta, mask=mask, log_pi=None)


def test_asympo_decays_positive_advantages_with_lag():
    ctrl = AsymPOController(pos_scale=1.0, neg_scale=0.5, pos_decay=0.5)
    adv = np.asarray([1.0, -1.0, 2.0])
    mask = np.ones((3, 4), np.float32)
    w = ctrl.loss_weights(_item(behavior=0, consume=2), advantages=adv,
                          log_beta=np.zeros((3, 4)), mask=mask)
    assert w.shape == (3, 4)
    np.testing.assert_allclose(w[0], 0.25)      # +adv, lag 2: 0.5**2
    np.testing.assert_allclose(w[1], 0.5)       # -adv: fixed neg_scale
    np.testing.assert_allclose(w[2], 0.25)
    # fresh: positive side at full pos_scale
    w0 = ctrl.loss_weights(_item(behavior=2, consume=2), advantages=adv,
                           log_beta=np.zeros((3, 4)), mask=mask)
    np.testing.assert_allclose(w0[0], 1.0)


# --- serve-produced provenance ----------------------------------------------


def _tiny_bundle():
    from repro.configs.base import ModelConfig
    from repro.data.tokenizer import get_tokenizer
    from repro.models.registry import build

    tok = get_tokenizer()
    cfg = ModelConfig(name="ctrl-serve", arch_type="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=tok.vocab_size)
    return build(cfg), tok


@pytest.mark.slow
def test_serve_producer_provenance_and_forced_lag():
    """The serve producer must put engine-exact provenance on the queue:
    per-token versions pinned to the forced-lag snapshot (including the
    first minibatch — the engine must not swap to latest at step 0), and
    log_beta that re-scores to ~zero TV against the generating params
    through the trainer's padded-prompt scoring path."""
    from repro.core.tv_filter import tv_estimate
    from repro.data.mathgen import MathTaskDataset
    from repro.rollout.sampler import score_tokens
    from repro.runtime import PolicyStore, ServeRolloutProducer
    from repro.serve import ServeEngine

    bundle, tok = _tiny_bundle()
    ds = MathTaskDataset(prompt_len=12, level=0, pool_size=64, seed=0)
    key = jax.random.PRNGKey(0)
    store = PolicyStore(bundle.init(key), capacity=4)
    # three more (distinct) published versions: v1..v3
    for i in range(3):
        k = jax.random.PRNGKey(i + 1)
        store.publish(bundle.init(k))
    engine = ServeEngine(bundle, store=store, num_blocks=32, block_size=8,
                         max_batch=4, max_seq_len=32, seed=0)
    queue = TrajectoryQueue()
    producer = ServeRolloutProducer(
        store, queue, engine, ds, prompts_per_minibatch=2,
        completions_per_prompt=2, max_new_tokens=5, version_offset=2)
    producer.fill()
    item = queue.get(learner_version=store.version)
    assert item.meta["producer"] == "serve"
    mb = item.payload
    versions = np.asarray(mb.versions)
    assert versions.shape == (4, 5)
    # forced lag 2 from v3 -> every generated token is v1, even in the
    # first minibatch (regression: a step-0 store poll used to swap the
    # engine to latest before the first token)
    assert versions.min() == versions.max() == 1
    assert item.behavior_version == 1
    assert item.behavior_version_newest == 1
    assert item.lag == 2 and item.lag_newest == 2
    # padded-prompt discipline: the engine's log_beta must agree with
    # teacher-forced scoring of the same padded rows under the same
    # params, i.e. the TV the gate would see on fresh data is ~0
    log_pi, _, _ = score_tokens(bundle, store.get(1), mb.gen.tokens,
                                ds.prompt_len)
    tv = float(tv_estimate(log_pi - mb.gen.log_beta, mb.gen.mask))
    assert tv < 5e-3, f"serve log_beta disagrees with score_tokens: tv={tv}"


# --- redesigned trainer path: bit-exact vs the legacy admission triple ------


@pytest.mark.slow
def test_trainer_controller_spec_matches_legacy_admission_bit_for_bit():
    """hp.controller='tv_gate:...' must reproduce the legacy
    hp.admission triple exactly: same phase logs, same final params."""
    from repro.data.mathgen import MathTaskDataset
    from repro.train.trainer_rlvr import RLVRHyperparams, RLVRTrainer

    bundle, tok = _tiny_bundle()

    def run(**admission_kwargs):
        ds = MathTaskDataset(prompt_len=12, level=0, pool_size=64, seed=0)
        hp = RLVRHyperparams(
            algorithm="grpo_vaco", n_minibatches=2,
            prompts_per_minibatch=2, completions_per_prompt=2,
            max_new_tokens=4, warmup_steps=2, delta=0.05,
            **admission_kwargs)
        tr = RLVRTrainer(bundle, ds, hp, seed=0)
        tr.warmup()
        res = tr.train(phases=2, eval_every=2)
        return res, tr.state.params

    res_a, params_a = run(admission="tv_gate",
                          admission_mode="downweight")
    res_b, params_b = run(
        controller="tv_gate:delta=0.05,mode=downweight")
    assert len(res_a.phase_logs) == len(res_b.phase_logs)
    for pa, pb in zip(res_a.phase_logs, res_b.phase_logs):
        assert pa == pb
    assert res_a.eval_accuracy == res_b.eval_accuracy
    for la, lb in zip(jax.tree.leaves(params_a),
                      jax.tree.leaves(params_b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# --- direction: the Eq. 8 gate under forced serve-produced lag --------------


@pytest.mark.slow
def test_tv_gate_beats_pass_through_under_forced_lag():
    """Deterministic direction check (two cells of the lag-sweep bench
    at its CI config): training on forced-max-lag serve rollouts, the
    downweighting TV gate must end at >= the final greedy accuracy of
    ungated consumption of the identical stream."""
    from repro.data.mathgen import MathTaskDataset
    from repro.train.trainer_rlvr import (
        RLVRHyperparams,
        RLVRTrainer,
        RLVRTrainState,
        adamw_init,
    )

    bundle, tok = _tiny_bundle()

    def make_ds():
        return MathTaskDataset(prompt_len=16, level=0, pool_size=256,
                               seed=1)

    def make_hp(spec):
        return RLVRHyperparams(
            algorithm="grpo", lr=1e-3, n_minibatches=3,
            prompts_per_minibatch=4, completions_per_prompt=4,
            max_new_tokens=6, warmup_steps=80, producer="serve",
            controller=spec, forced_lag=3, store_capacity=4,
            max_refills=4, engine_max_batch=8, engine_num_blocks=48)

    warm_tr = RLVRTrainer(bundle, make_ds(), make_hp("pass_through"),
                          seed=0)
    warm_tr.warmup()
    warm = warm_tr.state.params

    def final_acc(spec):
        tr = RLVRTrainer(bundle, make_ds(), make_hp(spec), seed=0)
        tr.state = RLVRTrainState(params=warm, opt_state=adamw_init(warm),
                                  updates=jnp.zeros((), jnp.int32))
        for _ in range(4):
            tr.store.publish(warm, event="preramp")
        res = tr.train(5, eval_every=10**9)
        assert res.phase_logs, f"{spec}: learner starved"
        assert all(pl.staleness == 3 for pl in res.phase_logs)
        return res.eval_accuracy[-1]

    gated = final_acc("tv_gate:delta=0.05,mode=downweight")
    ungated = final_acc("pass_through")
    assert gated >= ungated, (
        f"tv_gate ({gated:.3f}) lost to pass_through ({ungated:.3f}) "
        "on identical forced-lag serve rollouts")
