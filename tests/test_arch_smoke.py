"""Per-architecture smoke tests (harness requirement f).

For each of the 10 assigned architectures: instantiate the REDUCED
variant of the same family (2 layers, d_model <= 256, <= 4 experts), run
one forward pass AND one RL train step on CPU, asserting output shapes
and the absence of NaNs.  The FULL configs are exercised only via the
dry-run (abstract lowering — see launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, list_archs, reduced_config
from repro.core.losses import GRPOConfig, group_advantages, grpo_token_loss
from repro.models.registry import build
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.rollout.sampler import score_tokens

ALL_ARCHS = list_archs()


def _aux_inputs(bundle, batch):
    aux = {}
    for name, shape in bundle.aux_input_shapes.items():
        aux[name] = jnp.ones((batch,) + shape, jnp.float32) * 0.01
    return aux


def test_registry_has_all_ten():
    assert len(ALL_ARCHS) == 10
    for kind in ("dense", "vlm", "hybrid", "moe", "ssm", "audio"):
        assert any(ARCHS[a].arch_type == kind for a in ALL_ARCHS), kind


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_integrity(arch):
    cfg = get_config(arch)
    assert cfg.n_heads % cfg.n_kv_heads == 0
    assert cfg.param_count() > 1e8  # all assigned archs are >= 0.5B-class
    assert cfg.source, "every config must cite its source"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))

    b, prompt_len, comp_len = 2, 8, 4
    total = prompt_len + comp_len
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, total), 3,
                              cfg.vocab_size)
    aux = _aux_inputs(bundle, b)

    # --- forward ---
    out = bundle.forward(params, toks, **aux)
    assert out.logits.shape == (b, total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out.logits))), "NaN/inf logits"
    if cfg.value_head:
        assert out.value.shape == (b, total)
        assert bool(jnp.all(jnp.isfinite(out.value)))

    # --- one RL train step (GRPO+VACO over the completion tokens) ---
    log_beta = jax.random.normal(jax.random.PRNGKey(2), (b, comp_len)) - 3.0
    mask = jnp.ones((b, comp_len))
    rewards = jnp.asarray([1.0, 0.0])
    adv = group_advantages(rewards, group_size=2)
    opt_state = adamw_init(params)

    def loss_fn(p):
        log_pi, _, _ = score_tokens(bundle, p, toks, prompt_len, aux=aux)
        loss, l_aux = grpo_token_loss(
            log_pi=log_pi, log_beta=log_beta, advantages=adv,
            token_mask=mask,
            cfg=GRPOConfig(use_vaco=True, delta=0.05),
        )
        return loss, l_aux

    (loss, l_aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    new_params, _ = adamw_update(grads, opt_state, params,
                                 AdamWConfig(lr=1e-3))
    # parameters actually moved and stayed finite
    moved = jax.tree.map(
        lambda a, c: bool(jnp.all(jnp.isfinite(c))), params, new_params)
    assert all(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_decode_step(arch):
    """serve_step smoke: one token against a KV cache, all families."""
    cfg = reduced_config(arch)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    b = 2
    aux = _aux_inputs(bundle, b)
    cache_kwargs = {}
    if cfg.encoder_layers > 0:
        cache_kwargs["frames"] = aux["frames"]
    cache = bundle.init_cache(params, b, 16, **cache_kwargs)
    tok = jnp.ones((b,), jnp.int32)
    out, cache2 = bundle.decode_step(params, tok, cache)
    assert out.logits.shape == (b, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out.logits)))
    assert int(cache2["pos"][0]) == int(cache["pos"][0]) + 1


def test_long_500k_policy_matches_design():
    """DESIGN.md §Arch-applicability: sub-quadratic archs serve long_500k."""
    runs = {a for a in ALL_ARCHS if get_config(a).is_subquadratic}
    assert runs == {"rwkv6-1.6b", "hymba-1.5b", "gemma3-12b"}


def test_param_counts_plausible():
    """Analytic counts should land near the nameplate scales."""
    expectations = {
        "qwen2.5-14b": (12e9, 18e9),
        "gemma3-12b": (9e9, 14e9),
        "granite-20b": (18e9, 24e9),
        "codeqwen1.5-7b": (6e9, 9e9),
        "rwkv6-1.6b": (1.2e9, 2.4e9),
        "hymba-1.5b": (1.0e9, 2.4e9),
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "llama4-scout-17b-a16e": (80e9, 130e9),  # 16 full experts resident
        "whisper-large-v3": (1.2e9, 2.0e9),
        "paligemma-3b": (2.0e9, 3.5e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B not in [{lo/1e9}, {hi/1e9}]"
    # MoE active-param counts
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.active_param_count() < 0.1 * kimi.param_count()
