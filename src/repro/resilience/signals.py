"""SIGINT/SIGTERM -> flush telemetry, then exit.

Launchers register one flush callback (producer shutdown, trace
export, metrics JSONL) so an interrupted run still leaves its
observability artifacts on disk — a chaos run that gets killed is
exactly the run whose trace you want.

The handlers are one-shot: the previous handlers are restored before
the flush runs, so a second signal during a wedged flush falls through
to the default disposition (hard kill stays available).
"""
from __future__ import annotations

import signal
from typing import Callable, Dict, Iterable


def install_flush_handlers(
    flush: Callable[[int], None],
    signals: Iterable[int] = (signal.SIGINT, signal.SIGTERM),
) -> Dict[int, object]:
    """Run ``flush(signum)`` once on the first of ``signals``, then exit
    with the conventional ``128 + signum`` code.  Returns the previous
    handlers (callers may restore them after a clean finish)."""
    previous: Dict[int, object] = {}

    def _handler(signum, frame):
        for sig, prev in previous.items():
            signal.signal(sig, prev)
        try:
            flush(signum)
        finally:
            raise SystemExit(128 + signum)

    for sig in signals:
        previous[sig] = signal.signal(sig, _handler)
    return previous
