"""Supervision for producer threads: heartbeat, bounded restart,
seeded backoff, and finiteness guards.

A producer thread that dies silently starves the queue — the trainer
just blocks on ``get`` until its timeout.  :func:`supervise` wraps the
producer loop body so a crash becomes a *measured* event instead: the
watchdog logs it, sleeps a deterministic exponential-backoff delay
(with seeded jitter so CI replays exactly), and restarts the loop up
to ``max_restarts`` times.  Restart context is handed to the loop via
:class:`RestartContext` so the producer can re-pin the *current* store
version and stamp a ``restart`` provenance flag on its first batches —
the recovery then hits admission as a measured lag spike rather than
bypassing the gate.

Finiteness guards (:func:`tree_all_finite`) back the quarantine path:
a non-finite publish or learner step is caught before it can poison
every actor at the next weight swap.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, List, Optional

import numpy as np

__all__ = [
    "BackoffPolicy",
    "RestartContext",
    "SupervisionError",
    "supervise",
    "tree_all_finite",
]


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Exponential backoff with deterministic seeded jitter.

    ``delay_s(attempt)`` is a pure function of ``(policy, attempt)``:
    the jitter for attempt *i* is drawn from ``RandomState(seed)``
    advanced exactly *i* steps, so two policies with equal fields
    produce bit-identical schedules (the determinism contract tested
    in ``tests/test_resilience.py``).
    """

    base_ms: float = 50.0
    factor: float = 2.0
    max_ms: float = 2000.0
    jitter: float = 0.25
    max_restarts: int = 3
    seed: int = 0

    def delay_s(self, attempt: int) -> float:
        if attempt < 0:
            raise ValueError("attempt must be >= 0")
        ms = min(self.max_ms, self.base_ms * self.factor ** attempt)
        if self.jitter > 0.0:
            rng = np.random.RandomState(self.seed)
            u = rng.random_sample(attempt + 1)[-1]  # i-th draw, reproducible
            ms *= 1.0 + self.jitter * u
        return float(ms) / 1e3

    def schedule(self) -> List[float]:
        """The full restart-delay schedule in seconds."""
        return [self.delay_s(i) for i in range(self.max_restarts)]


@dataclasses.dataclass
class RestartContext:
    """Handed to a supervised loop body on (re)entry.

    ``attempt`` is 0 on the first run and *n* after the *n*-th
    restart.  ``last_error`` is the exception that killed the previous
    incarnation.  The loop body should treat ``attempt > 0`` as "I am
    a restarted producer": re-pin the current store version and stamp
    ``restart=True`` provenance on the first item it publishes.
    """

    attempt: int = 0
    last_error: Optional[BaseException] = None


class SupervisionError(RuntimeError):
    """Producer exceeded its restart budget; carries the final error."""

    def __init__(self, name: str, restarts: int,
                 last_error: BaseException) -> None:
        super().__init__(
            f"supervised producer {name!r} exceeded restart budget "
            f"({restarts} restarts); last error: {last_error!r}")
        self.restarts = restarts
        self.last_error = last_error


def supervise(
    run: Callable[[RestartContext], None],
    *,
    policy: BackoffPolicy,
    name: str = "producer",
    should_stop: Callable[[], bool] = lambda: False,
    clean_exits: tuple = (),
    registry: Optional[Any] = None,
    tracer: Optional[Any] = None,
    sleep: Callable[[float], None] = time.sleep,
    heartbeat: Optional["Heartbeat"] = None,
) -> int:
    """Run ``run(ctx)`` under watchdog supervision; returns the number
    of restarts consumed.

    ``run`` returning normally — or raising one of ``clean_exits``
    (e.g. ``QueueClosed``) — ends supervision.  Any other exception
    consumes one restart: the watchdog emits a ``watchdog_restart``
    trace instant + ``watchdog_restart_total`` counter, sleeps the
    seeded backoff delay (checking ``should_stop`` so shutdown is not
    held hostage to a long backoff), and re-enters the loop with an
    incremented :class:`RestartContext`.  Exceeding ``max_restarts``
    raises :class:`SupervisionError`.
    """
    attempt = 0
    last_error: Optional[BaseException] = None
    while not should_stop():
        ctx = RestartContext(attempt=attempt, last_error=last_error)
        try:
            if heartbeat is not None:
                heartbeat.beat()
            run(ctx)
            return attempt
        except clean_exits:
            return attempt
        except BaseException as exc:  # noqa: BLE001 - supervision boundary
            last_error = exc
            if attempt >= policy.max_restarts:
                raise SupervisionError(name, attempt, exc) from exc
            delay = policy.delay_s(attempt)
            attempt += 1
            if registry is not None:
                registry.counter(
                    "watchdog_restart_total", producer=name).inc()
            if tracer is not None:
                tracer.instant(
                    "watchdog_restart", pid="resilience", tid=name,
                    attempt=attempt, delay_s=round(delay, 6),
                    error=repr(exc))
            # interruptible backoff: never outlive a stop request
            deadline = time.monotonic() + delay
            while not should_stop():
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                sleep(min(remaining, 0.05))
    return attempt


class Heartbeat:
    """A timestamped liveness marker a watchdog thread can poll.

    Producers call :meth:`beat` each loop iteration; anyone holding a
    reference can ask :meth:`stale` whether the producer has been
    silent for longer than ``timeout_s`` (a straggler detector — used
    by the chaos bench to prove stalls are *visible*, not fatal).
    """

    def __init__(self, timeout_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.timeout_s = float(timeout_s)
        self._clock = clock
        self._last = clock()
        self._beats = 0
        self._lock = threading.Lock()

    def beat(self) -> None:
        with self._lock:
            self._last = self._clock()
            self._beats += 1

    @property
    def beats(self) -> int:
        with self._lock:
            return self._beats

    def age_s(self) -> float:
        with self._lock:
            return self._clock() - self._last

    def stale(self) -> bool:
        return self.age_s() > self.timeout_s


def tree_all_finite(tree: Any) -> bool:
    """True iff every array leaf of the pytree is fully finite."""
    import jax
    import jax.numpy as jnp

    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return True
    ok = True
    for leaf in leaves:
        arr = jnp.asarray(leaf)
        if not jnp.issubdtype(arr.dtype, jnp.floating):
            continue
        ok = ok & jnp.all(jnp.isfinite(arr))
    return bool(ok)
