"""Fault injection + supervision for the async runtime.

``faults`` — deterministic, seed-driven :class:`FaultInjector` driven
by ``"kind:key=val,...;kind:..."`` plan strings, with hooks threaded
through the producer regimes, ``PolicyStore.publish``,
``TrajectoryQueue`` and the ``ServeEngine`` decode loop.

``supervision`` — watchdog/restart for producer threads (bounded
retries, seeded exponential backoff with jitter, restart provenance),
plus the finiteness guard backing publish/learner-step quarantine.
"""
from repro.resilience.faults import (
    FaultEvent,
    FaultInjector,
    InjectedFault,
    NULL_INJECTOR,
    parse_fault_plan,
)
from repro.resilience.signals import install_flush_handlers
from repro.resilience.supervision import (
    BackoffPolicy,
    Heartbeat,
    RestartContext,
    SupervisionError,
    supervise,
    tree_all_finite,
)

__all__ = [
    "BackoffPolicy",
    "FaultEvent",
    "FaultInjector",
    "Heartbeat",
    "InjectedFault",
    "NULL_INJECTOR",
    "install_flush_handlers",
    "RestartContext",
    "SupervisionError",
    "parse_fault_plan",
    "supervise",
    "tree_all_finite",
]
