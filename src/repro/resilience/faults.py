"""Deterministic, seed-driven fault injection for the async runtime.

The runtime so far only ever exercises the happy path.  Real
deployments see producer crashes, straggler slots, hung queues and
poisoned weight pushes — all of which are *lag generators*: a
restarted actor resumes against a moved-on learner, a stalled slot
holds pages while the store advances.  This module gives the repo a
first-class way to rehearse those failures deterministically so the
supervision layer (see :mod:`repro.resilience.supervision`) and the
admission controllers can be tested against them.

Fault plans are spec strings in the same ``name:key=val,...`` grammar
as controller specs (PR 8), with multiple events joined by ``;``::

    "producer_crash:at_step=2;stall:slot=0,ms=200;nan_publish:at_publish=3"

Supported kinds and their trigger sites:

===============  ==============  =========================================
kind             site            match keys (all optional unless noted)
===============  ==============  =========================================
producer_crash   producer        ``at_step`` (Nth produced item, 0-based)
stall            engine_step     ``at_step``, ``slot``; ``ms`` (duration)
queue_stall      queue_put /     ``at_call``; ``ms`` (duration);
                 queue_get       ``site`` (restrict to one side)
nan_publish      publish         ``at_publish`` (Nth publish, 1-based) or
                                 ``version`` (absolute store version)
learner_nan      learner_step    ``at_step``
===============  ==============  =========================================

Every event also accepts ``count`` (max number of firings, default 1)
and ``p`` (firing probability per matching call, default 1.0 — drawn
from the injector's seeded RNG, so a given ``(plan, seed)`` pair
replays bit-identically).  Stall durations jitter by ``jitter`` (a
fraction of ``ms``, default 0) from the same RNG.

The injector is a null object when the plan is empty: every hook is a
cheap early-out, so production paths can call it unconditionally.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

__all__ = [
    "FaultEvent",
    "FaultInjector",
    "InjectedFault",
    "NULL_INJECTOR",
    "parse_fault_plan",
]

# kind -> site(s) where it can fire
FAULT_SITES: Dict[str, Tuple[str, ...]] = {
    "producer_crash": ("producer",),
    "stall": ("engine_step",),
    "queue_stall": ("queue_put", "queue_get"),
    "nan_publish": ("publish",),
    "learner_nan": ("learner_step",),
}

# keys every kind accepts on top of its own match keys
_COMMON_KEYS = ("count", "p", "jitter")
_KIND_KEYS: Dict[str, Tuple[str, ...]] = {
    "producer_crash": ("at_step", "producer"),
    "stall": ("at_step", "slot", "ms"),
    "queue_stall": ("at_call", "ms", "site"),
    "nan_publish": ("at_publish", "version"),
    "learner_nan": ("at_step",),
}


def _parse_value(text: str) -> Any:
    low = text.strip().lower()
    if low in ("true", "false"):
        return low == "true"
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            continue
    return text


@dataclasses.dataclass
class FaultEvent:
    """One parsed fault: a kind, match keys, and firing bookkeeping."""

    kind: str
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    count: int = 1
    p: float = 1.0
    fires: int = 0

    @property
    def exhausted(self) -> bool:
        return self.fires >= self.count

    def canonical(self) -> str:
        body = ",".join(f"{k}={v}" for k, v in sorted(self.params.items()))
        return f"{self.kind}:{body}" if body else self.kind

    def matches(self, site: str, ctx: Dict[str, Any]) -> bool:
        """An event matches when every match key it names agrees with
        the call context.  A key the caller did not supply is a
        non-match (never a wildcard) so e.g. ``slot=3`` cannot fire
        from a site that does not report slots."""
        if self.exhausted or site not in FAULT_SITES[self.kind]:
            return False
        want_site = self.params.get("site")
        if want_site is not None and want_site != site:
            return False
        for key, want in self.params.items():
            if key in ("ms", "site"):
                continue
            if key not in ctx or ctx[key] != want:
                return False
        return True


def parse_fault_plan(text: Union[str, List[str], None]) -> List[FaultEvent]:
    """Parse ``"kind:k=v,...;kind:k=v"`` (or a list of such chunks)
    into :class:`FaultEvent` s.  An empty/None plan parses to ``[]``."""
    if text is None:
        return []
    chunks: List[str] = []
    if isinstance(text, str):
        chunks = [c for c in text.split(";") if c.strip()]
    else:
        for part in text:
            chunks.extend(c for c in str(part).split(";") if c.strip())
    events: List[FaultEvent] = []
    for chunk in chunks:
        chunk = chunk.strip()
        kind, _, body = chunk.partition(":")
        kind = kind.strip()
        if kind not in FAULT_SITES:
            raise ValueError(
                f"unknown fault kind {kind!r}; available: "
                f"{', '.join(sorted(FAULT_SITES))}")
        params: Dict[str, Any] = {}
        count, p = 1, 1.0
        if body.strip():
            for item in body.split(","):
                key, eq, val = item.partition("=")
                key = key.strip()
                if not key or not eq:
                    raise ValueError(
                        f"bad fault option {item!r} in {chunk!r} "
                        "(expected key=value)")
                value = _parse_value(val)
                if key == "count":
                    count = int(value)
                elif key == "p":
                    p = float(value)
                elif key in _KIND_KEYS[kind] or key in _COMMON_KEYS:
                    params[key] = value
                else:
                    raise ValueError(
                        f"unknown option {key!r} for fault {kind!r}; "
                        f"accepted: {sorted(_KIND_KEYS[kind] + _COMMON_KEYS)}")
        events.append(FaultEvent(kind=kind, params=params, count=count, p=p))
    return events


class InjectedFault(RuntimeError):
    """Raised by crash-type faults; carries the event that fired."""

    def __init__(self, event: FaultEvent, site: str) -> None:
        super().__init__(f"injected fault {event.canonical()} at {site}")
        self.event = event
        self.site = site


class FaultInjector:
    """Deterministic fault plan executor.

    One injector instance is shared across the components of a run
    (store, queue, regimes, engine, trainer); each component calls the
    hook for its site unconditionally.  All mutable state (fire
    counts, the RNG) is guarded by a lock because producer threads and
    the learner thread hit the same plan concurrently.
    """

    def __init__(
        self,
        plan: Union[str, List[str], None] = "",
        *,
        seed: int = 0,
        registry: Optional[Any] = None,
        tracer: Optional[Any] = None,
        sleep: Callable[[float], None] = time.sleep,
    ) -> None:
        self.events = parse_fault_plan(plan)
        self.seed = int(seed)
        self.registry = registry
        self.tracer = tracer
        self._sleep = sleep
        self._rng = np.random.RandomState(self.seed)
        self._lock = threading.Lock()
        self.fired: List[Tuple[str, str]] = []  # (kind, site) log

    @property
    def active(self) -> bool:
        return bool(self.events)

    def fired_counts(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for kind, _site in self.fired:
                out[kind] = out.get(kind, 0) + 1
            return out

    # -- internal ----------------------------------------------------

    def _fire(self, site: str, ctx: Dict[str, Any]) -> List[FaultEvent]:
        """Return the events firing at this call, updating counters."""
        if not self.events:
            return []
        hits: List[FaultEvent] = []
        with self._lock:
            for ev in self.events:
                if not ev.matches(site, ctx):
                    continue
                if ev.p < 1.0 and self._rng.random_sample() >= ev.p:
                    continue
                ev.fires += 1
                self.fired.append((ev.kind, site))
                hits.append(ev)
        for ev in hits:
            if self.registry is not None:
                self.registry.counter(
                    "fault_injected_total", kind=ev.kind, site=site).inc()
            if self.tracer is not None:
                info = {"kind": ev.kind, "site": site,
                        "spec": ev.canonical()}
                info.update(ctx)
                self.tracer.instant(
                    "fault", pid="resilience", tid="injector", **info)
        return hits

    def _jittered_ms(self, ev: FaultEvent) -> float:
        ms = float(ev.params.get("ms", 0.0))
        jitter = float(ev.params.get("jitter", 0.0))
        if jitter > 0.0:
            with self._lock:
                ms *= 1.0 + jitter * (2.0 * self._rng.random_sample() - 1.0)
        return ms

    # -- hooks (call sites use exactly one of these per site) --------

    def crash_if(self, site: str, **ctx: Any) -> None:
        """Raise :class:`InjectedFault` if a crash fault matches."""
        hits = self._fire(site, ctx)
        for ev in hits:
            if ev.kind in ("producer_crash",):
                raise InjectedFault(ev, site)

    def stall(self, site: str, **ctx: Any) -> float:
        """Sleep out any matching stall faults; returns seconds slept."""
        hits = self._fire(site, ctx)
        total_ms = sum(self._jittered_ms(ev) for ev in hits
                       if ev.kind in ("stall", "queue_stall"))
        if total_ms > 0.0:
            self._sleep(total_ms / 1e3)
        return total_ms / 1e3

    def poison(self, site: str, params: Any, **ctx: Any) -> Tuple[Any, bool]:
        """Replace the first array leaf with NaNs if a poison fault
        matches; returns ``(params, poisoned)``."""
        hits = [ev for ev in self._fire(site, ctx)
                if ev.kind in ("nan_publish", "learner_nan")]
        if not hits:
            return params, False
        import jax
        import jax.numpy as jnp

        leaves, treedef = jax.tree_util.tree_flatten(params)
        if leaves:
            leaves = [jnp.full_like(leaves[0], jnp.nan)] + list(leaves[1:])
        return jax.tree_util.tree_unflatten(treedef, leaves), True


NULL_INJECTOR = FaultInjector("")
