"""Learning-rate schedules, returned as step -> lr_scale callables.

Scales multiply ``AdamWConfig.lr``; the classic-RL setup uses
``linear_anneal`` (CleanRL's "Learning Rate Annealing = True", Table 1),
the RLVR setup uses a constant 1e-6 (Table 2).
"""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule():
    def f(step):
        return jnp.ones_like(jnp.asarray(step, jnp.float32))

    return f


def linear_anneal(total_steps: int, floor: float = 0.0):
    def f(step):
        t = jnp.asarray(step, jnp.float32) / float(max(total_steps, 1))
        return jnp.maximum(1.0 - t, floor)

    return f


def cosine_schedule(total_steps: int, floor: float = 0.0):
    def f(step):
        t = jnp.clip(
            jnp.asarray(step, jnp.float32) / float(max(total_steps, 1)),
            0.0,
            1.0,
        )
        return floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))

    return f


def warmup_cosine(warmup_steps: int, total_steps: int, floor: float = 0.0):
    cos = cosine_schedule(max(total_steps - warmup_steps, 1), floor)

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = step / float(max(warmup_steps, 1))
        return jnp.where(
            step < warmup_steps, warm, cos(step - warmup_steps)
        )

    return f
