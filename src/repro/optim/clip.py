"""Global-norm gradient clipping (Table 1: Max Grad Norm = 0.5)."""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_global_norm


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    """Scale `grads` so their global L2 norm is at most `max_norm`.

    Returns (clipped_grads, pre_clip_norm).
    """
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
