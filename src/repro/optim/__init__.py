from repro.optim.adamw import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    sgd_update,
)
from repro.optim.schedule import (
    constant_schedule,
    linear_anneal,
    cosine_schedule,
    warmup_cosine,
)
from repro.optim.clip import clip_by_global_norm

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "adamw_init",
    "adamw_update",
    "sgd_update",
    "constant_schedule",
    "linear_anneal",
    "cosine_schedule",
    "warmup_cosine",
    "clip_by_global_norm",
]
