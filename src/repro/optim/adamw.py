"""AdamW, from scratch (optax is not available in this container).

Moments are kept in float32 regardless of parameter dtype, mirroring the
mixed-precision layout the dry-run shards (params bf16, m/v fp32).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


class AdamWState(NamedTuple):
    step: jax.Array  # scalar int32
    m: Any           # first moment, float32 pytree
    v: Any           # second moment, float32 pytree


def adamw_init(params: Any) -> AdamWState:
    zeros32 = lambda x: jnp.zeros(x.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros32, params),
        v=jax.tree.map(zeros32, params),
    )


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    cfg: AdamWConfig,
    lr_scale: jax.Array | float = 1.0,
):
    """One AdamW step. Returns (new_params, new_state).

    ``lr_scale`` multiplies cfg.lr — schedules pass the current factor so
    the config stays hashable/static under jit.
    """
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2

    def upd_m(m, g):
        return b1 * m + (1.0 - b1) * g.astype(jnp.float32)

    def upd_v(v, g):
        g32 = g.astype(jnp.float32)
        return b2 * v + (1.0 - b2) * g32 * g32

    m = jax.tree.map(upd_m, state.m, grads)
    v = jax.tree.map(upd_v, state.v, grads)

    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t
    lr = cfg.lr * lr_scale

    def upd_p(p, m_, v_):
        update = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        if cfg.weight_decay > 0.0:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype)

    new_params = jax.tree.map(upd_p, params, m, v)
    return new_params, AdamWState(step=step, m=m, v=v)


def sgd_update(grads: Any, params: Any, lr: float):
    """Plain SGD — used by tests and tiny tabular experiments."""
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
