"""Pallas TPU kernel for the RWKV-6 (Finch) WKV recurrence.

Chunked linear-attention (flash-linear-attention style), adapted to TPU:

* grid = (batch, heads, num_chunks); chunks are the innermost sequential
  axis so the running state S [K, V] persists in VMEM scratch.
* within a chunk of C tokens everything is parallel: with
  la_t = cumsum(log w) the intra-chunk contribution is a strictly-lower-
  triangular score matrix
      scores[t, i] = sum_k r[t,k] k[i,k] exp(la_{t-1,k} - la_{i,k})  (i < t)
  plus the diagonal "bonus" term (r ⊙ u)·k, and the inter-chunk part is
  (r ⊙ exp(la_{t-1})) @ S — two MXU matmuls per chunk.
* numerical safety: all exponent differences are <= 0 by construction
  (la is non-increasing), so no log-space renormalization is needed —
  unlike the GPU fla kernels that divide by cumprods, nothing here
  overflows regardless of how aggressive the learned decay is.

The pairwise [C, C, K] tensor bounds the chunk size: C=64, K=64 fp32 is
1 MiB of VMEM — the default.  Decode (S=1) bypasses the kernel entirely
(state recurrence is a single rank-1 update).

Validated in interpret mode against repro.kernels.ref.ref_wkv6.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(
    r_ref,      # [1, 1, C, K]
    k_ref,      # [1, 1, C, K]
    v_ref,      # [1, 1, C, V]
    w_ref,      # [1, 1, C, K]
    u_ref,      # [1, K]
    s0_ref,     # [1, 1, K, V] initial state
    y_ref,      # [1, 1, C, V] out
    sf_ref,     # [1, 1, K, V] out (final state)
    s_scratch,  # [K, V] fp32
    *,
    chunk: int,
    num_chunks: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_scratch[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)     # [C, K]
    k = k_ref[0, 0].astype(jnp.float32)     # [C, K]
    v = v_ref[0, 0].astype(jnp.float32)     # [C, V]
    w = w_ref[0, 0].astype(jnp.float32)     # [C, K]
    u = u_ref[0].astype(jnp.float32)        # [K]
    s = s_scratch[...]                      # [K, V]

    logw = jnp.log(w)
    la = jnp.cumsum(logw, axis=0)           # inclusive  [C, K]
    la_prev = la - logw                     # exclusive  [C, K]

    # Intra-chunk pairwise scores (strictly lower-triangular), exponent
    # differences la_prev[t] - la[i] <= 0 for i < t.
    diff = la_prev[:, None, :] - la[None, :, :]          # [C, C, K]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (ti > ii)[:, :, None]
    pair = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jnp.einsum("tk,ik,tik->ti", r, k, pair)     # [C, C]
    bonus = jnp.sum(r * u[None, :] * k, axis=1)          # [C]
    scores = scores + jnp.where(
        ti == ii, bonus[:, None], 0.0
    )

    y_intra = scores @ v                                  # [C, V]
    y_inter = (r * jnp.exp(la_prev)) @ s                  # [C, V]
    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # State to end of chunk: S' = diag(e^{la_C}) S + sum_i (k_i e^{la_C-la_i}) v_i
    la_end = la[-1]                                       # [K]
    k_scaled = k * jnp.exp(la_end[None, :] - la)          # [C, K]
    s_new = jnp.exp(la_end)[:, None] * s + k_scaled.T @ v
    s_scratch[...] = s_new

    @pl.when(ic == num_chunks - 1)
    def _final():
        sf_ref[0, 0] = s_new.astype(sf_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def wkv6_pallas(
    r: jax.Array,   # [B, S, H, K]
    k: jax.Array,   # [B, S, H, K]
    v: jax.Array,   # [B, S, H, V]
    w: jax.Array,   # [B, S, H, K] decay in (0, 1)
    u: jax.Array,   # [H, K]
    state: Optional[jax.Array] = None,   # [B, H, K, V]
    *,
    chunk: int = 64,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    b, s, h, kd = r.shape
    vd = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, kd, vd), jnp.float32)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        # Padding with w=1 (log w = 0) and k=0 is recurrence-neutral.
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    sp = s + pad
    num_chunks = sp // chunk

    # [B, H, S, *] layout.
    rt, kt, vt, wt = (a.transpose(0, 2, 1, 3) for a in (r, k, v, w))

    seq_spec_k = pl.BlockSpec((1, 1, chunk, kd),
                              lambda b_, h_, c: (b_, h_, c, 0))
    seq_spec_v = pl.BlockSpec((1, 1, chunk, vd),
                              lambda b_, h_, c: (b_, h_, c, 0))
    u_spec = pl.BlockSpec((1, kd), lambda b_, h_, c: (h_, 0))
    st_spec = pl.BlockSpec((1, 1, kd, vd), lambda b_, h_, c: (b_, h_, 0, 0))

    y, sf = pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk, num_chunks=num_chunks),
        grid=(b, h, num_chunks),
        in_specs=[seq_spec_k, seq_spec_k, seq_spec_v, seq_spec_k, u_spec,
                  st_spec],
        out_specs=[seq_spec_v, st_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sp, vd), r.dtype),
            jax.ShapeDtypeStruct((b, h, kd, vd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kd, vd), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u, state)
    return y.transpose(0, 2, 1, 3)[:, :s], sf
