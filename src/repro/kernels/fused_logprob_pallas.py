"""Pallas TPU kernel: fused per-token log-prob (+ entropy) over the vocab.

The RLVR losses (§5.2; GRPO/VACO) need log pi(a_t|s_t) for every token of
every completion — for the assigned vocabularies (up to 262k) the naive
``log_softmax(logits)[target]`` materializes a [B, S, V] fp32 log-softmax
three times the size of the logits themselves.  This kernel streams the
vocab axis through VMEM once with an online logsumexp, gathering the
target logit on the fly:

    grid = (num_token_blocks, num_vocab_blocks)   (vocab innermost)
    scratch: running max m [BN], running sum l [BN],
             target-logit tgt [BN], entropy partial s [BN]

    out_logp    = tgt - (m + log l)
    out_entropy = (m + log l) - s / l            (s = sum e^{x-m} x)

HBM traffic: read logits once, write two [N] vectors — vs. ~4x logits
traffic for the unfused path.  The TV-filter itself (repro.core.tv_filter)
then operates on [N] quantities and is trivially fused by XLA.

Vocab blocks default to 2048 lanes; token blocks to 8 sublanes.
Forward-only: the trainers compute gradients through the jnp reference
path, and use this kernel for the (no-grad) behavior-policy logprobs and
serve-side scoring, where the memory win matters most.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _logprob_kernel(
    logits_ref,   # [BN, BV]
    targets_ref,  # [BN, 1]
    logp_ref,     # [BN, 1] out
    ent_ref,      # [BN, 1] out
    m_ref,        # scratch [BN]
    l_ref,        # scratch [BN]
    tgt_ref,      # scratch [BN]
    s_ref,        # scratch [BN]
    *,
    block_v: int,
    num_v: int,
):
    jv = pl.program_id(1)

    @pl.when(jv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        tgt_ref[...] = jnp.zeros_like(tgt_ref)
        s_ref[...] = jnp.zeros_like(s_ref)

    x = logits_ref[...].astype(jnp.float32)          # [BN, BV]
    bn = x.shape[0]
    tgt_ids = targets_ref[...][:, 0]                 # [BN]

    cols = jv * block_v + jax.lax.broadcasted_iota(
        jnp.int32, x.shape, 1)
    hit = cols == tgt_ids[:, None]
    tgt_ref[...] = tgt_ref[...] + jnp.sum(
        jnp.where(hit, x, 0.0), axis=1)

    m_prev = m_ref[...]
    m_cur = jnp.max(x, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(x - m_new[:, None])
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1)
    s_ref[...] = alpha * s_ref[...] + jnp.sum(p * x, axis=1)
    m_ref[...] = m_new

    @pl.when(jv == num_v - 1)
    def _final():
        lse = m_ref[...] + jnp.log(jnp.maximum(l_ref[...], 1e-30))
        logp_ref[...] = (tgt_ref[...] - lse)[:, None].astype(logp_ref.dtype)
        ent_ref[...] = (
            lse - s_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        )[:, None].astype(ent_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_v", "interpret")
)
def logprobs_pallas(
    logits: jax.Array,    # [N, V]
    targets: jax.Array,   # [N] int32
    *,
    block_n: int = 8,
    block_v: int = 2048,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logp [N], entropy [N]) in fp32."""
    n, vsz = logits.shape
    block_n = min(block_n, n)
    block_v = min(block_v, vsz)
    pad_n = (-n) % block_n
    pad_v = (-vsz) % block_v
    if pad_n or pad_v:
        logits = jnp.pad(logits, ((0, pad_n), (0, pad_v)),
                         constant_values=NEG_INF)
        targets = jnp.pad(targets, (0, pad_n))
    np_, vp = n + pad_n, vsz + pad_v
    num_n, num_v = np_ // block_n, vp // block_v

    logits_spec = pl.BlockSpec((block_n, block_v), lambda i, j: (i, j))
    tgt_spec = pl.BlockSpec((block_n, 1), lambda i, j: (i, 0))
    out_spec = pl.BlockSpec((block_n, 1), lambda i, j: (i, 0))

    logp, ent = pl.pallas_call(
        functools.partial(_logprob_kernel, block_v=block_v, num_v=num_v),
        grid=(num_n, num_v),
        in_specs=[logits_spec, tgt_spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((np_, 1), jnp.float32),
            jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_n,), jnp.float32),
            pltpu.VMEM((block_n,), jnp.float32),
            pltpu.VMEM((block_n,), jnp.float32),
            pltpu.VMEM((block_n,), jnp.float32),
        ],
        interpret=interpret,
    )(logits, targets.astype(jnp.int32)[:, None])
    return logp[:n, 0], ent[:n, 0]
