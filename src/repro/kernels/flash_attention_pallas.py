"""Pallas TPU flash attention: causal / sliding-window, GQA-aware.

Online-softmax attention with explicit BlockSpec VMEM tiling:

* grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the kv axis is the
  innermost (sequential) dimension so the fp32 accumulator, row-max and
  row-sum live in VMEM scratch across kv iterations.
* blocks default to 128x128 — MXU-aligned on the (q, kv) score matmul and
  the (kv, d) value matmul.
* GQA: the kv BlockSpec index map folds the query head onto its kv group
  (``h // (H // KV)``), so grouped keys/values are streamed once from HBM
  without materializing the broadcast.
* causal + sliding-window masks are applied from block coordinates;
  fully-masked kv blocks are skipped with ``pl.when`` (a 5:1 local:global
  gemma3 layer at S=4k skips ~97% of kv blocks in its local layers).

VMEM at defaults: q/k/v/out tiles 4 x 128 x 128 x 4B = 256 KiB + scratch
~ 65 KiB — far under budget; block sizes are tunable per §Perf.

Forward-only (the serve/prefill path); training uses the XLA reference
(repro.kernels.ref.ref_attention) which autodiffs.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,    # [1, 1, BQ, D]
    k_ref,    # [1, 1, BK, D]
    v_ref,    # [1, 1, BK, D]
    o_ref,    # [1, 1, BQ, D]
    m_ref,    # scratch [BQ]
    l_ref,    # scratch [BQ]
    acc_ref,  # scratch [BQ, D]
    *,
    block_q: int,
    block_k: int,
    num_k: int,
    window: Optional[int],
    scale: float,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    # Causal reachability: the earliest q row of this block must not be
    # strictly before the first k column; windowed: the latest q row must
    # still reach the last k column.
    reachable = k_start <= q_start + block_q - 1
    if window is not None:
        reachable = jnp.logical_and(
            reachable, (q_start - (k_start + block_k - 1)) < window
        )

    @pl.when(reachable)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        scores = q @ k.T  # [BQ, BK]

        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = qpos >= kpos
        if window is not None:
            mask = jnp.logical_and(mask, (qpos - kpos) < window)
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(scores, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # Renormalize the running sums; rows still at NEG_INF stay zeroed.
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[:, None])
        l_new = alpha * l_prev + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ik == num_k - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,   # [B, S, H, D]
    k: jax.Array,   # [B, S, KV, D]
    v: jax.Array,   # [B, S, KV, D]
    *,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Causal (optionally sliding-window) flash attention."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = d ** -0.5

    block_q = min(block_q, s)
    block_k = min(block_k, s)
    pad = (-s) % max(block_q, block_k)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad

    # [B, H, S, D] layouts for clean 2D tiles.
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    num_q = sp // block_q
    num_k = sp // block_k
    grid = (b, h, num_q, num_k)

    q_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda b_, h_, iq, ik: (b_, h_, iq, 0))
    kv_spec = pl.BlockSpec((1, 1, block_k, d),
                           lambda b_, h_, iq, ik: (b_, h_ // group, ik, 0))
    o_spec = pl.BlockSpec((1, 1, block_q, d),
                          lambda b_, h_, iq, ik: (b_, h_, iq, 0))

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, block_q=block_q, block_k=block_k, num_k=num_k,
            window=window, scale=scale,
        ),
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)[:, :s]
