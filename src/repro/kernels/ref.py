"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` is the semantic ground truth the kernels must reproduce;
tests sweep shapes/dtypes and assert_allclose kernel-vs-oracle.  These are
also the CPU/autodiff fallback paths used by the models when the Pallas
route is disabled.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# V-trace (paper Eqs. 14-15) — same math as repro.core.vtrace, re-exported
# here so the kernel package is self-contained for its tests.
# ---------------------------------------------------------------------------


def ref_vtrace(
    log_ratios: jax.Array,      # [B, T]
    values: jax.Array,          # [B, T]
    bootstrap_value: jax.Array,  # [B]
    rewards: jax.Array,         # [B, T]
    discounts: jax.Array,       # [B, T]
    rho_bar: float = 1.0,
    c_bar: float = 1.0,
    lam: float = 1.0,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (vs, advantages)."""
    from repro.core.vtrace import vtrace

    out = vtrace(
        log_ratios=log_ratios, values=values,
        bootstrap_value=bootstrap_value, rewards=rewards,
        discounts=discounts, rho_bar=rho_bar, c_bar=c_bar, lam=lam,
    )
    return out.vs, out.advantages


# ---------------------------------------------------------------------------
# Flash attention (causal / sliding-window, GQA)
# ---------------------------------------------------------------------------


def ref_attention(
    q: jax.Array,   # [B, S, H, D]
    k: jax.Array,   # [B, S, KV, D]
    v: jax.Array,   # [B, S, KV, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,  # None = global
) -> jax.Array:
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = d ** -0.5
    qg = q.reshape(b, s, kv, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg * scale, k,
                        preferred_element_type=jnp.float32)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = qi >= ki
    if window is not None:
        mask = jnp.logical_and(mask, (qi - ki) < window)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, s, h, d)


# ---------------------------------------------------------------------------
# Paged decode attention (serve engine's block-table KV cache)
# ---------------------------------------------------------------------------


def ref_paged_attention(
    q: jax.Array,             # [B, H, D] one query token per request
    k_pages: jax.Array,       # [KV, NB, BS, D] pooled key blocks
    v_pages: jax.Array,       # [KV, NB, BS, D] pooled value blocks
    block_tables: jax.Array,  # [B, M] int32 page ids (pad slots may be any
                              # in-range id; they are masked by context_lens)
    context_lens: jax.Array,  # [B] int32 valid tokens per request (0 = slot
                              # inactive -> zero output)
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """Gather K/V through per-request block tables and attend.

    The logical sequence of request b is the concatenation of its table's
    blocks; token t lives in block t // BS at offset t % BS.  Only the
    first ``context_lens[b]`` positions are real (ragged sequences), and
    the newest token (the query's own K/V row) is expected to already be
    written at position ``context_lens[b] - 1``.
    """
    kv, _, bs, d = k_pages.shape
    b, h, _ = q.shape
    g = h // kv
    scale = d ** -0.5
    # [KV, B, M, BS, D] -> [KV, B, S, D] with S = M * BS
    keys = k_pages[:, block_tables].reshape(kv, b, -1, d)
    vals = v_pages[:, block_tables].reshape(kv, b, -1, d)
    qg = q.reshape(b, kv, g, d)
    scores = jnp.einsum("bkgd,kbsd->bkgs", qg * scale, keys,
                        preferred_element_type=jnp.float32)
    pos = jnp.arange(keys.shape[2], dtype=jnp.int32)[None, :]     # [1, S]
    valid = pos < context_lens[:, None]
    if window is not None:
        q_pos = context_lens[:, None] - 1
        valid = jnp.logical_and(valid, (q_pos - pos) < window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgs,kbsd->bkgd", probs, vals)
    # Inactive slots (context_len 0) have no valid keys; zero them rather
    # than returning the softmax-of-NEG_INF uniform average.
    out = jnp.where(context_lens[:, None, None, None] > 0, out, 0.0)
    return out.reshape(b, h, d)


def ref_paged_attention_varlen(
    q: jax.Array,             # [B, T, H, D] ragged query chunks, right-padded
    k_pages: jax.Array,       # [KV, NB, BS, D] pooled key blocks
    v_pages: jax.Array,       # [KV, NB, BS, D] pooled value blocks
    block_tables: jax.Array,  # [B, M] int32 page ids
    row_start: jax.Array,     # [B] int32 abs position of query row 0
    row_len: jax.Array,       # [B] int32 live rows per slot (0 = inactive)
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """Ragged multi-token paged attention ground truth.

    Query ``t < row_len[b]`` of request ``b`` sits at absolute position
    ``row_start[b] + t`` and attends causally over positions ``<=`` its
    own (its K/V row — and those of the earlier rows in the chunk — are
    expected to already be written).  Padding rows ``t >= row_len[b]``
    and fully inactive slots (``row_len[b] == 0``) yield exactly zero.
    Decode, speculative verify and chunked prefill tiles are all this
    one shape with different ``(row_start, row_len)`` tables.
    """
    kv, _, bs, d = k_pages.shape
    b, t, h, _ = q.shape
    g = h // kv
    scale = d ** -0.5
    row_start = row_start.astype(jnp.int32)
    row_len = row_len.astype(jnp.int32)
    keys = k_pages[:, block_tables].reshape(kv, b, -1, d)
    vals = v_pages[:, block_tables].reshape(kv, b, -1, d)
    qg = q.reshape(b, t, kv, g, d)
    scores = jnp.einsum("btkgd,kbsd->bkgts", qg * scale, keys,
                        preferred_element_type=jnp.float32)
    pos = jnp.arange(keys.shape[2], dtype=jnp.int32)[None, None, :]
    qpos = (row_start[:, None]
            + jnp.arange(t, dtype=jnp.int32)[None, :])[:, :, None]
    valid = pos <= qpos                                   # [B, T, S]
    if window is not None:
        valid = jnp.logical_and(valid, (qpos - pos) < window)
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgts,kbsd->btkgd", probs, vals)
    row_live = (jnp.arange(t, dtype=jnp.int32)[None, :]
                < row_len[:, None])                       # [B, T]
    out = jnp.where(row_live[:, :, None, None, None], out, 0.0)
    return out.reshape(b, t, h, d)


def ref_paged_attention_multi(
    q: jax.Array,             # [B, T, H, D] consecutive query tokens
    k_pages: jax.Array,       # [KV, NB, BS, D] pooled key blocks
    v_pages: jax.Array,       # [KV, NB, BS, D] pooled value blocks
    block_tables: jax.Array,  # [B, M] int32 page ids
    context_lens: jax.Array,  # [B] int32 rows live *including* the T chunk
    *,
    window: Optional[int] = None,
) -> jax.Array:
    """Multi-token (speculative-verify) paged attention ground truth.

    The fixed-``T`` shape of :func:`ref_paged_attention_varlen`: query
    ``t`` of request ``b`` sits at absolute position ``context_lens[b]
    - T + t``.  ``T = 1`` reduces exactly to
    :func:`ref_paged_attention`.
    """
    t = q.shape[1]
    context_lens = context_lens.astype(jnp.int32)
    active = context_lens > 0
    row_start = jnp.where(active, context_lens - t, 0)
    row_len = jnp.where(active, t, 0)
    return ref_paged_attention_varlen(
        q, k_pages, v_pages, block_tables, row_start, row_len,
        window=window)


# ---------------------------------------------------------------------------
# Paged KV row write (serve engine's in-place pool append)
# ---------------------------------------------------------------------------


def masked_inplace_update(
    arr: jax.Array,
    new: jax.Array,
    start: Tuple[jax.Array, ...],
    valid,   # bool scalar or broadcastable-to-`new` mask
) -> jax.Array:
    """dynamic_update_slice of ``new`` at ``start``, keeping old values
    where ``valid`` is False.

    This read-select-writeback idiom is the load-bearing in-place
    pattern of the paged pool: XLA updates a DUS on a dead operand in
    place (also inside scan bodies), so callers pay O(slice), not
    O(array).  Shared by the decode-row oracle below and the prefill
    tile writer (``models.transformer.write_prefill_to_pages``) so the
    invariant lives in one place.
    """
    old = jax.lax.dynamic_slice(arr, start, new.shape)
    return jax.lax.dynamic_update_slice(
        arr, jnp.where(valid, new, old), start)


def ref_paged_kv_write(
    k_pages: jax.Array,   # [L, KV, NB, BS, D] pooled key blocks
    v_pages: jax.Array,   # [L, KV, NB, BS, D] pooled value blocks
    k_rows: jax.Array,    # [B, KV, D] new key rows (one per slot)
    v_rows: jax.Array,    # [B, KV, D] new value rows
    page_idx: jax.Array,  # [B] int32 destination page per slot
    offset: jax.Array,    # [B] int32 destination row within the page
    active: jax.Array,    # [B] bool; False slots write nothing
    *,
    layer: int,
) -> Tuple[jax.Array, jax.Array]:
    """Write slot b's K/V row at ``[layer, :, page_idx[b], offset[b], :]``.

    Semantic ground truth for ``paged_kv_write_pallas``.  Deliberately a
    per-slot ``dynamic_update_slice`` chain rather than one vector
    scatter: XLA updates DUS-on-a-dead-operand in place (also inside
    scan bodies), so the reference serve path pays O(rows written) per
    step instead of O(pool) — the same flatness in ``num_blocks`` the
    Pallas kernel gets from DMA + buffer aliasing.  Inactive slots keep
    the old row (read-select-writeback), mirroring the kernel's skipped
    copy; distinct slots never share a destination (allocator invariant),
    so the chain order is immaterial.
    """
    b, kv, d = k_rows.shape
    k_rows = k_rows.astype(k_pages.dtype)
    v_rows = v_rows.astype(v_pages.dtype)
    safe_page = jnp.where(active, page_idx, 0).astype(jnp.int32)
    offset = offset.astype(jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    for i in range(b):
        start = (jnp.asarray(layer, jnp.int32), zero, safe_page[i],
                 offset[i], zero)
        k_pages = masked_inplace_update(
            k_pages, k_rows[i].reshape(1, kv, 1, 1, d), start, active[i])
        v_pages = masked_inplace_update(
            v_pages, v_rows[i].reshape(1, kv, 1, 1, d), start, active[i])
    return k_pages, v_pages


# ---------------------------------------------------------------------------
# WKV6 linear-attention recurrence (rwkv6 time-mix)
# ---------------------------------------------------------------------------


def ref_wkv6(
    r: jax.Array,   # [B, S, H, K]
    k: jax.Array,   # [B, S, H, K]
    v: jax.Array,   # [B, S, H, V]
    w: jax.Array,   # [B, S, H, K]   decay in (0, 1)
    u: jax.Array,   # [H, K]         bonus
    state: Optional[jax.Array] = None,  # [B, H, K, V]
) -> Tuple[jax.Array, jax.Array]:
    from repro.models.rwkv6 import wkv6_scan

    return wkv6_scan(r, k, v, w, u, state)


# ---------------------------------------------------------------------------
# Fused per-token log-prob (the RLVR hot-spot)
# ---------------------------------------------------------------------------


def ref_logprobs_from_logits(
    logits: jax.Array,   # [N, V] (callers flatten [B, S, V])
    targets: jax.Array,  # [N] int32
) -> jax.Array:
    """log softmax gathered at targets, fp32 accumulation."""
    logits32 = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    tgt = jnp.take_along_axis(logits32, targets[:, None], axis=1)[:, 0]
    return tgt - lse


def ref_entropy_from_logits(logits: jax.Array) -> jax.Array:
    """Per-row softmax entropy, fp32."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.sum(jnp.exp(lp) * lp, axis=-1)


# ---------------------------------------------------------------------------
# Selective-SSM (Mamba/S6) scan — hymba's SSM branch
# ---------------------------------------------------------------------------


def ref_ssm_scan(
    u: jax.Array,     # [B, S, I]
    dt: jax.Array,    # [B, S, I]
    b_t: jax.Array,   # [B, S, N]
    c_t: jax.Array,   # [B, S, N]
    a: jax.Array,     # [I, N]
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    from repro.models.ssm import _ssm_scan

    return _ssm_scan(u, dt, b_t, c_t, a, h0)
