"""Pallas TPU kernel for V-trace advantage realignment (paper Eqs. 14-15).

TPU adaptation of a GPU per-trajectory loop: the recurrence is sequential
in time but embarrassingly parallel over trajectories, so the grid tiles
the *batch* dimension to the VPU sublane width (8) and each kernel
instance runs the backward time scan with its carry in vector registers.
The whole [B_BLK, T] tile lives in VMEM (for T=1000 rollouts and fp32
that's 8 x 1000 x 4B x 5 inputs ~ 160 KiB — comfortably under the
~16 MiB/core VMEM budget; tiles of B_BLK=8 keep lane pressure low).

All five inputs are consumed in one pass; vs and advantages are produced
together (the advantage needs v_{t+1}, available in the same sweep),
halving HBM traffic vs. running the scan and the TD step separately.

Validated in interpret mode against ``repro.kernels.ref.ref_vtrace``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vtrace_kernel(
    log_ratios_ref,   # [B_BLK, T]
    values_ref,       # [B_BLK, T]
    bootstrap_ref,    # [B_BLK, 1]
    rewards_ref,      # [B_BLK, T]
    discounts_ref,    # [B_BLK, T]
    vs_ref,           # [B_BLK, T] out
    adv_ref,          # [B_BLK, T] out
    *,
    t_len: int,
    rho_bar: float,
    c_bar: float,
    lam: float,
):
    ratios = jnp.exp(log_ratios_ref[...].astype(jnp.float32))
    rhos = jnp.minimum(rho_bar, ratios)
    cs = lam * jnp.minimum(c_bar, ratios)
    values = values_ref[...].astype(jnp.float32)
    rewards = rewards_ref[...].astype(jnp.float32)
    discounts = discounts_ref[...].astype(jnp.float32)
    bootstrap = bootstrap_ref[...][:, 0].astype(jnp.float32)

    # values_{t+1}: shift left, bootstrap in the last column.
    values_tp1 = jnp.concatenate(
        [values[:, 1:], bootstrap[:, None]], axis=1
    )
    deltas = rhos * (rewards + discounts * values_tp1 - values)

    # Backward scan over time; carry = (acc, v_{t+1}) per row.
    def step(t_rev, carry):
        acc, v_next = carry  # acc_t = vs_t - V_t
        t = t_len - 1 - t_rev
        delta_t = jax.lax.dynamic_slice_in_dim(deltas, t, 1, 1)[:, 0]
        disc_t = jax.lax.dynamic_slice_in_dim(discounts, t, 1, 1)[:, 0]
        c_t = jax.lax.dynamic_slice_in_dim(cs, t, 1, 1)[:, 0]
        val_t = jax.lax.dynamic_slice_in_dim(values, t, 1, 1)[:, 0]
        rew_t = jax.lax.dynamic_slice_in_dim(rewards, t, 1, 1)[:, 0]
        acc = delta_t + disc_t * c_t * acc
        vs_t = val_t + acc
        adv_t = rew_t + disc_t * v_next - val_t
        pl.store(vs_ref, (slice(None), pl.dslice(t, 1)),
                 vs_t[:, None].astype(vs_ref.dtype))
        pl.store(adv_ref, (slice(None), pl.dslice(t, 1)),
                 adv_t[:, None].astype(adv_ref.dtype))
        return acc, vs_t

    zero = jnp.zeros_like(bootstrap)
    jax.lax.fori_loop(0, t_len, step, (zero, bootstrap))


@functools.partial(
    jax.jit,
    static_argnames=("rho_bar", "c_bar", "lam", "block_b", "interpret"),
)
def vtrace_pallas(
    log_ratios: jax.Array,       # [B, T]
    values: jax.Array,           # [B, T]
    bootstrap_value: jax.Array,  # [B]
    rewards: jax.Array,          # [B, T]
    discounts: jax.Array,        # [B, T]
    *,
    rho_bar: float = 1.0,
    c_bar: float = 1.0,
    lam: float = 1.0,
    block_b: int = 8,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    b, t = log_ratios.shape
    block_b = min(block_b, b)
    pad_b = (-b) % block_b
    if pad_b:
        padder = lambda x: jnp.pad(x, ((0, pad_b),) + ((0, 0),) * (x.ndim - 1))
        log_ratios, values, rewards, discounts = map(
            padder, (log_ratios, values, rewards, discounts))
        bootstrap_value = jnp.pad(bootstrap_value, (0, pad_b))
    bp = b + pad_b

    grid = (bp // block_b,)
    row_spec = pl.BlockSpec((block_b, t), lambda i: (i, 0))
    boot_spec = pl.BlockSpec((block_b, 1), lambda i: (i, 0))

    vs, adv = pl.pallas_call(
        functools.partial(
            _vtrace_kernel, t_len=t, rho_bar=rho_bar, c_bar=c_bar, lam=lam,
        ),
        grid=grid,
        in_specs=[row_spec, row_spec, boot_spec, row_spec, row_spec],
        out_specs=[row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bp, t), jnp.float32),
            jax.ShapeDtypeStruct((bp, t), jnp.float32),
        ],
        interpret=interpret,
    )(log_ratios, values, bootstrap_value[:, None], rewards, discounts)
    return vs[:b], adv[:b]
