"""Pallas TPU kernel for the selective-SSM (Mamba/S6) recurrence.

    h_t = exp(dt_t ⊙ A) ⊙ h_{t-1} + dt_t ⊙ u_t ⊙ B_t
    y_t = h_t · C_t   (+ D-skip handled by the caller)

Parallel over (batch x channel blocks), sequential over time — the state
[I_BLK, N] lives in VMEM scratch for the whole trajectory, so each step
is a handful of VPU vector ops with zero HBM round-trips for the state
(the XLA scan reference spills the [B, I, N] carry between steps).

Channel blocks of 64 x state 16 keep the per-program working set
(inputs for all S timesteps + state) around 2-4 MiB for S=4096.

Validated in interpret mode against the jnp scan in repro.models.ssm.
Forward-only: training uses the autodiff-able reference; the kernel
serves the actor-side (no-grad) paths and is the TPU adaptation of the
CUDA selective-scan in the Mamba reference implementation.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(
    u_ref,    # [1, S, IB]
    dt_ref,   # [1, S, IB]
    b_ref,    # [1, S, N]
    c_ref,    # [1, S, N]
    a_ref,    # [IB, N]
    h0_ref,   # [1, IB, N]
    y_ref,    # [1, S, IB] out
    hT_ref,   # [1, IB, N] out
    h_scratch,  # [IB, N] fp32
    *,
    s_len: int,
):
    h_scratch[...] = h0_ref[0].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)          # [IB, N]

    def step(t, _):
        idx = (pl.dslice(0, 1), pl.dslice(t, 1), slice(None))
        u_t = pl.load(u_ref, idx)[0, 0]
        dt_t = pl.load(dt_ref, idx)[0, 0]
        b_t = pl.load(b_ref, idx)[0, 0]
        c_t = pl.load(c_ref, idx)[0, 0]
        u_t = u_t.astype(jnp.float32)
        dt_t = dt_t.astype(jnp.float32)
        b_t = b_t.astype(jnp.float32)
        c_t = c_t.astype(jnp.float32)

        h = h_scratch[...]
        decay = jnp.exp(dt_t[:, None] * a)                   # [IB, N]
        h = decay * h + (dt_t * u_t)[:, None] * b_t[None, :]
        h_scratch[...] = h
        y_t = jnp.sum(h * c_t[None, :], axis=1)              # [IB]
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 y_t[None, None, :].astype(y_ref.dtype))
        return ()

    jax.lax.fori_loop(0, s_len, step, ())
    hT_ref[0] = h_scratch[...].astype(hT_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_i", "interpret")
)
def ssm_scan_pallas(
    u: jax.Array,     # [B, S, I]
    dt: jax.Array,    # [B, S, I]
    b_t: jax.Array,   # [B, S, N]
    c_t: jax.Array,   # [B, S, N]
    a: jax.Array,     # [I, N] (negative reals)
    h0: Optional[jax.Array] = None,   # [B, I, N]
    *,
    block_i: int = 64,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,I], h_final [B,I,N])."""
    bsz, s, inner = u.shape
    n = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, inner, n), jnp.float32)
    block_i = min(block_i, inner)
    pad_i = (-inner) % block_i
    if pad_i:
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pad_i)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad_i)))
        a = jnp.pad(a, ((0, pad_i), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_i), (0, 0)))
    ip = inner + pad_i
    num_i = ip // block_i

    chan_spec = pl.BlockSpec((1, s, block_i), lambda b_, i: (b_, 0, i))
    state_in_spec = pl.BlockSpec((1, s, n), lambda b_, i: (b_, 0, 0))
    a_spec = pl.BlockSpec((block_i, n), lambda b_, i: (i, 0))
    h_spec = pl.BlockSpec((1, block_i, n), lambda b_, i: (b_, i, 0))

    y, hT = pl.pallas_call(
        functools.partial(_ssm_kernel, s_len=s),
        grid=(bsz, num_i),
        in_specs=[chan_spec, chan_spec, state_in_spec, state_in_spec,
                  a_spec, h_spec],
        out_specs=[chan_spec, h_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, ip), u.dtype),
            jax.ShapeDtypeStruct((bsz, ip, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_i, n), jnp.float32)],
        interpret=interpret,
    )(u, dt, b_t, c_t, a, h0)
    return y[..., :inner], hT[:, :inner]
