"""jit'd dispatch wrappers over the Pallas kernels and their jnp oracles.

Selection policy (``KernelMode``):

* ``reference``         — pure-jnp oracles (CPU, autodiff, dry-run).
* ``pallas_interpret``  — Pallas kernels executed by the interpreter
                          (CPU validation of the TPU kernel bodies).
* ``pallas``            — compiled Pallas (real TPU).

Default comes from ``REPRO_KERNEL_MODE`` (falls back to ``reference`` on
CPU hosts).  The wrappers keep one signature regardless of backend so the
models/trainers never branch.

**Mesh-sharded serve** (``mesh=`` on the paged ops): the paged KV pool
shards its ``NB`` (page) axis over the mesh's ``data`` axis, and every
request's pages live on exactly ONE shard (placement is host-side, in
``repro.serve``).  The sharded dispatchers wrap the same kernel bodies
in ``shard_map``:

* ``paged_attention`` / ``paged_attention_multi`` /
  ``paged_attention_varlen`` — block tables carry
  *shard-local* page ids; each device runs the kernel over its local
  pool with non-local slots masked to ``context_len 0`` (both the
  Pallas kernel and the oracle produce exact zeros there), then a
  ``psum`` over the data axis recombines the batch.  Since every slot
  is non-zero on exactly one shard, the sum is exact — the sharded path
  is bit-identical to the single-device one.
* ``paged_kv_write`` — each device applies the row scatter with the
  active mask restricted to its own slots; out_specs keep the pool
  sharded, and the in-place aliasing (Pallas ``input_output_aliases``
  / XLA DUS-on-dead-operand) survives because each shard updates only
  its local buffer.

``mesh=None`` (or a data axis of size 1) is the single-device special
case of the same code path, not a sibling implementation.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.kernels import ref as ref_mod
from repro.kernels.flash_attention_pallas import flash_attention
from repro.kernels.fused_logprob_pallas import logprobs_pallas
from repro.kernels.paged_attention_pallas import paged_attention as \
    paged_attention_pallas
from repro.kernels.paged_attention_pallas import paged_attention_multi as \
    paged_attention_multi_pallas
from repro.kernels.paged_attention_pallas import paged_attention_varlen as \
    paged_attention_varlen_pallas
from repro.kernels.paged_kv_write_pallas import paged_kv_write as \
    paged_kv_write_pallas
from repro.kernels.ssm_scan_pallas import ssm_scan_pallas
from repro.kernels.vtrace_pallas import vtrace_pallas
from repro.kernels.wkv6_pallas import wkv6_pallas

_VALID = ("reference", "pallas_interpret", "pallas")


def kernel_mode() -> str:
    mode = os.environ.get("REPRO_KERNEL_MODE", "reference")
    if mode not in _VALID:
        raise ValueError(f"REPRO_KERNEL_MODE={mode!r}; want one of {_VALID}")
    return mode


def _pallas_kwargs(mode: Optional[str]) -> Optional[dict]:
    mode = mode or kernel_mode()
    if mode == "reference":
        return None
    return {"interpret": mode == "pallas_interpret"}


def mesh_data_size(mesh, axis_name: str = "data") -> int:
    """Size of the mesh's serve-sharding axis (1 = unsharded/no mesh)."""
    if mesh is None or axis_name not in mesh.shape:
        return 1
    return int(mesh.shape[axis_name])


def _sharded(mesh, axis_name: str) -> bool:
    return mesh_data_size(mesh, axis_name) > 1


def vtrace(
    log_ratios, values, bootstrap_value, rewards, discounts,
    *, rho_bar: float = 1.0, c_bar: float = 1.0, lam: float = 1.0,
    mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_vtrace(
            log_ratios, values, bootstrap_value, rewards, discounts,
            rho_bar=rho_bar, c_bar=c_bar, lam=lam)
    return vtrace_pallas(
        log_ratios, values, bootstrap_value, rewards, discounts,
        rho_bar=rho_bar, c_bar=c_bar, lam=lam, **kw)


def attention(
    q, k, v, *, window: Optional[int] = None, causal: bool = True,
    mode: Optional[str] = None,
):
    kw = _pallas_kwargs(mode)
    if kw is None or not causal:
        return ref_mod.ref_attention(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, window=window, **kw)


def _paged_attention_local(
    q, k_pages, v_pages, block_tables, context_lens, *, window, mode,
):
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_paged_attention(
            q, k_pages, v_pages, block_tables, context_lens, window=window)
    return paged_attention_pallas(
        q, k_pages, v_pages, block_tables, context_lens,
        window=window, **kw)


def paged_attention(
    q, k_pages, v_pages, block_tables, context_lens,
    *, window: Optional[int] = None, mode: Optional[str] = None,
    mesh=None, slot_shard=None, axis_name: str = "data",
):
    """Decode attention over a block-table paged KV pool ([B, H, D]).

    With a ``mesh``, ``k_pages``/``v_pages`` are NB-sharded over
    ``axis_name``, ``block_tables`` hold shard-local page ids, and
    ``slot_shard[b]`` names the shard owning slot ``b``'s pages: each
    device attends over its local pool with foreign slots masked to
    context 0 (exact zero output) and a ``psum`` recombines the batch.
    """
    if not _sharded(mesh, axis_name):
        return _paged_attention_local(
            q, k_pages, v_pages, block_tables, context_lens,
            window=window, mode=mode)

    def body(q, kp, vp, tbl, lens, ss):
        idx = jax.lax.axis_index(axis_name)
        local_lens = jnp.where(ss == idx, lens, 0).astype(jnp.int32)
        out = _paged_attention_local(
            q, kp, vp, tbl, local_lens, window=window, mode=mode)
        return jax.lax.psum(out, axis_name)

    pool = P(None, axis_name, None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), pool, pool, P(), P(), P()),
        out_specs=P(), check_rep=False,
    )(q, k_pages, v_pages, block_tables, context_lens,
      slot_shard.astype(jnp.int32))


def _paged_attention_multi_local(
    q, k_pages, v_pages, block_tables, context_lens, *, window, mode,
):
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_paged_attention_multi(
            q, k_pages, v_pages, block_tables, context_lens, window=window)
    return paged_attention_multi_pallas(
        q, k_pages, v_pages, block_tables, context_lens,
        window=window, **kw)


def paged_attention_multi(
    q, k_pages, v_pages, block_tables, context_lens,
    *, window: Optional[int] = None, mode: Optional[str] = None,
    mesh=None, slot_shard=None, axis_name: str = "data",
):
    """Multi-token verify attention over the paged pool ([B, T, H, D]):
    query ``t`` sits at absolute position ``context_lens - T + t`` and
    attends causally — T drafted tokens scored in one dispatch.  Mesh
    semantics match :func:`paged_attention` (local tables + psum)."""
    if not _sharded(mesh, axis_name):
        return _paged_attention_multi_local(
            q, k_pages, v_pages, block_tables, context_lens,
            window=window, mode=mode)

    def body(q, kp, vp, tbl, lens, ss):
        idx = jax.lax.axis_index(axis_name)
        local_lens = jnp.where(ss == idx, lens, 0).astype(jnp.int32)
        out = _paged_attention_multi_local(
            q, kp, vp, tbl, local_lens, window=window, mode=mode)
        return jax.lax.psum(out, axis_name)

    pool = P(None, axis_name, None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), pool, pool, P(), P(), P()),
        out_specs=P(), check_rep=False,
    )(q, k_pages, v_pages, block_tables, context_lens,
      slot_shard.astype(jnp.int32))


def _paged_attention_varlen_local(
    q, k_pages, v_pages, block_tables, row_start, row_len, *, window, mode,
):
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_paged_attention_varlen(
            q, k_pages, v_pages, block_tables, row_start, row_len,
            window=window)
    return paged_attention_varlen_pallas(
        q, k_pages, v_pages, block_tables, row_start, row_len,
        window=window, **kw)


def paged_attention_varlen(
    q, k_pages, v_pages, block_tables, row_start, row_len,
    *, window: Optional[int] = None, mode: Optional[str] = None,
    mesh=None, slot_shard=None, axis_name: str = "data",
):
    """Ragged multi-token attention over the paged pool ([B, T, H, D]):
    query ``t < row_len[b]`` sits at absolute position ``row_start[b] +
    t`` and attends causally; padding rows and ``row_len == 0`` slots
    come back exactly zero.  Decode, speculative verify and chunked
    prefill tiles are call shapes of this one kernel.  Mesh semantics
    match :func:`paged_attention` — foreign slots are masked to
    ``row_len 0`` (exact zero) and a ``psum`` recombines the batch."""
    if not _sharded(mesh, axis_name):
        return _paged_attention_varlen_local(
            q, k_pages, v_pages, block_tables, row_start, row_len,
            window=window, mode=mode)

    def body(q, kp, vp, tbl, rs, rl, ss):
        idx = jax.lax.axis_index(axis_name)
        local_len = jnp.where(ss == idx, rl, 0).astype(jnp.int32)
        out = _paged_attention_varlen_local(
            q, kp, vp, tbl, rs, local_len, window=window, mode=mode)
        return jax.lax.psum(out, axis_name)

    pool = P(None, axis_name, None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), pool, pool, P(), P(), P(), P()),
        out_specs=P(), check_rep=False,
    )(q, k_pages, v_pages, block_tables, row_start, row_len,
      slot_shard.astype(jnp.int32))


def _paged_kv_write_local(
    k_pages, v_pages, k_rows, v_rows, page_idx, offset, active,
    *, layer, mode,
):
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_paged_kv_write(
            k_pages, v_pages, k_rows, v_rows, page_idx, offset, active,
            layer=layer)
    return paged_kv_write_pallas(
        k_pages, v_pages, k_rows, v_rows, page_idx, offset, active,
        layer=layer, **kw)


def paged_kv_write(
    k_pages, v_pages, k_rows, v_rows, page_idx, offset, active,
    *, layer: int, mode: Optional[str] = None,
    mesh=None, slot_shard=None, axis_name: str = "data",
) -> Tuple[jax.Array, jax.Array]:
    """In-place scatter of one decode step's K/V rows into the pool.

    Returns the updated ``(k_pages, v_pages)``; both paths update the
    buffer in place when the caller's pools are donated/dead (the Pallas
    route via ``input_output_aliases``, the reference route via XLA's
    in-place dynamic_update_slice), so per-step cost is O(rows), not
    O(pool).

    With a ``mesh`` the pools are NB-sharded over ``axis_name``,
    ``page_idx`` is shard-local, and each device narrows ``active`` to
    its own slots (``slot_shard``), so a slot's row lands only on its
    home shard; out_specs keep the pool sharded and the per-shard
    buffers update in place exactly as on one device.
    """
    if not _sharded(mesh, axis_name):
        return _paged_kv_write_local(
            k_pages, v_pages, k_rows, v_rows, page_idx, offset, active,
            layer=layer, mode=mode)

    def body(kp, vp, kr, vr, pidx, off, act, ss):
        idx = jax.lax.axis_index(axis_name)
        local_act = jnp.logical_and(act, ss == idx)
        return _paged_kv_write_local(
            kp, vp, kr, vr, pidx, off, local_act, layer=layer, mode=mode)

    pool = P(None, None, axis_name, None, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(pool, pool, P(), P(), P(), P(), P(), P()),
        out_specs=(pool, pool), check_rep=False,
    )(k_pages, v_pages, k_rows, v_rows, page_idx, offset, active,
      slot_shard.astype(jnp.int32))


def wkv6(
    r, k, v, w, u, state=None, *, mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_wkv6(r, k, v, w, u, state)
    return wkv6_pallas(r, k, v, w, u, state, **kw)


def ssm_scan(
    u, dt, b_t, c_t, a, h0=None, *, mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_ssm_scan(u, dt, b_t, c_t, a, h0)
    return ssm_scan_pallas(u, dt, b_t, c_t, a, h0, **kw)


def logprobs_from_logits(
    logits: jax.Array,    # [..., V]
    targets: jax.Array,   # [...]
    *, mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logp, entropy), shapes = targets.shape, fp32."""
    lead = logits.shape[:-1]
    vocab = logits.shape[-1]
    flat_logits = logits.reshape(-1, vocab)
    flat_targets = targets.reshape(-1)
    kw = _pallas_kwargs(mode)
    if kw is None:
        logp = ref_mod.ref_logprobs_from_logits(flat_logits, flat_targets)
        ent = ref_mod.ref_entropy_from_logits(flat_logits)
    else:
        logp, ent = logprobs_pallas(flat_logits, flat_targets, **kw)
    return logp.reshape(lead), ent.reshape(lead)
