"""jit'd dispatch wrappers over the Pallas kernels and their jnp oracles.

Selection policy (``KernelMode``):

* ``reference``         — pure-jnp oracles (CPU, autodiff, dry-run).
* ``pallas_interpret``  — Pallas kernels executed by the interpreter
                          (CPU validation of the TPU kernel bodies).
* ``pallas``            — compiled Pallas (real TPU).

Default comes from ``REPRO_KERNEL_MODE`` (falls back to ``reference`` on
CPU hosts).  The wrappers keep one signature regardless of backend so the
models/trainers never branch.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as ref_mod
from repro.kernels.flash_attention_pallas import flash_attention
from repro.kernels.fused_logprob_pallas import logprobs_pallas
from repro.kernels.paged_attention_pallas import paged_attention as \
    paged_attention_pallas
from repro.kernels.paged_attention_pallas import paged_attention_multi as \
    paged_attention_multi_pallas
from repro.kernels.paged_kv_write_pallas import paged_kv_write as \
    paged_kv_write_pallas
from repro.kernels.ssm_scan_pallas import ssm_scan_pallas
from repro.kernels.vtrace_pallas import vtrace_pallas
from repro.kernels.wkv6_pallas import wkv6_pallas

_VALID = ("reference", "pallas_interpret", "pallas")


def kernel_mode() -> str:
    mode = os.environ.get("REPRO_KERNEL_MODE", "reference")
    if mode not in _VALID:
        raise ValueError(f"REPRO_KERNEL_MODE={mode!r}; want one of {_VALID}")
    return mode


def _pallas_kwargs(mode: Optional[str]) -> Optional[dict]:
    mode = mode or kernel_mode()
    if mode == "reference":
        return None
    return {"interpret": mode == "pallas_interpret"}


def vtrace(
    log_ratios, values, bootstrap_value, rewards, discounts,
    *, rho_bar: float = 1.0, c_bar: float = 1.0, lam: float = 1.0,
    mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_vtrace(
            log_ratios, values, bootstrap_value, rewards, discounts,
            rho_bar=rho_bar, c_bar=c_bar, lam=lam)
    return vtrace_pallas(
        log_ratios, values, bootstrap_value, rewards, discounts,
        rho_bar=rho_bar, c_bar=c_bar, lam=lam, **kw)


def attention(
    q, k, v, *, window: Optional[int] = None, causal: bool = True,
    mode: Optional[str] = None,
):
    kw = _pallas_kwargs(mode)
    if kw is None or not causal:
        return ref_mod.ref_attention(q, k, v, causal=causal, window=window)
    return flash_attention(q, k, v, window=window, **kw)


def paged_attention(
    q, k_pages, v_pages, block_tables, context_lens,
    *, window: Optional[int] = None, mode: Optional[str] = None,
):
    """Decode attention over a block-table paged KV pool ([B, H, D])."""
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_paged_attention(
            q, k_pages, v_pages, block_tables, context_lens, window=window)
    return paged_attention_pallas(
        q, k_pages, v_pages, block_tables, context_lens,
        window=window, **kw)


def paged_attention_multi(
    q, k_pages, v_pages, block_tables, context_lens,
    *, window: Optional[int] = None, mode: Optional[str] = None,
):
    """Multi-token verify attention over the paged pool ([B, T, H, D]):
    query ``t`` sits at absolute position ``context_lens - T + t`` and
    attends causally — T drafted tokens scored in one dispatch."""
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_paged_attention_multi(
            q, k_pages, v_pages, block_tables, context_lens, window=window)
    return paged_attention_multi_pallas(
        q, k_pages, v_pages, block_tables, context_lens,
        window=window, **kw)


def paged_kv_write(
    k_pages, v_pages, k_rows, v_rows, page_idx, offset, active,
    *, layer: int, mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """In-place scatter of one decode step's K/V rows into the pool.

    Returns the updated ``(k_pages, v_pages)``; both paths update the
    buffer in place when the caller's pools are donated/dead (the Pallas
    route via ``input_output_aliases``, the reference route via XLA's
    in-place dynamic_update_slice), so per-step cost is O(rows), not
    O(pool).
    """
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_paged_kv_write(
            k_pages, v_pages, k_rows, v_rows, page_idx, offset, active,
            layer=layer)
    return paged_kv_write_pallas(
        k_pages, v_pages, k_rows, v_rows, page_idx, offset, active,
        layer=layer, **kw)


def wkv6(
    r, k, v, w, u, state=None, *, mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_wkv6(r, k, v, w, u, state)
    return wkv6_pallas(r, k, v, w, u, state, **kw)


def ssm_scan(
    u, dt, b_t, c_t, a, h0=None, *, mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    kw = _pallas_kwargs(mode)
    if kw is None:
        return ref_mod.ref_ssm_scan(u, dt, b_t, c_t, a, h0)
    return ssm_scan_pallas(u, dt, b_t, c_t, a, h0, **kw)


def logprobs_from_logits(
    logits: jax.Array,    # [..., V]
    targets: jax.Array,   # [...]
    *, mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logp, entropy), shapes = targets.shape, fp32."""
    lead = logits.shape[:-1]
    vocab = logits.shape[-1]
    flat_logits = logits.reshape(-1, vocab)
    flat_targets = targets.reshape(-1)
    kw = _pallas_kwargs(mode)
    if kw is None:
        logp = ref_mod.ref_logprobs_from_logits(flat_logits, flat_targets)
        ent = ref_mod.ref_entropy_from_logits(flat_logits)
    else:
        logp, ent = logprobs_pallas(flat_logits, flat_targets, **kw)
    return logp.reshape(lead), ent.reshape(lead)
