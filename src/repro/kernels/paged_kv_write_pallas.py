"""Pallas TPU in-place paged KV row write: block-table scatter via DMA.

The serve engine's decode step appends one K/V row per active slot into
the pooled block cache.  Expressing that append as a jnp scatter on a
scan-carried pool makes XLA rewrite the *entire* ``[L, KV, NB, BS, Dh]``
pool every step — per-step cost grows linearly in ``num_blocks`` even
though exactly one row per layer changes (ROADMAP: a 128-block pool
measured ~2.7x slower than 16-block at equal work).  This kernel is the
write-side mirror of ``kernels/paged_attention_pallas.py``'s gather:

* the pool rides in (and out) as an **aliased HBM operand**
  (``input_output_aliases`` + ``memory_space=ANY``): the output *is* the
  input buffer, so nothing outside the touched rows moves;
* per-slot page ids / in-page offsets arrive as *scalar prefetch*
  (``pltpu.PrefetchScalarGridSpec``), so the destination of each row is
  known before the body runs — the scatter happens in the DMA engine
  (``pltpu.make_async_copy`` VMEM -> HBM), not in compute;
* grid = (batch,): slot b DMAs its ``[KV, 1, 1, Dh]`` K and V rows into
  ``pages[layer, :, page_idx[b], offset[b], :]``; inactive slots skip
  the copy entirely with ``pl.when`` (the aliased buffer keeps its old
  rows — "drop" semantics for free, and zero traffic for dead slots).

Distinct requests own distinct pages (the allocator guarantees it), so
the per-slot DMAs never collide.  ``layer`` is static: the hoisted
decode loop (``transformer.decode_step_paged``) emits one dispatch per
layer against the stacked pool.

Forward-only; the pure-jnp oracle is
``repro.kernels.ref.ref_paged_kv_write`` (whose per-slot
``dynamic_update_slice`` structure XLA also updates in place — the
CPU/reference path gets the same flat-in-``num_blocks`` cost).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kv_write_kernel(
    page_idx_ref,   # scalar prefetch [B] int32 (in-range for active slots)
    offset_ref,     # scalar prefetch [B] int32 row offset within the page
    active_ref,     # scalar prefetch [B] int32 (0 = drop the write)
    k_rows_ref,     # [1, KV, 1, 1, D] VMEM — slot b's new K row
    v_rows_ref,     # [1, KV, 1, 1, D] VMEM
    k_in_ref,       # [L, KV, NB, BS, D] ANY/HBM (aliased with k_out_ref)
    v_in_ref,       # [L, KV, NB, BS, D] ANY/HBM (aliased with v_out_ref)
    k_out_ref,      # same buffer as k_in_ref
    v_out_ref,      # same buffer as v_in_ref
    k_sem,          # DMA semaphore
    v_sem,          # DMA semaphore
    *,
    layer: int,
):
    del k_in_ref, v_in_ref  # aliased: the out refs are the same buffers
    b = pl.program_id(0)

    @pl.when(active_ref[b] != 0)
    def _write():
        page = page_idx_ref[b]
        off = offset_ref[b]
        copy_k = pltpu.make_async_copy(
            k_rows_ref.at[0],
            k_out_ref.at[layer, :, pl.ds(page, 1), pl.ds(off, 1), :],
            k_sem,
        )
        copy_v = pltpu.make_async_copy(
            v_rows_ref.at[0],
            v_out_ref.at[layer, :, pl.ds(page, 1), pl.ds(off, 1), :],
            v_sem,
        )
        copy_k.start()
        copy_v.start()
        copy_k.wait()
        copy_v.wait()


@functools.partial(jax.jit, static_argnames=("layer", "interpret"))
def paged_kv_write(
    k_pages: jax.Array,   # [L, KV, NB, BS, D] pooled key blocks
    v_pages: jax.Array,   # [L, KV, NB, BS, D] pooled value blocks
    k_rows: jax.Array,    # [B, KV, D] new key rows (one per slot)
    v_rows: jax.Array,    # [B, KV, D] new value rows
    page_idx: jax.Array,  # [B] int32 destination page per slot
    offset: jax.Array,    # [B] int32 destination row within the page
    active: jax.Array,    # [B] bool/int; False slots write nothing
    *,
    layer: int,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Scatter one decode step's K/V rows into layer ``layer`` in place.

    Returns the (aliased) pools; the caller must treat its input pools as
    consumed, exactly like a donated buffer.  ``page_idx`` of an inactive
    slot may be any value (the copy is skipped before the id is read).
    """
    b, kv, d = k_rows.shape
    assert k_pages.ndim == 5, k_pages.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, kv, 1, 1, d),
                         lambda b_, pi, of, ac: (b_, 0, 0, 0, 0)),
            pl.BlockSpec((1, kv, 1, 1, d),
                         lambda b_, pi, of, ac: (b_, 0, 0, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        scratch_shapes=[
            pltpu.SemaphoreType.DMA(()),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kv_write_kernel, layer=layer),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(k_pages.shape, k_pages.dtype),
            jax.ShapeDtypeStruct(v_pages.shape, v_pages.dtype),
        ],
        # Operand indices count the scalar-prefetch args: the pools are
        # operands 5/6 and alias outputs 0/1 — the in-place contract.
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(page_idx.astype(jnp.int32), offset.astype(jnp.int32),
      active.astype(jnp.int32),
      k_rows.reshape(b, kv, 1, 1, d).astype(k_pages.dtype),
      v_rows.reshape(b, kv, 1, 1, d).astype(v_pages.dtype),
      k_pages, v_pages)
