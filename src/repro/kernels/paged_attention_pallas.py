"""Pallas TPU paged decode attention: block-table K/V gather in-kernel.

The serve engine's KV cache is a pool of fixed-size blocks; each request
owns an ordered *block table* mapping logical positions to pages.  Dense
decode attention would need the pool compacted per step — this kernel
instead gathers pages through the table inside the kernel, so a decode
step touches exactly the pages its requests own:

* grid = (batch, q_heads, max_blocks); the block axis is innermost
  (sequential) so the online-softmax accumulator lives in VMEM scratch
  across page iterations, as in the flash kernel.
* the block tables and context lengths ride in as *scalar prefetch*
  (``pltpu.PrefetchScalarGridSpec``): the k/v BlockSpec index maps read
  ``tables[b, j]`` to pick the HBM page to stream, which is the whole
  trick — the gather happens in the DMA engine, not in compute.
* pages are laid out ``[KV, NB, BS, D]`` (kv-head major) so one grid
  step streams a single ``[BS, D]`` tile; GQA folds the query head onto
  its kv group exactly like the flash kernel.
* ragged sequences: positions >= context_lens[b] are masked, and pages
  entirely past the context (or entirely outside a sliding window) are
  skipped with ``pl.when`` — a request with 3 live pages in a 64-page
  table does 3 page-iterations of work.

Pad slots of a table must hold an *in-range* page id (the allocator pads
with 0): the index map runs for skipped iterations too.

Two entry points share the machinery:

* :func:`paged_attention` — one query token per request (the plain
  decode step).
* :func:`paged_attention_varlen` — up to ``Tmax`` consecutive query
  tokens per request with a *per-slot* ``(row_start, row_len)`` table
  riding in as scalar prefetch: query ``t < row_len[b]`` of request
  ``b`` sits at absolute position ``row_start[b] + t`` and attends
  causally over exactly its own prefix; rows ``t >= row_len[b]`` are
  padding and come back exactly zero.  Decode (``row_len == 1``),
  speculative verify (``row_len == k``) and chunked prefill tiles
  (ragged ``row_len`` per slot) are three call shapes of this one
  kernel — the online-softmax state grows a ``Tmax`` row axis and the
  page loop, scalar-prefetch gather and window logic are unchanged.
* :func:`paged_attention_multi` — the fixed-``T`` shape (every active
  slot supplies exactly ``T`` rows ending at ``context_lens[b]``);
  kept as a thin wrapper that derives ``row_start = ctx - T`` /
  ``row_len = T`` and calls the varlen kernel.

Forward-only (decode); the pure-jnp oracles are
``repro.kernels.ref.ref_paged_attention`` and
``ref.ref_paged_attention_varlen``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(
    tables_ref,   # scalar prefetch [B, M] int32
    lens_ref,     # scalar prefetch [B] int32
    q_ref,        # [1, 1, D]
    k_ref,        # [1, 1, BS, D]
    v_ref,        # [1, 1, BS, D]
    o_ref,        # [1, 1, D]
    m_ref,        # scratch [1, 1]
    l_ref,        # scratch [1, 1]
    acc_ref,      # scratch [1, D]
    *,
    block_size: int,
    num_blocks_max: int,
    window: Optional[int],
    scale: float,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    ctx = lens_ref[b]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = j * block_size
    live = k_start < ctx                       # page overlaps the context
    if window is not None:
        # Newest token is at ctx-1; skip pages fully left of the window.
        live = jnp.logical_and(
            live, (ctx - 1) - (k_start + block_size - 1) < window
        )

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [BS, D]
        v = v_ref[0, 0].astype(jnp.float32)
        scores = jnp.dot(k, q, preferred_element_type=jnp.float32)  # [BS]

        kpos = k_start + jax.lax.iota(jnp.int32, block_size)
        mask = kpos < ctx
        if window is not None:
            mask = jnp.logical_and(mask, (ctx - 1) - kpos < window)
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_ref[0, 0]
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(scores))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)                          # [BS]
        l_ref[0, 0] = alpha * l_prev + jnp.sum(p)
        acc_ref[...] = acc_ref[...] * alpha + (p @ v)[None, :]
        m_ref[0, 0] = m_new

    @pl.when(j == num_blocks_max - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[0, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[0] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "interpret"),
)
def paged_attention(
    q: jax.Array,             # [B, H, D]
    k_pages: jax.Array,       # [KV, NB, BS, D]
    v_pages: jax.Array,       # [KV, NB, BS, D]
    block_tables: jax.Array,  # [B, M] int32 page ids (pads must be in-range)
    context_lens: jax.Array,  # [B] int32
    *,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Single-token decode attention over a paged KV pool."""
    b, h, d = q.shape
    kv, _, block_size, _ = k_pages.shape
    m = block_tables.shape[1]
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = d ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, m),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda b_, h_, j, tbl, cl: (b_, h_, 0)),
            pl.BlockSpec(
                (1, 1, block_size, d),
                lambda b_, h_, j, tbl, cl: (h_ // group, tbl[b_, j], 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_size, d),
                lambda b_, h_, j, tbl, cl: (h_ // group, tbl[b_, j], 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, d), lambda b_, h_, j, tbl, cl: (b_, h_, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _paged_kernel, block_size=block_size, num_blocks_max=m,
            window=window, scale=scale,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), context_lens.astype(jnp.int32),
      q, k_pages, v_pages)


def _paged_varlen_kernel(
    tables_ref,   # scalar prefetch [B, M] int32
    start_ref,    # scalar prefetch [B] int32 (abs position of query row 0)
    len_ref,      # scalar prefetch [B] int32 (live query rows, 0 = inactive)
    q_ref,        # [1, T, 1, D]
    k_ref,        # [1, 1, BS, D]
    v_ref,        # [1, 1, BS, D]
    o_ref,        # [1, T, 1, D]
    m_ref,        # scratch [T, 1]
    l_ref,        # scratch [T, 1]
    acc_ref,      # scratch [T, D]
    *,
    block_size: int,
    num_blocks_max: int,
    q_len: int,
    window: Optional[int],
    scale: float,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    base = start_ref[b]           # absolute position of query 0
    n = len_ref[b]                # live rows; padding rows t >= n
    ctx = base + n                # rows live once the chunk is written

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = j * block_size
    live = jnp.logical_and(k_start < ctx, n > 0)
    if window is not None:
        # The *oldest* query (position `base`) has the leftmost window;
        # a page fully left of it is dead for every query in the chunk.
        live = jnp.logical_and(
            live, base - (k_start + block_size - 1) < window
        )

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0].astype(jnp.float32) * scale       # [T, D]
        k = k_ref[0, 0].astype(jnp.float32)                  # [BS, D]
        v = v_ref[0, 0].astype(jnp.float32)
        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # [T, BS]

        kpos = k_start + jax.lax.iota(jnp.int32, block_size)  # [BS]
        qpos = base + jax.lax.iota(jnp.int32, q_len)          # [T]
        mask = kpos[None, :] <= qpos[:, None]                 # causal
        if window is not None:
            mask = jnp.logical_and(
                mask, (qpos[:, None] - kpos[None, :]) < window)
        # Padding rows (t >= n) get a fully-masked score row; their m
        # saturates at NEG_INF and the accumulator fills with garbage
        # that _finalize zeroes out.
        scores = jnp.where(mask, scores, NEG_INF)

        m_prev = m_ref[:, 0]                                  # [T]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[:, None])                  # [T, BS]
        l_ref[...] = (alpha * l_prev + jnp.sum(p, axis=1))[:, None]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new[:, None]

    @pl.when(j == num_blocks_max - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)
        row_live = jax.lax.iota(jnp.int32, q_len) < n         # [T]
        out = jnp.where(
            row_live[:, None], acc_ref[...] / denom[:, None], 0.0)
        o_ref[0, :, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "interpret"),
)
def paged_attention_varlen(
    q: jax.Array,             # [B, T, H, D] ragged query chunks, right-padded
    k_pages: jax.Array,       # [KV, NB, BS, D]
    v_pages: jax.Array,       # [KV, NB, BS, D]
    block_tables: jax.Array,  # [B, M] int32 page ids (pads must be in-range)
    row_start: jax.Array,     # [B] int32 abs position of query row 0
    row_len: jax.Array,       # [B] int32 live rows per slot (0 = inactive)
    *,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Ragged multi-token attention over a paged KV pool.

    Query ``t < row_len[b]`` of request ``b`` sits at absolute position
    ``row_start[b] + t`` and attends causally over positions ``<=`` its
    own; rows ``t >= row_len[b]`` are padding and yield exactly zero, as
    does a slot with ``row_len[b] == 0``.  Decode (``row_len == 1``),
    speculative verify (``row_len == k``) and chunked prefill tiles are
    all this one kernel called with different ``(row_start, row_len)``
    tables."""
    b, t, h, d = q.shape
    kv, _, block_size, _ = k_pages.shape
    m = block_tables.shape[1]
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = d ** -0.5

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, h, m),
        in_specs=[
            pl.BlockSpec(
                (1, t, 1, d), lambda b_, h_, j, tbl, rs, rl: (b_, 0, h_, 0)),
            pl.BlockSpec(
                (1, 1, block_size, d),
                lambda b_, h_, j, tbl, rs, rl: (h_ // group, tbl[b_, j], 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_size, d),
                lambda b_, h_, j, tbl, rs, rl: (h_ // group, tbl[b_, j], 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, t, 1, d), lambda b_, h_, j, tbl, rs, rl: (b_, 0, h_, 0)),
        scratch_shapes=[
            pltpu.VMEM((t, 1), jnp.float32),
            pltpu.VMEM((t, 1), jnp.float32),
            pltpu.VMEM((t, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _paged_varlen_kernel, block_size=block_size, num_blocks_max=m,
            q_len=t, window=window, scale=scale,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, h, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), row_start.astype(jnp.int32),
      row_len.astype(jnp.int32), q, k_pages, v_pages)


@functools.partial(
    jax.jit, static_argnames=("window", "interpret"),
)
def paged_attention_multi(
    q: jax.Array,             # [B, T, H, D] consecutive query tokens
    k_pages: jax.Array,       # [KV, NB, BS, D]
    v_pages: jax.Array,       # [KV, NB, BS, D]
    block_tables: jax.Array,  # [B, M] int32 page ids (pads must be in-range)
    context_lens: jax.Array,  # [B] int32 rows live *including* the T chunk
    *,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Fixed-``T`` shape of :func:`paged_attention_varlen`: query ``t``
    of request ``b`` sits at absolute position ``context_lens[b] - T +
    t`` and attends causally over positions ``<=`` its own.  A slot with
    ``context_lens[b] == 0`` is inactive and yields exactly zero."""
    t = q.shape[1]
    context_lens = context_lens.astype(jnp.int32)
    active = context_lens > 0
    row_start = jnp.where(active, context_lens - t, 0)
    row_len = jnp.where(active, t, 0)
    return paged_attention_varlen(
        q, k_pages, v_pages, block_tables, row_start, row_len,
        window=window, interpret=interpret)
