"""Pallas TPU kernels for the performance hot-spots, with jnp oracles.

- vtrace_pallas         : batch-tiled backward time-scan (Eqs. 14-15)
- flash_attention_pallas: online-softmax causal/SWA attention, GQA-aware
- wkv6_pallas           : chunked RWKV-6 linear-attention recurrence
- paged_kv_write_pallas : aliased DMA row scatter into the paged KV pool
- fused_logprob_pallas  : vocab-streamed log-prob + entropy (RLVR hot-spot)
- ops                   : jit'd dispatch (reference | pallas_interpret | pallas)
- ref                   : pure-jnp oracles, autodiff/CPU fallback
"""
from repro.kernels import ops, ref
