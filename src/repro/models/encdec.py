"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the harness carve-out, the mel-spectrogram + conv feature extractor is
NOT implemented: ``input_specs`` supplies precomputed frame embeddings
``[B, S_enc, d_model]`` (post-conv, post-subsampling — whisper-large-v3's
1500 frames). The transformer itself — encoder self-attention stack and
decoder with causal self-attention + cross-attention + KV cache — is real
and fully trainable, with sinusoidal encoder positions and learned decoder
positions like Whisper (arXiv:2212.04356).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    dense_apply,
    dense_init,
    embedding_apply,
    embedding_init,
    layernorm_apply,
    layernorm_init,
    mlp_apply,
    mlp_init,
)
from repro.models.transformer import ModelOutput, scan_layers


def _enc_layer_init(key, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 2)
    return {
        "norm1": layernorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.head_dim, dtype=dtype),
        "norm2": layernorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def _dec_layer_init(key, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "norm1": layernorm_init(cfg.d_model, dtype),
        "self_attn": attn.attn_init(ks[0], cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.head_dim,
                                    dtype=dtype),
        "norm2": layernorm_init(cfg.d_model, dtype),
        "cross_attn": attn.attn_init(ks[1], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim,
                                     dtype=dtype),
        "norm3": layernorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    k_emb, k_enc, k_dec, k_val = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    p: Dict[str, Any] = {
        "embed": embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "enc_layers": jax.vmap(
            lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
        "dec_layers": jax.vmap(
            lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
        "enc_final_norm": layernorm_init(cfg.d_model, dtype),
        "dec_final_norm": layernorm_init(cfg.d_model, dtype),
    }
    if cfg.value_head:
        p["value_head"] = dense_init(k_val, cfg.d_model, 1, dtype, bias=True)
    return p


def _sinusoids(length: int, channels: int) -> jax.Array:
    log_timescale = jnp.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    scaled = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


def encode(params: Dict, cfg: ModelConfig,
           frames: jax.Array, unroll_layers: bool = False,
           remat: bool = False) -> jax.Array:
    """Encoder over stubbed frame embeddings [B, S_enc, D]."""
    b, se, d = frames.shape
    x = frames + _sinusoids(se, d).astype(frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32), (b, se))

    def body(x, lp):
        h = layernorm_apply(lp["norm1"], x)
        x = x + _bidir_attn(lp["attn"], h, cfg)
        h = layernorm_apply(lp["norm2"], x)
        x = x + mlp_apply(lp["mlp"], h, "gelu")
        return x, None

    x, _ = scan_layers(body, x, params["enc_layers"], unroll_layers,
                       remat)
    return layernorm_apply(params["enc_final_norm"], x)


def _bidir_attn(p: Dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Bidirectional self-attention (encoder): cross-attn of x onto x."""
    return attn.cross_attn_forward(
        p, x, x, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
    )


def forward(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,          # [B, S_dec]
    *,
    frames: jax.Array,          # [B, S_enc, D] stubbed audio features
    encoder_out: Optional[jax.Array] = None,  # reuse cached encoding
    kv_valid: Optional[jax.Array] = None,
    return_cache: bool = False,
    cache_len: Optional[int] = None,
    unroll_layers: bool = False,
    remat: bool = False,
) -> ModelOutput:
    enc = encoder_out if encoder_out is not None else encode(
        params, cfg, frames, unroll_layers, remat)
    b, s = tokens.shape
    x = embedding_apply(params["embed"], tokens)
    enc = enc.astype(x.dtype)  # keep the decoder residual carry uniform
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, lp):
        h = layernorm_apply(lp["norm1"], x)
        out, (k, v) = attn.attn_forward(
            lp["self_attn"], h, positions,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=jnp.inf, kv_valid=kv_valid,
        )
        x = x + out
        h = layernorm_apply(lp["norm2"], x)
        x = x + attn.cross_attn_forward(
            lp["cross_attn"], h, enc,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
        )
        h = layernorm_apply(lp["norm3"], x)
        x = x + mlp_apply(lp["mlp"], h, "gelu")
        ys = {}
        if return_cache:
            pad = cache_len if cache_len is not None else s
            kc = jnp.zeros((b, pad) + k.shape[2:], k.dtype)
            vc = jnp.zeros((b, pad) + v.shape[2:], v.dtype)
            ys = {
                "k": jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0)),
            }
        return x, ys

    x, cache_ys = scan_layers(body, x, params["dec_layers"],
                              unroll_layers, remat)
    x = layernorm_apply(params["dec_final_norm"], x)
    logits = x @ params["embed"]["table"].astype(x.dtype).T  # tied
    value = None
    if cfg.value_head:
        value = dense_apply(params["value_head"], x)[..., 0]
    cache = None
    if return_cache:
        cache = dict(cache_ys)
        cache["pos"] = jnp.full((b,), s, jnp.int32)
        cache["enc"] = enc
    return ModelOutput(logits=logits, value=value, cache=cache,
                       aux_loss=jnp.zeros((), jnp.float32))


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               encoder_out: jax.Array, dtype=jnp.float32) -> Dict:
    L = cfg.n_layers
    return {
        "pos": jnp.zeros((batch,), jnp.int32),
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                       dtype),
        "enc": encoder_out,
    }


def decode_step(
    params: Dict,
    cfg: ModelConfig,
    token: jax.Array,     # [B]
    cache: Dict,
    unroll_layers: bool = False,
) -> Tuple[ModelOutput, Dict]:
    x = embedding_apply(params["embed"], token[:, None])
    pos = cache["pos"]
    enc = cache["enc"].astype(x.dtype)

    def body(x, xs):
        lp, ck, cv = xs
        h = layernorm_apply(lp["norm1"], x)
        out, (ck, cv) = attn.attn_decode(
            lp["self_attn"], h, pos, ck, cv,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=jnp.inf,
        )
        x = x + out
        h = layernorm_apply(lp["norm2"], x)
        x = x + attn.cross_attn_forward(
            lp["cross_attn"], h, enc,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
        )
        h = layernorm_apply(lp["norm3"], x)
        x = x + mlp_apply(lp["mlp"], h, "gelu")
        return x, {"k": ck, "v": cv}

    x, new = scan_layers(body, x, (params["dec_layers"], cache["k"],
                               cache["v"]), unroll_layers)
    x = layernorm_apply(params["dec_final_norm"], x)
    logits = x @ params["embed"]["table"].astype(x.dtype).T
    value = None
    if cfg.value_head:
        value = dense_apply(params["value_head"], x)[..., 0]
    out = ModelOutput(
        logits=logits[:, 0], value=None if value is None else value[:, 0],
        cache=None, aux_loss=jnp.zeros((), jnp.float32),
    )
    new_cache = dict(new, pos=pos + 1, enc=enc)
    return out, new_cache
