from repro.models.registry import ModelBundle, build
from repro.models.transformer import (
    ModelOutput,
    init_params,
    forward,
    decode_step,
    init_cache,
)
from repro.models.mlp_policy import (
    mlp_policy_init,
    policy_dist,
    value_fn,
    act,
)

__all__ = [
    "ModelBundle",
    "build",
    "ModelOutput",
    "init_params",
    "forward",
    "decode_step",
    "init_cache",
    "mlp_policy_init",
    "policy_dist",
    "value_fn",
    "act",
]
