"""Grouped-query attention with RoPE, sliding windows, prefix-LM masks and
KV-cache decode — the reference (pure-jnp/XLA) path.

The Pallas flash kernel in ``repro.kernels`` implements the same math for
TPU; ``impl="pallas_interpret"`` routes through it in interpreter mode for
CPU validation.  Sliding windows are expressed as a *traced* per-layer
scalar (``jnp.inf`` = global), so a scan over heterogeneous layers (e.g.
gemma3's 5 local : 1 global) stays a single fused HLO loop.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_apply, dense_init

NEG_INF = -1e30

# KV-cache row-insert strategy: "onehot" (baseline) | "scatter" (optimized;
# EXPERIMENTS.md §Perf hillclimb #3).  Env-switchable so the dry-run can
# A/B the two lowerings.
import os as _os

CACHE_UPDATE_MODE = _os.environ.get("REPRO_CACHE_UPDATE", "onehot")


def attn_init(
    key,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    qkv_bias: bool = False,
    dtype=jnp.float32,
) -> Dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype,
                         bias=qkv_bias),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype,
                         bias=qkv_bias),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype,
                         bias=qkv_bias),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }


def _split_heads(x: jax.Array, n: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def make_attention_mask(
    q_positions: jax.Array,   # [B, Sq]
    kv_positions: jax.Array,  # [B, Sk]
    *,
    window,                   # scalar (may be traced); jnp.inf = global
    kv_valid: Optional[jax.Array] = None,  # [B, Sk] bool
    prefix_len: int = 0,      # prefix-LM: keys with pos < prefix_len visible
    causal: bool = True,
) -> jax.Array:
    """Boolean [B, 1, Sq, Sk] mask (True = attend)."""
    q = q_positions[:, :, None].astype(jnp.int32)
    k = kv_positions[:, None, :].astype(jnp.int32)
    if causal:
        mask = q >= k
    else:
        mask = jnp.ones_like(q >= k)
    mask = jnp.logical_and(mask, (q - k).astype(jnp.float32) < window)
    if prefix_len > 0:
        mask = jnp.logical_or(mask, k < prefix_len)
    if kv_valid is not None:
        mask = jnp.logical_and(mask, kv_valid[:, None, :])
    return mask[:, None, :, :]


def _sdpa(
    q: jax.Array,  # [B, Sq, H, Dh]
    k: jax.Array,  # [B, Sk, KV, Dh]
    v: jax.Array,  # [B, Sk, KV, Dh]
    mask: jax.Array,  # [B, 1, Sq, Sk]
) -> jax.Array:
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    g = h // kv
    scale = dh ** -0.5
    qg = q.reshape(b, sq, kv, g, dh)
    # [B, KV, G, Sq, Sk]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg * scale, k,
                        preferred_element_type=jnp.float32)
    scores = jnp.where(mask[:, :, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h * dh)


# Above this query length the XLA path switches to the q-chunked
# memory-efficient attention (Rabe & Staats-style): full [Sq, Sk] score
# materialization at 32k+ would dominate the memory roofline.  The Pallas
# flash kernel replaces both paths on real TPU.
CHUNKED_ATTN_THRESHOLD = 2048
CHUNK_Q = 512


def _sdpa_chunked(
    q: jax.Array,            # [B, Sq, H, Dh]
    k: jax.Array,            # [B, Sk, KV, Dh]
    v: jax.Array,            # [B, Sk, KV, Dh]
    q_positions: jax.Array,  # [B, Sq]
    kv_positions: jax.Array,  # [B, Sk]
    *,
    window,
    kv_valid,
    prefix_len: int,
    causal: bool = True,
) -> jax.Array:
    """Query-chunked attention: peak score memory O(CHUNK_Q * Sk).

    Chunks are checkpointed so the backward pass recomputes scores per
    chunk instead of storing them (the standard memory-efficient
    attention trade: ~1 extra forward of compute for O(S^2) -> O(S)
    activation memory).
    """
    b, sq, h, dh = q.shape
    pad = (-sq) % CHUNK_Q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pad)))
    nq = q.shape[1] // CHUNK_Q
    # [nq, B, C, H, Dh] for lax.map over chunks.
    qc = q.reshape(b, nq, CHUNK_Q, h, dh).transpose(1, 0, 2, 3, 4)
    pc = q_positions.reshape(b, nq, CHUNK_Q).transpose(1, 0, 2)

    @jax.checkpoint
    def one_chunk(args):
        q_i, p_i = args  # [B, C, H, Dh], [B, C]
        mask = make_attention_mask(
            p_i, kv_positions, window=window, kv_valid=kv_valid,
            prefix_len=prefix_len, causal=causal,
        )
        return _sdpa(q_i, k, v, mask)  # [B, C, H*Dh]

    out = jax.lax.map(one_chunk, (qc, pc))       # [nq, B, C, H*Dh]
    out = out.transpose(1, 0, 2, 3).reshape(b, nq * CHUNK_Q, h * dh)
    return out[:, :sq]


def attn_forward(
    p: Dict,
    x: jax.Array,              # [B, S, D]
    positions: jax.Array,      # [B, S]
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window,                    # scalar, jnp.inf for global
    kv_valid: Optional[jax.Array] = None,
    prefix_len: int = 0,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention (train / prefill).

    Returns (out [B,S,D], (k, v) [B,S,KV,Dh] post-RoPE for cache writes).
    """
    q = _split_heads(dense_apply(p["wq"], x), n_heads)
    k = _split_heads(dense_apply(p["wk"], x), n_kv_heads)
    v = _split_heads(dense_apply(p["wv"], x), n_kv_heads)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    if x.shape[1] > CHUNKED_ATTN_THRESHOLD:
        out = _sdpa_chunked(
            q, k, v, positions, positions, window=window,
            kv_valid=kv_valid, prefix_len=prefix_len,
        )
    else:
        mask = make_attention_mask(
            positions, positions, window=window, kv_valid=kv_valid,
            prefix_len=prefix_len,
        )
        out = _sdpa(q, k, v, mask)
    return dense_apply(p["wo"], out), (k, v)


def attn_decode(
    p: Dict,
    x: jax.Array,              # [B, 1, D]
    position: jax.Array,       # [B] current absolute position
    cache_k: jax.Array,        # [B, Smax, KV, Dh]
    cache_v: jax.Array,        # [B, Smax, KV, Dh]
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window,
    prefix_len: int = 0,
    window_slice: Optional[int] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Single-token decode against a (possibly seq-sharded) KV cache.

    The caller owns the cache write; we return the new (k, v) row.  The
    validity mask is positional: slots with index <= position are valid
    (the cache is written densely in position order).

    ``window_slice`` (static, §Perf hillclimb): for sliding-window layers
    with a STATIC window (unrolled decode), attention reads only a
    window-sized dynamic slice of the cache instead of all ``Smax`` rows —
    the cache-read bytes drop by window/Smax (e.g. 32x for gemma3 local
    layers at decode_32k).  Assumes the batch decodes in lockstep
    (uniform ``position``), which holds for the serve engine.
    """
    b, smax = cache_k.shape[0], cache_k.shape[1]
    q = _split_heads(dense_apply(p["wq"], x), n_heads)
    k_new = _split_heads(dense_apply(p["wk"], x), n_kv_heads)
    v_new = _split_heads(dense_apply(p["wv"], x), n_kv_heads)
    q = apply_rope(q, position[:, None], rope_theta)
    k_new = apply_rope(k_new, position[:, None], rope_theta)

    # Insert the new row.  Two strategies (a §Perf knob, see
    # EXPERIMENTS.md hillclimb #3):
    #   onehot  — blend via a one-hot mask: reads AND rewrites the whole
    #             cache every step (3x cache traffic) but places no
    #             constraint on sharding.  The paper-faithful baseline
    #             shipped with this.
    #   scatter — jnp .at[].set row scatter: writes one row per stream;
    #             cache traffic drops to ~1 read of k+v.  Lowers cleanly
    #             under GSPMD for batch- and seq-sharded caches.
    if CACHE_UPDATE_MODE == "scatter":
        b_idx = jnp.arange(b)
        cache_k = cache_k.at[b_idx, position].set(k_new[:, 0])
        cache_v = cache_v.at[b_idx, position].set(v_new[:, 0])
    else:
        oh = jax.nn.one_hot(position, smax, dtype=cache_k.dtype)
        oh = oh[:, :, None, None]
        cache_k = cache_k * (1.0 - oh) + oh * k_new
        cache_v = cache_v * (1.0 - oh) + oh * v_new

    if window_slice is not None and window_slice < smax:
        start = jnp.clip(
            position[0].astype(jnp.int32) - window_slice + 1,
            0, smax - window_slice,
        )
        k_read = jax.lax.dynamic_slice_in_dim(
            cache_k, start, window_slice, axis=1)
        v_read = jax.lax.dynamic_slice_in_dim(
            cache_v, start, window_slice, axis=1)
        kv_pos = start + jnp.arange(window_slice, dtype=jnp.int32)
        kv_pos = jnp.broadcast_to(kv_pos, (b, window_slice))
    else:
        k_read, v_read = cache_k, cache_v
        kv_pos = jnp.broadcast_to(
            jnp.arange(smax, dtype=jnp.int32), (b, smax))
    mask = make_attention_mask(
        position[:, None], kv_pos, window=window,
        kv_valid=kv_pos <= position[:, None], prefix_len=prefix_len,
    )
    out = _sdpa(q, k_read, v_read, mask)
    return dense_apply(p["wo"], out), (cache_k, cache_v)


def cross_attn_forward(
    p: Dict,
    x: jax.Array,            # [B, Sq, D] decoder states
    enc: jax.Array,          # [B, Se, D] encoder outputs
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
) -> jax.Array:
    """Encoder-decoder cross attention (whisper). No RoPE, no mask."""
    b, se, _ = enc.shape
    q = _split_heads(dense_apply(p["wq"], x), n_heads)
    k = _split_heads(dense_apply(p["wk"], enc), n_kv_heads)
    v = _split_heads(dense_apply(p["wv"], enc), n_kv_heads)
    mask = jnp.ones((b, 1, x.shape[1], se), bool)
    out = _sdpa(q, k, v, mask)
    return dense_apply(p["wo"], out)
