"""Gaussian MLP actor-critic for the classic-RL (§5.1) experiments.

CleanRL's PPO architecture: two separate 2x64-tanh MLPs (actor mean +
critic), state-independent log-std.  Orthogonal-ish init via scaled
truncated normals (the exact CleanRL orthogonal init is immaterial to the
lag study; scale factors match: 0.01 on the policy head, 1.0 on value).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.distributions import DiagGaussian
from repro.models.layers import dense_apply, dense_init


def mlp_policy_init(key, obs_dim: int, act_dim: int,
                    hidden: int = 64) -> Dict:
    ks = jax.random.split(key, 7)
    return {
        "actor": {
            "l1": dense_init(ks[0], obs_dim, hidden, bias=True),
            "l2": dense_init(ks[1], hidden, hidden, bias=True),
            "head": dense_init(ks[2], hidden, act_dim, bias=True,
                               scale=0.01),
        },
        "log_std": jnp.zeros((act_dim,), jnp.float32),
        "critic": {
            "l1": dense_init(ks[3], obs_dim, hidden, bias=True),
            "l2": dense_init(ks[4], hidden, hidden, bias=True),
            "head": dense_init(ks[5], hidden, 1, bias=True),
        },
    }


def _mlp(p: Dict, x: jax.Array) -> jax.Array:
    x = jnp.tanh(dense_apply(p["l1"], x))
    x = jnp.tanh(dense_apply(p["l2"], x))
    return dense_apply(p["head"], x)


def policy_dist(params: Dict, obs: jax.Array) -> DiagGaussian:
    mean = _mlp(params["actor"], obs)
    log_std = jnp.broadcast_to(params["log_std"], mean.shape)
    return DiagGaussian(mean=mean, log_std=log_std)


def value_fn(params: Dict, obs: jax.Array) -> jax.Array:
    return _mlp(params["critic"], obs)[..., 0]


def act(params: Dict, obs: jax.Array, key: jax.Array
        ) -> Tuple[jax.Array, jax.Array]:
    """Sample an action and its log-prob."""
    dist = policy_dist(params, obs)
    a = dist.sample(key)
    return a, dist.log_prob(a)
