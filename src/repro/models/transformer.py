"""Decoder-only policy backbone: dense / MoE / hybrid / VLM / attn-free.

One assembly covers eight of the ten assigned architectures (whisper's
encoder-decoder lives in ``repro.models.encdec``; the Gaussian MLP policy
for classic RL in ``repro.models.mlp_policy``).

Design points:

* **scan over layers** — layer parameters are stacked with a leading
  ``[L]`` axis and the block is a single ``jax.lax.scan`` body, keeping
  HLO size O(1) in depth (48-61-layer archs compile quickly and the
  dry-run stays tractable).
* **heterogeneous layers without unrolling** — per-layer differences
  (gemma3's 5 local : 1 global window pattern, hymba's 3 global layers)
  are expressed as a traced ``[L]`` window array (jnp.inf = global), so
  the mask math is data-dependent and the scan body stays uniform.
* **KV cache as scan ys/xs** — caches are ``[L, ...]`` stacked pytrees
  threaded through the same scan.
* **value head** — per-token critic for VACO/PPO RLVR (Alg. 1's V_phi).

The forward returns per-token logits; per-token log-probs for the RL
losses are computed by ``repro.kernels.ops.logprobs_from_logits`` (fused
Pallas path or jnp reference).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_rope,
    dense_apply,
    dense_init,
    embedding_apply,
    embedding_attend,
    embedding_init,
    mlp_apply,
    mlp_init,
    rmsnorm_apply,
    rmsnorm_init,
    softcap,
)


class ModelOutput(NamedTuple):
    logits: jax.Array            # [B, S, V]
    value: Optional[jax.Array]   # [B, S] or None
    cache: Any                   # updated cache pytree (or None)
    aux_loss: jax.Array          # router load-balance etc.


def scan_layers(body, carry, xs, unroll: bool = False,
                remat: bool = False):
    """jax.lax.scan over stacked layers, or a Python unroll.

    ``remat=True`` wraps the body in jax.checkpoint (per-layer activation
    rematerialization) — the standard training memory policy: backward
    recomputes each layer instead of storing its internals, bounding
    activation memory to the inter-layer residual stream.

    The unrolled form exists for the dry-run's cost extrapolation: XLA's
    cost_analysis counts a while-loop body once regardless of trip count,
    so exact per-layer FLOP/byte/collective numbers come from compiling
    shallow *unrolled* variants (launch/dryrun.py).
    """
    if remat:
        body = jax.checkpoint(body)
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys_all = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys_all.append(y)
    if not ys_all or not jax.tree.leaves(ys_all[0]):
        return carry, ys_all[0] if ys_all else None
    ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys_all)
    return carry, ys


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.attn_free:
        p["rwkv"] = rwkv_mod.rwkv6_init(ks[0], cfg.d_model, cfg.d_ff, dtype)
        return p
    p["attn"] = attn.attn_init(
        ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
        qkv_bias=cfg.qkv_bias, dtype=dtype,
    )
    if cfg.hybrid_attn_ssm:
        p["ssm"] = ssm_mod.ssm_init(ks[1], cfg.d_model, cfg.ssm, dtype)
    if cfg.moe is not None:
        p["moe"] = moe_mod.moe_init(
            ks[2], cfg.d_model, cfg.moe, cfg.activation, dtype
        )
    else:
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.activation,
                            dtype)
    return p


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    k_emb, k_layers, k_head, k_val, k_vis = jax.random.split(key, 5)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(layer_keys)
    p: Dict[str, Any] = {
        "embed": embedding_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "layers": layers,
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    if cfg.value_head:
        p["value_head"] = dense_init(k_val, cfg.d_model, 1, dtype, bias=True)
    if cfg.vision_prefix_len > 0:
        # Projector from the (stubbed) vision tower embedding dim.
        p["vision_proj"] = dense_init(k_vis, vision_stub_dim(cfg),
                                      cfg.d_model, dtype)
    return p


def vision_stub_dim(cfg: ModelConfig) -> int:
    """Embedding dim of the stubbed modality frontend (SigLIP-so400m)."""
    return 1152


def layer_windows(cfg: ModelConfig, decode_cache_len: Optional[int] = None
                  ) -> jax.Array:
    """[L] float32 window sizes; jnp.inf marks global layers."""
    ws = []
    for l in range(cfg.n_layers):
        w = cfg.window_for_layer(l)
        ws.append(jnp.inf if w is None else float(w))
    return jnp.asarray(ws, jnp.float32)


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> Dict:
    """Allocate the decode cache for `batch` streams of up to `max_len`."""
    c: Dict[str, Any] = {"pos": jnp.zeros((batch,), jnp.int32)}
    L = cfg.n_layers
    if cfg.attn_free:
        h = cfg.d_model // rwkv_mod.HEAD_DIM
        c["wkv"] = jnp.zeros((L, batch, h, rwkv_mod.HEAD_DIM,
                              rwkv_mod.HEAD_DIM), jnp.float32)
        c["shift_tm"] = jnp.zeros((L, batch, 1, cfg.d_model), dtype)
        c["shift_cm"] = jnp.zeros((L, batch, 1, cfg.d_model), dtype)
        return c
    c["k"] = jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                       dtype)
    c["v"] = jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
                       dtype)
    if cfg.hybrid_attn_ssm:
        inner = cfg.ssm.expand * cfg.d_model
        c["ssm"] = jnp.zeros((L, batch, inner, cfg.ssm.state_dim),
                             jnp.float32)
        c["conv"] = jnp.zeros((L, batch, cfg.ssm.conv_width - 1, inner),
                              dtype)
    return c


def paged_arch_unsupported(cfg: ModelConfig) -> Optional[str]:
    """Why this config cannot run the paged decode path (None = it can).

    The paged KV pool covers the standard attention archs — including
    gemma3-style per-layer sliding windows, which the paged kernels
    mask natively (the hoisted layer loop passes each layer's static
    window).  Recurrent state (rwkv/ssm) has no per-position rows to
    page; prefix-LM/VLM prefixes are still serve/ follow-ons.
    """
    if cfg.attn_free:
        return "attn-free (rwkv) archs keep recurrent state, not KV rows"
    if cfg.hybrid_attn_ssm:
        return "hybrid attn+ssm archs carry unpaged ssm/conv state"
    if cfg.encoder_layers > 0:
        return "encoder-decoder cross-attention cache is not paged"
    if cfg.vision_prefix_len > 0:
        return "vision prefix rows are not paged"
    return None


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.float32) -> Dict:
    """Allocate the pooled block KV cache shared by all requests.

    Layout ``[L, KV, NB, BS, Dh]`` (kv-head major within a layer) so the
    paged-attention kernel streams one ``[BS, Dh]`` tile per page visit.
    Ownership of pages lives host-side in ``repro.serve.paged_cache``.
    """
    reason = paged_arch_unsupported(cfg)
    if reason is not None:
        raise ValueError(f"{cfg.name}: paged decode unsupported: {reason}")
    shape = (cfg.n_layers, cfg.n_kv_heads, num_blocks, block_size,
             cfg.head_dim)
    return {"k_pages": jnp.zeros(shape, dtype),
            "v_pages": jnp.zeros(shape, dtype)}


def _paged_layer_tail(cfg: ModelConfig, lp: Dict, x: jax.Array,
                      attn_out: jax.Array) -> jax.Array:
    """Shared post-attention half of a paged decode layer ([B, S, ...])."""
    b = x.shape[0]
    attn_out = attn_out.reshape(b, -1, cfg.n_heads * cfg.head_dim)
    x = x + dense_apply(lp["attn"]["wo"], attn_out)
    h = rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        mlp_out, _ = moe_mod.moe_apply(
            lp["moe"], h, cfg.moe, cfg.activation, group_size=h.shape[0],
        )
    else:
        mlp_out = mlp_apply(lp["mlp"], h, cfg.activation)
    return x + mlp_out


def _paged_qkv(cfg: ModelConfig, lp: Dict, x: jax.Array,
               positions: jax.Array) -> Tuple[jax.Array, jax.Array,
                                              jax.Array]:
    """Projections + rope for one paged decode layer ([B, S, ...]);
    ``positions`` is [B, S] absolute rope positions."""
    h = rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
    q = attn._split_heads(dense_apply(lp["attn"]["wq"], h), cfg.n_heads)
    k_new = attn._split_heads(
        dense_apply(lp["attn"]["wk"], h), cfg.n_kv_heads)
    v_new = attn._split_heads(
        dense_apply(lp["attn"]["wv"], h), cfg.n_kv_heads)
    q = apply_rope(q, positions, cfg.rope_theta)
    k_new = apply_rope(k_new, positions, cfg.rope_theta)
    return q, k_new, v_new


def _paged_head_full(params: Dict, cfg: ModelConfig, x: jax.Array
                     ) -> ModelOutput:
    """Final norm + readout over every query position ([B, S, V])."""
    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = embedding_attend(params["embed"], x)
    else:
        logits = dense_apply(params["lm_head"], x)
    logits = softcap(logits, cfg.logit_softcap)
    value = None
    if cfg.value_head:
        value = dense_apply(params["value_head"], x)[..., 0]
    return ModelOutput(
        logits=logits, value=value,
        cache=None, aux_loss=jnp.zeros((), jnp.float32),
    )


def _paged_head(params: Dict, cfg: ModelConfig, x: jax.Array
                ) -> ModelOutput:
    out = _paged_head_full(params, cfg, x)
    return out._replace(
        logits=out.logits[:, 0],
        value=None if out.value is None else out.value[:, 0],
    )


def decode_step_paged(
    params: Dict,
    cfg: ModelConfig,
    token: jax.Array,         # [B] current token ids (one per slot)
    pages: Dict,              # {"k_pages","v_pages"} [L, KV, NB, BS, Dh]
    block_tables: jax.Array,  # [B, M] int32 page ids (pads in-range)
    pos: jax.Array,           # [B] int32 tokens already cached per slot
    active: jax.Array,        # [B] bool; inactive slots write/read nothing
    *,
    kernel_mode: Optional[str] = None,
    mesh=None,
    slot_shard: Optional[jax.Array] = None,  # [B] int32 home shard per slot
) -> Tuple[ModelOutput, Dict]:
    """One decode step for a batch of *independent ragged* requests.

    Unlike :func:`decode_step`, slots need not be in lockstep: each slot
    writes its new K/V row at its own ``pos`` through its own block
    table, and attends over exactly its ``pos + 1`` live positions.  The
    incoming token's row is written first (so it attends to itself),
    matching the dense path's validity rule ``kv_pos <= position``.

    The layer loop is *hoisted* (a Python unroll, HLO O(L)) rather than
    a ``lax.scan`` so the pool never rides a scan as a carried value:
    each layer's row append is an in-place-able op
    (``kernels.ops.paged_kv_write`` — aliased Pallas DMA scatter, or its
    dynamic-update-slice oracle), which keeps per-step cost O(rows
    written), independent of ``num_blocks``.  The scan-carried
    formulation made XLA rewrite the whole ``[L, KV, NB, BS, Dh]`` pool
    every step (~2.7x slower at 128 vs 16 blocks at equal work); it is
    kept as :func:`decode_step_paged_carried` as the equivalence oracle
    for this path.  Serve archs run reduced depths, so the O(L) HLO is
    cheap; the O(1)-HLO training forward is untouched.

    The hoisted loop also gives each layer its *static* sliding window
    (``cfg.window_for_layer``), so gemma3-style local:global patterns
    run the paged path natively — the kernels mask reads outside the
    window; rows behind it are never read, which is what lets the
    scheduler's window reclamation (all-windowed archs) release whole
    pages behind the widest window mid-flight.

    With ``mesh``/``slot_shard`` the pool is NB-sharded over the mesh's
    ``data`` axis and block tables carry shard-local page ids; the
    kernels dispatch through ``shard_map`` (see ``kernels.ops``) and
    this function's math is bit-identical to the single-device case.
    """
    from repro.kernels import ops as kops

    block_size = pages["k_pages"].shape[3]
    x = embedding_apply(params["embed"], token[:, None])
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    safe_pos = jnp.maximum(pos, 0)
    page_idx = jnp.take_along_axis(
        block_tables, (safe_pos // block_size)[:, None], axis=1)[:, 0]
    offset = safe_pos % block_size
    context_lens = jnp.where(active, safe_pos + 1, 0).astype(jnp.int32)

    k_pages, v_pages = pages["k_pages"], pages["v_pages"]
    for layer in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[layer], params["layers"])
        q, k_new, v_new = _paged_qkv(cfg, lp, x, safe_pos[:, None])
        k_pages, v_pages = kops.paged_kv_write(
            k_pages, v_pages, k_new[:, 0], v_new[:, 0],
            page_idx, offset, active, layer=layer, mode=kernel_mode,
            mesh=mesh, slot_shard=slot_shard,
        )
        attn_out = kops.paged_attention(
            q[:, 0], k_pages[layer], v_pages[layer], block_tables,
            context_lens, window=cfg.window_for_layer(layer),
            mode=kernel_mode, mesh=mesh, slot_shard=slot_shard,
        )
        x = _paged_layer_tail(cfg, lp, x, attn_out)

    out = _paged_head(params, cfg, x)
    return out, {"k_pages": k_pages, "v_pages": v_pages}


def decode_step_paged_multi(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,        # [B, T] consecutive tokens per slot
    pages: Dict,              # {"k_pages","v_pages"} [L, KV, NB, BS, Dh]
    block_tables: jax.Array,  # [B, M] int32 page ids (pads in-range)
    pos: jax.Array,           # [B] int32 tokens already cached per slot
    active: jax.Array,        # [B] bool; inactive slots write/read nothing
    write_cap: jax.Array,     # [B] int32 rows this slot owns pages for
    *,
    kernel_mode: Optional[str] = None,
    mesh=None,
    slot_shard: Optional[jax.Array] = None,  # [B] int32 home shard per slot
) -> Tuple[ModelOutput, Dict]:
    """Score ``T`` consecutive tokens per slot in one dispatch (the
    speculative-decode verifier).

    Token ``t`` of slot ``b`` sits at absolute position ``pos[b] + t``:
    its K/V row is written first (at that position, through the slot's
    block table) and it attends causally over its own prefix — exactly
    ``T`` sequential :func:`decode_step_paged` calls fused into one
    launch, with the attention read done by the multi-query paged
    kernel (``kernels.ops.paged_attention_multi``) instead of ``T``
    single-query ones.  ``T = 1`` is the plain decode step.

    ``write_cap[b]`` bounds the rows slot ``b`` may write (its allocated
    pages): positions ``>= write_cap`` *drop* their K/V write instead of
    landing in the table's in-range pad pages (page 0 belongs to someone
    else).  Logits at such positions are garbage — callers never emit
    from them (the scheduler allocates pages for every row that can
    influence an emitted token; only past-end-of-budget draft positions
    are ever uncovered).

    Rollback after partial acceptance is *pure position arithmetic*: the
    caller rewinds ``pos`` to the accepted prefix and the rejected rows
    are simply overwritten by the next chunk — no page copies, no
    retraction of emitted tokens, preemption-safe (a preempted request
    re-prefills prompt + emitted tokens exactly as before).
    """
    from repro.kernels import ops as kops

    b, t = tokens.shape
    block_size = pages["k_pages"].shape[3]
    x = embedding_apply(params["embed"], tokens)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    safe_pos = jnp.maximum(pos, 0)
    positions = safe_pos[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    page_idx = jnp.take_along_axis(
        block_tables, positions // block_size, axis=1)       # [B, T]
    offset = positions % block_size
    write_ok = jnp.logical_and(
        active[:, None], positions < write_cap[:, None])     # [B, T]
    context_lens = jnp.where(active, safe_pos + t, 0).astype(jnp.int32)

    k_pages, v_pages = pages["k_pages"], pages["v_pages"]
    for layer in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[layer], params["layers"])
        q, k_new, v_new = _paged_qkv(cfg, lp, x, positions)
        for step in range(t):
            k_pages, v_pages = kops.paged_kv_write(
                k_pages, v_pages, k_new[:, step], v_new[:, step],
                page_idx[:, step], offset[:, step], write_ok[:, step],
                layer=layer, mode=kernel_mode,
                mesh=mesh, slot_shard=slot_shard,
            )
        attn_out = kops.paged_attention_multi(
            q, k_pages[layer], v_pages[layer], block_tables,
            context_lens, window=cfg.window_for_layer(layer),
            mode=kernel_mode, mesh=mesh, slot_shard=slot_shard,
        )
        x = _paged_layer_tail(cfg, lp, x, attn_out)

    out = _paged_head_full(params, cfg, x)
    return out, {"k_pages": k_pages, "v_pages": v_pages}


def decode_step_paged_varlen(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,        # [B, T] ragged token chunks, right-padded
    pages: Dict,              # {"k_pages","v_pages"} [L, KV, NB, BS, Dh]
    block_tables: jax.Array,  # [B, M] int32 page ids (pads in-range)
    row_start: jax.Array,     # [B] int32 rows already cached per slot
    row_len: jax.Array,       # [B] int32 live tokens per slot (0 = idle)
    write_cap: jax.Array,     # [B] int32 rows this slot owns pages for
    *,
    kernel_mode: Optional[str] = None,
    mesh=None,
    slot_shard: Optional[jax.Array] = None,  # [B] int32 home shard per slot
) -> Tuple[ModelOutput, Dict]:
    """Score a *ragged* chunk of consecutive tokens per slot in one
    dispatch — the varlen generalization of :func:`decode_step_paged_multi`
    that unifies chunked prefill, decode and speculative verify.

    Token ``t < row_len[b]`` of slot ``b`` sits at absolute position
    ``row_start[b] + t``: its K/V row is written (through the slot's
    block table, dropped past ``write_cap``) and it attends causally
    over its own prefix via the varlen paged kernel.  Padding rows
    (``t >= row_len[b]``) write nothing and their logits are garbage —
    callers only read rows ``< row_len``.  ``row_len == 1`` everywhere
    is the plain decode step; ``row_len == T`` everywhere is the
    verifier; mixed values interleave prefill tiles with decode rows in
    one launch.  Layer-loop hoisting, in-place page writes, per-layer
    windows and mesh semantics are identical to the multi path.
    """
    from repro.kernels import ops as kops

    b, t = tokens.shape
    block_size = pages["k_pages"].shape[3]
    x = embedding_apply(params["embed"], tokens)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    safe_start = jnp.maximum(row_start, 0)
    row_len = row_len.astype(jnp.int32)
    positions = safe_start[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
    page_idx = jnp.take_along_axis(
        block_tables, positions // block_size, axis=1)       # [B, T]
    offset = positions % block_size
    live = jnp.arange(t, dtype=jnp.int32)[None, :] < row_len[:, None]
    write_ok = jnp.logical_and(
        live, positions < write_cap[:, None])                # [B, T]

    k_pages, v_pages = pages["k_pages"], pages["v_pages"]
    for layer in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[layer], params["layers"])
        q, k_new, v_new = _paged_qkv(cfg, lp, x, positions)
        for step in range(t):
            k_pages, v_pages = kops.paged_kv_write(
                k_pages, v_pages, k_new[:, step], v_new[:, step],
                page_idx[:, step], offset[:, step], write_ok[:, step],
                layer=layer, mode=kernel_mode,
                mesh=mesh, slot_shard=slot_shard,
            )
        attn_out = kops.paged_attention_varlen(
            q, k_pages[layer], v_pages[layer], block_tables,
            safe_start, row_len, window=cfg.window_for_layer(layer),
            mode=kernel_mode, mesh=mesh, slot_shard=slot_shard,
        )
        x = _paged_layer_tail(cfg, lp, x, attn_out)

    out = _paged_head_full(params, cfg, x)
    return out, {"k_pages": k_pages, "v_pages": v_pages}


def decode_step_paged_carried(
    params: Dict,
    cfg: ModelConfig,
    token: jax.Array,
    pages: Dict,
    block_tables: jax.Array,
    pos: jax.Array,
    active: jax.Array,
    *,
    kernel_mode: Optional[str] = None,
    mesh=None,
    slot_shard: Optional[jax.Array] = None,
) -> Tuple[ModelOutput, Dict]:
    """Legacy paged decode step: pool carried through the layer scan.

    Semantically identical to :func:`decode_step_paged` — tests assert
    greedy *token* equality bit-for-bit and ulp-level logit/pool
    closeness (scan-fused vs standalone ops round the last bit
    differently) — but O(pool) per step: the pages ride the scan as
    xs/ys, so every step re-materializes the full ``[L, ...]`` pool.
    Kept as the oracle for the aliased path; not used by the engine.
    Uniform-scan body: no per-layer windows (use the hoisted path for
    sliding-window archs) and no mesh dispatch.
    """
    from repro.kernels import ops as kops

    if cfg.sliding_window is not None:
        raise ValueError(
            "decode_step_paged_carried has a uniform scan body and "
            "cannot carry per-layer sliding windows; use "
            "decode_step_paged")
    if mesh is not None and kops.mesh_data_size(mesh) > 1:
        raise ValueError(
            "decode_step_paged_carried is a single-device test oracle; "
            "mesh dispatch lives on decode_step_paged")

    num_blocks = pages["k_pages"].shape[2]
    block_size = pages["k_pages"].shape[3]
    x = embedding_apply(params["embed"], token[:, None])
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    safe_pos = jnp.maximum(pos, 0)
    # Out-of-pool page index + scatter mode="drop" turns inactive slots'
    # writes into no-ops without branching.
    page_idx = jnp.take_along_axis(
        block_tables, (safe_pos // block_size)[:, None], axis=1)[:, 0]
    page_idx = jnp.where(active, page_idx, num_blocks)
    offset = safe_pos % block_size
    context_lens = jnp.where(active, safe_pos + 1, 0).astype(jnp.int32)

    def layer_step(x, xs):
        lp, k_pages, v_pages = xs
        q, k_new, v_new = _paged_qkv(cfg, lp, x, safe_pos[:, None])
        # [B, 1, KV, Dh] -> [KV, B, Dh] rows, scattered per slot.
        k_rows = k_new[:, 0].transpose(1, 0, 2)
        v_rows = v_new[:, 0].transpose(1, 0, 2)
        k_pages = k_pages.at[:, page_idx, offset, :].set(
            k_rows.astype(k_pages.dtype), mode="drop")
        v_pages = v_pages.at[:, page_idx, offset, :].set(
            v_rows.astype(v_pages.dtype), mode="drop")
        attn_out = kops.paged_attention(
            q[:, 0], k_pages, v_pages, block_tables, context_lens,
            mode=kernel_mode,
        )
        x = _paged_layer_tail(cfg, lp, x, attn_out)
        return x, {"k_pages": k_pages, "v_pages": v_pages}

    x, new_pages = scan_layers(
        layer_step, x,
        (params["layers"], pages["k_pages"], pages["v_pages"]),
    )
    out = _paged_head(params, cfg, x)
    return out, new_pages


def write_prefill_to_pages(
    cache_k: jax.Array,       # [L, 1, P, KV, Dh] dense prefill rows
    cache_v: jax.Array,
    pages: Dict,
    blocks: jax.Array,        # [M] int32 page ids owned by this request
    prompt_len: jax.Array,    # scalar int32: rows >= prompt_len are dropped
) -> Dict:
    """Scatter one request's prefill K/V rows into its allocated pages.

    Structured as one ``dynamic_update_slice`` per table slot (a static
    count of page-sized tiles) rather than a row scatter: with the pool
    donated, XLA updates the tiles in place, so a prefill costs O(rows
    written), not O(pool).  Tiles past ``prompt_len`` — and the pad
    slots of ``blocks`` (page 0) — write their *old* contents back
    (read-select-writeback), i.e. drop semantics without touching the
    rest of the pool.
    """
    k_pages, v_pages = pages["k_pages"], pages["v_pages"]
    block_size = k_pages.shape[3]
    p = cache_k.shape[2]
    n_tiles = -(-p // block_size)
    pad = n_tiles * block_size - p
    # [L, 1, P, KV, Dh] -> [L, KV, P(+pad), Dh]
    k_rows = cache_k[:, 0].transpose(0, 2, 1, 3).astype(k_pages.dtype)
    v_rows = cache_v[:, 0].transpose(0, 2, 1, 3).astype(v_pages.dtype)
    if pad:
        k_rows = jnp.pad(k_rows, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_rows = jnp.pad(v_rows, ((0, 0), (0, 0), (0, pad), (0, 0)))
    from repro.kernels.ref import masked_inplace_update

    zero = jnp.zeros((), jnp.int32)
    for j in range(n_tiles):
        rows = j * block_size + jnp.arange(block_size, dtype=jnp.int32)
        valid = (rows < prompt_len)[None, None, None, :, None]
        start = (zero, zero, blocks[j].astype(jnp.int32), zero, zero)
        new_k = k_rows[:, :, None, j * block_size:(j + 1) * block_size, :]
        new_v = v_rows[:, :, None, j * block_size:(j + 1) * block_size, :]
        k_pages = masked_inplace_update(k_pages, new_k, start, valid)
        v_pages = masked_inplace_update(v_pages, new_v, start, valid)
    return {"k_pages": k_pages, "v_pages": v_pages}


def write_prefill_batch_to_pages(
    cache_k: jax.Array,       # [L, N, P, KV, Dh] dense prefill rows
    cache_v: jax.Array,
    pages: Dict,
    blocks: jax.Array,        # [N, M] int32 page ids (shard-local w/ mesh)
    prompt_lens: jax.Array,   # [N] int32 rows to write per request
    home_shard: Optional[jax.Array] = None,   # [N] int32 (mesh only)
    *,
    mesh=None,
    axis_name: str = "data",
) -> Dict:
    """Scatter a *group* of prefilled requests into their pages.

    The single-device path is exactly ``N`` calls to
    :func:`write_prefill_to_pages` (the bit-pinned baseline).  With a
    ``mesh`` the pool is NB-sharded over ``axis_name`` and each request
    writes only on its ``home_shard``: inside ``shard_map`` foreign
    requests get ``prompt_len 0`` (every tile's validity mask is then
    all-False, i.e. read-select-writeback keeps the local pool rows
    untouched), so the per-shard buffers still update in place.
    """
    n = cache_k.shape[1]

    def write_all(kc, vc, pages, blocks, plens):
        for i in range(n):
            pages = write_prefill_to_pages(
                jax.lax.slice_in_dim(kc, i, i + 1, axis=1),
                jax.lax.slice_in_dim(vc, i, i + 1, axis=1),
                pages, blocks[i], plens[i])
        return pages

    from repro.kernels.ops import _sharded

    if not _sharded(mesh, axis_name):
        return write_all(cache_k, cache_v, pages, blocks, prompt_lens)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(kc, vc, k_pages, v_pages, blocks, plens, home):
        idx = jax.lax.axis_index(axis_name)
        local_plens = jnp.where(home == idx, plens, 0).astype(jnp.int32)
        out = write_all(kc, vc, {"k_pages": k_pages, "v_pages": v_pages},
                        blocks, local_plens)
        return out["k_pages"], out["v_pages"]

    pool = P(None, None, axis_name, None, None)
    k_pages, v_pages = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), pool, pool, P(), P(), P()),
        out_specs=(pool, pool), check_rep=False,
    )(cache_k, cache_v, pages["k_pages"], pages["v_pages"],
      blocks, prompt_lens, home_shard.astype(jnp.int32))
    return {"k_pages": k_pages, "v_pages": v_pages}


def copy_page_rows(
    pages: Dict,
    src: jax.Array,           # [N] int32 source page ids (shard-local)
    dst: jax.Array,           # [N] int32 destination page ids
    rows: jax.Array,          # [N] int32 leading rows to copy per pair
    home_shard: Optional[jax.Array] = None,   # [N] int32 (mesh only)
    *,
    mesh=None,
    axis_name: str = "data",
) -> Dict:
    """Copy the leading ``rows[i]`` K/V rows of page ``src[i]`` into page
    ``dst[i]`` across every layer and kv head — the prefix cache's
    copy-on-write step, run before a divergent suffix appends into a
    partially-matched shared page.

    Same in-place discipline as the prefill writers: one
    ``dynamic_slice`` read of the source tile plus one masked
    read-select-writeback ``dynamic_update_slice`` per pair, so with the
    pool donated the copy costs O(rows copied), not O(pool).  Rows past
    ``rows[i]`` keep the destination's old contents.  Under a ``mesh``
    both pages live on the pair's ``home_shard`` (page sharing is
    shard-local); foreign shards mask ``rows`` to 0 and write nothing.
    """
    from repro.kernels.ref import masked_inplace_update

    n = src.shape[0]

    def copy_all(k_pages, v_pages, src, dst, rows):
        bs = k_pages.shape[3]
        zero = jnp.zeros((), jnp.int32)
        sizes = (k_pages.shape[0], k_pages.shape[1], 1, bs,
                 k_pages.shape[4])
        for i in range(n):
            valid = (jnp.arange(bs, dtype=jnp.int32)
                     < rows[i])[None, None, None, :, None]
            at_src = (zero, zero, src[i].astype(jnp.int32), zero, zero)
            at_dst = (zero, zero, dst[i].astype(jnp.int32), zero, zero)
            k_tile = jax.lax.dynamic_slice(k_pages, at_src, sizes)
            v_tile = jax.lax.dynamic_slice(v_pages, at_src, sizes)
            k_pages = masked_inplace_update(k_pages, k_tile, at_dst, valid)
            v_pages = masked_inplace_update(v_pages, v_tile, at_dst, valid)
        return k_pages, v_pages

    from repro.kernels.ops import _sharded

    if not _sharded(mesh, axis_name):
        k_pages, v_pages = copy_all(
            pages["k_pages"], pages["v_pages"], src, dst, rows)
        return {"k_pages": k_pages, "v_pages": v_pages}

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(k_pages, v_pages, src, dst, rows, home):
        idx = jax.lax.axis_index(axis_name)
        local_rows = jnp.where(home == idx, rows, 0).astype(jnp.int32)
        return copy_all(k_pages, v_pages, src, dst, local_rows)

    pool = P(None, None, axis_name, None, None)
    k_pages, v_pages = shard_map(
        body, mesh=mesh,
        in_specs=(pool, pool, P(), P(), P(), P()),
        out_specs=(pool, pool), check_rep=False,
    )(pages["k_pages"], pages["v_pages"], src, dst, rows,
      home_shard.astype(jnp.int32))
    return {"k_pages": k_pages, "v_pages": v_pages}


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _embed_inputs(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,
    prefix_embeds: Optional[jax.Array],
) -> Tuple[jax.Array, int]:
    x = embedding_apply(params["embed"], tokens)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma-style scaling
    prefix_len = 0
    if cfg.vision_prefix_len > 0:
        assert prefix_embeds is not None, (
            f"{cfg.name}: vision/audio prefix embeddings required"
        )
        proj = dense_apply(params["vision_proj"], prefix_embeds)
        x = jnp.concatenate([proj.astype(x.dtype), x], axis=1)
        prefix_len = cfg.vision_prefix_len
    return x, prefix_len


def forward(
    params: Dict,
    cfg: ModelConfig,
    tokens: jax.Array,                       # [B, S]
    *,
    prefix_embeds: Optional[jax.Array] = None,  # [B, P, vision_dim]
    kv_valid: Optional[jax.Array] = None,       # [B, S(+P)] padding mask
    return_cache: bool = False,
    cache_len: Optional[int] = None,            # cache capacity for prefill
    unroll_layers: bool = False,
    remat: bool = False,
) -> ModelOutput:
    x, prefix_len = _embed_inputs(params, cfg, tokens, prefix_embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    windows = layer_windows(cfg)
    prefix = prefix_len if cfg.prefix_lm else 0

    aux0 = jnp.zeros((), jnp.float32)

    def body(carry, xs):
        x, aux = carry
        lp, window = xs
        ys = {}
        if cfg.attn_free:
            h = rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
            out, (wkv_state, shift_tm) = rwkv_mod.rwkv6_time_mix(
                lp["rwkv"], h
            )
            x = x + out
            h = rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
            out, shift_cm = rwkv_mod.rwkv6_channel_mix(lp["rwkv"], h)
            x = x + out
            if return_cache:
                ys = {"wkv": wkv_state, "shift_tm": shift_tm,
                      "shift_cm": shift_cm}
            return (x, aux), ys

        h = rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
        attn_out, (k, v) = attn.attn_forward(
            lp["attn"], h, positions,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
            window=window, kv_valid=kv_valid, prefix_len=prefix,
        )
        if cfg.hybrid_attn_ssm:
            ssm_out, (ssm_state, conv_state) = ssm_mod.ssm_forward(
                lp["ssm"], h, cfg.ssm
            )
            mix = 0.5 * (attn_out + ssm_out)   # hymba: mean-fused heads
            x = x + mix
            if return_cache:
                ys = {"ssm": ssm_state, "conv": conv_state}
        else:
            x = x + attn_out
        if return_cache:
            pad = cache_len if cache_len is not None else s
            kc = jnp.zeros((b, pad) + k.shape[2:], k.dtype)
            vc = jnp.zeros((b, pad) + v.shape[2:], v.dtype)
            kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
            ys = dict(ys, k=kc, v=vc)

        h = rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            mlp_out, moe_aux = moe_mod.moe_apply(
                lp["moe"], h, cfg.moe, cfg.activation,
                group_size=cfg.moe.group_size,
            )
            aux = aux + moe_aux
        else:
            mlp_out = mlp_apply(lp["mlp"], h, cfg.activation)
        x = x + mlp_out
        return (x, aux), ys

    (x, aux), cache_ys = scan_layers(
        body, (x, aux0), (params["layers"], windows), unroll_layers, remat
    )

    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = embedding_attend(params["embed"], x)
    else:
        logits = dense_apply(params["lm_head"], x)
    logits = softcap(logits, cfg.logit_softcap)

    value = None
    if cfg.value_head:
        value = dense_apply(params["value_head"], x)[..., 0]

    cache = None
    if return_cache:
        cache = dict(cache_ys)
        cache["pos"] = jnp.full((b,), s, jnp.int32)
    # Strip the prefix positions from the heads (policy over text tokens).
    if prefix_len > 0:
        logits = logits[:, prefix_len:]
        if value is not None:
            value = value[:, prefix_len:]
    return ModelOutput(logits=logits, value=value, cache=cache, aux_loss=aux)


# ---------------------------------------------------------------------------
# Decode (single-token serve step)
# ---------------------------------------------------------------------------


def decode_step(
    params: Dict,
    cfg: ModelConfig,
    token: jax.Array,       # [B] current token ids
    cache: Dict,
    unroll_layers: bool = False,
) -> Tuple[ModelOutput, Dict]:
    """One autoregressive step against the cache. Returns logits [B, V]."""
    x = embedding_apply(params["embed"], token[:, None])
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    pos = cache["pos"]
    windows = layer_windows(cfg)
    prefix = cfg.vision_prefix_len if cfg.prefix_lm else 0

    if cfg.attn_free:
        def body(x, xs):
            lp, wkv, sh_tm, sh_cm = xs
            h = rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
            out, (wkv, sh_tm) = rwkv_mod.rwkv6_time_mix(
                lp["rwkv"], h, state=(wkv, sh_tm)
            )
            x = x + out
            h = rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
            out, sh_cm = rwkv_mod.rwkv6_channel_mix(lp["rwkv"], h, sh_cm)
            x = x + out
            return x, {"wkv": wkv, "shift_tm": sh_tm, "shift_cm": sh_cm}

        x, new = scan_layers(
            body, x,
            (params["layers"], cache["wkv"], cache["shift_tm"],
             cache["shift_cm"]),
            unroll_layers,
        )
        new_cache = dict(new, pos=pos + 1)
    else:
        def layer_step(x, xs, window_slice=None):
            if cfg.hybrid_attn_ssm:
                lp, window, ck, cv, ssm_state, conv_state = xs
            else:
                lp, window, ck, cv = xs
            ys = {}
            h = rmsnorm_apply(lp["norm1"], x, cfg.norm_eps)
            attn_out, (ck, cv) = attn.attn_decode(
                lp["attn"], h, pos, ck, cv,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                window=window, prefix_len=prefix,
                window_slice=window_slice,
            )
            ys["k"], ys["v"] = ck, cv
            if cfg.hybrid_attn_ssm:
                ssm_out, (ssm_state, conv_state) = ssm_mod.ssm_forward(
                    lp["ssm"], h, cfg.ssm, state=(ssm_state, conv_state)
                )
                ys["ssm"], ys["conv"] = ssm_state, conv_state
                x = x + 0.5 * (attn_out + ssm_out)
            else:
                x = x + attn_out
            h = rmsnorm_apply(lp["norm2"], x, cfg.norm_eps)
            if cfg.moe is not None:
                # Same grouped dispatch as training (group = the decode
                # batch) so expert parallelism lowers to the identical
                # all-to-all pattern in serve_step.
                mlp_out, _ = moe_mod.moe_apply(
                    lp["moe"], h, cfg.moe, cfg.activation,
                    group_size=h.shape[0],
                )
            else:
                mlp_out = mlp_apply(lp["mlp"], h, cfg.activation)
            x = x + mlp_out
            return x, ys

        if cfg.hybrid_attn_ssm:
            xs = (params["layers"], windows, cache["k"], cache["v"],
                  cache["ssm"], cache["conv"])
        else:
            xs = (params["layers"], windows, cache["k"], cache["v"])

        if unroll_layers and cfg.sliding_window is not None:
            # Unrolled decode with STATIC per-layer windows: local layers
            # read only a window-sized dynamic slice of the cache (§Perf
            # hillclimb #3b — cache-read bytes on local layers drop by
            # ~window/Smax, e.g. 32x for gemma3 decode_32k).
            ys_all = []
            for i in range(cfg.n_layers):
                xs_i = jax.tree.map(lambda a: a[i], xs)
                x, ys = layer_step(
                    x, xs_i, window_slice=cfg.window_for_layer(i))
                ys_all.append(ys)
            new = jax.tree.map(lambda *z: jnp.stack(z), *ys_all)
        else:
            x, new = scan_layers(
                lambda c, xs_i: layer_step(c, xs_i), x, xs, unroll_layers)
        new_cache = dict(new, pos=pos + 1)

    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = embedding_attend(params["embed"], x)
    else:
        logits = dense_apply(params["lm_head"], x)
    logits = softcap(logits, cfg.logit_softcap)
    value = None
    if cfg.value_head:
        value = dense_apply(params["value_head"], x)[..., 0]
    out = ModelOutput(
        logits=logits[:, 0], value=None if value is None else value[:, 0],
        cache=None, aux_loss=jnp.zeros((), jnp.float32),
    )
    return out, new_cache
