"""Model registry: one uniform interface over the backbone families.

``build(cfg)`` returns a ``ModelBundle`` of pure functions so trainers,
the serve engine and the dry-run never special-case architecture types:

    bundle.init(key, dtype)                        -> params
    bundle.forward(params, tokens, **aux)          -> ModelOutput
    bundle.decode_step(params, token, cache)       -> (ModelOutput, cache)
    bundle.init_cache(params, batch, max_len, ...) -> cache
    bundle.aux_inputs(batch, dtype)                -> dict of stub-frontend
                                                      inputs (VLM patches /
                                                      audio frames), or {}
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec as encdec_mod
from repro.models import transformer as tf_mod
from repro.models.transformer import ModelOutput, vision_stub_dim


@dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    decode_step: Callable
    init_cache: Callable
    aux_input_shapes: Dict[str, tuple]  # name -> shape suffix (per-batch)
    # Paged serve path (None when the arch can't page its decode state;
    # see transformer.paged_arch_unsupported for the reasons).
    decode_step_paged: Optional[Callable] = None
    init_paged_cache: Optional[Callable] = None
    # Multi-token verify step for speculative decode (None iff the paged
    # path is unsupported).
    decode_step_paged_multi: Optional[Callable] = None
    # Ragged varlen step — per-slot (row_start, row_len) chunks; unifies
    # chunked prefill, decode and verify (None iff paged unsupported).
    decode_step_paged_varlen: Optional[Callable] = None


def build(cfg: ModelConfig, unroll_layers: bool = False,
          remat: bool = False) -> ModelBundle:
    if cfg.encoder_layers > 0:
        return _build_encdec(cfg, unroll_layers, remat)
    return _build_decoder_only(cfg, unroll_layers, remat)


def _build_decoder_only(cfg: ModelConfig,
                        unroll_layers: bool = False,
                        remat: bool = False) -> ModelBundle:
    aux_shapes: Dict[str, tuple] = {}
    if cfg.vision_prefix_len > 0:
        aux_shapes["prefix_embeds"] = (
            cfg.vision_prefix_len, vision_stub_dim(cfg)
        )

    def init(key, dtype=jnp.float32):
        return tf_mod.init_params(key, cfg, dtype)

    def forward(params, tokens, **aux):
        return tf_mod.forward(params, cfg, tokens,
                              unroll_layers=unroll_layers, remat=remat,
                              **aux)

    def decode_step(params, token, cache):
        return tf_mod.decode_step(params, cfg, token, cache,
                                  unroll_layers=unroll_layers)

    def init_cache(params, batch, max_len, dtype=jnp.float32, **aux):
        return tf_mod.init_cache(cfg, batch, max_len, dtype)

    decode_step_paged = None
    init_paged_cache = None
    decode_step_paged_multi = None
    decode_step_paged_varlen = None
    if tf_mod.paged_arch_unsupported(cfg) is None:
        def decode_step_paged(params, token, pages, block_tables, pos,
                              active, kernel_mode=None, mesh=None,
                              slot_shard=None):
            return tf_mod.decode_step_paged(
                params, cfg, token, pages, block_tables, pos, active,
                kernel_mode=kernel_mode, mesh=mesh, slot_shard=slot_shard)

        def decode_step_paged_multi(params, tokens, pages, block_tables,
                                    pos, active, write_cap,
                                    kernel_mode=None, mesh=None,
                                    slot_shard=None):
            return tf_mod.decode_step_paged_multi(
                params, cfg, tokens, pages, block_tables, pos, active,
                write_cap, kernel_mode=kernel_mode, mesh=mesh,
                slot_shard=slot_shard)

        def decode_step_paged_varlen(params, tokens, pages, block_tables,
                                     row_start, row_len, write_cap,
                                     kernel_mode=None, mesh=None,
                                     slot_shard=None):
            return tf_mod.decode_step_paged_varlen(
                params, cfg, tokens, pages, block_tables, row_start,
                row_len, write_cap, kernel_mode=kernel_mode, mesh=mesh,
                slot_shard=slot_shard)

        def init_paged_cache(num_blocks, block_size, dtype=jnp.float32):
            return tf_mod.init_paged_cache(cfg, num_blocks, block_size,
                                           dtype)

    return ModelBundle(cfg, init, forward, decode_step, init_cache,
                       aux_shapes, decode_step_paged=decode_step_paged,
                       init_paged_cache=init_paged_cache,
                       decode_step_paged_multi=decode_step_paged_multi,
                       decode_step_paged_varlen=decode_step_paged_varlen)


def _build_encdec(cfg: ModelConfig,
                  unroll_layers: bool = False,
                  remat: bool = False) -> ModelBundle:
    aux_shapes = {"frames": (cfg.encoder_seq_len, cfg.d_model)}

    def init(key, dtype=jnp.float32):
        return encdec_mod.init_params(key, cfg, dtype)

    def forward(params, tokens, **aux):
        return encdec_mod.forward(params, cfg, tokens,
                                  unroll_layers=unroll_layers, remat=remat,
                                  **aux)

    def decode_step(params, token, cache):
        return encdec_mod.decode_step(params, cfg, token, cache,
                                      unroll_layers=unroll_layers)

    def init_cache(params, batch, max_len, dtype=jnp.float32, *,
                   encoder_out=None, frames=None):
        if encoder_out is None:
            assert frames is not None, "whisper cache needs encoder output"
            encoder_out = encdec_mod.encode(params, cfg, frames)
        return encdec_mod.init_cache(cfg, batch, max_len, encoder_out,
                                     dtype)

    return ModelBundle(cfg, init, forward, decode_step, init_cache,
                       aux_shapes)
