"""RWKV-6 "Finch" block (arXiv:2404.05892): data-dependent decay linear
attention (time-mix) + squared-ReLU channel-mix.

Recurrence per head (head dim K = V = 64):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (w_t in (0,1), learned
    y_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t     data-dependent decay)

State is [B, H, K, V] — O(1) per token in decode, making rwkv6 the
canonical long_500k architecture.  Training uses a chunked parallel form
(see repro.kernels.wkv6) or this scan reference.

Simplifications vs. the released Finch (documented in DESIGN.md §8): the
low-rank "token-shift LoRA" mixers are collapsed to plain learned
interpolation vectors, and the decay LoRA keeps a single hidden layer.
The recurrence itself — the architectural contribution — is exact.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_apply, dense_init


HEAD_DIM = 64


def rwkv6_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Dict:
    n_heads = d_model // HEAD_DIM
    ks = jax.random.split(key, 12)
    decay_hidden = max(32, d_model // 32)
    return {
        # time-mix interpolation vectors (token shift).
        "mu_r": 0.5 * jnp.ones((d_model,), dtype),
        "mu_k": 0.5 * jnp.ones((d_model,), dtype),
        "mu_v": 0.5 * jnp.ones((d_model,), dtype),
        "mu_w": 0.5 * jnp.ones((d_model,), dtype),
        "mu_g": 0.5 * jnp.ones((d_model,), dtype),
        "w_r": dense_init(ks[0], d_model, d_model, dtype),
        "w_k": dense_init(ks[1], d_model, d_model, dtype),
        "w_v": dense_init(ks[2], d_model, d_model, dtype),
        "w_g": dense_init(ks[3], d_model, d_model, dtype),
        # data-dependent decay: d -> hidden -> d (low-rank MLP), plus base.
        "decay_a": dense_init(ks[4], d_model, decay_hidden, dtype),
        "decay_b": dense_init(ks[5], decay_hidden, d_model, dtype),
        "decay_base": jnp.linspace(-6.0, -1.0, d_model).astype(jnp.float32),
        "bonus_u": 0.1 * jax.random.normal(
            ks[6], (n_heads, HEAD_DIM), jnp.float32
        ).astype(dtype),
        "w_o": dense_init(ks[7], d_model, d_model, dtype),
        "ln_x_scale": jnp.ones((d_model,), dtype),
        # channel-mix.
        "mu_ck": 0.5 * jnp.ones((d_model,), dtype),
        "w_ck": dense_init(ks[8], d_model, d_ff, dtype),
        "w_cv": dense_init(ks[9], d_ff, d_model, dtype),
        "w_cr": dense_init(ks[10], d_model, d_model, dtype),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} stream; `prev` is the last token of the previous segment."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x: jax.Array, x_prev: jax.Array, mu: jax.Array) -> jax.Array:
    return x + (x_prev - x) * mu.astype(x.dtype)[None, None, :]


def wkv6_scan(
    r: jax.Array,   # [B, S, H, K]
    k: jax.Array,   # [B, S, H, K]
    v: jax.Array,   # [B, S, H, V]
    w: jax.Array,   # [B, S, H, K] decay in (0, 1)
    u: jax.Array,   # [H, K] bonus
    state: Optional[jax.Array] = None,  # [B, H, K, V]
) -> Tuple[jax.Array, jax.Array]:
    """Reference WKV6 recurrence. Returns (y [B,S,H,V], final_state)."""
    bsz, s, h, kd = r.shape
    vd = v.shape[-1]
    if state is None:
        state = jnp.zeros((bsz, h, kd, vd), jnp.float32)

    def step(S, xs):
        r_t, k_t, v_t, w_t = [a.astype(jnp.float32) for a in xs]  # [B,H,*]
        kv = k_t[..., :, None] * v_t[..., None, :]                # [B,H,K,V]
        y = jnp.einsum(
            "bhkv,bhk->bhv", S + u.astype(jnp.float32)[None, :, :, None] * kv,
            r_t,
        )
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, w))
    final, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), final


class RWKVState(Tuple):
    pass


def rwkv6_time_mix(
    p: Dict,
    x: jax.Array,   # [B, S, D]
    state: Optional[Tuple[jax.Array, jax.Array]] = None,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Time-mix sub-block. state = (wkv_state [B,H,K,V], shift [B,1,D])."""
    d = x.shape[-1]
    h = d // HEAD_DIM
    wkv_state = state[0] if state is not None else None
    shift = state[1] if state is not None else None
    xp = _token_shift(x, shift)

    r = dense_apply(p["w_r"], _mix(x, xp, p["mu_r"]))
    k = dense_apply(p["w_k"], _mix(x, xp, p["mu_k"]))
    v = dense_apply(p["w_v"], _mix(x, xp, p["mu_v"]))
    g = jax.nn.silu(dense_apply(p["w_g"], _mix(x, xp, p["mu_g"])))

    wx = _mix(x, xp, p["mu_w"])
    decay_raw = p["decay_base"].astype(jnp.float32)[None, None, :] + (
        dense_apply(p["decay_b"], jnp.tanh(dense_apply(p["decay_a"], wx)))
    ).astype(jnp.float32)
    # w_t = exp(-exp(decay_raw)) in (0,1): the Finch parameterization.
    w = jnp.exp(-jnp.exp(decay_raw)).astype(x.dtype)

    def heads(t):
        return t.reshape(t.shape[0], t.shape[1], h, HEAD_DIM)

    if use_kernel:
        from repro.kernels import ops as kops
        y, new_state = kops.wkv6(
            heads(r), heads(k), heads(v), heads(w),
            p["bonus_u"].astype(x.dtype), wkv_state,
        )
    else:
        y, new_state = wkv6_scan(
            heads(r), heads(k), heads(v), heads(w),
            p["bonus_u"].astype(x.dtype), wkv_state,
        )
    y = y.reshape(x.shape[0], x.shape[1], d)
    # group-norm-lite over heads (Finch uses GroupNorm(h)).
    y32 = y.astype(jnp.float32).reshape(*y.shape[:2], h, HEAD_DIM)
    y32 = y32 * jax.lax.rsqrt(
        jnp.mean(jnp.square(y32), axis=-1, keepdims=True) + 1e-5
    )
    y = (y32.reshape(*y.shape) * p["ln_x_scale"].astype(jnp.float32)).astype(
        x.dtype
    )
    out = dense_apply(p["w_o"], y * g)
    return out, (new_state, x[:, -1:])


def rwkv6_channel_mix(
    p: Dict,
    x: jax.Array,
    shift: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Squared-ReLU channel mix. Returns (out, new_shift)."""
    xp = _token_shift(x, shift)
    k = dense_apply(p["w_ck"], _mix(x, xp, p["mu_ck"]))
    kv = dense_apply(p["w_cv"], jnp.square(jax.nn.relu(k)))
    rgate = jax.nn.sigmoid(dense_apply(p["w_cr"], xp))
    return rgate * kv, x[:, -1:]
