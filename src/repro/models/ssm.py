"""Mamba-style selective SSM block (hymba's SSM heads).

Implements the S6 recurrence with input-dependent (Δ, B, C):

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + Δ_t ⊙ (B_t ⊗ x_t)
    y_t = C_t · h_t + D ⊙ x_t

State is [inner, state_dim] per channel (diagonal A), which is hymba's
``ssm_state=16``.  Training runs a jax.lax.scan over time (the Pallas
chunked kernel in repro.kernels accelerates the same recurrence);
decode carries the [B, inner, N] state explicitly — O(1) per token, the
reason the hybrid archs serve long_500k.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_apply, dense_init


def ssm_init(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> Dict:
    inner = cfg.expand * d_model
    dt_rank = cfg.dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 8)
    # A: negative-real diagonal init (S4D-real): -(1..N) per channel.
    a_init = -jnp.tile(
        jnp.arange(1, cfg.state_dim + 1, dtype=jnp.float32)[None, :],
        (inner, 1),
    )
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * inner, dtype),
        "conv_w": (
            0.1 * jax.random.normal(ks[1], (cfg.conv_width, inner),
                                    jnp.float32)
        ).astype(dtype),
        "conv_b": jnp.zeros((inner,), dtype),
        "x_proj": dense_init(ks[2], inner, dt_rank + 2 * cfg.state_dim,
                             dtype),
        "dt_proj": dense_init(ks[3], dt_rank, inner, dtype, bias=True),
        "a_log": jnp.log(-a_init).astype(jnp.float32),  # A = -exp(a_log)
        "d_skip": jnp.ones((inner,), jnp.float32),
        "out_proj": dense_init(ks[4], inner, d_model, dtype),
    }


def _ssm_scan(
    u: jax.Array,       # [B, S, I] post-conv activations
    dt: jax.Array,      # [B, S, I]
    b_t: jax.Array,     # [B, S, N]
    c_t: jax.Array,     # [B, S, N]
    a: jax.Array,       # [I, N] (negative)
    init_state: Optional[jax.Array],  # [B, I, N]
) -> Tuple[jax.Array, jax.Array]:
    """Sequential selective scan. Returns (y [B,S,I], final_state)."""
    bsz, s, inner = u.shape
    n = a.shape[1]
    if init_state is None:
        init_state = jnp.zeros((bsz, inner, n), jnp.float32)

    def step(h, xs):
        u_t, dt_t, bb, cc = xs  # [B,I], [B,I], [B,N], [B,N]
        decay = jnp.exp(dt_t[..., None] * a[None])            # [B,I,N]
        drive = dt_t[..., None] * u_t[..., None] * bb[:, None, :]
        h = decay * h + drive
        y = jnp.einsum("bin,bn->bi", h, cc)
        return h, y

    xs = (
        u.transpose(1, 0, 2).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        b_t.transpose(1, 0, 2).astype(jnp.float32),
        c_t.transpose(1, 0, 2).astype(jnp.float32),
    )
    final, ys = jax.lax.scan(step, init_state, xs)
    return ys.transpose(1, 0, 2).astype(u.dtype), final


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. state = last (width-1) inputs [B,W-1,I]."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
        for i in range(width)
    )
    new_state = xp[:, -(width - 1):, :] if width > 1 else state
    return out + b[None, None, :], new_state


def ssm_forward(
    p: Dict,
    x: jax.Array,        # [B, S, D]
    cfg: SSMConfig,
    state: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence (or incremental, with `state`) selective-SSM block.

    state = (ssm_state [B,I,N], conv_state [B,W-1,I]).
    """
    dt_rank = p["dt_proj"]["w"].shape[0]
    n = p["a_log"].shape[1]

    xz = dense_apply(p["in_proj"], x)
    u, z = jnp.split(xz, 2, axis=-1)
    conv_state = state[1] if state is not None else None
    u, new_conv = _causal_conv(u, p["conv_w"].astype(x.dtype),
                               p["conv_b"].astype(x.dtype), conv_state)
    u = jax.nn.silu(u)

    proj = dense_apply(p["x_proj"], u)
    dt_lowrank = proj[..., :dt_rank]
    b_t = proj[..., dt_rank : dt_rank + n]
    c_t = proj[..., dt_rank + n :]
    dt = jax.nn.softplus(dense_apply(p["dt_proj"], dt_lowrank))

    a = -jnp.exp(p["a_log"])
    ssm_state = state[0] if state is not None else None
    y, new_state = _ssm_scan(u, dt, b_t, c_t, a, ssm_state)
    y = y + u * p["d_skip"].astype(x.dtype)[None, None, :]
    y = y * jax.nn.silu(z)
    return dense_apply(p["out_proj"], y), (new_state, new_conv)
