"""Mixture-of-Experts block: top-k router + capacity-factor dispatch.

Dispatch/combine are the Shazeer einsum formulation so that sharding the
expert axis over the mesh's ``model`` dimension yields the canonical
expert-parallel all-to-all pattern under GSPMD (kimi-k2's 384-expert
top-8 and llama4-scout's 16-expert top-1 both route through here).

The router aux (load-balance) loss follows Switch Transformer:
    aux = E * sum_e f_e * p_e
with f_e the fraction of tokens dispatched to expert e and p_e the mean
router probability of e.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_apply, dense_init, mlp_apply, mlp_init

# §Perf hillclimb #2: when enabled (REPRO_MOE_HINTS=1), pin the dispatch
# boundary tensors with explicit sharding constraints — groups on 'data',
# experts on 'model' — so GSPMD lowers the exchange as the canonical
# expert-parallel all-to-all instead of replicating the dispatch one-hots
# over the model axis.  No-op outside a ('data','model') mesh context.
import os as _os

MOE_SHARDING_HINTS = _os.environ.get("REPRO_MOE_HINTS", "0") == "1"


def _hint(x: jax.Array, spec_dims) -> jax.Array:
    if not MOE_SHARDING_HINTS:
        return x
    try:
        from jax.sharding import PartitionSpec as P

        return jax.lax.with_sharding_constraint(x, P(*spec_dims))
    except (ValueError, RuntimeError, NameError):
        return x  # no mesh context / axis names absent


# --- sharded-backward einsums (hillclimb #2, iter 4) -----------------------
#
# GSPMD does not propagate the forward hints to einsum COTANGENTS: the
# backward of combine (`gsec,egcd->gsd`) otherwise all-gathers a full
# [E,G,C,D] fp32 cotangent (17 GiB/layer for kimi-k2).  These custom_vjp
# wrappers pin the expert axis of both backward products to 'model'.
#
# NOTE: d(dispatch)/d(combine-onehots) are returned as REAL cotangents
# only where the caller needs them; `moe_apply` stop-gradients the
# routing one-hots, so `_dispatch_einsum` returns a zero cotangent for
# `dispatch` instead of materializing a [G,Sg,E,C] product.


@jax.custom_vjp
def _dispatch_einsum(dispatch, xg):
    return jnp.einsum("gsec,gsd->egcd", dispatch, xg)


def _dispatch_fwd(dispatch, xg):
    return _dispatch_einsum(dispatch, xg), (dispatch,)


def _dispatch_bwd(res, g):
    (dispatch,) = res
    g = _hint(g, ("model", "data", None, None))
    d_xg = jnp.einsum("gsec,egcd->gsd", dispatch, g)
    d_xg = _hint(d_xg, ("data", None, None))
    return jnp.zeros_like(dispatch), d_xg


_dispatch_einsum.defvjp(_dispatch_fwd, _dispatch_bwd)


@jax.custom_vjp
def _combine_einsum(combine, out_buf):
    return jnp.einsum("gsec,egcd->gsd", combine, out_buf)


def _combine_fwd(combine, out_buf):
    return _combine_einsum(combine, out_buf), (combine, out_buf)


def _combine_bwd(res, g):
    combine, out_buf = res
    g = _hint(g, ("data", None, None))
    # d_combine keeps its expert axis on 'model': it is consumed by the
    # gates contraction (sum over e,c), which reduces locally per expert
    # shard + a small [G,S,k] all-reduce — never materializing a
    # replicated [G,Sg,E,C] (= the 17 GiB/layer gather on kimi-k2).
    d_combine = jnp.einsum("gsd,egcd->gsec", g, out_buf)
    d_combine = _hint(d_combine, ("data", None, "model", None))
    d_out = jnp.einsum("gsec,gsd->egcd", combine, g)
    d_out = _hint(d_out, ("model", "data", None, None))
    return d_combine, d_out


_combine_einsum.defvjp(_combine_fwd, _combine_bwd)


def moe_init(key, d_model: int, cfg: MoEConfig, activation: str,
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 4)
    e, dff = cfg.n_experts, cfg.d_ff_expert

    def expert_bank(k, d_in, d_out):
        w = (d_in ** -0.5) * jax.random.truncated_normal(
            k, -2.0, 2.0, (e, d_in, d_out), jnp.float32
        )
        return w.astype(dtype)

    p = {
        "router": dense_init(ks[0], d_model, e, dtype),
        "gate_w": expert_bank(ks[1], d_model, dff),    # [E, D, F]
        "up_w": expert_bank(ks[2], d_model, dff),      # [E, D, F]
        "down_w": expert_bank(ks[3], dff, d_model),    # [E, F, D]
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = mlp_init(
            jax.random.fold_in(key, 7), d_model,
            cfg.n_shared_experts * cfg.d_ff_expert, activation, dtype,
        )
    return p


def _topk_routing(
    logits: jax.Array, top_k: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gate_weights [N, k], expert_ids [N, k], probs [N, E])."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, ids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, ids, probs


def moe_apply(
    p: Dict,
    x: jax.Array,          # [B, S, D]
    cfg: MoEConfig,
    activation: str,
    group_size: int = 512,
) -> Tuple[jax.Array, jax.Array]:
    """Grouped capacity dispatch (mesh-TF / GShard formulation).

    Tokens are split into groups of `group_size`; each group routes into a
    per-group expert capacity C = ceil(cf * group_size * k / E).  The
    dispatch one-hot is then [G, S_g, E, C] with total size
    N * S_g * k * cf — *independent of the expert count*, which is what
    keeps kimi-k2's 384-expert train_4k dispatch (~1e10 elements global,
    sharded over (data x model)) within per-device budgets.  Groups shard
    over the data axes, experts over the model axis: GSPMD lowers the
    buffer exchange to the canonical expert-parallel all-to-all pair.

    Returns (output [B,S,D], router aux loss scalar).
    """
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    sg = min(group_size, n)
    pad = (-n) % sg
    xf = x.reshape(n, d)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    g = (n + pad) // sg
    xg = xf.reshape(g, sg, d)                                 # [G, Sg, D]
    cap = max(1, int(cfg.capacity_factor * sg * k / e))

    logits = dense_apply(p["router"], xg)                     # [G, Sg, E]
    gates, ids, probs = _topk_routing(logits, k)              # [G,Sg,k], ...

    # Per-group position of each (token, choice) in its expert's buffer.
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.int32)          # [G, Sg, k, E]
    flat = onehot.reshape(g, sg * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat           # [G, Sg*k, E]
    pos = jnp.sum(flat * pos_in_expert, axis=-1).reshape(g, sg, k)
    keep = pos < cap

    cap_onehot = jax.nn.one_hot(
        jnp.where(keep, pos, cap), cap + 1, dtype=x.dtype
    )[..., :cap]                                              # [G, Sg, k, C]
    # The routing one-hots are piecewise-constant: stop_gradient keeps the
    # backward pass from materializing (and resharding) a phantom
    # [G,Sg,E,C] cotangent — gradients flow to the router only through
    # `gates` in the combine weights (§Perf hillclimb #2, iter 3).
    onehot_f = jax.lax.stop_gradient(onehot.astype(x.dtype))
    cap_onehot = jax.lax.stop_gradient(cap_onehot)
    dispatch = jnp.einsum("gske,gskc->gsec", onehot_f, cap_onehot)
    combine = jnp.einsum(
        "gske,gskc,gsk->gsec", onehot_f, cap_onehot, gates.astype(x.dtype)
    )

    dispatch = _hint(dispatch, ("data", None, None, None))
    combine = _hint(combine, ("data", None, None, None))

    # Expert buffers [E, G, C, D] — the all-to-all boundary.
    buf = _dispatch_einsum(dispatch, xg)
    buf = _hint(buf, ("model", "data", None, None))
    h_gate = jnp.einsum("egcd,edf->egcf", buf,
                        p["gate_w"].astype(x.dtype))
    h_up = jnp.einsum("egcd,edf->egcf", buf, p["up_w"].astype(x.dtype))
    h = jax.nn.silu(h_gate) * h_up
    out_buf = jnp.einsum("egcf,efd->egcd", h,
                         p["down_w"].astype(x.dtype))
    out_buf = _hint(out_buf, ("model", "data", None, None))
    yg = _combine_einsum(combine, out_buf)
    yg = _hint(yg, ("data", None, None))

    yf = yg.reshape(g * sg, d)[:n]
    if "shared" in p:
        yf = yf + mlp_apply(p["shared"], xf[:n], activation)

    # Switch-style load-balance aux (over all tokens incl. groups).
    frac_dispatched = jnp.mean(
        jnp.sum(onehot.astype(jnp.float32), axis=2), axis=(0, 1)
    )                                                         # [E]
    mean_prob = jnp.mean(probs, axis=(0, 1))                  # [E]
    aux = cfg.router_aux_coef * e * jnp.sum(frac_dispatched * mean_prob)

    return yf.reshape(b, s, d), aux


def moe_apply_dense_fallback(
    p: Dict, x: jax.Array, cfg: MoEConfig, activation: str
) -> Tuple[jax.Array, jax.Array]:
    """Decode-friendly path: compute all experts densely, weight by gates.

    For single-token decode (S == 1) the capacity machinery degenerates;
    weighting a dense [E] bank by the router is cheaper in HLO and shards
    identically over the expert axis.
    """
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = dense_apply(p["router"], xf)
    gates, ids, probs = _topk_routing(logits, cfg.top_k)
    w = jnp.zeros_like(probs).at[
        jnp.arange(xf.shape[0])[:, None], ids
    ].set(gates)                                               # [N, E]
    h_gate = jnp.einsum("nd,edf->nef", xf, p["gate_w"].astype(x.dtype))
    h_up = jnp.einsum("nd,edf->nef", xf, p["up_w"].astype(x.dtype))
    h = jax.nn.silu(h_gate) * h_up
    y = jnp.einsum("nef,efd,ne->nd", h, p["down_w"].astype(x.dtype),
                   w.astype(x.dtype))
    if "shared" in p:
        y = y + mlp_apply(p["shared"], xf, activation)
    aux = jnp.zeros((), jnp.float32)
    return y.reshape(b, s, d), aux
