"""Shared neural building blocks (pure JAX, dict-pytree parameters).

Conventions:
* params are nested dicts of jnp arrays; init_* functions return them.
* apply functions are pure: f(params, x, ...) -> y.
* compute dtype follows the input x; params may be bf16 or fp32.
* all matmul inits are truncated-normal with 1/sqrt(fan_in) scaling.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               bias: bool = False, scale: float = 1.0) -> Dict:
    std = scale / (d_in ** 0.5)
    w = std * jax.random.truncated_normal(
        key, -2.0, 2.0, (d_in, d_out), jnp.float32
    )
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: Dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> Dict:
    emb = jax.random.normal(key, (vocab, d), jnp.float32)
    return {"table": (emb * (d ** -0.5)).astype(dtype)}


def embedding_apply(p: Dict, ids: jax.Array) -> jax.Array:
    return p["table"][ids]


def embedding_attend(p: Dict, x: jax.Array) -> jax.Array:
    """Tied-softmax readout: x @ table^T."""
    return x @ p["table"].astype(x.dtype).T


def rmsnorm_init(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: Dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p: Dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """[head_dim // 2] inverse frequencies."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(
    x: jax.Array,                 # [..., S, H, Dh]
    positions: jax.Array,         # [..., S] absolute positions
    theta: float = 10000.0,
) -> jax.Array:
    dh = x.shape[-1]
    inv_freq = rope_frequencies(dh, theta)
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # [...,S,Dh/2]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Feed-forward blocks
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, d_ff: int, activation: str,
             dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "gate": dense_init(ks[0], d, d_ff, dtype),
            "up": dense_init(ks[1], d, d_ff, dtype),
            "down": dense_init(ks[2], d_ff, d, dtype),
        }
    return {
        "up": dense_init(ks[0], d, d_ff, dtype),
        "down": dense_init(ks[1], d_ff, d, dtype),
    }


def mlp_apply(p: Dict, x: jax.Array, activation: str) -> jax.Array:
    if activation == "swiglu":
        h = jax.nn.silu(dense_apply(p["gate"], x)) * dense_apply(p["up"], x)
    else:
        h = jax.nn.gelu(dense_apply(p["up"], x))
    return dense_apply(p["down"], h)


def softcap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-style logit soft-capping: cap * tanh(logits / cap)."""
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)
