"""Vectorized environment rollout collector with per-actor policies.

The simulated-async protocol (Fig. 1 left) requires each parallel actor to
run a *different* policy (sampled from the policy buffer).  The collector
therefore vmaps the policy apply over a stacked actor-parameter pytree and
scans the environment for ``num_steps``, entirely inside jit.

Output layout is batch-major [N_actors, T, ...] to match the advantage
estimators in repro.core.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.envs.base import Env


class RolloutBatch(NamedTuple):
    obs: jax.Array        # [N, T, obs_dim]
    actions: jax.Array    # [N, T, act_dim]
    log_beta: jax.Array   # [N, T]   behavior log-probs at collection
    rewards: jax.Array    # [N, T]
    dones: jax.Array      # [N, T]   episode boundary AFTER this step
    final_obs: jax.Array  # [N, obs_dim]  for bootstrap values


def collect_rollout(
    env: Env,
    policy_apply: Callable[[Any, jax.Array, jax.Array],
                           Tuple[jax.Array, jax.Array]],
    actor_params: Any,      # pytree, leaves lead with N (one policy/actor)
    env_states: Any,        # pytree, leaves lead with N
    key: jax.Array,
    num_steps: int,
) -> Tuple[Any, RolloutBatch]:
    """Run every actor for `num_steps` with its own policy.

    ``policy_apply(params_i, obs_i [obs_dim], key) -> (action, log_prob)``.
    Returns (new_env_states, batch).
    """
    n = jax.tree.leaves(env_states)[0].shape[0]
    observe = jax.vmap(env.observe)

    def step_fn(carry, key_t):
        states = carry
        obs = observe(states)
        k_act, k_env = jax.random.split(key_t)
        act_keys = jax.random.split(k_act, n)
        actions, log_probs = jax.vmap(policy_apply)(
            actor_params, obs, act_keys
        )
        env_keys = jax.random.split(k_env, n)
        states, ts = jax.vmap(env.step)(states, actions, env_keys)
        out = (obs, actions, log_probs, ts.reward, ts.done)
        return states, out

    keys = jax.random.split(key, num_steps)
    env_states, (obs, actions, log_beta, rewards, dones) = jax.lax.scan(
        step_fn, env_states, keys
    )
    # time-major -> batch-major
    tm = lambda x: jnp.swapaxes(x, 0, 1)
    batch = RolloutBatch(
        obs=tm(obs),
        actions=tm(actions),
        log_beta=tm(log_beta),
        rewards=tm(rewards),
        dones=tm(dones),
        final_obs=observe(env_states),
    )
    return env_states, batch


def init_env_states(env: Env, key: jax.Array, n: int) -> Any:
    return jax.vmap(env.reset)(jax.random.split(key, n))


def evaluate_policy(
    env: Env,
    policy_apply_det: Callable[[Any, jax.Array], jax.Array],
    params: Any,
    key: jax.Array,
    n_episodes: int = 16,
) -> jax.Array:
    """Mean undiscounted return of the (deterministic) policy."""
    def one_episode(k):
        k0, k1 = jax.random.split(k)
        state = env.reset(k0)

        def step(carry, k_t):
            state, ret = carry
            obs = env.observe(state)
            a = policy_apply_det(params, obs)
            state, ts = env.step(state, a, k_t)
            return (state, ret + ts.reward), None

        keys = jax.random.split(k1, env.max_episode_steps)
        (_, ret), _ = jax.lax.scan(step, (state, 0.0), keys)
        return ret

    return jnp.mean(jax.vmap(one_episode)(jax.random.split(key, n_episodes)))
