"""LLM serve engine: prefill + KV-cache decode generation loop.

This is the actor side of the §5.2 asynchronous RLVR setup — the role
vLLM plays in the paper.  ``generate`` runs a jitted prefill + a
``lax.scan`` of single-token decode steps, returning the sampled
completions together with the *behavior log-probs* recorded at sampling
time (the β_T(a|s) term every loss in repro.core consumes).

Because our learner scores sequences with the same forward pass (same
kernels, same dtype), the vllm-vs-transformers logprob mismatch the paper
flags (Yao et al., 2025) does not arise here; the realignment ratio at
generation time is exactly 1 for fresh data.

Sampling: temperature + top-p nucleus, both jit-static.  EOS handling:
the EOS token itself is scored (mask 1); afterwards rows produce PAD
with *exact zeros* for mask, log_beta and value — every row of the
result is well-formed stand-alone (even an EOS on the very first decode
step yields the single-token mask [1, 0, ...]), so per-request
consumers need not re-apply the batch mask.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.data.tokenizer import EOS, PAD
from repro.models.registry import ModelBundle


class GenerationResult(NamedTuple):
    tokens: jax.Array        # [B, P + N] prompt + completion ids
    completion: jax.Array    # [B, N]
    log_beta: jax.Array      # [B, N] behavior log-probs of sampled tokens
    mask: jax.Array          # [B, N] 1.0 up to and including EOS
    values: Optional[jax.Array]  # [B, N] critic values at sampling (or None)


def _top_p_filter(logits: jax.Array, top_p: float) -> jax.Array:
    """Zero out (set -inf) the tail outside the nucleus."""
    if top_p >= 1.0:
        return logits
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # smallest set with cumulative prob >= top_p; keep at least 1 token.
    cutoff_idx = jnp.sum(cum < top_p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
    return jnp.where(logits >= cutoff, logits, -jnp.inf)


def generate(
    bundle: ModelBundle,
    params: Any,
    prompt: jax.Array,          # [B, P] (left-padded) prompt token ids
    key: jax.Array,
    *,
    max_new_tokens: int,
    temperature: float = 1.0,
    top_p: float = 1.0,
    aux: Optional[dict] = None,
) -> GenerationResult:
    """Sample completions; fully jittable (call under jax.jit)."""
    b, p = prompt.shape
    total = p + max_new_tokens
    aux = aux or {}

    # Prefill: write the prompt into a cache sized for the full rollout.
    out = bundle.forward(
        params, prompt, return_cache=True, cache_len=total, **aux
    )
    cache = out.cache
    last_logits = out.logits[:, -1]  # [B, V]

    def sample_token(logits, k):
        logits = logits.astype(jnp.float32) / max(temperature, 1e-6)
        logits = _top_p_filter(logits, top_p)
        tok = jax.random.categorical(k, logits, axis=-1)
        logp = jax.nn.log_softmax(logits, axis=-1)
        lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
        return tok, lp

    def step(carry, k_t):
        cache, logits, alive = carry
        tok, lp = sample_token(logits, k_t)
        tok = jnp.where(alive, tok, PAD)
        # Dead rows re-sample from whatever logits the PAD feed produced;
        # zero their (log_beta, value) so each row is well-formed on its
        # own — per-request consumers (the serve engine's tokenwise TV
        # provenance) read these vectors without the batch mask.  A row
        # whose *first* step emits EOS is the extreme case: mask
        # [1, 0, ...] with exact zeros beyond the single scored token.
        lp = jnp.where(alive, lp, 0.0)
        mask = alive.astype(jnp.float32)
        alive = jnp.logical_and(alive, tok != EOS)
        out, cache = bundle.decode_step(params, tok, cache)
        value = out.value if out.value is not None else jnp.zeros((b,))
        value = value * mask
        return (cache, out.logits, alive), (tok, lp, mask, value)

    keys = jax.random.split(key, max_new_tokens)
    alive0 = jnp.ones((b,), bool)
    (_, _, _), (toks, lps, masks, values) = jax.lax.scan(
        step, (cache, last_logits, alive0), keys
    )
    completion = toks.T           # [B, N]
    log_beta = lps.T
    mask = masks.T
    values = values.T

    tokens = jnp.concatenate([prompt, completion], axis=1)
    return GenerationResult(
        tokens=tokens,
        completion=completion,
        log_beta=log_beta,
        mask=mask,
        values=values if bundle.cfg.value_head else None,
    )


def speculative_accept(
    verifier_logits: jax.Array,  # [B, K, V] L_i: verifier logits after
                                 # consuming query i (= the token the
                                 # draft's step i also consumed)
    draft_tokens: jax.Array,     # [B, K] int32 proposed tokens d_{i+1}
    draft_logits: jax.Array,     # [B, K, V] draft logits that sampled
                                 # d_{i+1} (same position alignment)
    key: jax.Array,
    *,
    temperature: float = 1.0,
    top_p: float = 1.0,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Speculative-sampling accept/rollback (Leviathan et al., 2023).

    Position ``i`` accepts draft token ``d`` with probability
    ``min(1, p_i(d) / q_i(d))`` where ``p``/``q`` are the verifier/draft
    distributions under the *same* temperature + top-p transform the
    serve engine samples with.  The first rejection emits a correction
    drawn from the residual ``max(p - q, 0)`` (renormalized) instead,
    and everything after it is rolled back.  The emitted-token marginal
    is exactly ``p`` at every position, so the recorded ``log_beta`` is
    the **verifier's** log-prob of the emitted token — β stays the
    latest policy and downstream TV-gate admission is unchanged.

    Greedy decode is the ``temperature -> 0`` limit of the same rule:
    the sharpened ``p``/``q`` are one-hot, so a draft token is accepted
    iff it equals the verifier argmax and the residual collapses onto
    the verifier argmax — speculative greedy output is token-exact with
    non-speculative greedy decode at any acceptance rate.

    Returns ``(tokens [B, K], log_p [B, K], n_accepted [B],
    n_emitted [B])``: positions ``< n_emitted`` hold the emitted tokens
    (accepted prefix + one correction when a rejection happened;
    ``n_emitted == K`` means every draft was accepted and no correction
    is appended — the caller re-feeds ``d_K`` as the next query, which
    rewrites its row idempotently).  Positions ``>= n_emitted`` are PAD
    with log-prob exactly 0.
    """
    b, k, _ = verifier_logits.shape
    temp = max(float(temperature), 1e-6)

    def _log_dist(logits):
        logits = logits.astype(jnp.float32) / temp
        logits = _top_p_filter(logits, top_p)
        return jax.nn.log_softmax(logits, axis=-1)

    logp_p = _log_dist(verifier_logits)                    # [B, K, V]
    logp_q = _log_dist(draft_logits)
    p_d = jnp.take_along_axis(
        logp_p, draft_tokens[..., None], axis=-1)[..., 0]  # [B, K]
    q_d = jnp.take_along_axis(
        logp_q, draft_tokens[..., None], axis=-1)[..., 0]

    k_u, k_r = jax.random.split(key)
    u = jax.random.uniform(k_u, (b, k), minval=1e-7)
    # accept_i: u < p(d)/q(d), in log space (p_d = -inf always rejects).
    accept = jnp.log(u) < (p_d - q_d)
    n_acc = jnp.cumprod(accept.astype(jnp.int32), axis=1).sum(axis=1)

    # Correction at the first rejected position (clamped when everything
    # was accepted; that sample is masked out below).
    rej = jnp.minimum(n_acc, k - 1)
    pr = jnp.take_along_axis(logp_p, rej[:, None, None], axis=1)[:, 0]
    qr = jnp.take_along_axis(logp_q, rej[:, None, None], axis=1)[:, 0]
    residual = jnp.maximum(jnp.exp(pr) - jnp.exp(qr), 0.0)  # [B, V]
    res_sum = residual.sum(axis=-1, keepdims=True)
    # Degenerate residual (p == q everywhere) can only pair with a
    # rejection through float round-off; fall back to sampling from p.
    corr_logits = jnp.where(
        res_sum > 1e-9, jnp.log(jnp.maximum(residual, 1e-38)), pr)
    corr = jax.random.categorical(k_r, corr_logits, axis=-1)  # [B]
    corr_logp = jnp.take_along_axis(pr, corr[:, None], axis=1)[:, 0]

    idx = jnp.arange(k, dtype=jnp.int32)[None, :]
    is_acc = idx < n_acc[:, None]
    is_corr = jnp.logical_and(idx == n_acc[:, None], n_acc[:, None] < k)
    tokens = jnp.where(
        is_acc, draft_tokens,
        jnp.where(is_corr, corr[:, None], jnp.int32(PAD)))
    log_p = jnp.where(is_acc, p_d,
                      jnp.where(is_corr, corr_logp[:, None], 0.0))
    n_emit = jnp.minimum(n_acc + 1, k)
    return tokens, log_p, n_acc, n_emit


def score_tokens(
    bundle: ModelBundle,
    params: Any,
    tokens: jax.Array,         # [B, T] full sequences (prompt + completion)
    prompt_len: int,
    *,
    aux: Optional[dict] = None,
    kernel_mode: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, Optional[jax.Array]]:
    """Teacher-forced per-completion-token (logp, entropy, value).

    logits at position t predict token t+1; completion tokens live at
    positions [prompt_len, T), so we score logits [prompt_len-1, T-1).
    Uses the fused logprob kernel path when enabled.
    """
    from repro.kernels import ops as kops

    aux = aux or {}
    out = bundle.forward(params, tokens, **aux)
    logits = out.logits[:, prompt_len - 1 : -1]          # [B, N, V]
    targets = tokens[:, prompt_len:]                     # [B, N]
    logp, entropy = kops.logprobs_from_logits(
        logits, targets, mode=kernel_mode
    )
    value = None
    if out.value is not None:
        value = out.value[:, prompt_len - 1 : -1]
    return logp, entropy, value
