from repro.rollout.env_rollout import (
    RolloutBatch,
    collect_rollout,
    init_env_states,
    evaluate_policy,
)
from repro.rollout.sampler import GenerationResult, generate, score_tokens
from repro.rollout.async_engine import (
    SimulatedAsyncActors,
    ForwardLagGenerator,
    ForwardLagBatch,
    RLVRMinibatch,
)

__all__ = [
    "RolloutBatch",
    "collect_rollout",
    "init_env_states",
    "evaluate_policy",
    "GenerationResult",
    "generate",
    "score_tokens",
    "SimulatedAsyncActors",
    "ForwardLagGenerator",
    "ForwardLagBatch",
    "RLVRMinibatch",
]
