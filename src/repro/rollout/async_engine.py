"""Asynchronous actor-learner simulators for both experimental regimes.

*Backward lag* (§5.1, Fig. 1 left): ``SimulatedAsyncActors`` owns the
policy buffer; each collection phase samples one stale policy per actor
and rolls the vectorized environments — yielding the episodic-mixture
behavior policy β_T of Eq. 1 with a controllable degree of asynchronicity
(the buffer capacity K).

*Forward lag* (§5.2): ``ForwardLagGenerator`` freezes the current policy,
generates N minibatches of completions with the serve engine, and hands
them to the learner one per update — by minibatch k the learner is k
updates ahead of the data's behavior policy, reproducing the paper's
N-minibatch protocol (Noukhovitch et al., 2025 style).

Both are thin, jit-friendly coordinators over repro.core.policy_lag,
repro.rollout.env_rollout and repro.rollout.sampler.
"""
from __future__ import annotations

from typing import Any, Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy_lag import (
    PolicyBuffer,
    buffer_init,
    buffer_push,
    buffer_sample,
)
from repro.envs.base import Env
from repro.rollout.env_rollout import (
    RolloutBatch,
    collect_rollout,
    init_env_states,
)
from repro.rollout.sampler import GenerationResult, generate


class SimulatedAsyncActors:
    """Policy-buffer actors over vectorized pure-JAX environments."""

    def __init__(
        self,
        env: Env,
        policy_apply: Callable,
        init_params: Any,
        *,
        n_actors: int,
        buffer_capacity: int,
        rollout_steps: int,
        seed: int = 0,
    ) -> None:
        self.env = env
        self.n_actors = n_actors
        self.rollout_steps = rollout_steps
        self._key = jax.random.PRNGKey(seed)
        self.buffer: PolicyBuffer = buffer_init(init_params, buffer_capacity)
        self._env_states = init_env_states(
            env, self._next_key(), n_actors
        )

        def _collect(buffer, env_states, key):
            k_sample, k_roll = jax.random.split(key)
            actor_params, slots = buffer_sample(buffer, k_sample, n_actors)
            env_states, batch = collect_rollout(
                env, policy_apply, actor_params, env_states, k_roll,
                rollout_steps,
            )
            return env_states, batch, slots

        self._collect = jax.jit(_collect)

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def push_policy(self, params: Any) -> None:
        """Learner publishes a new policy snapshot (end of train phase)."""
        self.buffer = buffer_push(self.buffer, params)

    def collect(self) -> Tuple[RolloutBatch, jax.Array]:
        """One collection phase: every actor re-samples a stale policy and
        rolls `rollout_steps` steps.  Returns (batch, sampled buffer slots).
        """
        self._env_states, batch, slots = self._collect(
            self.buffer, self._env_states, self._next_key()
        )
        return batch, slots


class ForwardLagBatch(NamedTuple):
    gen: GenerationResult
    rewards: jax.Array         # [B] binary verifier rewards
    answers: List[str]
    staleness: int             # updates the learner is ahead when consumed


class ForwardLagGenerator:
    """Generate-N-then-train-N protocol for RLVR (§5.2)."""

    def __init__(
        self,
        bundle,
        dataset,
        *,
        n_minibatches: int,
        prompts_per_minibatch: int,
        completions_per_prompt: int,
        max_new_tokens: int,
        temperature: float = 1.0,
        seed: int = 0,
    ) -> None:
        self.bundle = bundle
        self.dataset = dataset
        self.n_minibatches = n_minibatches
        self.prompts_per_minibatch = prompts_per_minibatch
        self.group_size = completions_per_prompt
        self.max_new_tokens = max_new_tokens
        self._key = jax.random.PRNGKey(seed)

        def _gen(params, prompt_tokens, key):
            return generate(
                bundle, params, prompt_tokens, key,
                max_new_tokens=max_new_tokens, temperature=temperature,
            )

        self._gen = jax.jit(_gen)

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def generate_phase(self, params: Any) -> List[ForwardLagBatch]:
        """Freeze `params` as β and produce N minibatches of labeled data.

        Minibatch k will be trained on after k prior updates — its
        ``staleness`` field records the forward lag at consumption time.
        """
        from repro.data.mathgen import verify

        out: List[ForwardLagBatch] = []
        tok = self.dataset.tok
        for k in range(self.n_minibatches):
            toks_np, _, answers = self.dataset.sample_batch(
                self.prompts_per_minibatch
            )
            # Group: repeat each prompt G times (GRPO groups contiguous).
            toks_np = np.repeat(toks_np, self.group_size, axis=0)
            answers = [a for a in answers for _ in range(self.group_size)]
            gen = self._gen(params, jnp.asarray(toks_np), self._next_key())
            comp_np = np.asarray(gen.completion)
            rewards = jnp.asarray(
                [
                    verify(tok.decode(row), ans)
                    for row, ans in zip(comp_np, answers)
                ],
                jnp.float32,
            )
            out.append(ForwardLagBatch(
                gen=gen, rewards=rewards, answers=answers, staleness=k,
            ))
        return out

    def eval_accuracy(self, params: Any, n: Optional[int] = 256) -> float:
        """Greedy-decode exact-match accuracy on the held-out set."""
        from repro.data.mathgen import verify

        toks_np, _, answers = self.dataset.eval_batch(n)
        gen = jax.jit(
            lambda p, t, k: generate(
                self.bundle, p, t, k,
                max_new_tokens=self.max_new_tokens, temperature=1e-4,
            )
        )(params, jnp.asarray(toks_np), self._next_key())
        comp = np.asarray(gen.completion)
        hits = [
            verify(self.dataset.tok.decode(row), ans)
            for row, ans in zip(comp, answers)
        ]
        return float(np.mean(hits))
