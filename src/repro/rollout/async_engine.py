"""Back-compat adapters over the asynchronous actor-learner runtime.

The two phase-locked simulators that used to live here are now thin
veneers over ``repro.runtime`` (versioned :class:`PolicyStore`,
staleness-tagged :class:`TrajectoryQueue`, pluggable lag regimes):

* ``SimulatedAsyncActors`` — the §5.1 backward-lag mixture.  Owns a
  PolicyStore whose ring is the old ``PolicyBuffer`` and a
  :class:`MixtureRolloutProducer` with the identical jitted collect
  graph, so existing runs are bit-for-bit unchanged.
* ``ForwardLagGenerator`` — the §5.2 generate-N/train-N protocol.  Its
  ``generate_minibatch`` is the producer callable the forward_n and
  threaded regimes drive; ``generate_phase`` remains as the legacy
  phase-locked surface.

New code should use ``repro.runtime`` directly (see
``examples/async_runtime.py``).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs.base import Env
from repro.rollout.env_rollout import RolloutBatch
from repro.rollout.sampler import GenerationResult, generate
from repro.runtime.policy_store import PolicyStore


class SimulatedAsyncActors:
    """Policy-ring actors over vectorized pure-JAX environments (adapter)."""

    def __init__(
        self,
        env: Env,
        policy_apply: Callable,
        init_params: Any,
        *,
        n_actors: int,
        buffer_capacity: int,
        rollout_steps: int,
        seed: int = 0,
    ) -> None:
        # Imported here: regimes imports rollout.env_rollout, whose package
        # __init__ re-exports this module (a cycle at import time only).
        from repro.runtime.regimes import MixtureRolloutProducer

        self.env = env
        self.n_actors = n_actors
        self.rollout_steps = rollout_steps
        self.store = PolicyStore(init_params, buffer_capacity)
        self._producer = MixtureRolloutProducer(
            env, policy_apply,
            n_actors=n_actors, rollout_steps=rollout_steps, seed=seed,
        )

    @property
    def buffer(self):
        """The underlying jit-friendly policy ring (legacy attribute)."""
        return self.store.buffer

    def push_policy(self, params: Any) -> int:
        """Learner publishes a new policy snapshot (end of train phase)."""
        return self.store.publish(params)

    def collect(self) -> Tuple[RolloutBatch, jax.Array]:
        """One collection phase: every actor re-samples a stale policy and
        rolls `rollout_steps` steps.  Returns (batch, sampled buffer slots).
        """
        return self._producer(self.store.buffer)


class ForwardLagBatch(NamedTuple):
    gen: GenerationResult
    rewards: jax.Array         # [B] binary verifier rewards
    answers: List[str]
    staleness: int             # updates the learner is ahead when consumed


class RLVRMinibatch(NamedTuple):
    """One generated+verified minibatch — the TrajectoryQueue payload.

    ``versions`` is the per-token producing-policy version ``[B, T]``
    (None when the generator has no version source).  A minibatch is
    generated under one frozen policy so the matrix is constant, but the
    tokenwise TV gate consumes the same ``(tv_tokens, versions)``
    interface for these as for the serve engine's swap-straddling
    trajectories, so the field carries the honest per-token record.
    """

    gen: GenerationResult
    rewards: jax.Array
    answers: List[str]
    versions: Optional[np.ndarray] = None


class ForwardLagGenerator:
    """Serve-side producer for RLVR (§5.2): generation + verification."""

    def __init__(
        self,
        bundle,
        dataset,
        *,
        n_minibatches: int,
        prompts_per_minibatch: int,
        completions_per_prompt: int,
        max_new_tokens: int,
        temperature: float = 1.0,
        seed: int = 0,
        version_fn: Optional[Callable[[], int]] = None,
    ) -> None:
        self.bundle = bundle
        self.dataset = dataset
        self.n_minibatches = n_minibatches
        self.prompts_per_minibatch = prompts_per_minibatch
        self.group_size = completions_per_prompt
        self.max_new_tokens = max_new_tokens
        # Reads the producing policy's version at generation time (the
        # trainer closes this over its PolicyStore); feeds the per-token
        # version record the tokenwise TV gate consumes.  Best-effort:
        # the lag regimes re-stamp the record from their own
        # (params, version) pair at enqueue (regimes._stamp_versions),
        # which closes the publish-during-generation race this read
        # alone would have.
        self.version_fn = version_fn
        self._key = jax.random.PRNGKey(seed)
        # Under the threaded regime, generation (producer thread) and
        # eval (learner thread) share this key chain; split-then-store
        # is not atomic, so serialize it.
        self._key_lock = threading.Lock()

        def _gen(params, prompt_tokens, key):
            return generate(
                bundle, params, prompt_tokens, key,
                max_new_tokens=max_new_tokens, temperature=temperature,
            )

        self._gen = jax.jit(_gen)
        # Greedy eval decode, jitted once at construction (repeated evals
        # must not re-trace).
        self._eval_gen = jax.jit(
            lambda p, t, k: generate(
                bundle, p, t, k,
                max_new_tokens=max_new_tokens, temperature=1e-4,
            )
        )

    def _next_key(self) -> jax.Array:
        with self._key_lock:
            self._key, k = jax.random.split(self._key)
        return k

    def generate_minibatch(self, params: Any) -> RLVRMinibatch:
        """Sample prompts, generate grouped completions, verify rewards.

        This is the producer callable the runtime regimes drive; the key
        chain advances once per call, so N sequential calls reproduce the
        legacy ``generate_phase`` exactly.
        """
        from repro.data.mathgen import verify

        # Read the producing version *before* generating: under the
        # threaded regime a learner publish can land mid-generation, and
        # these tokens were still sampled from the pre-publish params
        # the regime handed us.
        version = (int(self.version_fn())
                   if self.version_fn is not None else None)
        tok = self.dataset.tok
        toks_np, _, answers = self.dataset.sample_batch(
            self.prompts_per_minibatch
        )
        # Group: repeat each prompt G times (GRPO groups contiguous).
        toks_np = np.repeat(toks_np, self.group_size, axis=0)
        answers = [a for a in answers for _ in range(self.group_size)]
        gen = self._gen(params, jnp.asarray(toks_np), self._next_key())
        comp_np = np.asarray(gen.completion)
        rewards = jnp.asarray(
            [
                verify(tok.decode(row), ans)
                for row, ans in zip(comp_np, answers)
            ],
            jnp.float32,
        )
        versions = None
        if version is not None:
            versions = np.full(comp_np.shape, version, np.int64)
        return RLVRMinibatch(gen=gen, rewards=rewards, answers=answers,
                             versions=versions)

    def generate_phase(self, params: Any) -> List[ForwardLagBatch]:
        """Freeze `params` as β and produce N minibatches of labeled data.

        Minibatch k will be trained on after k prior updates — its
        ``staleness`` field records the forward lag at consumption time.
        (Legacy phase-locked surface; the runtime's forward_n regime
        drives ``generate_minibatch`` directly.)
        """
        out: List[ForwardLagBatch] = []
        for k in range(self.n_minibatches):
            mb = self.generate_minibatch(params)
            out.append(ForwardLagBatch(
                gen=mb.gen, rewards=mb.rewards, answers=mb.answers,
                staleness=k,
            ))
        return out

    def eval_accuracy(self, params: Any, n: Optional[int] = 256) -> float:
        """Greedy-decode exact-match accuracy on the held-out set."""
        from repro.data.mathgen import verify

        toks_np, _, answers = self.dataset.eval_batch(n)
        gen = self._eval_gen(
            params, jnp.asarray(toks_np), self._next_key()
        )
        comp = np.asarray(gen.completion)
        hits = [
            verify(self.dataset.tok.decode(row), ans)
            for row, ans in zip(comp, answers)
        ]
        return float(np.mean(hits))
