"""Observability: span tracer, unified metrics registry, exporters.

The lag the paper studies is born somewhere concrete — admission wait,
prefill stall, a speculation rollback, an in-flight weight swap.  This
package makes that visible on a live run:

* ``tracer``   — ring-buffered span/instant/counter collector with
                 monotonic clocks; ``NULL_TRACER`` makes every
                 instrumentation point free when tracing is off.
* ``registry`` — one ``MetricsRegistry`` that ``ServeStats``,
                 ``RuntimeQueueStats`` and the trainers register into;
                 one ``snapshot()`` feeds telemetry, launchers and
                 benchmarks alike.
* ``perfetto`` — Chrome/Perfetto ``trace_event`` JSON + JSONL export,
                 and optional ``jax.profiler`` trace annotations.

``benchmarks/trace_report.py`` turns an exported trace into the
lag-attribution report (time-in-state per request, lag-at-emission
histogram, swap-to-first-stale-token latency).
"""
from repro.obs.perfetto import (
    events_to_trace_json,
    export_perfetto,
    export_trace_jsonl,
    load_trace_events,
    trace_annotation,
)
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.tracer import (
    NULL_TRACER,
    Span,
    TraceEvent,
    Tracer,
    make_tracer,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "Span",
    "TraceEvent",
    "Tracer",
    "events_to_trace_json",
    "export_perfetto",
    "export_trace_jsonl",
    "load_trace_events",
    "make_tracer",
    "trace_annotation",
]
