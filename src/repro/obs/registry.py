"""Unified metrics registry for serve, runtime and training telemetry.

Before this module the repo had three ad-hoc snapshot dicts —
``ServeStats.as_dict()``, ``RuntimeQueueStats.as_dict()`` and the
trainers' per-phase metric dicts — each with its own keys, collection
time and export path.  ``MetricsRegistry`` replaces that with one
sink:

* **Instruments** — :class:`Counter` (monotone), :class:`Gauge` (last
  value) and :class:`Histogram` (bounded raw-sample reservoir with
  exact percentiles over the retained window), all label-aware:
  ``registry.counter("drops", reason="tv_gate").inc()``.
* **Producers** — components that already maintain their own state
  (the engine's ``ServeStats``, the queue's ``RuntimeQueueStats``, a
  trainer) register a ``name -> fn`` producer; ``snapshot()`` calls
  every producer and merges its dict under its name.  Telemetry and
  benchmarks read the *same* snapshot, so they can never disagree.
* **Export** — ``snapshot()`` is a plain JSON-serializable dict;
  :meth:`MetricsRegistry.export_jsonl` appends it atomically as one
  line (see ``metrics.logging.MetricLogger`` for the streaming sink).

Histograms retain raw samples in a bounded deque (default 65536) so
serve-time percentiles (TTFT, inter-token, queue-wait) are exact over
the retained window and benchmarks can take **windowed** readings:
``h.count`` before a run, ``h.percentiles(start=before)`` after —
per-run percentiles from a registry shared across repeats.
"""
from __future__ import annotations

import json
import math
import threading
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
]

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _render(name: str, key: LabelKey) -> str:
    if not key:
        return name
    inner = ",".join(f"{k}={v}" for k, v in key)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        self.value += amount


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Histogram:
    """Bounded raw-sample histogram with exact window percentiles.

    ``count``/``total`` cover every observation ever made; percentile
    queries cover the retained window (the last ``max_samples``
    observations).  ``percentiles(start=n)`` restricts to observations
    made after the ``count`` stood at ``n`` — the benchmark's per-run
    delta read on a shared registry.  Raises no errors on empty
    windows; returns zeros.
    """

    __slots__ = ("samples", "count", "total", "min", "max")

    def __init__(self, max_samples: int = 1 << 16) -> None:
        self.samples: deque = deque(maxlen=max_samples)
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, value: float) -> None:
        value = float(value)
        self.samples.append(value)
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def _window(self, start: Optional[int]) -> List[float]:
        if start is None:
            return list(self.samples)
        fresh = self.count - start
        if fresh <= 0:
            return []
        if fresh >= len(self.samples):
            return list(self.samples)
        return list(self.samples)[-fresh:]

    def percentiles(self, qs: Iterable[float] = (50.0, 90.0, 99.0),
                    start: Optional[int] = None) -> Dict[str, float]:
        """Exact percentiles (nearest-rank) over the retained window,
        or over observations made after ``count == start``."""
        xs = sorted(self._window(start))
        out: Dict[str, float] = {}
        n = len(xs)
        for q in qs:
            label = f"p{q:g}".replace(".", "_")
            if n == 0:
                out[label] = 0.0
            else:
                idx = min(n - 1, max(0, math.ceil(q / 100.0 * n) - 1))
                out[label] = xs[idx]
        return out

    def summary(self, start: Optional[int] = None) -> Dict[str, float]:
        xs = self._window(start)
        n = len(xs)
        base = {
            "count": float(self.count if start is None
                           else max(0, self.count - start)),
            "mean": (sum(xs) / n) if n else 0.0,
            "min": min(xs) if n else 0.0,
            "max": max(xs) if n else 0.0,
        }
        base.update(self.percentiles(start=start))
        return base


class MetricsRegistry:
    """Process-wide (or per-run) metric namespace.

    Thread-safe for instrument creation; instrument mutation itself is
    GIL-atomic (float add / deque append), matching the tracer's
    lock-free hot path.
    """

    def __init__(self) -> None:
        self._counters: Dict[Tuple[str, LabelKey], Counter] = {}
        self._gauges: Dict[Tuple[str, LabelKey], Gauge] = {}
        self._hists: Dict[Tuple[str, LabelKey], Histogram] = {}
        self._producers: Dict[str, Callable[[], Dict[str, Any]]] = {}
        self._lock = threading.Lock()

    # -- instruments ----------------------------------------------------------

    def counter(self, name: str, **labels: Any) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(key, Counter())
        return c

    def gauge(self, name: str, **labels: Any) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(key, Gauge())
        return g

    def histogram(self, name: str, max_samples: int = 1 << 16,
                  **labels: Any) -> Histogram:
        key = (name, _label_key(labels))
        h = self._hists.get(key)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(
                    key, Histogram(max_samples=max_samples))
        return h

    # -- producers ------------------------------------------------------------

    def register_producer(self, name: str,
                          fn: Callable[[], Dict[str, Any]]) -> None:
        """``snapshot()[name] = fn()`` — components that keep their own
        stats (ServeStats, RuntimeQueueStats, trainers) plug in here.
        Re-registering a name replaces the producer (engines are
        rebuilt across benchmark repeats)."""
        with self._lock:
            self._producers[name] = fn

    def unregister_producer(self, name: str) -> None:
        with self._lock:
            self._producers.pop(name, None)

    def counter_values(self, *names: str) -> Dict[str, float]:
        """Rendered ``{name{labels}: value}`` for counters whose metric
        name is in ``names`` (all counters when empty).  Unlike
        :meth:`snapshot` this never invokes producers, so stats
        producers may call it without recursing into themselves."""
        with self._lock:
            counters = list(self._counters.items())
        return {
            _render(n, k): c.value
            for (n, k), c in counters
            if not names or n in names
        }

    # -- export ---------------------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        """One merged dict: producer sections by name, then
        ``counters`` / ``gauges`` / ``histograms`` sections keyed by
        rendered metric name (labels inline, Prometheus-style)."""
        out: Dict[str, Any] = {}
        with self._lock:
            producers = list(self._producers.items())
            counters = list(self._counters.items())
            gauges = list(self._gauges.items())
            hists = list(self._hists.items())
        for name, fn in producers:
            out[name] = fn()
        if counters:
            out["counters"] = {
                _render(n, k): c.value for (n, k), c in counters}
        if gauges:
            out["gauges"] = {
                _render(n, k): g.value for (n, k), g in gauges}
        if hists:
            out["histograms"] = {
                _render(n, k): h.summary() for (n, k), h in hists}
        return out

    def export_jsonl(self, path: str, **extra: Any) -> Dict[str, Any]:
        """Append one atomic JSONL line holding ``snapshot()`` (+extra).

        The full line is encoded first and handed to the kernel as a
        single unbuffered write, so a crash mid-export can't leave a
        truncated row."""
        snap = self.snapshot()
        snap.update(extra)
        data = (json.dumps(snap) + "\n").encode("utf-8")
        with open(path, "ab", buffering=0) as f:
            f.write(data)
        return snap
