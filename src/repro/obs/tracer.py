"""Low-overhead span tracer for the serve/runtime request lifecycle.

Design constraints, in order:

1. **Zero cost when disabled.**  Every instrumented call site holds a
   ``Tracer`` reference that is :data:`NULL_TRACER` by default — a
   no-op singleton whose methods do nothing and whose ``enabled`` /
   ``full`` flags are ``False`` so hot paths can skip even building the
   args dict.  No ``if tracer is not None`` branches at call sites.
2. **Low overhead when enabled.**  Events are plain tuples appended to
   a bounded ``collections.deque`` (``maxlen`` ring: old events fall
   off, tracing never OOMs a long run).  Timestamps come from
   ``time.monotonic_ns()`` relative to the tracer's epoch — monotonic,
   immune to wall-clock steps, cheap.  ``deque.append`` is atomic under
   the GIL, so runtime producer threads and the engine thread share one
   tracer without a lock on the hot path.
3. **Perfetto-shaped.**  Events carry the Chrome ``trace_event``
   phases directly: ``B``/``E`` sync spans nest per track, ``b``/``e``
   async spans (keyed by an id) model per-request lifecycle states
   that overlap arbitrarily across requests, ``i`` instants, ``C``
   counter samples.  ``obs.perfetto`` serializes them 1:1.

Tracks are ``(pid, tid)`` *string* pairs — e.g. ``("serve",
"slot0")``, ``("runtime", "producer")`` — mapped to integer ids at
export time, with metadata naming events emitted for Perfetto's UI.

Detail levels (``--trace-detail``):

* ``off``   — tracer disabled entirely (``NULL_TRACER`` semantics).
* ``spans`` — lifecycle spans, dispatch spans, instants, counters.
* ``full``  — adds per-token instant events (rid, version, lag): the
  provenance stream ``benchmarks/trace_report.py`` builds its
  lag-at-emission histogram from.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Tuple

__all__ = [
    "NULL_TRACER",
    "Span",
    "TraceEvent",
    "Tracer",
    "make_tracer",
]

DETAIL_LEVELS = ("off", "spans", "full")


@dataclass(frozen=True)
class TraceEvent:
    """One trace event; field names follow Chrome ``trace_event``.

    ``ts`` is nanoseconds since the tracer epoch (exporters convert to
    the format's microseconds).  ``pid``/``tid`` are symbolic track
    names.  ``id`` is set only for async (``b``/``e``) events.
    """

    ph: str                      # B E b e i C
    name: str
    ts: int                      # ns since tracer epoch
    pid: str
    tid: str
    args: Optional[Dict[str, Any]] = None
    id: Optional[int] = None     # async-span correlation id


class Span:
    """Context manager closing a sync span on exit (exceptions too)."""

    __slots__ = ("_tracer", "_name", "_pid", "_tid")

    def __init__(self, tracer: "Tracer", name: str, pid: str,
                 tid: str) -> None:
        self._tracer = tracer
        self._name = name
        self._pid = pid
        self._tid = tid

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self._tracer.end(self._name, self._pid, self._tid)


class Tracer:
    """Ring-buffered host-side trace collector.

    One instance is shared by the serve engine, scheduler, allocator,
    runtime store/queue and trainer; they address disjoint tracks, so
    a single export shows the full end-to-end picture.
    """

    enabled: bool = True

    def __init__(self, capacity: int = 1 << 16,
                 detail: str = "spans") -> None:
        if detail not in DETAIL_LEVELS:
            raise ValueError(
                f"detail must be one of {DETAIL_LEVELS}, got {detail!r}")
        if detail == "off":
            raise ValueError(
                "detail='off' means no tracer: use NULL_TRACER")
        self.detail = detail
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=self.capacity)
        self._epoch_ns = time.monotonic_ns()
        self._dropped = 0
        self._lock = threading.Lock()   # only for clear()/drain races

    # -- clocks ---------------------------------------------------------------

    @property
    def full(self) -> bool:
        """True when per-token events should be emitted."""
        return self.detail == "full"

    def now(self) -> int:
        """ns since the tracer epoch (monotonic)."""
        return time.monotonic_ns() - self._epoch_ns

    def to_trace_ns(self, monotonic_s: float) -> int:
        """Convert a ``time.monotonic()`` stamp (seconds) into this
        tracer's timebase — lets pre-recorded stamps like
        ``Request.submit_time`` land on the same axis."""
        return int(monotonic_s * 1e9) - self._epoch_ns

    # -- emission -------------------------------------------------------------

    def _emit(self, ev: TraceEvent) -> None:
        if len(self._events) == self._events.maxlen:
            self._dropped += 1
        self._events.append(ev)

    def begin(self, name: str, pid: str = "serve", tid: str = "engine",
              ts: Optional[int] = None, **args: Any) -> None:
        """Open a sync span on track (pid, tid); must nest."""
        self._emit(TraceEvent("B", name, self.now() if ts is None else ts,
                              pid, tid, args or None))

    def end(self, name: str, pid: str = "serve", tid: str = "engine",
            ts: Optional[int] = None, **args: Any) -> None:
        self._emit(TraceEvent("E", name, self.now() if ts is None else ts,
                              pid, tid, args or None))

    def span(self, name: str, pid: str = "serve",
             tid: str = "engine", **args: Any) -> Span:
        """``with tracer.span("decode", tid="engine"): ...``"""
        self.begin(name, pid, tid, **args)
        return Span(self, name, pid, tid)

    def async_begin(self, name: str, aid: int, pid: str = "serve",
                    tid: str = "requests", ts: Optional[int] = None,
                    **args: Any) -> None:
        """Open an async span keyed by ``aid`` (request lifecycles:
        many requests overlap, so they can't nest on one track)."""
        self._emit(TraceEvent("b", name, self.now() if ts is None else ts,
                              pid, tid, args or None, id=aid))

    def async_end(self, name: str, aid: int, pid: str = "serve",
                  tid: str = "requests", ts: Optional[int] = None,
                  **args: Any) -> None:
        self._emit(TraceEvent("e", name, self.now() if ts is None else ts,
                              pid, tid, args or None, id=aid))

    def instant(self, name: str, pid: str = "serve",
                tid: str = "engine", ts: Optional[int] = None,
                **args: Any) -> None:
        self._emit(TraceEvent("i", name, self.now() if ts is None else ts,
                              pid, tid, args or None))

    def counter(self, name: str, pid: str = "serve",
                tid: str = "counters", ts: Optional[int] = None,
                **values: float) -> None:
        """Sample counter series (one Perfetto counter track per name,
        one series per kwarg)."""
        self._emit(TraceEvent("C", name, self.now() if ts is None else ts,
                              pid, tid, dict(values)))

    # -- access ---------------------------------------------------------------

    def events(self) -> List[TraceEvent]:
        """Snapshot of the ring (oldest first)."""
        with self._lock:
            return list(self._events)

    @property
    def dropped(self) -> int:
        """Events evicted from the ring since construction."""
        return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self) -> Iterator[TraceEvent]:
        return iter(self.events())


class _NullSpan:
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class _NullTracer(Tracer):
    """Do-nothing tracer: the default at every instrumentation point.

    Methods are overridden to plain no-ops (no ring, no clock reads),
    so instrumented code pays one attribute lookup + an empty call when
    tracing is off — and call sites can skip even that by checking
    ``tracer.enabled`` before assembling args.
    """

    enabled = False

    def __init__(self) -> None:   # noqa: D401 - deliberately no super()
        self.detail = "off"
        self.capacity = 0
        self._events = deque(maxlen=0)
        self._dropped = 0
        self._epoch_ns = 0
        self._lock = threading.Lock()

    @property
    def full(self) -> bool:
        return False

    def begin(self, *a: Any, **k: Any) -> None:
        pass

    def end(self, *a: Any, **k: Any) -> None:
        pass

    def span(self, *a: Any, **k: Any) -> _NullSpan:  # type: ignore[override]
        return _NULL_SPAN

    def async_begin(self, *a: Any, **k: Any) -> None:
        pass

    def async_end(self, *a: Any, **k: Any) -> None:
        pass

    def instant(self, *a: Any, **k: Any) -> None:
        pass

    def counter(self, *a: Any, **k: Any) -> None:
        pass


NULL_TRACER = _NullTracer()


def make_tracer(detail: str = "spans",
                capacity: int = 1 << 16) -> Tracer:
    """``detail='off'`` returns :data:`NULL_TRACER`; anything else a
    live :class:`Tracer` — the one switch launchers need."""
    if detail not in DETAIL_LEVELS:
        raise ValueError(
            f"detail must be one of {DETAIL_LEVELS}, got {detail!r}")
    if detail == "off":
        return NULL_TRACER
    return Tracer(capacity=capacity, detail=detail)
