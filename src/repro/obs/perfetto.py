"""Trace exporters: Chrome/Perfetto ``trace_event`` JSON + JSONL.

``export_perfetto`` writes the classic ``{"traceEvents": [...]}``
format that both ``chrome://tracing`` and https://ui.perfetto.dev load
directly: symbolic ``(pid, tid)`` track names become integer ids with
``M`` (metadata) naming events, sync ``B``/``E`` spans nest per track,
async ``b``/``e`` spans (one per request lifecycle state) correlate by
id + category, ``C`` events render as counter tracks (pool occupancy,
queue depth, live policy lag).

``export_trace_jsonl`` is the grep-able flat form (one event per
line); ``benchmarks/trace_report.py`` reads either.

``trace_annotation`` wraps ``jax.profiler.TraceAnnotation`` when the
installed jax has it — so a ``jax.profiler.trace()`` capture taken
around a serve run shows the engine's dispatch names on the device
timeline — and degrades to a no-op context otherwise.
"""
from __future__ import annotations

import contextlib
import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

from repro.obs.tracer import TraceEvent, Tracer

__all__ = [
    "events_to_trace_json",
    "export_perfetto",
    "export_trace_jsonl",
    "load_trace_events",
    "trace_annotation",
]

# Async spans need a category for id-scoping in the trace_event spec.
_ASYNC_CAT = "request"


def _resolve(events_or_tracer: Union[Tracer, Sequence[TraceEvent]]
             ) -> List[TraceEvent]:
    if isinstance(events_or_tracer, Tracer):
        return events_or_tracer.events()
    return list(events_or_tracer)


def events_to_trace_json(
        events_or_tracer: Union[Tracer, Sequence[TraceEvent]],
        extra_metadata: Optional[Dict[str, Any]] = None
) -> Dict[str, Any]:
    """Build the ``{"traceEvents": [...]}`` dict (pure; no I/O)."""
    events = _resolve(events_or_tracer)
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}
    out: List[Dict[str, Any]] = []

    def pid_of(name: str) -> int:
        pid = pids.get(name)
        if pid is None:
            pid = pids[name] = len(pids) + 1
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0, "args": {"name": name}})
        return pid

    def tid_of(pname: str, tname: str) -> int:
        key = (pname, tname)
        tid = tids.get(key)
        if tid is None:
            tid = tids[key] = len(tids) + 1
            out.append({"ph": "M", "name": "thread_name",
                        "pid": pid_of(pname), "tid": tid,
                        "args": {"name": tname}})
        return tid

    for ev in events:
        rec: Dict[str, Any] = {
            "ph": ev.ph,
            "name": ev.name,
            "ts": ev.ts / 1e3,            # ns -> trace_event µs
            "pid": pid_of(ev.pid),
            "tid": tid_of(ev.pid, ev.tid),
        }
        if ev.args:
            rec["args"] = ev.args
        if ev.ph in ("b", "e", "n"):
            rec["cat"] = _ASYNC_CAT
            rec["id"] = ev.id
        elif ev.ph == "i":
            rec["s"] = "t"                # thread-scoped instant
        out.append(rec)
    meta: Dict[str, Any] = {"displayTimeUnit": "ms"}
    if extra_metadata:
        meta["metadata"] = extra_metadata
    meta["traceEvents"] = out
    return meta


def export_perfetto(
        events_or_tracer: Union[Tracer, Sequence[TraceEvent]],
        path: str,
        extra_metadata: Optional[Dict[str, Any]] = None) -> int:
    """Write Perfetto-loadable JSON; returns the event count."""
    doc = events_to_trace_json(events_or_tracer, extra_metadata)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)
    return sum(1 for e in doc["traceEvents"] if e["ph"] != "M")


def export_trace_jsonl(
        events_or_tracer: Union[Tracer, Sequence[TraceEvent]],
        path: str) -> int:
    """One raw event per line (symbolic tracks kept; ts stays ns)."""
    events = _resolve(events_or_tracer)
    lines = []
    for ev in events:
        rec: Dict[str, Any] = {"ph": ev.ph, "name": ev.name,
                               "ts": ev.ts, "pid": ev.pid, "tid": ev.tid}
        if ev.args:
            rec["args"] = ev.args
        if ev.id is not None:
            rec["id"] = ev.id
        lines.append(json.dumps(rec))
    data = ("\n".join(lines) + "\n") if lines else ""
    with open(path, "w", encoding="utf-8") as f:
        f.write(data)
    return len(events)


def load_trace_events(path: str) -> List[Dict[str, Any]]:
    """Load either export format back into a flat list of event dicts
    with ``ts`` in microseconds (metadata events dropped).

    Perfetto JSON keeps its integer pid/tid; JSONL keeps symbolic
    names and converts ns -> µs, so a report reads both identically.
    """
    with open(path, "r", encoding="utf-8") as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        doc = None                    # multi-line JSONL (or garbage)
    if isinstance(doc, dict) and "traceEvents" in doc:
        return [e for e in doc["traceEvents"] if e.get("ph") != "M"]
    events = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        rec = json.loads(line)
        rec["ts"] = rec["ts"] / 1e3
        events.append(rec)
    return events


@contextlib.contextmanager
def trace_annotation(name: str):
    """``jax.profiler.TraceAnnotation`` when available, else no-op."""
    ann = None
    try:
        import jax.profiler as _prof
        ann = getattr(_prof, "TraceAnnotation", None)
    except Exception:
        ann = None
    if ann is None:
        yield
        return
    with ann(name):
        yield
