"""msgpack-based pytree checkpointing (orbax/flax serialization absent).

Layout: a single ``<step>.ckpt`` file per save containing
    {"meta": {...}, "tree": <structure>, "leaves": [raw buffers]}
Arrays are stored as (dtype, shape, bytes) triples; the tree structure is
recorded via jax.tree flatten-with-path so restoration does not need an
example pytree (but can verify against one when given).
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode_leaf(x) -> Dict[str, Any]:
    arr = np.asarray(jax.device_get(x))
    return {
        b"dtype": arr.dtype.str.encode(),
        b"shape": list(arr.shape),
        b"data": arr.tobytes(),
    }


def _decode_leaf(d: Dict[bytes, Any]) -> np.ndarray:
    arr = np.frombuffer(d[b"data"], dtype=np.dtype(d[b"dtype"].decode()))
    return arr.reshape(d[b"shape"])


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def save_checkpoint(
    directory: str, step: int, tree: Any, meta: Optional[Dict] = None
) -> str:
    """Serialize `tree` to `<directory>/<step>.ckpt`. Returns the path."""
    os.makedirs(directory, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = {
        b"meta": {k.encode(): v for k, v in (meta or {}).items()},
        b"step": step,
        b"leaves": {
            _path_str(p).encode(): _encode_leaf(x)
            for p, x in leaves_with_paths
        },
    }
    path = os.path.join(directory, f"{step}.ckpt")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload))
    os.replace(tmp, path)
    return path


def load_checkpoint(path: str, like: Any):
    """Restore into the structure of `like` (shapes/dtypes verified).

    Returns (tree, step, meta).
    """
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), strict_map_key=False)
    stored = {
        k.decode() if isinstance(k, bytes) else k: v
        for k, v in payload[b"leaves"].items()
    }
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for p, x in leaves_with_paths:
        key = _path_str(p)
        if key not in stored:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = _decode_leaf(stored[key])
        want = np.asarray(x)
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(
                f"shape mismatch at {key}: {arr.shape} vs {want.shape}"
            )
        new_leaves.append(jnp.asarray(arr).astype(want.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    meta = {
        (k.decode() if isinstance(k, bytes) else k): v
        for k, v in payload[b"meta"].items()
    }
    return tree, int(payload[b"step"]), meta


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(f[:-5]) for f in os.listdir(directory) if f.endswith(".ckpt")
    ]
    if not steps:
        return None
    return os.path.join(directory, f"{max(steps)}.ckpt")
