from repro.checkpoint.checkpoint import (
    latest_checkpoint,
    load_checkpoint,
    save_checkpoint,
)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_checkpoint"]
