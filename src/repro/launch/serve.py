"""Serving launcher: batched completion generation against a reduced
assigned architecture (the actor side of the async RLVR loop).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-0.5b \\
      --batch 8 --max-new-tokens 16

Loads a checkpoint when given (--checkpoint), else serves random init —
the point on this host is exercising the prefill + KV-cache decode
engine; on TPU the same ``generate`` runs under the production mesh with
the serve_step shardings proven by the dry-run.

``--runtime versioned`` routes the weights through the async runtime's
versioned PolicyStore — the serve loop pulls ``store.latest()`` exactly
like the threaded regime's producer does, and reports the policy version
it served so generated data can be staleness-tagged downstream.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-0.5b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--level", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--runtime", default="direct",
                    choices=["direct", "versioned"],
                    help="versioned: serve through the PolicyStore "
                         "(staleness-taggable actor side of the runtime)")
    args = ap.parse_args(argv)

    from repro.configs import reduced_config
    from repro.data.mathgen import MathTaskDataset, verify
    from repro.data.tokenizer import get_tokenizer
    from repro.models.registry import build
    from repro.rollout.sampler import generate
    from repro.checkpoint import load_checkpoint

    tok = get_tokenizer()
    cfg = reduced_config(args.arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    init_params = bundle.init(jax.random.PRNGKey(args.seed))
    params = init_params
    if args.checkpoint:
        params, step, meta = load_checkpoint(args.checkpoint, params)
        print(f"loaded checkpoint step={step} meta={meta}")

    behavior_version = None
    if args.runtime == "versioned":
        from repro.runtime import PolicyStore

        # v0 is the true random init; the checkpoint (if any) becomes v1.
        store = PolicyStore(init_params, capacity=2,
                            meta={"source": "init"})
        if args.checkpoint:
            store.publish(params, source="checkpoint",
                          checkpoint=args.checkpoint)
        params, behavior_version = store.latest()
        print(f"serving policy version {behavior_version} "
              f"(retained: {store.retained_versions()})")

    ds = MathTaskDataset(prompt_len=32, level=args.level,
                         seed=args.seed + 1)
    toks_np, prompts, answers = ds.sample_batch(args.batch)

    gen_fn = jax.jit(lambda p, t, k: generate(
        bundle, p, t, k, max_new_tokens=args.max_new_tokens,
        temperature=args.temperature, top_p=args.top_p,
    ))
    # warm + timed call (measures the jitted serve loop on this host).
    key = jax.random.PRNGKey(args.seed + 2)
    res = gen_fn(params, jnp.asarray(toks_np), key)
    jax.block_until_ready(res.tokens)
    t0 = time.time()
    res = gen_fn(params, jnp.asarray(toks_np), key)
    jax.block_until_ready(res.tokens)
    dt = time.time() - t0
    n_tok = args.batch * args.max_new_tokens
    tag = ("" if behavior_version is None
           else f" [policy v{behavior_version}]")
    print(f"decode: {n_tok} tokens in {dt*1e3:.1f} ms "
          f"({n_tok/dt:.0f} tok/s on this host){tag}")

    comp = np.asarray(res.completion)
    for i in range(min(args.batch, 8)):
        text = tok.decode(comp[i])
        r = verify(text, answers[i])
        print(f"  [{i}] {prompts[i]!r} -> {text!r} "
              f"(gold {answers[i]}, reward {r})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
