"""Serving launcher: completion generation against a reduced assigned
architecture (the actor side of the async RLVR loop).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-0.5b \\
      --engine continuous --requests 12 --mixed-lengths 4,8,16,32

Two engines:

* ``--engine static`` — the phase-locked fixed-batch ``generate()``
  loop (prefill + lax.scan decode): every request waits for the
  slowest row.  Kept as the baseline/fallback.
* ``--engine continuous`` — the ``repro.serve`` continuous-batching
  engine: paged KV cache, per-request admission/retire between decode
  steps, and (with ``--runtime versioned``) in-flight weight swap from
  the PolicyStore.

Loads a checkpoint when given (--checkpoint), else serves random init —
the point on this host is exercising the serve engines; on TPU the same
paths run under the production mesh with the serve_step shardings
proven by the dry-run.

``--runtime versioned`` routes the weights through the async runtime's
versioned PolicyStore and reports the served policy version **per
request** (a continuous-batching request may straddle versions; its
summary shows the span, e.g. ``v0->v1``).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def _version_tag(versions) -> str:
    """Human summary of the per-token version vector of one request."""
    uniq = sorted(set(int(v) for v in versions))
    if len(uniq) == 1:
        return f"v{uniq[0]}"
    return f"v{uniq[0]}->v{uniq[-1]}"


def _serve_static(args, bundle, params, store, tok, prompts_np, answers):
    from repro.data.mathgen import verify
    from repro.rollout.sampler import generate

    behavior_version = None
    if store is not None:
        params, behavior_version = store.latest()
        print(f"serving policy version {behavior_version} "
              f"(retained: {store.retained_versions()})")
    gen_fn = jax.jit(lambda p, t, k: generate(
        bundle, p, t, k, max_new_tokens=args.max_new_tokens,
        temperature=args.temperature, top_p=args.top_p,
    ))
    key = jax.random.PRNGKey(args.seed + 2)
    res = gen_fn(params, jnp.asarray(prompts_np), key)   # warm
    jax.block_until_ready(res.tokens)
    t0 = time.time()
    res = gen_fn(params, jnp.asarray(prompts_np), key)
    jax.block_until_ready(res.tokens)
    dt = time.time() - t0
    n_tok = prompts_np.shape[0] * args.max_new_tokens
    tag = ("" if behavior_version is None
           else f" [policy v{behavior_version}]")
    print(f"decode: {n_tok} tokens in {dt*1e3:.1f} ms "
          f"({n_tok/dt:.0f} tok/s on this host){tag}")
    comp = np.asarray(res.completion)
    for i in range(min(len(answers), 8)):
        text = tok.decode(comp[i])
        r = verify(text, answers[i])
        vtag = ("" if behavior_version is None
                else f" [policy v{behavior_version}]")
        print(f"  [{i}] -> {text!r} (gold {answers[i]}, reward {r}){vtag}")


def _parse_draft(spec: str, args, bundle, params, tok):
    """--draft grammar: ``version:-n`` (self-speculation from the
    PolicyStore ring), ``model:<arch>`` (small registry draft model),
    ``self`` (verifier's own params; accept-all ceiling)."""
    import jax as _jax

    if spec.startswith("version:"):
        return ("version", int(spec.split(":", 1)[1]))
    if spec.startswith("model:"):
        from repro.configs import reduced_config
        from repro.models.registry import build

        dcfg = reduced_config(spec.split(":", 1)[1], vocab=tok.vocab_size)
        dbundle = build(dcfg)
        dparams = dbundle.init(_jax.random.PRNGKey(args.seed + 7))
        return ("model", dbundle, dparams)
    if spec == "self":
        return ("params", params)
    raise SystemExit(f"--draft {spec!r}: want version:-n, model:<arch> "
                     "or self")


def _shadow_admission(args, engine, store, bundle, trajs):
    """Replay retired trajectories through a lag controller's admission
    hook — verdict-only (nothing is removed from the serve output), so
    operators can preview what a trainer-side ``--controller`` would do
    to this traffic before wiring it into a training run.

    tv_gate scores each request's completion against the *latest*
    policy (the store head under ``--runtime versioned``, else the
    engine's params); tv_gate_tokenwise additionally segments by the
    request's own per-token version record, so mid-swap requests get
    the per-segment Eq. 8 treatment.  Verdicts land on the engine's
    metrics registry as
    ``serve_shadow_admission_total{controller,outcome,reason}``.
    """
    from repro.core.tv_filter import tv_estimate
    from repro.rollout.sampler import score_tokens
    from repro.runtime import make_controller, parse_controller_spec
    from repro.runtime.queue import TrajectoryItem

    spec = parse_controller_spec(args.controller)
    ref_version = store.version if store is not None else engine.version

    def _score(traj):
        params = store.latest()[0] if store is not None else engine.params
        prompt = np.asarray(traj.prompt)
        row = np.concatenate([prompt, np.asarray(traj.tokens)])
        log_pi, _, _ = score_tokens(
            bundle, params, jnp.asarray(row)[None, :], len(prompt))
        return log_pi

    def tv_fn(traj):
        log_pi = _score(traj)
        return float(tv_estimate(
            log_pi - jnp.asarray(traj.log_beta)[None, :],
            jnp.asarray(traj.mask)[None, :]))

    def token_tv_fn(traj):
        log_pi = np.asarray(_score(traj))[0]
        tv = 0.5 * np.abs(np.exp(log_pi - np.asarray(traj.log_beta)) - 1.0)
        valid = np.asarray(traj.mask) > 0
        return tv[valid], np.asarray(traj.versions)[valid]

    controller = make_controller(spec, tv_fn=tv_fn,
                                 token_tv_fn=token_tv_fn)
    counts = {}
    for t in trajs:
        versions = np.asarray(t.versions)
        oldest = int(versions.min()) if versions.size else ref_version
        newest = int(versions.max()) if versions.size else ref_version
        item = TrajectoryItem(
            payload=t, behavior_version=oldest,
            enqueue_learner_version=ref_version,
            behavior_version_newest=newest,
        )
        item.learner_version_at_consume = ref_version
        d = controller.admit(item)
        outcome = ("drop" if not d.admit
                   else "admit" if d.weight == 1.0 else "downweight")
        counts[(outcome, d.reason)] = counts.get((outcome, d.reason), 0) + 1
        engine.metrics.counter(
            "serve_shadow_admission_total", controller=controller.name,
            outcome=outcome, reason=d.reason).inc()
    total = len(trajs)
    print(f"  shadow controller {spec.canonical()!r} over {total} "
          f"retired requests (verdict-only, nothing dropped):")
    for (outcome, reason), n in sorted(counts.items()):
        print(f"    {outcome:<10} reason={reason:<24} {n}/{total}")


def _serve_continuous(args, bundle, params, store, tok, ds, mesh=None,
                      tracer=None, flush_state=None):
    from repro.data.mathgen import verify
    from repro.serve import ServeEngine

    lengths = [int(x) for x in args.mixed_lengths.split(",")] \
        if args.mixed_lengths else [args.max_new_tokens]
    draft = None
    if args.speculate:
        draft = _parse_draft(args.draft, args, bundle, params, tok)
    engine = ServeEngine(
        bundle, params if store is None else None, store=store,
        num_blocks=args.num_blocks, block_size=args.block_size,
        max_batch=args.max_batch, max_seq_len=args.max_seq_len,
        decode_chunk=args.decode_chunk,
        swap_interval=args.swap_interval, temperature=args.temperature,
        top_p=args.top_p, seed=args.seed + 2,
        speculate_k=args.speculate, draft=draft,
        batch_prefill=not args.no_batch_prefill,
        chunked_prefill=not args.no_chunked_prefill,
        prefill_chunk=args.prefill_chunk,
        dispatch_budget=args.dispatch_budget,
        mesh=mesh, speculate_adaptive=args.speculate_adaptive,
        prefix_cache=args.prefix_cache,
        tracer=tracer, annotate=args.profiler_annotations,
    )
    if flush_state is not None:
        flush_state["metrics"] = engine.metrics
    toks_np, prompts, answers = ds.sample_batch(args.requests)
    meta = {}
    for i in range(args.requests):
        row = toks_np[i]
        row = row[row != tok.pad_id]            # ragged: true prompt only
        for _ in range(max(args.best_of, 1)):
            req = engine.submit(row, lengths[i % len(lengths)])
            meta[req.request_id] = (prompts[i], answers[i])
    t0 = time.time()
    trajs = engine.run(max_steps=args.max_steps)
    dt = time.time() - t0
    from repro.metrics.runtime_metrics import collect_serve_stats

    stats = collect_serve_stats(engine)
    n_tok = stats["tokens_out"]
    print(f"continuous decode: {n_tok} tokens / {len(trajs)} requests in "
          f"{dt*1e3:.1f} ms ({n_tok/dt:.0f} tok/s on this host)")
    lat_tag = "latency n/a (nothing retired; raise --max-steps)"
    if stats["request_latency_count"]:
        lat_tag = (f"latency p50 {stats['request_latency_p50_ms']:.1f} ms "
                   f"p99 {stats['request_latency_p99_ms']:.1f} ms")
    print(f"  occupancy {stats['mean_occupancy']:.2f}/{args.max_batch}, "
          f"prefills {stats['prefills']} "
          f"({stats['prefill_dispatches']} dispatches), "
          f"preemptions {stats['preemptions']}, swaps {stats['swaps']}, "
          f"{lat_tag}")
    if stats["ttft_count"]:
        print(f"  ttft p50 {stats['ttft_p50_ms']:.1f} ms "
              f"p99 {stats['ttft_p99_ms']:.1f} ms, inter-token p50 "
              f"{stats['inter_token_p50_ms']:.2f} ms p99 "
              f"{stats['inter_token_p99_ms']:.2f} ms, queue-wait p50 "
              f"{stats['queue_wait_p50_ms']:.1f} ms")
    if stats.get("num_shards", 1) > 1:
        print(f"  sharded over {stats['num_shards']} shards: "
              f"free pages by shard {stats['pool_free_by_shard']}, "
              f"live slots by shard {stats['live_slots_by_shard']}")
    if stats.get("prefix_cache"):
        print(f"  prefix cache: hit rate "
              f"{stats['prefix_hit_rate']:.2f} "
              f"({stats['prefix_hits']}/{stats['prefix_queries']} "
              f"admissions), token hit rate "
              f"{stats['prefix_token_hit_rate']:.2f} "
              f"({stats['prefix_matched_tokens']} matched / "
              f"{stats['prefill_tokens']} computed), "
              f"cow copies {stats['cow_copies']}, "
              f"cached pages {stats['cached_pages']}, "
              f"evictions {stats['cache_evictions']}")
    if "reclaimed_window_pages" in stats:
        print(f"  window reclamation (W={stats['reclaim_window']}): "
              f"{stats['reclaimed_window_pages']} pages released")
    if args.speculate:
        dv = stats.get("draft_version")
        dtag = ("oracle/callable" if dv is None and engine.draft is not None
                and not hasattr(engine.draft, "pages")
                else f"v{dv}" if dv is not None else "fixed-params")
        print(f"  speculative k={args.speculate}: acceptance "
              f"{stats['acceptance_rate']:.2f} "
              f"({stats['accepted_tokens']}/{stats['drafted_tokens']} "
              f"drafted), draft {dtag}, lag hist "
              f"{stats.get('draft_version_lag_histogram', {})}")
        if args.speculate_adaptive:
            print(f"  adaptive k in [1, {args.speculate}]: chosen-k "
                  f"histogram {stats.get('chosen_k_histogram', {})}")
    for t in sorted(trajs, key=lambda t: t.request_id)[:8]:
        prompt_text, ans = meta[t.request_id]
        text = tok.decode(t.tokens)
        r = verify(text, ans)
        vtag = ("" if store is None
                else f" [policy {_version_tag(t.versions)}]")
        print(f"  [{t.request_id}] -> {text!r} ({t.num_tokens} tok, "
              f"{t.finish_reason}, gold {ans}, reward {r}){vtag}")
    if args.controller:
        _shadow_admission(args, engine, store, bundle, trajs)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2.5-0.5b")
    ap.add_argument("--engine", default="static",
                    choices=["static", "continuous"],
                    help="static: phase-locked batch generate(); "
                         "continuous: paged-KV continuous batching")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--requests", type=int, default=None,
                    help="continuous: total requests (default --batch)")
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--mixed-lengths", default=None,
                    help="continuous: comma list of per-request "
                         "max-new-tokens, cycled (e.g. 4,8,16,32)")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="continuous: decode slots")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=128)
    ap.add_argument("--max-seq-len", type=int, default=256)
    ap.add_argument("--max-steps", type=int, default=10_000)
    ap.add_argument("--decode-chunk", type=int, default=4,
                    help="continuous: decode steps per dispatch "
                         "(scheduling happens between chunks)")
    ap.add_argument("--speculate", type=int, default=0,
                    help="continuous: speculative-decode draft length k "
                         "(0 = off); k drafted tokens are verified in "
                         "one multi-token dispatch")
    ap.add_argument("--draft", default="version:-1",
                    help="draft policy: version:-n (self-speculation "
                         "from the PolicyStore, needs --runtime "
                         "versioned), model:<arch> (small registry "
                         "draft), self (verifier params; accept-all)")
    ap.add_argument("--speculate-adaptive", action="store_true",
                    help="continuous: adapt the per-round draft length "
                         "in [1, --speculate] from each slot's measured "
                         "acceptance-rate EMA")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="continuous: content-address full KV pages and "
                         "share resident prompt prefixes across requests "
                         "(refcounted read-only pages + copy-on-write); "
                         "prefill runs only the unmatched suffix")
    ap.add_argument("--best-of", type=int, default=1,
                    help="continuous: submit each prompt N times "
                         "(best-of-N fan-out — the access pattern "
                         "--prefix-cache collapses to ~1x prefill)")
    ap.add_argument("--no-batch-prefill", action="store_true",
                    help="continuous: prefill admissions one dispatch "
                         "per request (default stacks same-padded-"
                         "length admissions)")
    ap.add_argument("--no-chunked-prefill", action="store_true",
                    help="continuous: disable chunked ragged prefill "
                         "and fall back to the DEPRECATED batched "
                         "prefill path (one blocking dispatch per "
                         "padded-length group)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="continuous: rows per prefill tile in the "
                         "unified varlen dispatch (chunked prefill)")
    ap.add_argument("--dispatch-budget", type=int, default=32,
                    help="continuous: max tokens per unified dispatch "
                         "while prefills are pending — decode rows are "
                         "reserved first, the rest goes to prefill "
                         "tiles (bounds inter-token latency under "
                         "long-prompt bursts)")
    ap.add_argument("--mesh", default=None,
                    help="shard the serve path over a device mesh, e.g. "
                         "'data=2': the paged pool partitions its page "
                         "axis, requests are placed per shard (CPU "
                         "hosts: set XLA_FLAGS=--xla_force_host_"
                         "platform_device_count=N first)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write an execution trace of the run: .json -> "
                         "Chrome/Perfetto trace_event format (load in "
                         "ui.perfetto.dev), .jsonl -> flat event lines; "
                         "either feeds benchmarks/trace_report.py")
    ap.add_argument("--trace-detail", default="spans",
                    choices=["off", "spans", "full"],
                    help="off: no tracer (zero overhead); spans: request "
                         "lifecycle + dispatch spans + counter tracks; "
                         "full: adds a per-emitted-token instant with "
                         "version/lag provenance")
    ap.add_argument("--profiler-annotations", action="store_true",
                    help="wrap engine dispatches in jax.profiler."
                         "TraceAnnotation (names show up on the device "
                         "timeline of a jax.profiler.trace() capture)")
    ap.add_argument("--swap-interval", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--level", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--runtime", default="direct",
                    choices=["direct", "versioned"],
                    help="versioned: serve through the PolicyStore "
                         "(staleness-taggable actor side of the runtime; "
                         "continuous engine swaps in-flight)")
    ap.add_argument("--controller", default=None, metavar="SPEC",
                    help="continuous: shadow-evaluate a lag controller "
                         "('name:key=val,...', same grammar as the "
                         "training launcher) over the retired requests "
                         "— verdicts and reasons only, nothing dropped")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="append one metrics-registry snapshot as a "
                         "JSONL line at exit (flushed early on "
                         "SIGINT/SIGTERM)")
    args = ap.parse_args(argv)
    if args.requests is None:
        args.requests = args.batch
    if args.controller and args.engine != "continuous":
        raise SystemExit("--controller needs --engine continuous "
                         "(shadow admission runs over retired requests)")

    from repro.obs.tracer import make_tracer
    from repro.resilience import install_flush_handlers

    tracer = make_tracer(args.trace_detail if args.trace else "off")

    def _export_trace() -> None:
        if not args.trace:
            return
        from repro.obs.perfetto import export_perfetto, export_trace_jsonl

        if args.trace.endswith(".jsonl"):
            n = export_trace_jsonl(tracer, args.trace)
        else:
            n = export_perfetto(tracer, args.trace)
        print(f"trace: {n} events -> {args.trace} "
              f"(detail={args.trace_detail}, "
              f"ring-dropped={tracer.dropped})")

    # SIGINT/SIGTERM still leave the trace + metrics on disk.
    _flush_state = {"metrics": None}

    def _flush(signum: int) -> None:
        metrics = _flush_state.get("metrics")
        if metrics is not None and args.metrics_out:
            metrics.export_jsonl(args.metrics_out, signal=signum)
            print(f"metrics: flushed -> {args.metrics_out}")
        _export_trace()

    install_flush_handlers(_flush)

    from repro.configs import reduced_config
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.models.registry import build
    from repro.checkpoint import load_checkpoint

    tok = get_tokenizer()
    cfg = reduced_config(args.arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    init_params = bundle.init(jax.random.PRNGKey(args.seed))
    params = init_params
    if args.checkpoint:
        params, step, meta = load_checkpoint(args.checkpoint, params)
        print(f"loaded checkpoint step={step} meta={meta}")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_debug_mesh, parse_mesh_spec

        sizes = parse_mesh_spec(args.mesh)
        if args.engine != "continuous":
            raise SystemExit("--mesh requires --engine continuous")
        n_dev = len(jax.devices())
        if sizes["data"] * sizes["model"] > n_dev:
            raise SystemExit(
                f"--mesh {args.mesh}: wants "
                f"{sizes['data'] * sizes['model']} devices, host has "
                f"{n_dev} (CPU: export XLA_FLAGS=--xla_force_host_"
                f"platform_device_count=N before launching)")
        mesh = make_debug_mesh(data=sizes["data"], model=sizes["model"])
        print(f"serving over mesh {dict(mesh.shape)} "
              f"({len(mesh.devices.flat)} devices)")

    store = None
    if args.runtime == "versioned":
        from repro.runtime import PolicyStore

        sharding = None
        if mesh is not None:
            from repro.distributed.sharding import replicated

            sharding = replicated(mesh)
        # v0 is the true random init; the checkpoint (if any) becomes v1.
        store = PolicyStore(init_params, capacity=2,
                            meta={"source": "init"}, sharding=sharding,
                            tracer=tracer)
        if args.checkpoint:
            store.publish(params, source="checkpoint",
                          checkpoint=args.checkpoint)

    ds = MathTaskDataset(prompt_len=32, level=args.level,
                         seed=args.seed + 1)
    if args.engine == "continuous":
        _serve_continuous(args, bundle, params, store, tok, ds, mesh=mesh,
                          tracer=tracer, flush_state=_flush_state)
    else:
        toks_np, prompts, answers = ds.sample_batch(args.batch)
        _serve_static(args, bundle, params, store, tok, toks_np, answers)
    _export_trace()
    if args.metrics_out and _flush_state.get("metrics") is not None:
        _flush_state["metrics"].export_jsonl(args.metrics_out)
        print(f"metrics: snapshot -> {args.metrics_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
