"""Training launcher.

Two modes, matching the paper's two experimental regimes, both running on
the unified async actor-learner runtime (``--runtime`` selects the lag
regime, ``--controller`` the queue's lag controller as a
``"name:key=val,..."`` spec; the old ``--admission`` flags survive as
deprecation shims):

  # classic RL (simulated-async MuJoCo-analog, §5.1)
  PYTHONPATH=src python -m repro.launch.train rl \\
      --env pendulum --algorithm vaco --buffer-capacity 4 --phases 30 \\
      --runtime backward_mixture

  # genuinely concurrent producer thread + TV-gated admission
  PYTHONPATH=src python -m repro.launch.train rl \\
      --env pendulum --algorithm vaco --runtime threaded \\
      --controller "tv_gate:delta=0.2,mode=downweight" --phases 30

  # RLVR (forward-lag GRPO/VACO, §5.2) on a reduced assigned arch
  PYTHONPATH=src python -m repro.launch.train rlvr \\
      --arch qwen2.5-0.5b --algorithm grpo_vaco --n-minibatches 8 \\
      --phases 20 --runtime forward_n

  # RLVR with the ServeEngine as the rollout producer: real per-token
  # {version, log_beta} provenance under a scripted 2-back lag
  PYTHONPATH=src python -m repro.launch.train rlvr \\
      --producer serve --forced-lag 2 \\
      --controller "tv_gate:delta=0.05,mode=downweight" --phases 10

On a real TPU cluster the same entry point runs under
``jax.distributed.initialize()`` with the production mesh from
launch/mesh.py; on this CPU host it runs the reduced configs end-to-end.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax


def _add_runtime_args(p, *, regimes, default_regime,
                      admissions=("pass_through", "max_lag", "tv_gate"),
                      ) -> None:
    p.add_argument("--runtime", default=default_regime, choices=regimes,
                   help="lag regime driving the actor-learner runtime")
    p.add_argument("--controller", default=None, metavar="SPEC",
                   help="lag controller spec 'name:key=val,...' — e.g. "
                        "'tv_gate:delta=0.2,mode=downweight', "
                        "'stable_async:c_max=2.0'; see "
                        "repro.runtime.available_controllers()")
    # Deprecated string-keyed admission flags; kept as shims over
    # --controller (explicit use warns and maps to the equivalent spec).
    p.add_argument("--admission", default=None,
                   choices=list(admissions),
                   help="DEPRECATED: use --controller 'name:...'")
    p.add_argument("--max-lag", type=int, default=None,
                   help="DEPRECATED: use --controller 'max_lag:max_lag=N'")
    p.add_argument("--admission-mode", default=None,
                   choices=["drop", "downweight"],
                   help="DEPRECATED: use --controller "
                        "'tv_gate:delta=...,mode=...'")
    p.add_argument("--queue-maxsize", type=int, default=4,
                   help="bounded queue size (threaded backpressure)")
    p.add_argument("--trace", default=None, metavar="PATH",
                   help="write an execution trace (produce spans, "
                        "queue put/pop/drop, publish/pin, learner "
                        "steps): .json -> Perfetto, .jsonl -> flat "
                        "event lines for benchmarks/trace_report.py")
    p.add_argument("--trace-detail", default="spans",
                   choices=["off", "spans", "full"],
                   help="trace verbosity (off disables the tracer)")
    p.add_argument("--metrics-out", default=None, metavar="PATH",
                   help="append one metrics-registry snapshot as a "
                        "JSONL line at exit (flushed early on "
                        "SIGINT/SIGTERM)")


def _resolve_controller(args, *, delta):
    """Controller spec text from --controller or the deprecated
    --admission/--max-lag/--admission-mode flags (explicit legacy use
    warns and maps to the equivalent spec).  None = config default."""
    legacy_used = (args.admission is not None
                   or args.max_lag is not None
                   or args.admission_mode is not None)
    if args.controller is not None:
        if legacy_used:
            raise SystemExit(
                "--controller conflicts with the deprecated --admission/"
                "--max-lag/--admission-mode flags; pass one or the other")
        return args.controller
    if not legacy_used:
        return None
    from repro.runtime import spec_from_legacy

    spec = spec_from_legacy(
        args.admission or "pass_through",
        max_lag=args.max_lag if args.max_lag is not None else 4,
        delta=delta,
        mode=args.admission_mode or "drop",
        warn=True,
    )
    return spec.canonical()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="mode", required=True)

    rl = sub.add_parser("rl", help="simulated-async classic RL (§5.1)")
    rl.add_argument("--env", default="pendulum")
    rl.add_argument("--algorithm", default="vaco",
                    choices=["vaco", "ppo", "ppo_kl", "spo", "impala"])
    rl.add_argument("--buffer-capacity", type=int, default=1)
    rl.add_argument("--n-actors", type=int, default=32)
    rl.add_argument("--rollout-steps", type=int, default=128)
    rl.add_argument("--phases", type=int, default=30)
    rl.add_argument("--seed", type=int, default=0)
    rl.add_argument("--delta", type=float, default=0.2)
    rl.add_argument("--forward-n", type=int, default=4,
                    help="items per frozen policy (forward_n regime)")
    rl.add_argument("--checkpoint-dir", default=None)
    _add_runtime_args(
        rl, regimes=["backward_mixture", "forward_n", "threaded"],
        default_regime="backward_mixture")

    rv = sub.add_parser("rlvr", help="forward-lag RLVR (§5.2)")
    rv.add_argument("--arch", default="qwen2.5-0.5b")
    rv.add_argument("--algorithm", default="grpo_vaco",
                    choices=["grpo", "grpo_vaco"])
    rv.add_argument("--n-minibatches", type=int, default=4)
    rv.add_argument("--phases", type=int, default=10)
    rv.add_argument("--level", type=int, default=0,
                    help="math curriculum level")
    rv.add_argument("--warmup-steps", type=int, default=300)
    rv.add_argument("--seed", type=int, default=0)
    rv.add_argument("--delta", type=float, default=0.05)
    rv.add_argument("--checkpoint-dir", default=None)
    rv.add_argument("--producer", default="legacy",
                    choices=["legacy", "serve"],
                    help="rollout producer: the synthetic forward-lag "
                         "generator, or the continuous-batching "
                         "ServeEngine (real per-token provenance)")
    rv.add_argument("--forced-lag", type=int, default=None,
                    help="serve producer: generate from the learner's "
                         "k-back snapshot (scripted lag)")
    rv.add_argument("--max-new-tokens", type=int, default=None,
                    help="completion length (default: hp default)")
    rv.add_argument("--engine-max-batch", type=int, default=8,
                    help="serve producer: engine decode batch size")
    # Resilience (see repro.resilience and README "Fault tolerance").
    rv.add_argument("--fault-plan", default="", metavar="PLAN",
                    help="fault-injection plan, ';'-joined "
                         "'kind:key=val,...' chunks — e.g. "
                         "'producer_crash:at_step=4;"
                         "nan_publish:at_publish=7'")
    rv.add_argument("--fault-seed", type=int, default=0,
                    help="seed for probabilistic faults + stall jitter")
    rv.add_argument("--watchdog-restarts", type=int, default=0,
                    help="supervise threaded producers: restart a "
                         "crashed producer up to N times with seeded "
                         "exponential backoff (0 = crash-fast)")
    rv.add_argument("--watchdog-backoff-ms", type=float, default=50.0,
                    help="watchdog restart backoff base (doubles per "
                         "attempt, jittered)")
    rv.add_argument("--request-deadline", type=float, default=None,
                    metavar="SECONDS",
                    help="serve producer: per-request wall-clock "
                         "budget; expired requests retire as "
                         "finish_reason='timeout' and free their pages")
    rv.add_argument("--no-finiteness-guard", action="store_true",
                    help="disable the NaN/Inf firewall (non-finite "
                         "publishes quarantined, non-finite learner "
                         "steps skipped + rolled back)")
    rv.add_argument("--guard-checkpoint-dir", default=None,
                    help="finiteness guard restores from the newest "
                         "checkpoint here (also written after every "
                         "finite step) instead of the in-memory copy")
    # tv_gate_tokenwise: Eq. 8 per producing-version segment, scored by
    # a tv_fn closed over the PolicyStore (ROADMAP item).  RLVR-only:
    # classic-RL rollout payloads carry no per-token version record.
    _add_runtime_args(
        rv, regimes=["forward_n", "threaded"],
        default_regime="forward_n",
        admissions=("pass_through", "max_lag", "tv_gate",
                    "tv_gate_tokenwise"))

    args = ap.parse_args(argv)

    from repro.obs.tracer import make_tracer
    from repro.resilience import install_flush_handlers

    tracer = make_tracer(args.trace_detail if args.trace else "off")

    def _export_trace() -> None:
        if not args.trace:
            return
        from repro.obs.perfetto import export_perfetto, export_trace_jsonl

        if args.trace.endswith(".jsonl"):
            n = export_trace_jsonl(tracer, args.trace)
        else:
            n = export_perfetto(tracer, args.trace)
        print(f"trace: {n} events -> {args.trace} "
              f"(detail={args.trace_detail}, "
              f"ring-dropped={tracer.dropped})")

    # Graceful shutdown: SIGINT/SIGTERM stops producers and flushes the
    # trace/metrics buffers before exiting — an interrupted (or chaos-
    # killed) run still leaves its telemetry on disk.
    _flush_state = {"trainer": None}

    def _flush(signum: int) -> None:
        trainer = _flush_state.get("trainer")
        if trainer is not None:
            try:
                trainer.close()
            except Exception:
                pass
            if args.metrics_out:
                trainer.metrics.export_jsonl(
                    args.metrics_out, signal=signum)
                print(f"metrics: flushed -> {args.metrics_out}")
        _export_trace()

    install_flush_handlers(_flush)

    if args.mode == "rl":
        from repro.train.runner_rl import AsyncRLRunConfig, run_async_rl
        from repro.train.trainer_rl import RLHyperparams

        res = run_async_rl(AsyncRLRunConfig(
            env_name=args.env, algorithm=args.algorithm,
            buffer_capacity=args.buffer_capacity,
            n_actors=args.n_actors, rollout_steps=args.rollout_steps,
            total_phases=args.phases, seed=args.seed,
            hp=RLHyperparams(delta=args.delta),
            runtime=args.runtime, forward_n=args.forward_n,
            queue_maxsize=args.queue_maxsize,
            controller=_resolve_controller(args, delta=args.delta),
            tracer=tracer if args.trace else None,
        ))
        print(json.dumps({
            "runtime": args.runtime,
            "returns": res.returns,
            "final_tv": res.final_tv,
            "runtime_stats": res.runtime_stats,
        }, indent=1))
        _export_trace()
        return 0

    # rlvr
    from repro.configs import reduced_config, get_config
    from repro.data.mathgen import MathTaskDataset
    from repro.data.tokenizer import get_tokenizer
    from repro.models.registry import build
    from repro.train.trainer_rlvr import RLVRHyperparams, RLVRTrainer
    from repro.checkpoint import save_checkpoint

    tok = get_tokenizer()
    cfg = reduced_config(args.arch, vocab=tok.vocab_size)
    bundle = build(cfg)
    ds = MathTaskDataset(prompt_len=32, level=args.level)
    hp_kwargs = dict(
        algorithm=args.algorithm, n_minibatches=args.n_minibatches,
        warmup_steps=args.warmup_steps, delta=args.delta,
        runtime=args.runtime, queue_maxsize=args.queue_maxsize,
        controller=_resolve_controller(args, delta=args.delta),
        producer=args.producer, forced_lag=args.forced_lag,
        engine_max_batch=args.engine_max_batch,
        fault_plan=args.fault_plan, fault_seed=args.fault_seed,
        watchdog_restarts=args.watchdog_restarts,
        watchdog_backoff_ms=args.watchdog_backoff_ms,
        request_deadline_s=args.request_deadline,
        finiteness_guard=not args.no_finiteness_guard,
        guard_checkpoint_dir=args.guard_checkpoint_dir,
    )
    if args.max_new_tokens is not None:
        hp_kwargs["max_new_tokens"] = args.max_new_tokens
    hp = RLVRHyperparams(**hp_kwargs)
    trainer = RLVRTrainer(bundle, ds, hp, seed=args.seed, tracer=tracer)
    _flush_state["trainer"] = trainer
    wl = trainer.warmup()
    print(f"[warmup] loss={wl:.4f} acc={trainer.evaluate(128):.3f}")
    res = trainer.train(args.phases, eval_every=max(args.phases // 4, 1))
    step_summary = trainer.metrics.histogram("train_step_s").summary()
    print(json.dumps({
        "arch": cfg.name,
        "algorithm": args.algorithm,
        "runtime": args.runtime,
        "n_minibatches": args.n_minibatches,
        "eval_accuracy": res.eval_accuracy,
        "final_tv": res.phase_logs[-1].tv if res.phase_logs else None,
        "runtime_stats": res.runtime_stats,
        "train_step_ms": {
            "count": step_summary["count"],
            "mean": step_summary["mean"] * 1e3,
            "p50": step_summary["p50"] * 1e3,
            "p99": step_summary["p99"] * 1e3,
        },
    }, indent=1))
    _export_trace()
    if args.metrics_out:
        trainer.metrics.export_jsonl(args.metrics_out)
        print(f"metrics: snapshot -> {args.metrics_out}")
    if args.checkpoint_dir:
        path = save_checkpoint(
            args.checkpoint_dir, args.phases, trainer.state.params,
            meta={"arch": cfg.name})
        print(f"checkpoint: {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
