"""The three lowered step functions (train / prefill / serve) and their
abstract input specs — shared by the dry-run, the roofline harness and
the real launchers.

``input_specs`` returns ShapeDtypeStructs only (weak-type-correct,
shardable, no device allocation): the FULL assigned configs are exercised
exclusively through ``jit(...).lower(**specs).compile()``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.core.losses import GRPOConfig, grpo_token_loss, value_loss_mse
from repro.models.registry import ModelBundle, build
from repro.optim import AdamWConfig, adamw_init, adamw_update, \
    clip_by_global_norm

PARAM_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def make_train_step(bundle: ModelBundle, prompt_len: int,
                    use_vaco: bool = True):
    """RLVR policy update: forward -> GRPO(+VACO) token loss (+ value MSE)
    -> global-norm clip -> AdamW.  This is the real learner step the
    framework trains with, lowered at production shape."""
    cfg = GRPOConfig(use_vaco=use_vaco, delta=0.05)
    opt_cfg = AdamWConfig(lr=1e-5, weight_decay=0.0)

    def loss_fn(params, batch):
        out = bundle.forward(params, batch["tokens"], **{
            k: v for k, v in batch.items()
            if k in bundle.aux_input_shapes
        })
        logits = out.logits[:, prompt_len - 1 : -1]
        targets = batch["tokens"][:, prompt_len:]
        logits32 = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits32, axis=-1)
        log_pi = jnp.take_along_axis(
            logits32, targets[..., None], axis=-1)[..., 0] - lse
        loss, aux = grpo_token_loss(
            log_pi=log_pi, log_beta=batch["log_beta"],
            advantages=batch["advantages"], token_mask=batch["mask"],
            cfg=cfg,
        )
        if out.value is not None:
            loss = loss + 0.5 * value_loss_mse(
                out.value[:, prompt_len - 1 : -1],
                batch["value_targets"], batch["mask"],
            )
        loss = loss + out.aux_loss
        return loss, aux["tv"]

    def train_step(params, opt_state, batch):
        (loss, tv), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, {"loss": loss, "tv": tv,
                                   "grad_norm": gnorm}

    return train_step


def make_prefill_step(bundle: ModelBundle):
    def prefill_step(params, tokens, aux):
        # aux is a (possibly empty) dict pytree — positional because pjit
        # rejects kwargs when in_shardings is given.
        out = bundle.forward(params, tokens, return_cache=True, **aux)
        return out.logits[:, -1], out.cache

    return prefill_step


def make_serve_step(bundle: ModelBundle):
    def serve_step(params, token, cache):
        out, cache = bundle.decode_step(params, token, cache)
        return out.logits, cache

    return serve_step


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------


def abstract_params(bundle: ModelBundle, dtype=PARAM_DTYPE):
    return jax.eval_shape(
        lambda: bundle.init(jax.random.PRNGKey(0), dtype=dtype)
    )


def abstract_opt_state(params_abs):
    return jax.eval_shape(adamw_init, params_abs)


def _aux_specs(bundle: ModelBundle, batch: int) -> Dict[str, Any]:
    return {
        name: jax.ShapeDtypeStruct((batch,) + shape, jnp.float32)
        for name, shape in bundle.aux_input_shapes.items()
    }


def train_batch_specs(bundle: ModelBundle, shape: InputShape,
                      prompt_len: int) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    comp = s - prompt_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "log_beta": jax.ShapeDtypeStruct((b, comp), jnp.float32),
        "mask": jax.ShapeDtypeStruct((b, comp), jnp.float32),
        "advantages": jax.ShapeDtypeStruct((b,), jnp.float32),
        "value_targets": jax.ShapeDtypeStruct((b, comp), jnp.float32),
    }
    specs.update(_aux_specs(bundle, b))
    return specs


def prefill_specs(bundle: ModelBundle, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    cfg = bundle.cfg
    s_text = s - cfg.vision_prefix_len  # VLM: patches occupy the prefix
    specs = {"tokens": jax.ShapeDtypeStruct((b, s_text), jnp.int32)}
    specs.update(_aux_specs(bundle, b))
    return specs


def abstract_cache(bundle: ModelBundle, shape: InputShape,
                   dtype=PARAM_DTYPE):
    b, s = shape.global_batch, shape.seq_len
    cfg = bundle.cfg

    def mk():
        kwargs = {}
        if cfg.encoder_layers > 0:
            kwargs["encoder_out"] = jnp.zeros(
                (b, cfg.encoder_seq_len, cfg.d_model), dtype)
        return bundle.init_cache(None, b, s, dtype=dtype, **kwargs)

    return jax.eval_shape(mk)


def serve_specs(bundle: ModelBundle, shape: InputShape) -> Dict[str, Any]:
    b = shape.global_batch
    return {
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "cache": abstract_cache(bundle, shape),
    }
