"""Launchers: production meshes, the multi-pod dry-run, train/serve CLIs.

NOTE: repro.launch.dryrun must be executed as __main__ (it sets XLA_FLAGS
before importing jax); do not import it from a process that already
initialized jax unless 512 host devices are intended.
"""
from repro.launch.mesh import (
    make_production_mesh,
    make_debug_mesh,
    parse_mesh_spec,
    PEAK_FLOPS_BF16,
    HBM_BW,
    ICI_BW,
    HBM_PER_CHIP,
)
