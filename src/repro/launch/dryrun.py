import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, with no device allocation.

For each combination this script:
  1. builds the FULL assigned config and the matching step function
     (train_step for train_4k, prefill_step for prefill_32k, serve_step
     for decode_32k / long_500k);
  2. constructs ShapeDtypeStruct inputs and NamedShardings from
     repro.distributed.sharding;
  3. ``jax.jit(step, in_shardings=...).lower(...).compile()`` on the
     16x16 single-pod mesh AND the 2x16x16 multi-pod mesh;
  4. records memory_analysis / cost_analysis / collective bytes for
     EXPERIMENTS.md §Dry-run and §Roofline.

Run:  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
          [--multi-pod] [--policy fsdp|tensor|fsdp2d] [--out results.json]
"""
import argparse
import json
import sys
import time
import traceback
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.distributed.sharding import (
    ShardingPolicy,
    batch_sharding,
    cache_shardings,
    params_shardings,
    replicated,
)
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as steps_mod
from repro.models.registry import build
from repro.utils.hlo import collective_bytes


@dataclass
class DryRunRecord:
    arch: str
    shape: str
    mesh: str
    status: str                      # ok | skipped | failed
    reason: str = ""
    seconds: float = 0.0
    # Raw per-device numbers from the full-depth compile.  NOTE: XLA's
    # cost_analysis counts a scan (while-loop) body ONCE, so for the
    # scan-over-layers models these are ~1/L of the true totals.
    flops_raw: float = 0.0
    hbm_bytes_raw: float = 0.0
    # Depth-extrapolated per-device totals (see _extrapolate): the body
    # cost is measured as compile(2 layers) - compile(1 layer) and scaled
    # by the real layer count.  These feed §Roofline.
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes_per_device: float = 0.0
    peak_memory_per_device: float = 0.0
    argument_size_per_device: float = 0.0
    output_size_per_device: float = 0.0
    collective_breakdown: Dict[str, int] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)


def should_skip(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return ("pure full-attention arch: long_500k requires "
                "sub-quadratic attention (DESIGN.md policy)")
    return None


def _lower_compile(fn, in_shardings, args_abs, kwargs_abs=None,
                   donate=()):
    jitted = jax.jit(fn, in_shardings=in_shardings,
                     donate_argnums=donate)
    lowered = jitted.lower(*args_abs, **(kwargs_abs or {}))
    compiled = lowered.compile()
    return lowered, compiled


def _compile_combo(cfg, shape, mesh, policy, *, unroll_layers=False,
                   remat=True):
    """Lower + compile the step for one (config, shape) on `mesh`."""
    # remat: per-layer activation rematerialization — the production
    # training memory policy (a §Perf knob; serve paths ignore it).
    # REPRO_DECODE_WINDOWED=1: unroll decode so local layers read only a
    # window-sized cache slice (§Perf hillclimb #3b).
    if (shape.kind == "decode"
            and os.environ.get("REPRO_DECODE_WINDOWED") == "1"
            and cfg.sliding_window is not None):
        unroll_layers = True
    if os.environ.get("REPRO_NO_REMAT") == "1":
        remat = False  # §Perf knob: skip per-layer rematerialization
    bundle = build(cfg, unroll_layers=unroll_layers,
                   remat=remat and shape.kind == "train")
    params_abs = steps_mod.abstract_params(bundle)
    params_sh = params_shardings(params_abs, mesh, policy)

    with mesh:
        if shape.kind == "train":
            prompt_len = shape.seq_len // 2
            step = steps_mod.make_train_step(bundle, prompt_len)
            opt_abs = steps_mod.abstract_opt_state(params_abs)
            opt_sh = params_shardings(opt_abs, mesh, policy)
            # AdamWState.step counter is replicated.
            opt_sh = opt_sh._replace(step=replicated(mesh))
            batch = steps_mod.train_batch_specs(bundle, shape, prompt_len)
            batch_sh = {
                k: batch_sharding(mesh, v.shape[0], v.ndim, policy)
                for k, v in batch.items()
            }
            # params/opt are donated: the update is in-place on-device,
            # as a real learner runs.
            return _lower_compile(
                step, (params_sh, opt_sh, batch_sh),
                (params_abs, opt_abs, batch), donate=(0, 1),
            )
        if shape.kind == "prefill":
            step = steps_mod.make_prefill_step(bundle)
            specs = steps_mod.prefill_specs(bundle, shape)
            tokens = specs.pop("tokens")
            tok_sh = batch_sharding(
                mesh, tokens.shape[0], tokens.ndim, policy)
            aux_sh = {
                k: batch_sharding(mesh, v.shape[0], v.ndim, policy)
                for k, v in specs.items()
            }
            return _lower_compile(
                step, (params_sh, tok_sh, aux_sh),
                (params_abs, tokens, specs),
            )
        # decode
        step = steps_mod.make_serve_step(bundle)
        specs = steps_mod.serve_specs(bundle, shape)
        shard_seq = shape.name == "long_500k"
        cache_sh = cache_shardings(
            specs["cache"], mesh, shard_seq=shard_seq, policy=policy)
        # the KV cache is donated: decode updates it in place.
        return _lower_compile(
            step, (params_sh, replicated(mesh), cache_sh),
            (params_abs, specs["token"], specs["cache"]), donate=(2,),
        )


def _costs(compiled):
    cost = compiled.cost_analysis() or {}
    # Newer jaxlibs return one properties dict per device instead of a
    # bare dict; the mesh is homogeneous so any device's entry works.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    stats = collective_bytes(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        stats,
    )


def run_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    policy: Optional[ShardingPolicy] = None,
    verbose: bool = True,
    extrapolate: bool = True,
    probe_depths: tuple = (1, 2),
) -> DryRunRecord:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = DryRunRecord(arch=arch, shape=shape_name, mesh=mesh_name,
                       status="ok")
    skip = should_skip(arch, shape_name)
    if skip:
        rec.status, rec.reason = "skipped", skip
        return rec

    policy = policy or ShardingPolicy()
    t0 = time.time()
    try:
        cfg = get_config(arch)
        shape = INPUT_SHAPES[shape_name]
        mesh = make_production_mesh(multi_pod=multi_pod)

        # 1. FULL-depth compile: proves the production lowering and gives
        #    memory_analysis (+ raw, scan-body-once cost numbers).
        lowered, compiled = _compile_combo(cfg, shape, mesh, policy)
        rec.flops_raw, rec.hbm_bytes_raw, raw_stats = _costs(compiled)
        mem = compiled.memory_analysis()
        if mem is not None:
            rec.peak_memory_per_device = float(
                getattr(mem, "temp_size_in_bytes", 0))
            rec.argument_size_per_device = float(
                getattr(mem, "argument_size_in_bytes", 0))
            rec.output_size_per_device = float(
                getattr(mem, "output_size_in_bytes", 0))

        # 2. Depth extrapolation: compile UNROLLED 1- and 2-layer variants
        #    (XLA counts a while-loop body once; the unrolled delta is the
        #    true per-layer cost).  total = m1 + (L-1) * (m2 - m1).
        if extrapolate:
            def depth_variant(k: int):
                kwargs = {"n_layers": k}
                if cfg.encoder_layers > 0:
                    kwargs["encoder_layers"] = k
                return cfg.replace(**kwargs)

            da, db = probe_depths
            # total = m_a + (L - a)/(b - a) * (m_b - m_a); heterogeneous
            # layer patterns (gemma3 5:1) use (a, b) = one/two full
            # pattern periods so the delta averages a whole period.
            _, c1 = _compile_combo(depth_variant(da), shape, mesh, policy,
                                   unroll_layers=True)
            _, c2 = _compile_combo(depth_variant(db), shape, mesh, policy,
                                   unroll_layers=True)
            f1, b1, s1 = _costs(c1)
            f2, b2, s2 = _costs(c2)
            L = cfg.n_layers
            scale = (L - da) / (db - da)
            rec.flops = f1 + scale * max(f2 - f1, 0.0)
            rec.hbm_bytes = b1 + scale * max(b2 - b1, 0.0)
            kinds = set(s1.bytes_by_kind) | set(s2.bytes_by_kind)
            for kind in kinds:
                v1 = s1.bytes_by_kind.get(kind, 0)
                v2 = s2.bytes_by_kind.get(kind, 0)
                n1 = s1.count_by_kind.get(kind, 0)
                n2 = s2.count_by_kind.get(kind, 0)
                rec.collective_breakdown[kind] = int(
                    v1 + scale * max(v2 - v1, 0))
                rec.collective_counts[kind] = int(
                    n1 + scale * max(n2 - n1, 0))
            rec.collective_bytes_per_device = float(
                sum(rec.collective_breakdown.values()))
        else:
            rec.flops, rec.hbm_bytes = rec.flops_raw, rec.hbm_bytes_raw
            rec.collective_bytes_per_device = float(raw_stats.total_bytes)
            rec.collective_breakdown = dict(raw_stats.bytes_by_kind)
            rec.collective_counts = dict(raw_stats.count_by_kind)

        rec.seconds = time.time() - t0
        if verbose:
            print(
                f"[ok] {arch:24s} {shape_name:12s} {mesh_name:8s} "
                f"{rec.seconds:6.1f}s flops/dev={rec.flops:.3e} "
                f"bytes/dev={rec.hbm_bytes:.3e} "
                f"coll/dev={rec.collective_bytes_per_device:.3e} "
                f"peak_mem/dev={rec.peak_memory_per_device/2**30:.2f}GiB",
                flush=True,
            )
    except Exception as e:  # noqa: BLE001 — record and continue the grid
        rec.status = "failed"
        rec.reason = f"{type(e).__name__}: {e}"
        rec.seconds = time.time() - t0
        if verbose:
            print(f"[FAIL] {arch} {shape_name} {mesh_name}: {rec.reason}")
            traceback.print_exc()
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="single arch id (default: all 10)")
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES), help="single input shape")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 512-chip mesh")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod")
    ap.add_argument("--policy", default="fsdp",
                    choices=["fsdp", "tensor", "fsdp2d", "replicated"])
    ap.add_argument("--batch-mode", default="data",
                    choices=["data", "dp_all"])
    ap.add_argument("--out", default=None, help="write JSON records here")
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="skip the 1/2-layer probes (compile-proof only)")
    ap.add_argument("--probe-depths", nargs=2, type=int, default=[1, 2],
                    help="layer depths for the cost extrapolation probes")
    args = ap.parse_args(argv)

    n_dev = len(jax.devices())
    assert n_dev == 512, (
        f"dry-run needs 512 host devices, got {n_dev} — "
        "XLA_FLAGS was set too late?")

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    policy = ShardingPolicy(weight_mode=args.policy,
                            batch_mode=args.batch_mode)

    records: List[DryRunRecord] = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                records.append(
                    run_one(arch, shape, multi_pod=mp, policy=policy,
                            extrapolate=not args.no_extrapolate,
                            probe_depths=tuple(args.probe_depths))
                )

    ok = sum(r.status == "ok" for r in records)
    skipped = sum(r.status == "skipped" for r in records)
    failed = sum(r.status == "failed" for r in records)
    print(f"\ndry-run: {ok} ok, {skipped} skipped (documented), "
          f"{failed} failed / {len(records)} total")
    if args.out:
        with open(args.out, "w") as f:
            json.dump([asdict(r) for r in records], f, indent=1)
        print(f"wrote {args.out}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
