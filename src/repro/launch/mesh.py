"""Production mesh definitions (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and tests/benches must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2 pods = 512 chips with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices the host actually has."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh_spec(spec: str):
    """``"data=4"`` / ``"data=4,model=2"`` -> axis-size dict.

    The grammar of the launchers' ``--mesh`` flag; axes it doesn't name
    default to 1.  Raises ValueError on unknown axes so a typo doesn't
    silently serve unsharded.
    """
    sizes = {"data": 1, "model": 1}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, val = part.partition("=")
        name = name.strip()
        if (name not in sizes or not val.strip().isdigit()
                or int(val) < 1):
            raise ValueError(
                f"--mesh {spec!r}: want e.g. 'data=4' or "
                f"'data=4,model=2' with positive sizes "
                f"(axes: {sorted(sizes)})")
        sizes[name] = int(val)
    return sizes


# Hardware constants for the roofline analysis (TPU v5e).
PEAK_FLOPS_BF16 = 197e12        # per chip, FLOP/s
HBM_BW = 819e9                  # per chip, bytes/s
ICI_BW = 50e9                   # per link, bytes/s (~per chip per direction)
HBM_PER_CHIP = 16 * 1024**3     # 16 GiB
