from repro.data.tokenizer import CharTokenizer, get_tokenizer
from repro.data.mathgen import (
    MathTaskDataset,
    Problem,
    sample_problem,
    verify,
    extract_answer,
)
from repro.data.pipeline import (
    PackedBatch,
    Prefetcher,
    pack_examples,
    packed_warmup_batches,
)

__all__ = [
    "CharTokenizer",
    "get_tokenizer",
    "MathTaskDataset",
    "Problem",
    "sample_problem",
    "verify",
    "extract_answer",
    "PackedBatch",
    "Prefetcher",
    "pack_examples",
    "packed_warmup_batches",
]
