"""Synthetic verifiable math-reasoning task (offline GSM8k stand-in).

Generates short multi-step word problems over small integers with an
exact-match verifiable answer — preserving the structure the paper's §5.2
experiment needs: prompt -> sampled completion -> binary reward from a
verifier (Lambert et al., 2025 RLVR).  Difficulty levels provide a
curriculum so a from-scratch ~1-10M char-level model can reach non-trivial
accuracy within CPU budgets:

    level 0:  "3+5=?#"            answer "8"
    level 1:  "12+7-4=?#"         answer "15"
    level 2:  "(3+5)*2=?#"        answer "16"
    level 3:  one-sentence word problem, two operations

The verifier extracts the first integer of the completion and compares it
to the canonical answer — same binary reward as GSM8k exact-match.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.data.tokenizer import CharTokenizer, get_tokenizer

_TEMPLATES_L3 = [
    ("tom has {a} apples and buys {b} more, then eats {c}. "
     "how many are left?#", lambda a, b, c: a + b - c),
    ("a box holds {a} pens. with {b} boxes and {c} loose pens, "
     "how many pens?#", lambda a, b, c: a * b + c),
    ("sara reads {a} pages a day for {b} days and then {c} pages. "
     "total pages?#", lambda a, b, c: a * b + c),
    ("{a} birds sit on a wire. {b} fly away and {c} arrive. "
     "how many now?#", lambda a, b, c: a - b + c),
]


@dataclass
class Problem:
    prompt: str
    answer: str


def sample_problem(rng: np.random.Generator, level: int = 1) -> Problem:
    if level <= 0:
        a, b = rng.integers(0, 10, 2)
        return Problem(f"{a}+{b}=?#", str(a + b))
    if level == 1:
        a, b, c = rng.integers(0, 20, 3)
        return Problem(f"{a}+{b}-{c}=?#", str(a + b - c))
    if level == 2:
        a, b = rng.integers(0, 10, 2)
        c = int(rng.integers(1, 5))
        return Problem(f"({a}+{b})*{c}=?#", str((a + b) * c))
    idx = int(rng.integers(0, len(_TEMPLATES_L3)))
    tmpl, fn = _TEMPLATES_L3[idx]
    a = int(rng.integers(2, 15))
    b = int(rng.integers(1, min(a, 9) + 1))
    c = int(rng.integers(1, 10))
    return Problem(tmpl.format(a=a, b=b, c=c), str(fn(a, b, c)))


_INT_RE = re.compile(r"-?\d+")


def extract_answer(completion: str) -> Optional[str]:
    m = _INT_RE.search(completion)
    return m.group(0) if m else None


def verify(completion: str, answer: str) -> float:
    """Binary exact-match reward, as in GSM8k RLVR."""
    got = extract_answer(completion)
    return 1.0 if got is not None and got == answer else 0.0


class MathTaskDataset:
    """Batch sampler for prompts + verifier targets.

    Mirrors the paper's protocol constants (Table 2): fixed prompt length,
    fixed completion budget, grouped completions per prompt handled by the
    rollout engine.
    """

    def __init__(
        self,
        prompt_len: int = 64,
        level: int = 1,
        seed: int = 0,
        tokenizer: Optional[CharTokenizer] = None,
        eval_fraction: float = 0.1,
        pool_size: int = 8192,
    ) -> None:
        self.tok = tokenizer or get_tokenizer()
        self.prompt_len = prompt_len
        self.level = level
        rng = np.random.default_rng(seed)
        pool = [sample_problem(rng, level) for _ in range(pool_size)]
        n_eval = max(1, int(pool_size * eval_fraction))
        self.eval_set: List[Problem] = pool[:n_eval]
        self.train_set: List[Problem] = pool[n_eval:]
        self._rng = rng

    def _encode_prompts(self, probs: List[Problem]) -> np.ndarray:
        rows = [
            self.tok.pad_to(
                self.tok.encode(p.prompt), self.prompt_len, left=True
            )
            for p in probs
        ]
        return np.stack(rows)

    def sample_batch(
        self, n_prompts: int
    ) -> Tuple[np.ndarray, List[str], List[str]]:
        """Returns (tokens [n, prompt_len] left-padded, prompts, answers)."""
        idx = self._rng.integers(0, len(self.train_set), n_prompts)
        probs = [self.train_set[i] for i in idx]
        return (
            self._encode_prompts(probs),
            [p.prompt for p in probs],
            [p.answer for p in probs],
        )

    def eval_batch(
        self, n: Optional[int] = None
    ) -> Tuple[np.ndarray, List[str], List[str]]:
        probs = self.eval_set if n is None else self.eval_set[:n]
        return (
            self._encode_prompts(probs),
            [p.prompt for p in probs],
            [p.answer for p in probs],
        )

    def supervised_batch(
        self, n: int, completion_len: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, loss_mask) for the warm-start pretraining phase.

        Sequence = <bos> prompt answer <eos> <pad>...; the mask covers the
        answer + eos positions (teacher forcing on the verifiable part).
        """
        idx = self._rng.integers(0, len(self.train_set), n)
        total = self.prompt_len + completion_len
        toks = np.zeros((n, total), np.int32)
        mask = np.zeros((n, total), np.float32)
        for r, i in enumerate(idx):
            p = self.train_set[i]
            prompt_ids = self.tok.encode(p.prompt)
            ans_ids = self.tok.encode(
                p.answer, add_bos=False, add_eos=True
            )
            seq = (prompt_ids + ans_ids)[:total]
            toks[r, : len(seq)] = seq
            lo = min(len(prompt_ids), total)
            hi = min(len(seq), total)
            mask[r, lo:hi] = 1.0
        return toks, mask
