"""Batching/packing pipeline for the supervised warm-start and eval paths.

Two pieces a production trainer needs that the raw generator lacks:

* **sequence packing** — concatenate many short (prompt, answer) examples
  into fixed-length rows with an example-id segmentation array, so the
  warmup step wastes no FLOPs on padding (the assigned shapes train at
  4k tokens; synthetic math examples are ~20 tokens).
* **host prefetch** — a tiny double-buffered iterator that overlaps host
  batch assembly with device compute (numpy side; device transfer happens
  at jit boundary).

Packing uses attention *resets* via the segment-ids convention: the model
masks cross-example attention when given `segment_ids` (supported by
make_attention_mask's kv_valid path at the trainer level; the warmup loss
here only needs the loss-mask semantics, which packing preserves).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, Iterator, List, Optional, Tuple

import numpy as np

from repro.data.mathgen import MathTaskDataset
from repro.data.tokenizer import CharTokenizer


@dataclass
class PackedBatch:
    tokens: np.ndarray        # [B, L] int32
    loss_mask: np.ndarray     # [B, L] float32 (answer positions)
    segment_ids: np.ndarray   # [B, L] int32, 0 = padding
    n_examples: int           # total examples packed into the batch


def pack_examples(
    examples: List[Tuple[List[int], List[int]]],
    batch: int,
    length: int,
    pad_id: int = 0,
) -> PackedBatch:
    """Greedy first-fit packing of (prompt_ids, answer_ids) examples."""
    tokens = np.full((batch, length), pad_id, np.int32)
    loss_mask = np.zeros((batch, length), np.float32)
    segment_ids = np.zeros((batch, length), np.int32)
    row, col, seg, packed = 0, 0, 1, 0
    for prompt, answer in examples:
        need = len(prompt) + len(answer)
        if need > length:
            continue
        if col + need > length:
            row, col = row + 1, 0
            if row >= batch:
                break
        seq = prompt + answer
        tokens[row, col : col + need] = seq
        loss_mask[row, col + len(prompt) : col + need] = 1.0
        segment_ids[row, col : col + need] = seg
        col += need
        seg += 1
        packed += 1
    return PackedBatch(tokens=tokens, loss_mask=loss_mask,
                       segment_ids=segment_ids, n_examples=packed)


def packed_warmup_batches(
    dataset: MathTaskDataset,
    *,
    batch: int,
    length: int,
    steps: int,
    completion_len: int = 8,
) -> Iterator[PackedBatch]:
    """Stream of packed supervised batches from the math generator."""
    tok = dataset.tok
    rng = np.random.default_rng(1234)
    for _ in range(steps):
        idx = rng.integers(0, len(dataset.train_set),
                           batch * max(2, length // 24))
        examples = []
        for i in idx:
            p = dataset.train_set[i]
            examples.append((
                tok.encode(p.prompt),
                tok.encode(p.answer, add_bos=False, add_eos=True),
            ))
        yield pack_examples(examples, batch, length, tok.pad_id)


class Prefetcher:
    """Double-buffered host-side prefetch around any iterator."""

    _SENTINEL = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._thread = threading.Thread(
            target=self._fill, args=(it,), daemon=True)
        self._err: Optional[BaseException] = None
        self._thread.start()

    def _fill(self, it: Iterator) -> None:
        try:
            for item in it:
                self._q.put(item)
        except BaseException as e:  # surfaced on next()
            self._err = e
        finally:
            self._q.put(self._SENTINEL)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item
