"""Character-level tokenizer for the synthetic verifiable-math task.

The RLVR experiments (§5.2) need a tokenizer that is (a) fully offline,
(b) tiny, and (c) loss-free for arithmetic strings.  A fixed char
vocabulary covers the generator's alphabet; ids are stable across runs so
checkpoints and cached rollouts interoperate.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

PAD, BOS, EOS = 0, 1, 2
_SPECIALS = ["<pad>", "<bos>", "<eos>"]
_ALPHABET = list("0123456789+-*/()=<>. ,?abcdefghijklmnopqrstuvwxyz#")


class CharTokenizer:
    """Fixed-vocabulary char tokenizer. Vocab size 54 (3 specials + 51)."""

    def __init__(self) -> None:
        self.itos: List[str] = list(_SPECIALS) + list(_ALPHABET)
        self.stoi = {c: i for i, c in enumerate(self.itos)}
        self.pad_id, self.bos_id, self.eos_id = PAD, BOS, EOS

    @property
    def vocab_size(self) -> int:
        return len(self.itos)

    def encode(
        self, text: str, add_bos: bool = True, add_eos: bool = False
    ) -> List[int]:
        ids = [self.stoi[c] for c in text.lower() if c in self.stoi]
        if add_bos:
            ids = [self.bos_id] + ids
        if add_eos:
            ids = ids + [self.eos_id]
        return ids

    def decode(self, ids: Sequence[int], strip_special: bool = True) -> str:
        out = []
        for i in ids:
            i = int(i)
            if i >= len(self.itos) or i < 0:
                continue
            if strip_special and i < len(_SPECIALS):
                if i == self.eos_id:
                    break
                continue
            out.append(self.itos[i])
        return "".join(out)

    def pad_to(
        self,
        ids: Sequence[int],
        length: int,
        left: bool = False,
    ) -> np.ndarray:
        ids = list(ids)[:length]
        pad = [self.pad_id] * (length - len(ids))
        return np.asarray(pad + ids if left else ids + pad, np.int32)


_DEFAULT = None


def get_tokenizer() -> CharTokenizer:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = CharTokenizer()
    return _DEFAULT
