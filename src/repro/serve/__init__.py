"""Continuous-batching serve engine with a paged KV cache.

The actor side of the §5.2 asynchronous RLVR setup as a *serving
system* rather than a fixed-batch ``generate()`` loop:

* ``paged_cache``  — free-list block allocator over a pooled KV cache
                     (fixed-size pages, per-request block tables,
                     copy-free release on EOS).  With
                     ``prefix_cache=True`` full pages are content-
                     addressed (hash over token ids + policy version +
                     arch config) and shared read-only across requests
                     under refcounts, with copy-on-write for divergent
                     appends and LRU eviction of zero-ref cached pages.
* ``scheduler``    — continuous-batching scheduler: admit / preempt /
                     retire requests *between* decode steps so the
                     decode batch stays full instead of draining with
                     the slowest row.
* ``engine``       — the decode loop over
                     ``models.transformer.decode_step_paged`` (paged-
                     attention kernel), with in-flight versioned weight
                     swap from a ``runtime.PolicyStore``: every emitted
                     token records the policy version that produced it,
                     so finished trajectories carry per-token version
                     vectors + per-token ``log_beta`` for the runtime's
                     ``tv_gate_tokenwise`` admission policy.  Optional
                     speculative decode (draft slot + single-dispatch
                     multi-token verify, rollback = pos rewind) and
                     batched same-padded-length prefill admissions.
"""
from repro.serve.engine import (
    CallableDraft,
    ModelDraft,
    ServeEngine,
    ServeStats,
    ServedTrajectory,
)
from repro.serve.paged_cache import (
    RECLAIMED,
    BlockAllocator,
    OutOfBlocks,
    PrefixIndex,
    PrefixKey,
    PrefixMatch,
    ShardedBlockAllocator,
    make_allocator,
    prefix_key,
)
from repro.serve.scheduler import (
    ContinuousBatchingScheduler,
    Request,
    RequestState,
)

__all__ = [
    "RECLAIMED",
    "BlockAllocator",
    "CallableDraft",
    "ContinuousBatchingScheduler",
    "ModelDraft",
    "OutOfBlocks",
    "PrefixIndex",
    "PrefixKey",
    "PrefixMatch",
    "Request",
    "RequestState",
    "ServeEngine",
    "ServeStats",
    "ServedTrajectory",
    "ShardedBlockAllocator",
    "make_allocator",
    "prefix_key",
]
