"""Continuous-batching scheduler: admit / preempt / retire between steps.

The decode batch is a fixed set of ``max_batch`` *slots*.  Instead of
running a batch until its slowest member finishes (the phase-locked
``generate()`` loop), the scheduler refills slots the moment their
request retires, so the decode step stays full under mixed completion
lengths — the vLLM iteration-level scheduling model.

Decisions happen *between* decode steps, in :meth:`schedule`:

1. **extend** — every running request about to write into a fresh page
   gets one allocated; if the pool is dry, the most recently admitted
   running request is preempted (LIFO victim choice, vLLM-style) until
   the extension fits.
2. **admit** — FIFO over the waiting queue while free slots *and*
   enough pages for the whole prompt (plus the first decode write)
   exist.  Head-of-line blocking is deliberate: skipping ahead starves
   long prompts.

Preemption frees the victim's pages copy-free and re-queues it at the
*front* of the waiting queue.  Already-emitted tokens are never
retracted (they may have been streamed to a client): on re-admission
the engine recomputes KV for prompt + emitted tokens and resumes.

**Sharded pools**: with a :class:`~repro.serve.paged_cache.
ShardedBlockAllocator`, every request is *placed* on one shard at
admission — all of its pages come from that shard's free list and its
attention reads only that shard's pool slice.  Placement balances
**live slots per shard** (fewest running requests wins; ties break to
the shard with the most free pages) so decode work spreads across the
mesh instead of piling onto one device.  Pool-pressure preemption is
shard-local: only a victim on the starved request's own shard frees
pages that help, so the LIFO victim choice walks that shard's
admissions.  An unsharded allocator is the one-shard special case of
the same logic.
"""
from __future__ import annotations

import enum
import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional, Tuple

import numpy as np

from repro.obs.tracer import NULL_TRACER, Tracer
from repro.serve.paged_cache import (BlockAllocator, PrefixKey, PrefixMatch,
                                     RECLAIMED)


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


_rid_counter = itertools.count()


@dataclass(eq=False)    # identity equality: lists of Requests use `is`
class Request:
    """One generation request plus its recorded per-token provenance."""

    prompt: np.ndarray               # [P] int32 token ids (no padding)
    max_new_tokens: int
    request_id: int = field(default_factory=lambda: next(_rid_counter))
    state: RequestState = RequestState.WAITING
    slot: Optional[int] = None
    # Home shard of a placed request: all `blocks` are local ids on this
    # shard's pool slice (always 0 with an unsharded allocator).
    shard: Optional[int] = None
    blocks: List[int] = field(default_factory=list)
    # Per emitted token: id, behavior log-prob, producing policy version.
    tokens: List[int] = field(default_factory=list)
    log_beta: List[float] = field(default_factory=list)
    versions: List[int] = field(default_factory=list)
    submit_time: float = field(default_factory=time.monotonic)
    # When the request last entered the waiting queue (submit or
    # preemption re-queue): admission queue-wait is measured from here,
    # NOT from submit_time (which anchors TTFT).
    queued_time: float = field(default_factory=time.monotonic)
    first_token_time: Optional[float] = None   # admission latency probe
    last_emit_time: Optional[float] = None     # inter-token latency probe
    # Most recent admission wall-clock (set by the engine): splits TTFT
    # into queue-wait (submit -> admit) vs prefill-compute (admit ->
    # first token).  Re-admissions overwrite it, so a preempted-before-
    # first-token request books its earlier attempts as queue time.
    admit_time: Optional[float] = None
    finish_time: Optional[float] = None
    finish_reason: Optional[str] = None
    num_preemptions: int = 0
    # Prefix-cache bookkeeping, set at admission: how many leading
    # token rows are already resident (shared pages), and — when the
    # match ends mid-page — the source page whose leading `cow_rows`
    # rows the engine must copy into `blocks[num_shared_full]` before
    # its suffix prefill (copy-on-write; the source ref is held until
    # the copy lands).
    num_matched: int = 0
    num_shared_full: int = 0
    cow_src: Optional[Tuple[int, int]] = None   # (page, rows)
    # Chunked-prefill progress: KV rows computed so far (starts at
    # ``num_matched`` on admission) and whether the prefill has landed
    # in full.  A request with ``prefill_done == False`` holds its pages
    # but is not decode-eligible; the engine advances ``num_prefilled``
    # tile by tile and flips the flag when the last chunk lands (the
    # legacy one-dispatch prefill flips it immediately).
    num_prefilled: int = 0
    prefill_done: bool = True
    # Per-request wall-clock budget (seconds from submit_time); None
    # defers to the scheduler-wide default.  Enforced by expire().
    deadline_s: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def num_cached(self) -> int:
        """KV rows resident once (re)prefilled: prompt + all emitted
        tokens except the pending one (written by the next decode)."""
        return self.prompt_len + max(len(self.tokens) - 1, 0)


class ContinuousBatchingScheduler:
    """Slot/page bookkeeping for the serve engine's decode loop."""

    def __init__(
        self,
        allocator: BlockAllocator,
        *,
        max_batch: int,
        max_blocks_per_request: int,
        prefix_fn: Optional[Callable[[Request], PrefixKey]] = None,
        reclaim_window: Optional[int] = None,
        tracer: Tracer = NULL_TRACER,
        request_deadline_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
        registry: Optional[object] = None,
    ) -> None:
        self.allocator = allocator
        self.tracer = tracer
        self.max_batch = max_batch
        self.max_blocks_per_request = max_blocks_per_request
        # Content address of a request's committed ids (engine-provided,
        # version-salted).  None disables prefix matching at admission.
        self.prefix_fn = prefix_fn
        # Widest attention window across layers when EVERY layer is
        # windowed: pages entirely behind it are released back to the
        # pool each round.  None keeps all pages resident.
        self.reclaim_window = reclaim_window
        self.waiting: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * max_batch
        self._admission_order: List[Request] = []   # oldest first
        self.preemptions = 0
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.prefix_matched_tokens = 0
        self.reclaimed_pages = 0
        # Per-request deadlines: default budget, injectable clock (tests
        # drive expiry deterministically), timeout bookkeeping.
        self.request_deadline_s = request_deadline_s
        self._clock = clock
        self.registry = registry
        self.timeouts = 0
        self.timeouts_by_state: dict = {}

    # -- introspection -------------------------------------------------------

    @property
    def running(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    @property
    def has_work(self) -> bool:
        return bool(self.waiting) or any(
            r is not None for r in self.slots)

    # -- lifecycle -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        total = req.prompt_len + req.max_new_tokens
        cap = self.max_blocks_per_request * self.allocator.block_size
        if total > cap:
            raise ValueError(
                f"request {req.request_id} needs {total} token rows > "
                f"table capacity {cap}")
        # A request lives entirely on one shard, so the bound is the
        # per-shard slice (= the whole pool when unsharded).
        if self.allocator.blocks_for(total) > self.allocator.shard_num_blocks:
            raise ValueError(
                f"request {req.request_id} can never fit one pool shard "
                f"({total} rows > {self.allocator.shard_num_blocks} pages "
                f"x {self.allocator.block_size})")
        req.state = RequestState.WAITING
        req.queued_time = time.monotonic()
        self.waiting.append(req)
        tr = self.tracer
        if tr.enabled:
            tr.instant("submit", tid="scheduler", rid=req.request_id,
                       prompt_len=req.prompt_len,
                       max_new=req.max_new_tokens)
            tr.async_begin("waiting", req.request_id)

    def _release_all(self, req: Request) -> None:
        """Drop every page reference `req` holds (RECLAIMED sentinels
        were already released; a pending COW source ref too)."""
        shard = req.shard or 0
        if not req.prefill_done:
            self._abort_prefill(req, shard)
        self.allocator.release(
            [b for b in req.blocks if b != RECLAIMED], shard)
        if req.cow_src is not None:
            self.allocator.release([req.cow_src[0]], shard)
            req.cow_src = None
        req.blocks = []
        req.shard = None
        req.num_matched = 0
        req.num_shared_full = 0
        req.num_prefilled = 0

    def _abort_prefill(self, req: Request, shard: int) -> None:
        """A partially-prefilled request is going away.  Rows past its
        ``num_prefilled`` were never computed, so (a) the registered but
        not-yet-complete pages must leave the prefix index before any
        future admission can match their garbage rows, and (b) a running
        request that already shares one of them (it was gated waiting
        for those rows to land) must recompute from scratch.  Fires
        exactly once per prefill attempt — re-admission starts a new
        one.  Requests that already *started* computing against this
        chain only ever read rows the owner had finished, so they are
        untouched (their shared pages are disjoint from the bad set)."""
        req.prefill_done = True
        if self.prefix_fn is None or \
                not getattr(self.allocator, "prefix_cache", False):
            return
        bs = self.allocator.block_size
        bad = {b for j, b in enumerate(req.blocks)
               if b != RECLAIMED and (j + 1) * bs > req.num_prefilled}
        if not bad:
            return
        self.allocator.unregister(bad, shard)
        if self.tracer.enabled:
            self.tracer.instant(
                "prefill_abort", tid="scheduler", rid=req.request_id,
                prefilled=req.num_prefilled, pages=len(bad))
        for r in list(self.running):
            if r is req or (r.shard or 0) != shard:
                continue
            shared = set(r.blocks[:r.num_shared_full])
            if r.cow_src is not None:
                shared.add(r.cow_src[0])
            if shared & bad:
                self._preempt(r)

    def retire(self, req: Request, reason: str) -> None:
        """Finish a request: release its pages copy-free, free the slot.

        With prefix caching on, "release" only drops this request's
        references — pages other live block tables point at stay put,
        and registered pages park on the evictable LRU for future
        matches instead of returning to the free list outright.

        Idempotent: retiring an already-FINISHED request is a no-op, so
        a deadline expiry racing the engine's own finish path (or a
        preemption list naming a request a timeout just killed) can
        never double-release pages or double-decrement prefix-cache
        refcounts.
        """
        if req.state is RequestState.FINISHED:
            return
        was_running = req.state is RequestState.RUNNING
        req.state = RequestState.FINISHED
        req.finish_reason = reason
        req.finish_time = time.monotonic()
        tr = self.tracer
        if tr.enabled:
            if was_running:
                tr.async_end("running", req.request_id)
            else:
                tr.async_end("waiting", req.request_id)
            tr.instant("retire", tid="scheduler", rid=req.request_id,
                       reason=reason, tokens=len(req.tokens),
                       state="running" if was_running else "waiting",
                       preemptions=req.num_preemptions)
        self._release_all(req)
        if req.slot is not None:
            self.slots[req.slot] = None
            req.slot = None
        if req in self._admission_order:
            self._admission_order.remove(req)
        if req in self.waiting:
            self.waiting.remove(req)

    def expire(self, now: Optional[float] = None) -> List[Request]:
        """Retire every request past its deadline; returns them.

        A request's budget is ``deadline_s`` (or the scheduler default)
        seconds of wall clock from ``submit_time`` — preemptions do not
        reset it (the user has been waiting the whole time).  Expired
        RUNNING requests release their slot + pages/refcounts through
        the one :meth:`retire` path; expired WAITING requests leave the
        queue before they can be admitted.  The engine turns each into
        a (possibly empty) ``finish_reason="timeout"`` trajectory.
        """
        candidates = [
            r for r in list(self.waiting) + self.running
            if (r.deadline_s if r.deadline_s is not None
                else self.request_deadline_s) is not None
        ]
        if not candidates:
            return []
        if now is None:
            now = self._clock()
        expired: List[Request] = []
        for req in candidates:
            budget = (req.deadline_s if req.deadline_s is not None
                      else self.request_deadline_s)
            if now - req.submit_time <= budget:
                continue
            state = req.state.value
            self.retire(req, "timeout")
            self.timeouts += 1
            self.timeouts_by_state[state] = (
                self.timeouts_by_state.get(state, 0) + 1)
            if self.registry is not None:
                self.registry.counter(
                    "request_timeout_total", state=state).inc()
            expired.append(req)
        return expired

    def _preempt(self, victim: Request) -> None:
        if victim.state is RequestState.FINISHED:
            return    # lost the race against a timeout retirement
        self.preemptions += 1
        victim.num_preemptions += 1
        tr = self.tracer
        if tr.enabled:
            tr.async_end("running", victim.request_id)
            tr.instant("preempt", tid="scheduler",
                       rid=victim.request_id, shard=victim.shard or 0,
                       tokens=len(victim.tokens))
            tr.async_begin("waiting", victim.request_id)
        self._release_all(victim)
        if victim.slot is not None:
            self.slots[victim.slot] = None
            victim.slot = None
        self._admission_order.remove(victim)
        victim.state = RequestState.WAITING
        victim.queued_time = time.monotonic()
        self.waiting.appendleft(victim)

    # -- shard placement ------------------------------------------------------

    def _live_slots_by_shard(self) -> List[int]:
        live = [0] * self.allocator.num_shards
        for r in self.running:
            live[r.shard or 0] += 1
        return live

    def _place(self, total: int,
               matches: Optional[List[PrefixMatch]] = None
               ) -> Optional[int]:
        """Home shard for an admission needing `total` pages, or None.

        The shard holding the **longest resident prefix match** wins
        (page ids are shard-local, so a match is only usable on its own
        shard); then fewest live slots (decode work balances across the
        mesh); ties break to the most free pages, then the lowest shard
        id.  Single-shard allocators always place on shard 0, so the
        unsharded scheduler is unchanged.
        """
        live = self._live_slots_by_shard()
        best = None
        for s in range(self.allocator.num_shards):
            m = matches[s] if matches else PrefixMatch()
            # Fresh pages to pop, plus revivals: sharing a zero-ref
            # cached page pulls it off the evictable LRU, shrinking
            # allocatable capacity by one just like a fresh pop.
            need = total - len(m.full_pages)
            for p in m.full_pages:
                if self.allocator.ref(p, s) == 0:
                    need += 1
            if m.cow_page is not None and \
                    self.allocator.ref(m.cow_page, s) == 0:
                need += 1
            if not self.allocator.can_allocate(need, s):
                continue
            key = (-m.matched_tokens, live[s],
                   -self.allocator.shard_free(s), s)
            if best is None or key < best[0]:
                best = (key, s)
        return None if best is None else best[1]

    # -- the per-step decision -----------------------------------------------

    def _rows_needed(self, req: Request, lookahead: int) -> int:
        """KV rows `req` must own to run `lookahead` decode writes.

        Capped at the request's lifetime row count (prompt + budget):
        a request never writes its final emitted token's row.
        """
        writes = min(lookahead, req.max_new_tokens - len(req.tokens) + 1)
        return min(req.num_cached + max(writes, 1),
                   req.prompt_len + req.max_new_tokens)

    def schedule(self, lookahead: int = 1
                 ) -> Tuple[List[Request], List[Request]]:
        """Returns (admitted, preempted) for the next decode round.

        Admitted requests need a (re)prefill before the round;
        preempted ones have left their slots.  Every request still
        running after this call owns pages for its next `lookahead` KV
        writes (the engine's multi-step decode chunk runs that many
        steps without a scheduling point).
        """
        preempted: List[Request] = []

        # 0. Window reclamation: when every layer's attention is
        # windowed, KV rows at positions <= q - W are masked for all
        # future queries q' >= q, so pages entirely behind the widest
        # window can go back to the pool.  The table entry becomes a
        # RECLAIMED sentinel (later pages keep their positional slots;
        # padded_table maps it to page 0, whose garbage the window mask
        # hides).
        if self.reclaim_window is not None:
            bs = self.allocator.block_size
            for req in self.running:
                # A mid-prefill request's oldest *future* query sits at
                # num_prefilled, not num_cached — reclaim only behind it.
                rows = (req.num_cached if req.prefill_done
                        else req.num_prefilled)
                horizon = rows - self.reclaim_window
                for j, b in enumerate(req.blocks):
                    if (j + 1) * bs - 1 > horizon:
                        break
                    if b == RECLAIMED:
                        continue
                    self.allocator.release([b], req.shard or 0)
                    req.blocks[j] = RECLAIMED
                    self.reclaimed_pages += 1

        # 1. Extend running requests that cross a page boundary.  Pool
        # pressure is per-shard: only a victim on the same shard frees
        # pages the starved request can use, so the LIFO choice walks
        # that shard's admissions (the whole pool when unsharded).
        for req in list(self._admission_order):
            if req.slot is None:
                continue
            shard = req.shard or 0
            need = (
                self.allocator.blocks_for(self._rows_needed(req, lookahead))
                - len(req.blocks)
            )
            while need > 0 and not self.allocator.can_allocate(need, shard):
                victim = next(
                    r for r in reversed(self._admission_order)
                    if (r.shard or 0) == shard)
                self._preempt(victim)
                preempted.append(victim)
                if victim is req:
                    need = 0    # preempted itself; nothing to extend
            if need > 0:
                req.blocks.extend(self.allocator.allocate(need, shard))

        # 2. Admit from the waiting queue into free slots (FIFO), placing
        # each admission on its home shard — preferring the shard that
        # holds the longest resident prefix of its committed ids, whose
        # pages it then shares (refcount bump) instead of recomputing.
        admitted: List[Request] = []
        while self.waiting:
            free_slots = [i for i, r in enumerate(self.slots) if r is None]
            if not free_slots:
                break
            req = self.waiting[0]
            total = self.allocator.blocks_for(
                self._rows_needed(req, lookahead))
            key, matches = self._match(req)
            shard = self._place(total, matches)
            if shard is None:
                break
            self.waiting.popleft()
            req.shard = shard
            self._commit_match(req, key,
                               matches[shard] if matches else None,
                               total, shard)
            req.num_prefilled = req.num_matched
            req.slot = free_slots[0]
            req.state = RequestState.RUNNING
            self.slots[req.slot] = req
            self._admission_order.append(req)
            admitted.append(req)
            tr = self.tracer
            if tr.enabled:
                tr.async_end("waiting", req.request_id)
                tr.async_begin("running", req.request_id,
                               slot=req.slot, shard=shard,
                               matched=req.num_matched)
        return admitted, preempted

    # -- prefix matching at admission -----------------------------------------

    def _match(self, req: Request
               ) -> Tuple[Optional[PrefixKey],
                          Optional[List[PrefixMatch]]]:
        """Per-shard resident-prefix matches for `req`, or (None, None)
        when prefix caching is off.  At least one token is always left
        to compute — the admission must produce a logit to sample."""
        if self.prefix_fn is None or \
                not getattr(self.allocator, "prefix_cache", False):
            return None, None
        key = self.prefix_fn(req)
        limit = req.num_cached - 1
        self.prefix_queries += 1
        return key, [self.allocator.lookup(key, limit, s)
                     for s in range(self.allocator.num_shards)]

    def _commit_match(self, req: Request, key: Optional[PrefixKey],
                      match: Optional[PrefixMatch], total: int,
                      shard: int) -> None:
        """Build `req.blocks`: shared matched pages first (pinned before
        any allocation can evict them), then fresh pages; reserve the
        COW source and index the fresh pages for future admissions."""
        if match is None:
            req.blocks = self.allocator.allocate(total, shard)
            return
        for p in match.full_pages:
            self.allocator.share(p, shard)
        if match.cow_page is not None and match.cow_rows > 0:
            self.allocator.share(match.cow_page, shard)
            req.cow_src = (match.cow_page, match.cow_rows)
        fresh = self.allocator.allocate(
            total - len(match.full_pages), shard)
        req.blocks = list(match.full_pages) + fresh
        req.num_shared_full = len(match.full_pages)
        req.num_matched = match.matched_tokens
        if match.matched_tokens > 0:
            self.prefix_hits += 1
            self.prefix_matched_tokens += match.matched_tokens
        if key is not None:
            self.allocator.register(key, req.blocks,
                                    len(match.full_pages), shard)
