"""Continuous-batching decode engine with in-flight versioned weight swap.

One :meth:`ServeEngine.step` is one decode iteration for *all* running
slots: the scheduler first admits/preempts/extends (so the batch stays
full), admitted requests are prefilled into their pages, then a single
jitted ``decode_step_paged`` advances every active slot one token
through the paged-attention kernel.  Requests retire the moment they
emit EOS or hit their own ``max_new_tokens`` — nobody waits for the
slowest row, which is the entire throughput argument continuous
batching makes over the phase-locked ``rollout.sampler.generate`` loop
(kept as the static-batch fallback).

**In-flight weight swap**: when constructed over a
``runtime.PolicyStore``, the engine re-reads ``store.latest()`` every
``swap_interval`` steps — *between* decode steps, never inside one — so
a learner publish lands mid-generation.  Every emitted token records
the policy version that produced its logits; a finished trajectory
therefore carries a per-token version vector and per-token ``log_beta``
(the β_T term), exactly the provenance the paper's TV machinery needs
when the behavior policy changes *within* a trajectory
(``runtime.admission.TokenwiseTVGate`` consumes it per version
segment).

Preemption recomputes KV (re-prefill over prompt + already-emitted
tokens) rather than retracting tokens: emitted tokens may already be
streamed to a client and their recorded (log_beta, version) provenance
stays valid — the re-prefill only rebuilds cache rows.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS, PAD
from repro.models.registry import ModelBundle
from repro.models.transformer import write_prefill_to_pages
from repro.rollout.sampler import _top_p_filter
from repro.serve.paged_cache import BlockAllocator
from repro.serve.scheduler import ContinuousBatchingScheduler, Request


@dataclass(frozen=True)
class ServedTrajectory:
    """A finished request with per-token provenance.

    ``versions[t]`` is the policy version whose logits produced
    ``tokens[t]`` — constant when no swap happened mid-request, a step
    function across swap boundaries otherwise.  ``behavior_version`` is
    the *oldest* of them (the conservative representative the runtime's
    max-lag admission keys on, matching the mixture regime's
    convention).
    """

    request_id: int
    prompt: np.ndarray          # [P] int32
    tokens: np.ndarray          # [N] int32 (includes EOS when emitted)
    log_beta: np.ndarray        # [N] float32 behavior log-probs
    versions: np.ndarray        # [N] int64 producing policy versions
    mask: np.ndarray            # [N] float32 (all ones; EOS is scored)
    finish_reason: str          # "eos" | "length"
    latency_s: float            # submit -> finish wall time
    num_preemptions: int

    @property
    def behavior_version(self) -> int:
        return int(self.versions.min()) if self.versions.size else 0

    @property
    def num_tokens(self) -> int:
        return int(self.tokens.shape[0])


@dataclass
class ServeStats:
    steps: int = 0               # scheduling rounds (one chunk each)
    decode_steps: int = 0        # individual decode iterations
    prefills: int = 0
    finished: int = 0
    tokens_out: int = 0
    preemptions: int = 0
    swaps: int = 0
    occupancy_sum: float = 0.0   # emitting slots summed over decode steps

    def as_dict(self) -> Dict[str, Any]:
        d = dict(self.__dict__)
        d["mean_occupancy"] = (
            self.occupancy_sum / self.decode_steps
            if self.decode_steps else 0.0
        )
        return d


class ServeEngine:
    """Paged-KV continuous-batching generation over a ModelBundle."""

    def __init__(
        self,
        bundle: ModelBundle,
        params: Any = None,
        *,
        num_blocks: int = 64,
        block_size: int = 8,
        max_batch: int = 4,
        max_seq_len: int = 256,
        decode_chunk: int = 1,
        store: Any = None,            # Optional[runtime.PolicyStore]
        swap_interval: int = 1,
        temperature: float = 1.0,
        top_p: float = 1.0,
        seed: int = 0,
        kernel_mode: Optional[str] = None,
    ) -> None:
        if bundle.decode_step_paged is None:
            from repro.models.transformer import paged_arch_unsupported

            raise ValueError(
                f"{bundle.cfg.name}: {paged_arch_unsupported(bundle.cfg)}")
        if params is None and store is None:
            raise ValueError("need params or a PolicyStore")
        self.bundle = bundle
        self.store = store
        self.swap_interval = max(int(swap_interval), 1)
        if store is not None:
            self.params, self.version = store.latest()
        else:
            self.params, self.version = params, 0
        self.block_size = block_size
        max_blocks_per_request = -(-max_seq_len // block_size)
        self.allocator = BlockAllocator(num_blocks, block_size)
        self.scheduler = ContinuousBatchingScheduler(
            self.allocator, max_batch=max_batch,
            max_blocks_per_request=max_blocks_per_request)
        self.pages = bundle.init_paged_cache(num_blocks, block_size)
        self.max_batch = max_batch
        self._tables = np.zeros(
            (max_batch, max_blocks_per_request), np.int32)
        self._pos = np.zeros((max_batch,), np.int32)
        self._active = np.zeros((max_batch,), bool)
        self._last_tok = np.zeros((max_batch,), np.int32)
        self._key = jax.random.PRNGKey(seed)
        self.stats = ServeStats()
        self._kernel_mode = kernel_mode
        temp = max(float(temperature), 1e-6)

        def _sample(logits, key):
            logits = logits.astype(jnp.float32) / temp
            logits = _top_p_filter(logits, top_p)
            tok = jax.random.categorical(key, logits, axis=-1)
            logp = jax.nn.log_softmax(logits, axis=-1)
            lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
            return tok.astype(jnp.int32), lp

        chunk = max(int(decode_chunk), 1)
        self.decode_chunk = chunk

        def _decode(params, token, pages, tables, pos, active, remaining,
                    key):
            """`chunk` decode steps in one dispatch (lax.scan).

            Multi-step decode amortizes the per-step host round-trip —
            the cost that otherwise hands the phase-locked loop (whose
            whole decode is one fused scan) most of the continuous
            engine's structural win back.  Rows terminate *in-graph*
            (EOS or per-request budget via `remaining`); a retiring row
            idles masked until the chunk ends, bounding wasted work at
            chunk-1 steps per retirement.
            """
            def body(carry, k_t):
                token, pos, active, emitted, pages = carry
                out, pages = bundle.decode_step_paged(
                    params, token, pages, tables, pos, active,
                    kernel_mode=kernel_mode)
                tok, lp = _sample(out.logits, k_t)
                mask = active
                tok = jnp.where(active, tok, jnp.int32(PAD))
                lp = jnp.where(active, lp, 0.0)
                pos = pos + active.astype(jnp.int32)
                emitted = emitted + active.astype(jnp.int32)
                active = jnp.logical_and(active, tok != EOS)
                active = jnp.logical_and(active, emitted < remaining)
                return (tok, pos, active, emitted, pages), (tok, lp, mask)

            keys = jax.random.split(key, chunk)
            carry = (token, pos, active, jnp.zeros_like(pos), pages)
            (_, _, _, _, pages), (toks, lps, masks) = jax.lax.scan(
                body, carry, keys)
            return toks, lps, masks, pages

        # Pages are donated and every op that touches them inside the
        # dispatch is in-place-able (decode_step_paged's hoisted layer
        # loop + kernels.ops.paged_kv_write), so the pool is updated
        # in place end to end: per-chunk cost is O(rows written), flat
        # in num_blocks (bench_serve --sweep-blocks measures it).
        self._decode = jax.jit(_decode, donate_argnums=(2,))
        self._prefill_fns: Dict[int, Any] = {}   # keyed by padded length

        def _make_prefill(padded_len: int):
            def _prefill(params, prompt, kv_valid, blocks, plen, pages,
                         key):
                out = bundle.forward(
                    params, prompt, return_cache=True,
                    cache_len=padded_len, kv_valid=kv_valid)
                # Donated pages + per-tile dynamic_update_slice writes:
                # the prefill lands in the pool without copying it.
                pages = write_prefill_to_pages(
                    out.cache["k"], out.cache["v"], pages, blocks, plen)
                last = jnp.take(out.logits[0], plen - 1, axis=0)
                tok, lp = _sample(last[None], key)
                return tok[0], lp[0], pages

            return jax.jit(_prefill, donate_argnums=(5,))

        self._make_prefill = _make_prefill

    # -- request intake ------------------------------------------------------

    def submit(
        self,
        prompt: Sequence[int] | np.ndarray,
        max_new_tokens: int,
        request_id: Optional[int] = None,
    ) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        kw = {} if request_id is None else {"request_id": request_id}
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens, **kw)
        self.scheduler.submit(req)
        return req

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    # -- internals -----------------------------------------------------------

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def _maybe_swap(self) -> None:
        if self.store is None:
            return
        if self.stats.steps % self.swap_interval != 0:
            return
        params, version = self.store.latest()
        if version != self.version:
            self.params, self.version = params, version
            self.stats.swaps += 1

    def _prefill(self, req: Request, finished: List[ServedTrajectory]
                 ) -> None:
        """(Re)compute KV rows for prompt + emitted tokens; fresh
        requests also sample their first token from the prefill logits."""
        slot = req.slot
        resume = bool(req.tokens)
        ids = req.prompt if not resume else np.concatenate(
            [req.prompt, np.asarray(req.tokens[:-1], np.int32)])
        plen = int(ids.shape[0])
        padded = -(-plen // self.block_size) * self.block_size
        fn = self._prefill_fns.get(padded)
        if fn is None:
            fn = self._prefill_fns[padded] = self._make_prefill(padded)
        row = np.zeros((1, padded), np.int32)
        row[0, :plen] = ids
        kv_valid = np.zeros((1, padded), bool)
        kv_valid[0, :plen] = True
        table = self.allocator.padded_table(
            req.blocks, self._tables.shape[1])
        tok, lp, self.pages = fn(
            self.params, jnp.asarray(row), jnp.asarray(kv_valid),
            jnp.asarray(table), jnp.int32(plen), self.pages,
            self._next_key())
        self.stats.prefills += 1
        self._tables[slot] = table
        self._pos[slot] = plen
        if resume:
            self._last_tok[slot] = req.tokens[-1]
        else:
            self._record(req, int(tok), float(lp), finished)

    def _record(self, req: Request, tok: int, lp: float,
                finished: List[ServedTrajectory]) -> None:
        """Book one emitted token; retire the request when done."""
        req.tokens.append(tok)
        req.log_beta.append(lp)
        req.versions.append(self.version)
        self.stats.tokens_out += 1
        if tok == EOS:
            self._finish(req, "eos", finished)
        elif len(req.tokens) >= req.max_new_tokens:
            self._finish(req, "length", finished)
        else:
            self._last_tok[req.slot] = tok

    def _finish(self, req: Request, reason: str,
                finished: List[ServedTrajectory]) -> None:
        slot = req.slot
        self.scheduler.retire(req, reason)
        self._clear_slot(slot)
        self.stats.finished += 1
        n = len(req.tokens)
        finished.append(ServedTrajectory(
            request_id=req.request_id,
            prompt=req.prompt,
            tokens=np.asarray(req.tokens, np.int32),
            log_beta=np.asarray(req.log_beta, np.float32),
            versions=np.asarray(req.versions, np.int64),
            mask=np.ones((n,), np.float32),
            finish_reason=reason,
            latency_s=req.finish_time - req.submit_time,
            num_preemptions=req.num_preemptions,
        ))

    def _clear_slot(self, slot: Optional[int]) -> None:
        if slot is None:
            return
        self._active[slot] = False
        self._tables[slot] = 0
        self._pos[slot] = 0
        self._last_tok[slot] = 0

    # -- the decode loop -----------------------------------------------------

    def step(self) -> List[ServedTrajectory]:
        """One scheduling round + decode chunk; returns newly finished
        trajectories."""
        finished: List[ServedTrajectory] = []
        self._maybe_swap()
        self.stats.steps += 1
        admitted, _ = self.scheduler.schedule(lookahead=self.decode_chunk)
        self.stats.preemptions = self.scheduler.preemptions
        for req in admitted:
            self._prefill(req, finished)
        # Rebuild slot state from the scheduler: preempted/retired slots
        # (their Request no longer knows its old index) go quiet, and
        # running rows pick up pages the extension pass just granted.
        by_slot = {r.slot: r for r in self.scheduler.running}
        remaining = np.zeros((self.max_batch,), np.int32)
        for slot in range(self.max_batch):
            req = by_slot.get(slot)
            if req is None:
                self._clear_slot(slot)
            else:
                self._active[slot] = True
                self._tables[slot] = self.allocator.padded_table(
                    req.blocks, self._tables.shape[1])
                remaining[slot] = req.max_new_tokens - len(req.tokens)
        if not self._active.any():
            return finished
        toks, lps, masks, self.pages = self._decode(
            self.params, jnp.asarray(self._last_tok), self.pages,
            jnp.asarray(self._tables), jnp.asarray(self._pos),
            jnp.asarray(self._active), jnp.asarray(remaining),
            self._next_key())
        toks_np = np.asarray(toks)       # [chunk, B]
        lps_np = np.asarray(lps)
        masks_np = np.asarray(masks)
        self.stats.occupancy_sum += float(masks_np.sum())
        self.stats.decode_steps += self.decode_chunk
        for req in list(self.scheduler.running):
            slot = req.slot
            self._pos[slot] += int(masks_np[:, slot].sum())
            for t in range(self.decode_chunk):
                if not masks_np[t, slot]:
                    break
                self._record(req, int(toks_np[t, slot]),
                             float(lps_np[t, slot]), finished)
        return finished

    def run(self, max_steps: Optional[int] = None
            ) -> List[ServedTrajectory]:
        """Step until every submitted request finished (or max_steps)."""
        out: List[ServedTrajectory] = []
        steps = 0
        while self.has_work:
            out.extend(self.step())
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return out
