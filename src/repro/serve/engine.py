"""Continuous-batching decode engine with in-flight versioned weight swap.

One :meth:`ServeEngine.step` is one decode iteration for *all* running
slots: the scheduler first admits/preempts/extends (so the batch stays
full), admitted requests are prefilled into their pages, then a single
jitted ``decode_step_paged`` advances every active slot one token
through the paged-attention kernel.  Requests retire the moment they
emit EOS or hit their own ``max_new_tokens`` — nobody waits for the
slowest row, which is the entire throughput argument continuous
batching makes over the phase-locked ``rollout.sampler.generate`` loop
(kept as the static-batch fallback).

**In-flight weight swap**: when constructed over a
``runtime.PolicyStore``, the engine re-reads ``store.latest()`` every
``swap_interval`` steps — *between* decode steps, never inside one — so
a learner publish lands mid-generation.  Every emitted token records
the policy version that produced its logits; a finished trajectory
therefore carries a per-token version vector and per-token ``log_beta``
(the β_T term), exactly the provenance the paper's TV machinery needs
when the behavior policy changes *within* a trajectory
(``runtime.admission.TokenwiseTVGate`` consumes it per version
segment).

Preemption recomputes KV (re-prefill over prompt + already-emitted
tokens) rather than retracting tokens: emitted tokens may already be
streamed to a client and their recorded (log_beta, version) provenance
stays valid — the re-prefill only rebuilds cache rows.

**Speculative decode** (``speculate_k > 0``): a cheap *draft* policy
proposes ``k`` tokens per slot and the latest policy scores all of them
in one multi-token dispatch (``decode_step_paged_multi``), accepting a
prefix via the Leviathan accept rule (``rollout.sampler.
speculative_accept``).  The draft slot is either **self-speculation** —
a lagged PolicyStore version, pinned so learner publishes can't evict
it, which turns the very staleness the paper studies into actor-side
throughput — a separate small draft model, or a host callable (the
benchmark's zero-cost oracle).  Model drafts keep their *own* paged
pool addressed by the same block tables (draft K/V differ from verifier
K/V), so rollback after a rejection is a pure ``pos`` rewind on both:
rejected rows are simply overwritten by the next chunk, no page copies,
no retraction of emitted tokens, and preemption's recompute path is
untouched.  Emitted tokens are distributed exactly as the verifier's
policy, so per-token ``log_beta``/``version`` provenance — and
everything downstream that consumes it (TV-gate admission) — is
identical to non-speculative serving; speculative greedy decode is
token-exact with non-speculative greedy decode at any acceptance rate.

**Chunked ragged prefill** (``chunked_prefill=True``, default): an
admission's unmatched suffix is split into tiles of ``prefill_chunk``
rows and streamed through the same varlen paged kernel the decode and
verify steps use (``decode_step_paged_varlen``) — one dispatch per
round carries every decode-eligible slot's single-token row *and* the
pending prefill tiles as ragged ``(row_start, row_len)`` rows, bounded
by ``dispatch_budget`` tokens.  A long prompt therefore never blocks
in-flight decodes for a full prefill dispatch: decode rows ride every
round (they are reserved out of the budget first) and the prompt
streams in beside them, which is what bounds p99 inter-token latency
under bursty long-prompt load (``benchmarks.bench_serve --burst``
measures exactly that).  A partially-prefilled request holds its pages
but is not decode-eligible until its last chunk lands; greedy output
is token-exact with the unchunked engine, prefix cache, speculation
and sharding included.

**Batched prefill** (``chunked_prefill=False`` + ``batch_prefill=
True``): the legacy one-dispatch-per-padded-length prefill path, kept
behind a ``DeprecationWarning`` for comparison benchmarks; admissions
of the same padded prompt length stack into one prefill dispatch.

**Sharded serve** (``mesh=...``): the paged pool partitions its NB
(page) axis over the mesh's ``data`` axis; the scheduler places every
request's pages on ONE shard (balancing live slots per shard) and the
paged kernels dispatch through ``shard_map`` (``kernels.ops``) with
shard-local block tables — foreign slots mask to zero and a psum
recombines the batch, so sharded greedy output is **token-exact** with
the single-device engine, speculation and preemption included, and the
per-shard pool buffers still update in place.  ``mesh=None`` is the
single-shard special case of the same code path.

**Prefix caching** (``prefix_cache=True``): full KV pages are
content-addressed (chained hash over token ids, salted with the policy
version and arch identity) and refcounted; admissions whose committed
ids extend a resident prefix share those pages read-only and prefill
only the unmatched suffix through the multi-token paged step — best-of-N
fan-out pays ~1x prefill instead of Nx.  A match ending mid-page is
resolved by copy-on-write *at admission prefill* (the matched rows are
copied into the request's own fresh page before its divergent suffix
appends), so decode and speculative writes only ever touch exclusively
owned pages; an in-flight weight swap invalidates stale entries through
the version salt alone.  Greedy output is token-exact with the unshared
engine — speculation, preemption and sharding included (matches are
shard-local; the scheduler prefers the shard with the longest match).

**Adaptive speculation** (``speculate_adaptive=True``): a per-slot EMA
of the measured draft acceptance rate adapts the per-round draft
length between 1 and ``speculate_k`` — slots that keep rejecting stop
paying for long drafts; the chosen-k histogram lands in
``collect_serve_stats``.
"""
from __future__ import annotations

import contextlib
import time
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS, PAD
from repro.distributed.sharding import replicated, shard_paged_pool
from repro.kernels.ops import mesh_data_size
from repro.metrics.runtime_metrics import LagHistogram, collect_serve_stats
from repro.models.registry import ModelBundle
from repro.obs.perfetto import trace_annotation
from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import NULL_TRACER, Tracer
from repro.models.transformer import (copy_page_rows,
                                      write_prefill_batch_to_pages)
from repro.rollout.sampler import _top_p_filter, speculative_accept
from repro.serve.paged_cache import (RECLAIMED, PrefixKey, make_allocator,
                                     prefix_key)
from repro.serve.scheduler import (
    ContinuousBatchingScheduler,
    Request,
    RequestState,
)


@dataclass(frozen=True)
class ServedTrajectory:
    """A finished request with per-token provenance.

    ``versions[t]`` is the policy version whose logits produced
    ``tokens[t]`` — constant when no swap happened mid-request, a step
    function across swap boundaries otherwise.  ``behavior_version`` is
    the *oldest* of them (the conservative representative the runtime's
    max-lag admission keys on, matching the mixture regime's
    convention).
    """

    request_id: int
    prompt: np.ndarray          # [P] int32
    tokens: np.ndarray          # [N] int32 (includes EOS when emitted)
    log_beta: np.ndarray        # [N] float32 behavior log-probs
    versions: np.ndarray        # [N] int64 producing policy versions
    mask: np.ndarray            # [N] float32 (all ones; EOS is scored)
    finish_reason: str          # "eos" | "length"
    latency_s: float            # submit -> finish wall time
    num_preemptions: int

    @property
    def behavior_version(self) -> int:
        return int(self.versions.min()) if self.versions.size else 0

    @property
    def num_tokens(self) -> int:
        return int(self.tokens.shape[0])


@dataclass
class ServeStats:
    steps: int = 0               # scheduling rounds (one chunk each)
    decode_steps: int = 0        # decode iterations (1 per spec round)
    prefills: int = 0            # requests prefilled
    prefill_dispatches: int = 0  # prefill launches (< prefills when batched)
    finished: int = 0
    tokens_out: int = 0
    preemptions: int = 0
    swaps: int = 0
    occupancy_sum: float = 0.0   # emitting slots summed over decode steps
    # Speculative decode: drafted = k per active slot per round;
    # accepted counts draft tokens that survived verification
    # (corrections are emitted but not "accepted").
    spec_rounds: int = 0
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    # Prefix cache: KV rows actually computed by prefill dispatches
    # (suffix-only under a prefix hit) and COW page copies performed.
    prefill_tokens: int = 0
    cow_copies: int = 0
    # Resilience: deadline-expired requests retired with a (possibly
    # empty) "timeout" trajectory, and speculation auto-disable events.
    timeouts: int = 0
    spec_autodisables: int = 0

    def as_dict(self) -> Dict[str, Any]:
        d = dict(self.__dict__)
        d["mean_occupancy"] = (
            self.occupancy_sum / self.decode_steps
            if self.decode_steps else 0.0
        )
        d["acceptance_rate"] = (
            self.accepted_tokens / self.drafted_tokens
            if self.drafted_tokens else 0.0
        )
        return d


class ModelDraft:
    """Draft policy with its own paged pool (self-spec or a small model).

    ``version`` is the PolicyStore version the params were pinned from
    (None for fixed-params drafts); ``version_offset`` non-None marks a
    *self-speculation* draft that re-resolves ``latest + offset`` after
    every verifier weight swap.  The pool shares the verifier's page
    ids/tables but holds this draft's own K/V (different weights write
    different rows), so scheduler allocation covers both pools at once.
    """

    def __init__(self, bundle: ModelBundle, params: Any,
                 version: Optional[int], version_offset: Optional[int],
                 num_blocks: int, block_size: int, mesh: Any = None
                 ) -> None:
        if bundle.decode_step_paged is None or bundle.init_paged_cache is None:
            raise ValueError(
                f"draft arch {bundle.cfg.name} cannot run the paged path")
        self.bundle = bundle
        self.params = (params if mesh is None
                       else jax.device_put(params, replicated(mesh)))
        self.version = version
        self.version_offset = version_offset
        # The draft pool shards exactly like the verifier pool (same NB
        # axis, same shard-local tables), so one placement decision
        # covers both.
        self.pages = shard_paged_pool(
            bundle.init_paged_cache(num_blocks, block_size), mesh)


class CallableDraft:
    """Host-side draft: ``fn(request, k) -> up-to-k int32 token ids``.

    Zero-cost proposals (n-gram lookups, the benchmark's replay oracle).
    The proposal is treated as a deterministic one-hot draft
    distribution, which keeps the accept rule exactly
    verifier-distribution-preserving.
    """

    version: Optional[int] = None
    version_offset = None

    def __init__(self, fn: Callable[[Request, int], Any]) -> None:
        self.fn = fn


class ServeEngine:
    """Paged-KV continuous-batching generation over a ModelBundle."""

    def __init__(
        self,
        bundle: ModelBundle,
        params: Any = None,
        *,
        num_blocks: int = 64,
        block_size: int = 8,
        max_batch: int = 4,
        max_seq_len: int = 256,
        decode_chunk: int = 1,
        store: Any = None,            # Optional[runtime.PolicyStore]
        swap_interval: int = 1,
        temperature: float = 1.0,
        top_p: float = 1.0,
        seed: int = 0,
        kernel_mode: Optional[str] = None,
        speculate_k: int = 0,
        draft: Any = None,
        batch_prefill: bool = True,
        chunked_prefill: bool = True,
        prefill_chunk: int = 16,
        dispatch_budget: int = 32,
        mesh: Any = None,
        speculate_adaptive: bool = False,
        prefix_cache: bool = False,
        window_reclaim: bool = True,
        tracer: Optional[Tracer] = None,
        metrics: Optional[MetricsRegistry] = None,
        annotate: bool = False,
        injector: Any = None,
        request_deadline_s: Optional[float] = None,
        spec_disable_after: int = 8,
    ) -> None:
        """``speculate_k > 0`` turns on speculative decode; ``draft`` is
        one of ``("version", -n)`` (self-speculation from the store's
        ring, pinned), ``("params", p)`` (same arch, fixed params),
        ``("model", bundle, params)`` (separate draft model), a callable
        ``fn(request, k) -> token ids``, or None (defaults to
        ``("version", -1)`` with a store, else the verifier's own params).

        ``mesh`` (a jax Mesh with a ``data`` axis) shards the paged
        pool's NB axis over that axis; ``num_blocks`` is the TOTAL pool
        and must divide by the data-axis size.  ``speculate_adaptive``
        adapts the per-round draft length in ``[1, speculate_k]`` from
        each slot's measured acceptance EMA.

        ``chunked_prefill=True`` (default) streams each admission's prefill
        as ragged tiles of ``prefill_chunk`` rows through the varlen
        kernel, unified with decode rows in one dispatch of at most
        ``dispatch_budget`` tokens (decode rows are reserved first and
        always all run; the budget throttles prefill tiles).
        ``chunked_prefill=False`` falls back to the deprecated
        batched-prefill path.

        ``prefix_cache=True`` content-addresses full KV pages (hash over
        token ids, salted with the policy version and arch identity):
        admissions whose prompt prefix is already resident share those
        pages read-only (refcounted) and prefill only the unmatched
        suffix, with copy-on-write when the match ends mid-page — greedy
        output stays token-exact with the unshared engine.
        ``window_reclaim`` (on by default, a no-op unless EVERY layer is
        windowed) releases pages entirely behind the widest sliding
        window back to the pool.

        ``tracer`` (an ``obs.Tracer``; default: the zero-cost
        ``NULL_TRACER``) records the request lifecycle and dispatch
        spans; ``metrics`` (an ``obs.MetricsRegistry``; default: a
        fresh one) receives the engine's serve-time histograms (TTFT,
        inter-token, queue-wait, request latency) and the ``"serve"``
        snapshot producer.  ``annotate=True`` wraps jitted dispatches
        in ``jax.profiler`` trace annotations so device-side profiler
        captures show the engine's phase names.
        """
        if bundle.decode_step_paged is None:
            from repro.models.transformer import paged_arch_unsupported

            raise ValueError(
                f"{bundle.cfg.name}: {paged_arch_unsupported(bundle.cfg)}")
        if params is None and store is None:
            raise ValueError("need params or a PolicyStore")
        self.bundle = bundle
        self.store = store
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.metrics.register_producer(
            "serve", lambda: collect_serve_stats(self))
        # Serve-time latency histograms: observed always (raw-sample
        # reservoirs are cheap), reported via collect_serve_stats.
        self._h_ttft = self.metrics.histogram("serve_ttft_s")
        # TTFT decomposition: queue-wait (submit -> the admission that
        # produced the first token) + prefill-compute (that admission ->
        # first token).  The two sum to TTFT exactly; a request
        # preempted before its first token books its earlier attempts
        # as queue time.
        self._h_ttft_queue = self.metrics.histogram("serve_ttft_queue_s")
        self._h_ttft_prefill = self.metrics.histogram(
            "serve_ttft_prefill_s")
        self._h_inter_token = self.metrics.histogram("serve_inter_token_s")
        self._h_queue_wait = self.metrics.histogram("serve_queue_wait_s")
        self._h_latency = self.metrics.histogram("serve_request_latency_s")
        self._h_swap_stale = self.metrics.histogram("serve_swap_to_stale_s")
        self._swap_mono: Optional[float] = None   # last in-flight swap
        self._ann = (trace_annotation if annotate
                     else (lambda name: contextlib.nullcontext()))
        # 0 = never poll the store: weights move only by direct
        # params/version assignment (the serve-backed trainer's
        # forced-lag producer pins snapshots this way).
        self.swap_interval = max(int(swap_interval), 0)
        if store is not None:
            self.params, self.version = store.latest()
        else:
            self.params, self.version = params, 0
        self.mesh = mesh
        self.num_shards = mesh_data_size(mesh)
        if num_blocks % self.num_shards != 0:
            raise ValueError(
                f"num_blocks {num_blocks} must divide over the mesh's "
                f"data axis ({self.num_shards} shards)")
        if mesh is not None:
            # Replicate the weights over the mesh up front; swapped-in
            # versions get the same placement in _maybe_swap.
            self.params = jax.device_put(self.params, replicated(mesh))
        self.block_size = block_size
        max_blocks_per_request = -(-max_seq_len // block_size)
        self.prefix_cache = bool(prefix_cache)
        self.allocator = make_allocator(
            num_blocks, block_size, self.num_shards,
            prefix_cache=self.prefix_cache, tracer=self.tracer)
        windows = [bundle.cfg.window_for_layer(layer)
                   for layer in range(bundle.cfg.n_layers)]
        self._reclaim_window = (
            max(windows) if window_reclaim and windows
            and all(w is not None for w in windows) else None)
        if injector is None:
            from repro.resilience.faults import NULL_INJECTOR

            injector = NULL_INJECTOR
        self.injector = injector
        self.scheduler = ContinuousBatchingScheduler(
            self.allocator, max_batch=max_batch,
            max_blocks_per_request=max_blocks_per_request,
            prefix_fn=self._prefix_key if self.prefix_cache else None,
            reclaim_window=self._reclaim_window,
            tracer=self.tracer,
            request_deadline_s=request_deadline_s,
            registry=self.metrics)
        self.pages = shard_paged_pool(
            bundle.init_paged_cache(num_blocks, block_size), mesh)
        self.max_batch = max_batch
        self._tables = np.zeros(
            (max_batch, max_blocks_per_request), np.int32)
        self._pos = np.zeros((max_batch,), np.int32)
        self._active = np.zeros((max_batch,), bool)
        self._last_tok = np.zeros((max_batch,), np.int32)
        self._slot_shard = np.zeros((max_batch,), np.int32)
        # Device-side cache of slot-state arrays that only change on
        # scheduling events (admit/preempt/retire/extend).  A host->
        # device transfer of even a [B] int32 costs tens of µs on CPU;
        # at one decode/verify dispatch per round that overhead is a
        # measurable slice of a small-model round, so arrays are
        # re-uploaded only when their host copy actually changed.
        self._dev_cache: Dict[str, Tuple[np.ndarray, jax.Array]] = {}
        self._key = jax.random.PRNGKey(seed)
        self.stats = ServeStats()
        self._kernel_mode = kernel_mode
        temp = max(float(temperature), 1e-6)
        self._temperature = temp
        self._top_p = float(top_p)

        def _sample(logits, key):
            logits = logits.astype(jnp.float32) / temp
            logits = _top_p_filter(logits, top_p)
            tok = jax.random.categorical(key, logits, axis=-1)
            logp = jax.nn.log_softmax(logits, axis=-1)
            lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
            return tok.astype(jnp.int32), lp

        self._sample = _sample
        chunk = max(int(decode_chunk), 1)
        self.decode_chunk = chunk

        def _decode(params, token, pages, tables, pos, active, remaining,
                    slot_shard, key):
            """`chunk` decode steps in one dispatch (lax.scan).

            Multi-step decode amortizes the per-step host round-trip —
            the cost that otherwise hands the phase-locked loop (whose
            whole decode is one fused scan) most of the continuous
            engine's structural win back.  Rows terminate *in-graph*
            (EOS or per-request budget via `remaining`); a retiring row
            idles masked until the chunk ends, bounding wasted work at
            chunk-1 steps per retirement.
            """
            def body(carry, k_t):
                token, pos, active, emitted, pages = carry
                out, pages = bundle.decode_step_paged(
                    params, token, pages, tables, pos, active,
                    kernel_mode=kernel_mode, mesh=mesh,
                    slot_shard=slot_shard)
                tok, lp = _sample(out.logits, k_t)
                mask = active
                tok = jnp.where(active, tok, jnp.int32(PAD))
                lp = jnp.where(active, lp, 0.0)
                pos = pos + active.astype(jnp.int32)
                emitted = emitted + active.astype(jnp.int32)
                active = jnp.logical_and(active, tok != EOS)
                active = jnp.logical_and(active, emitted < remaining)
                return (tok, pos, active, emitted, pages), (tok, lp, mask)

            keys = jax.random.split(key, chunk)
            carry = (token, pos, active, jnp.zeros_like(pos), pages)
            (_, _, _, _, pages), (toks, lps, masks) = jax.lax.scan(
                body, carry, keys)
            return toks, lps, masks, pages

        # Pages are donated and every op that touches them inside the
        # dispatch is in-place-able (decode_step_paged's hoisted layer
        # loop + kernels.ops.paged_kv_write), so the pool is updated
        # in place end to end: per-chunk cost is O(rows written), flat
        # in num_blocks (bench_serve --sweep-blocks measures it).
        self._decode = jax.jit(_decode, donate_argnums=(2,))
        # Prefill dispatches are keyed by (padded length, group size):
        # batched prefill stacks same-padded-length admissions into one
        # forward, so bursty admissions stop paying a dispatch each.
        self.batch_prefill = bool(batch_prefill)
        # Chunked ragged prefill (default): admissions stream through
        # the unified varlen dispatch instead of the legacy batched
        # prefill forward.  Varlen dispatches are keyed by the padded
        # round width so steady tile sizes reuse one trace.
        self.chunked_prefill = bool(chunked_prefill)
        if not self.chunked_prefill:
            warnings.warn(
                "chunked_prefill=False: the batched-prefill serve path "
                "is deprecated and kept only for comparison; chunked "
                "ragged prefill is the default",
                DeprecationWarning, stacklevel=2)
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.dispatch_budget = max(int(dispatch_budget), 1)
        self._varlen_fns: Dict[int, Any] = {}
        self._draft_varlen_fns: Dict[int, Any] = {}
        self._prefill_fns: Dict[Tuple[int, int], Any] = {}
        self._draft_prefill_fns: Dict[Tuple[int, int], Any] = {}
        # Prefix-cache dispatches: suffix-only prefills keyed by (padded
        # suffix length, group size); COW copies keyed by group size
        # (jit retraces per pool shape, so one cache serves the
        # verifier and draft pools).
        self._suffix_fns: Dict[Tuple[int, int], Any] = {}
        self._draft_suffix_fns: Dict[Tuple[int, int], Any] = {}
        self._cow_fns: Dict[int, Any] = {}

        # -- speculative decode ---------------------------------------------
        self.speculate_k = max(int(speculate_k), 0)
        self.speculate_adaptive = bool(speculate_adaptive) and \
            self.speculate_k > 1
        self.draft: Any = None
        self._draft_lag_hist = LagHistogram()
        self._chosen_k_hist = LagHistogram()
        # Per-slot EMA of the measured acceptance rate; optimistic start
        # (1.0 = draft the full k) reset whenever a slot is re-admitted.
        self._accept_ema = np.ones((max_batch,), np.float64)
        self._accept_ema_alpha = 0.3
        # Graceful degradation: after `spec_disable_after` consecutive
        # rounds where the verifier rejected EVERY drafted token,
        # speculation turns itself off and the engine falls back to the
        # plain chunked decode path (the verifier's corrected tokens
        # keep the output exact either way — this is purely cutting the
        # wasted draft work of a hopeless draft).
        self.spec_disable_after = max(int(spec_disable_after), 1)
        self.spec_disabled = False
        self._all_reject_rounds = 0
        if self.speculate_k:
            if bundle.decode_step_paged_multi is None:
                raise ValueError(
                    f"{bundle.cfg.name}: multi-token verify unavailable "
                    "(paged path unsupported)")
            self.draft = self._build_draft(draft, num_blocks, block_size)
            # Draft/verify dispatches are keyed by the round's draft
            # length: adaptive speculation walks k in [1, speculate_k].
            self._draft_fns: Dict[int, Any] = {}
            self._verify_fns: Dict[int, Any] = {}

    # -- request intake ------------------------------------------------------

    def submit(
        self,
        prompt: Sequence[int] | np.ndarray,
        max_new_tokens: int,
        request_id: Optional[int] = None,
    ) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        kw = {} if request_id is None else {"request_id": request_id}
        req = Request(prompt=prompt, max_new_tokens=max_new_tokens, **kw)
        self.scheduler.submit(req)
        return req

    @property
    def has_work(self) -> bool:
        return self.scheduler.has_work

    # -- internals -----------------------------------------------------------

    def _next_key(self) -> jax.Array:
        self._key, k = jax.random.split(self._key)
        return k

    def _dev(self, name: str, arr: np.ndarray) -> jax.Array:
        """Device copy of `arr`, re-uploaded only when it changed."""
        hit = self._dev_cache.get(name)
        if hit is not None and np.array_equal(hit[0], arr):
            return hit[1]
        val = jnp.asarray(arr)
        self._dev_cache[name] = (arr.copy(), val)
        return val

    def _maybe_swap(self) -> None:
        if self.store is None or not self.swap_interval:
            return
        if self.stats.steps % self.swap_interval != 0:
            return
        params, version = self.store.latest()
        if version != self.version:
            old = self.version
            if self.mesh is not None:
                params = jax.device_put(params, replicated(self.mesh))
            self.params, self.version = params, version
            self.stats.swaps += 1
            # Swap-to-first-stale-token latency: armed here, observed by
            # the next _record (whose token carries the new version).
            self._swap_mono = time.monotonic()
            tr = self.tracer
            if tr.enabled:
                tr.instant("swap", tid="engine", old=old, new=version)
            self._refresh_draft()

    # -- prefix cache ---------------------------------------------------------

    @staticmethod
    def _committed_ids(req: Request) -> np.ndarray:
        """prompt + all emitted tokens except the pending one — exactly
        the rows a (re)prefill must make resident."""
        if not req.tokens:
            return req.prompt
        return np.concatenate(
            [req.prompt, np.asarray(req.tokens[:-1], np.int32)])

    def _prefix_key(self, req: Request) -> PrefixKey:
        """Version-salted content address of `req`'s committed ids.

        The salt folds in the policy version and arch identity, so an
        in-flight weight swap invalidates every stale entry without a
        flush — KV rows are a function of (token ids, params, arch).
        Cached per (version, length): recomputed only after a swap or
        when emitted tokens extend the committed ids (re-admission).
        """
        ids = self._committed_ids(req)
        cached = getattr(req, "_pkey", None)
        if cached is not None and cached[0] == (self.version, len(ids)):
            return cached[1]
        cfg = self.bundle.cfg
        salt = (
            f"{cfg.name}|{cfg.arch_type}|L{cfg.n_layers}|d{cfg.d_model}"
            f"|h{cfg.n_heads}x{cfg.n_kv_heads}|w{cfg.sliding_window}"
            f"/{cfg.global_every}|v{self.version}|bs{self.block_size}"
        ).encode()
        key = prefix_key(ids, self.block_size, salt)
        req._pkey = ((self.version, len(ids)), key)
        return key

    # -- speculative draft slot ----------------------------------------------

    def _build_draft(self, spec: Any, num_blocks: int,
                     block_size: int) -> Any:
        if callable(spec):
            return CallableDraft(spec)
        if spec is None:
            spec = (("version", -1) if self.store is not None
                    else ("params", self.params))
        kind = spec[0]
        if kind == "version":
            if self.store is None:
                raise ValueError("draft=('version', n) needs a PolicyStore")
            offset = int(spec[1])
            if offset > 0:
                raise ValueError(f"draft version offset must be <= 0, "
                                 f"got {offset}")
            params, version = self.store.pin_lagged(offset)
            return ModelDraft(self.bundle, params, version, offset,
                              num_blocks, block_size, self.mesh)
        if kind == "params":
            return ModelDraft(self.bundle, spec[1], None, None,
                              num_blocks, block_size, self.mesh)
        if kind == "model":
            return ModelDraft(spec[1], spec[2], None, None,
                              num_blocks, block_size, self.mesh)
        raise ValueError(f"unknown draft spec {spec!r}")

    def _refresh_draft(self) -> None:
        """Re-pin the self-speculation draft at latest+offset after a
        verifier swap.  Draft pool rows written under the old draft
        weights stay (they only shape *proposals*; correctness rides on
        the verifier) and age out as decode overwrites them."""
        d = self.draft
        if not isinstance(d, ModelDraft) or d.version_offset is None:
            return
        # Atomic resolve+pin: a learner publish between a resolve and a
        # separate pin could evict the resolved version mid-handoff.
        params, target = self.store.pin_lagged(d.version_offset)
        if target == d.version:
            self.store.release(target)   # unchanged; drop the extra pin
            return
        self.store.release(d.version)
        if self.mesh is not None:
            params = jax.device_put(params, replicated(self.mesh))
        d.params, d.version = params, target

    def _draft_fn(self, k: int):
        fn = self._draft_fns.get(k)
        if fn is None:
            fn = self._draft_fns[k] = self._make_draft_fn(k)
        return fn

    def _verify_fn(self, k: int):
        fn = self._verify_fns.get(k)
        if fn is None:
            fn = self._verify_fns[k] = self._make_verify_fn(k)
        return fn

    def _make_draft_fn(self, k: int):
        """k draft decode steps in one dispatch over the draft pool."""
        bundle_d = self.draft.bundle
        sample = self._sample
        kernel_mode = self._kernel_mode
        mesh = self.mesh

        def _draft(params, token, pages, tables, pos, active, cap,
                   slot_shard, key):
            def body(carry, k_t):
                token, pos, pages = carry
                # Past-allocation steps go inactive: their write would
                # land in the table's pad pages (owned by someone else),
                # and their proposals can never be recorded anyway.
                step_active = jnp.logical_and(active, pos < cap)
                out, pages = bundle_d.decode_step_paged(
                    params, token, pages, tables, pos, step_active,
                    kernel_mode=kernel_mode, mesh=mesh,
                    slot_shard=slot_shard)
                tok, _ = sample(out.logits, k_t)
                tok = jnp.where(step_active, tok, jnp.int32(PAD))
                return (tok, pos + 1, pages), (tok, out.logits)

            keys = jax.random.split(key, k)
            (_, _, pages), (toks, logits) = jax.lax.scan(
                body, (token, pos, pages), keys)
            return toks.T, logits.transpose(1, 0, 2), pages

        return jax.jit(_draft, donate_argnums=(2,))

    def _make_verify_fn(self, k: int):
        """Single-dispatch multi-token verify + accept + pos arithmetic."""
        bundle = self.bundle
        kernel_mode = self._kernel_mode
        mesh = self.mesh
        temp, top_p = self._temperature, self._top_p

        def _verify(params, first_tok, draft_toks, draft_logits, pages,
                    tables, pos, active, cap, slot_shard, key):
            # Queries = [t0, d1..d_{k-1}]: logits after query i score
            # draft token d_{i+1}.  All k rows are written; a rejection
            # just rewinds pos and the next chunk overwrites them.
            queries = jnp.concatenate(
                [first_tok[:, None], draft_toks[:, :-1]], axis=1)
            out, pages = bundle.decode_step_paged_multi(
                params, queries, pages, tables, pos, active, cap,
                kernel_mode=kernel_mode, mesh=mesh, slot_shard=slot_shard)
            toks, lps, n_acc, n_emit = speculative_accept(
                out.logits, draft_toks, draft_logits, key,
                temperature=temp, top_p=top_p)
            toks = jnp.where(active[:, None], toks, jnp.int32(PAD))
            lps = jnp.where(active[:, None], lps, 0.0)
            n_acc = jnp.where(active, n_acc, 0)
            n_emit = jnp.where(active, n_emit, 0)
            return toks, lps, n_acc, n_emit, pages

        return jax.jit(_verify, donate_argnums=(4,))

    # -- prefill (batched admissions) ----------------------------------------

    def _prefill_admitted(self, admitted: List[Request],
                          finished: List[ServedTrajectory]) -> None:
        """(Re)compute KV rows for every admitted request; same-padded-
        length admissions share one prefill dispatch (batch_prefill).

        Prefix-cache hits take the *suffix* path instead: their matched
        rows are already resident in shared pages, so only the unmatched
        tail runs (plus a COW copy when the match ends mid-page).  Dense
        (unmatched) prefills dispatch first and suffix prefills follow
        in admission order — an admission can only match pages indexed
        by *earlier* admissions, so every page a suffix dispatch reads
        was written by an earlier dispatch of this round or a previous
        round.
        """
        if not admitted:
            return
        dense: List = []
        shared: List = []
        for req in admitted:
            ids = self._committed_ids(req)
            plen = int(ids.shape[0])
            item = (req, ids, plen)
            (shared if req.num_matched > 0 else dense).append(item)
        groups: Dict[int, List] = {}
        for req, ids, plen in dense:
            padded = -(-plen // self.block_size) * self.block_size
            groups.setdefault(padded, []).append((req, ids, plen))
        for padded in sorted(groups):
            items = groups[padded]
            size = len(items) if self.batch_prefill else 1
            for lo in range(0, len(items), size):
                self._prefill_group(padded, items[lo:lo + size], finished)
        # Only runs of requests sharing exactly the same source pages
        # (best-of-N siblings) batch into one suffix dispatch — such
        # requests cannot depend on each other's writes.
        i = 0
        while i < len(shared):
            j = i + 1
            if self.batch_prefill:
                while j < len(shared) and \
                        self._suffix_compatible(shared[i], shared[j]):
                    j += 1
            self._suffix_group(shared[i:j], finished)
            i = j

    @staticmethod
    def _suffix_compatible(a, b) -> bool:
        ra, _, pa = a
        rb, _, pb = b
        nsf = ra.num_shared_full
        return (pa == pb and ra.num_matched == rb.num_matched
                and ra.shard == rb.shard and nsf == rb.num_shared_full
                and ra.blocks[:nsf] == rb.blocks[:nsf]
                and ra.cow_src == rb.cow_src)

    def _cow_fn(self, n: int):
        fn = self._cow_fns.get(n)
        if fn is None:
            mesh = self.mesh

            def _cow(pages, src, dst, rows, home):
                return copy_page_rows(pages, src, dst, rows, home,
                                      mesh=mesh)

            fn = self._cow_fns[n] = jax.jit(_cow, donate_argnums=(0,))
        return fn

    def _suffix_group(self, items: List,
                      finished: List[ServedTrajectory]) -> None:
        """COW copies + suffix-only prefill for one compatible group."""
        n = len(items)
        req0, _, plen0 = items[0]
        m = req0.num_matched
        t = plen0 - m                      # unmatched suffix length
        t_pad = -(-t // 4) * 4             # pad for jit-cache reuse
        width = self._tables.shape[1]
        toks = np.full((n, t_pad), PAD, np.int32)
        tables = np.zeros((n, width), np.int32)
        pos = np.full((n,), m, np.int32)
        cap = np.zeros((n,), np.int32)
        home = np.zeros((n,), np.int32)
        for i, (req, ids, plen) in enumerate(items):
            toks[i, :t] = ids[m:]
            tables[i] = self.allocator.padded_table(req.blocks, width)
            cap[i] = plen
            home[i] = req.shard or 0
        if req0.cow_src is not None:
            # The match ends mid-page: copy the matched rows of the
            # shared source page into each request's own fresh page
            # (the table already points there), then drop the source
            # ref the scheduler reserved.
            src = np.zeros((n,), np.int32)
            dst = np.zeros((n,), np.int32)
            rows = np.zeros((n,), np.int32)
            for i, (req, ids, plen) in enumerate(items):
                src[i], rows[i] = req.cow_src
                dst[i] = req.blocks[req.num_shared_full]
            fn = self._cow_fn(n)
            args = (jnp.asarray(src), jnp.asarray(dst),
                    jnp.asarray(rows), jnp.asarray(home))
            self.pages = fn(self.pages, *args)
            if isinstance(self.draft, ModelDraft):
                self.draft.pages = fn(self.draft.pages, *args)
            for req, _, _ in items:
                self.allocator.release([req.cow_src[0]], req.shard or 0)
                req.cow_src = None
            self.stats.cow_copies += n
            if self.tracer.enabled:
                self.tracer.instant("cow_copy", tid="engine", n=n)
        key = (t_pad, n)
        fn = self._suffix_fns.get(key)
        if fn is None:
            fn = self._suffix_fns[key] = self._make_suffix()
        toks_d = jnp.asarray(toks)
        tables_d = jnp.asarray(tables)
        pos_d = jnp.asarray(pos)
        cap_d = jnp.asarray(cap)
        home_d = jnp.asarray(home)
        tlast = jnp.full((n,), t - 1, jnp.int32)
        with self.tracer.span("suffix_prefill", tid="engine", n=n,
                              suffix=t), \
                self._ann("serve.suffix_prefill"):
            tok, lp, self.pages = fn(
                self.params, toks_d, self.pages, tables_d, pos_d, cap_d,
                home_d, tlast, self._next_key())
        self.stats.prefills += n
        self.stats.prefill_dispatches += 1
        self.stats.prefill_tokens += n * t
        if isinstance(self.draft, ModelDraft):
            dfn = self._draft_suffix_fns.get(key)
            if dfn is None:
                dfn = self._draft_suffix_fns[key] = \
                    self._make_suffix(draft=True)
            self.draft.pages = dfn(
                self.draft.params, toks_d, self.draft.pages, tables_d,
                pos_d, cap_d, home_d)
        tok_np, lp_np = np.asarray(tok), np.asarray(lp)
        for i, (req, ids, plen) in enumerate(items):
            slot = req.slot
            self._tables[slot] = tables[i]
            self._pos[slot] = plen
            req.num_prefilled = plen
            if req.tokens:                     # resume after preemption
                self._last_tok[slot] = req.tokens[-1]
            else:
                self._record(req, int(tok_np[i]), float(lp_np[i]),
                             finished)

    def _make_suffix(self, draft: bool = False):
        """Suffix-only prefill: T unmatched tokens through the
        multi-token paged step (writes their rows, attends over the
        shared prefix), sampling from the last true suffix position.
        The draft variant fills the draft pool and discards logits."""
        bundle = self.draft.bundle if draft else self.bundle
        sample = self._sample
        kernel_mode = self._kernel_mode
        mesh = self.mesh

        def _suffix(params, tokens, pages, tables, pos, cap, home,
                    tlast=None, key=None):
            ones = jnp.ones((tokens.shape[0],), bool)
            out, pages = bundle.decode_step_paged_multi(
                params, tokens, pages, tables, pos, ones, cap,
                kernel_mode=kernel_mode, mesh=mesh, slot_shard=home)
            if draft:
                return pages
            last = jnp.take_along_axis(
                out.logits, tlast[:, None, None], axis=1)[:, 0]
            tok, lp = sample(last, key)
            return tok, lp, pages

        return jax.jit(_suffix, donate_argnums=(2,))

    def _prefill_group(self, padded: int, items: List,
                       finished: List[ServedTrajectory]) -> None:
        n = len(items)
        rows = np.zeros((n, padded), np.int32)
        kv_valid = np.zeros((n, padded), bool)
        plens = np.zeros((n,), np.int32)
        home = np.zeros((n,), np.int32)
        tables = np.zeros((n, self._tables.shape[1]), np.int32)
        for i, (req, ids, plen) in enumerate(items):
            rows[i, :plen] = ids
            kv_valid[i, :plen] = True
            plens[i] = plen
            home[i] = req.shard or 0
            tables[i] = self.allocator.padded_table(
                req.blocks, self._tables.shape[1])
        key = (padded, n)
        fn = self._prefill_fns.get(key)
        if fn is None:
            fn = self._prefill_fns[key] = self._make_prefill(padded, n)
        with self.tracer.span("prefill", tid="engine", n=n,
                              padded=padded), \
                self._ann("serve.prefill"):
            toks, lps, self.pages = fn(
                self.params, jnp.asarray(rows), jnp.asarray(kv_valid),
                jnp.asarray(tables), jnp.asarray(plens),
                jnp.asarray(home), self.pages, self._next_key())
        self.stats.prefills += n
        self.stats.prefill_dispatches += 1
        self.stats.prefill_tokens += int(plens.sum())
        if isinstance(self.draft, ModelDraft):
            dfn = self._draft_prefill_fns.get(key)
            if dfn is None:
                dfn = self._draft_prefill_fns[key] = \
                    self._make_draft_prefill(padded, n)
            self.draft.pages = dfn(
                self.draft.params, jnp.asarray(rows), jnp.asarray(kv_valid),
                jnp.asarray(tables), jnp.asarray(plens),
                jnp.asarray(home), self.draft.pages)
        toks_np, lps_np = np.asarray(toks), np.asarray(lps)
        for i, (req, ids, plen) in enumerate(items):
            slot = req.slot
            self._tables[slot] = tables[i]
            self._pos[slot] = plen
            req.num_prefilled = plen
            if req.tokens:                     # resume after preemption
                self._last_tok[slot] = req.tokens[-1]
            else:
                self._record(req, int(toks_np[i]), float(lps_np[i]),
                             finished)

    def _make_prefill(self, padded_len: int, n: int):
        bundle = self.bundle
        sample = self._sample
        mesh = self.mesh

        def _prefill(params, prompts, kv_valid, blocks, plens, home,
                     pages, key):
            out = bundle.forward(
                params, prompts, return_cache=True,
                cache_len=padded_len, kv_valid=kv_valid)
            # Donated pages + per-tile dynamic_update_slice writes: each
            # request's prefill lands in the pool without copying it
            # (under a mesh: only on its home shard, via shard_map).
            pages = write_prefill_batch_to_pages(
                out.cache["k"], out.cache["v"], pages, blocks, plens,
                home, mesh=mesh)
            last = jnp.take_along_axis(
                out.logits, (plens - 1)[:, None, None], axis=1)[:, 0]
            tok, lp = sample(last, key)
            return tok, lp, pages

        return jax.jit(_prefill, donate_argnums=(6,))

    def _make_draft_prefill(self, padded_len: int, n: int):
        bundle_d = self.draft.bundle
        mesh = self.mesh

        def _prefill(params, prompts, kv_valid, blocks, plens, home,
                     pages):
            out = bundle_d.forward(
                params, prompts, return_cache=True,
                cache_len=padded_len, kv_valid=kv_valid)
            return write_prefill_batch_to_pages(
                out.cache["k"], out.cache["v"], pages, blocks, plens,
                home, mesh=mesh)

        return jax.jit(_prefill, donate_argnums=(6,))

    def _record(self, req: Request, tok: int, lp: float,
                finished: List[ServedTrajectory]) -> None:
        """Book one emitted token; retire the request when done."""
        now = time.monotonic()
        if req.first_token_time is None:
            req.first_token_time = now
            self._h_ttft.observe(now - req.submit_time)
            if req.admit_time is not None:
                # Exact decomposition: queue + prefill == TTFT.
                self._h_ttft_queue.observe(
                    req.admit_time - req.submit_time)
                self._h_ttft_prefill.observe(now - req.admit_time)
        else:
            self._h_inter_token.observe(now - req.last_emit_time)
        req.last_emit_time = now
        if self._swap_mono is not None:
            # First token after an in-flight swap: how long until the
            # new policy's first served token reached a client.
            self._h_swap_stale.observe(now - self._swap_mono)
            self._swap_mono = None
        req.tokens.append(tok)
        req.log_beta.append(lp)
        req.versions.append(self.version)
        self.stats.tokens_out += 1
        tr = self.tracer
        if tr.full:
            # Per-token provenance stream: trace_report builds the
            # lag-at-emission histogram from exactly these events.
            lag = (self.store.version - self.version
                   if self.store is not None else 0)
            tr.instant("token", tid="tokens", rid=req.request_id,
                       v=self.version, lag=lag, tok=tok)
        if tok == EOS:
            self._finish(req, "eos", finished)
        elif len(req.tokens) >= req.max_new_tokens:
            self._finish(req, "length", finished)
        else:
            self._last_tok[req.slot] = tok

    def _finish(self, req: Request, reason: str,
                finished: List[ServedTrajectory]) -> None:
        slot = req.slot
        self.scheduler.retire(req, reason)
        self._clear_slot(slot)
        self.stats.finished += 1
        self._h_latency.observe(req.finish_time - req.submit_time)
        n = len(req.tokens)
        finished.append(ServedTrajectory(
            request_id=req.request_id,
            prompt=req.prompt,
            tokens=np.asarray(req.tokens, np.int32),
            log_beta=np.asarray(req.log_beta, np.float32),
            versions=np.asarray(req.versions, np.int64),
            mask=np.ones((n,), np.float32),
            finish_reason=reason,
            latency_s=req.finish_time - req.submit_time,
            num_preemptions=req.num_preemptions,
        ))

    @property
    def _spec_k_active(self) -> int:
        """Speculation depth for this round: 0 once auto-disabled."""
        return 0 if self.spec_disabled else self.speculate_k

    def _timeout_finish(self, req: Request,
                        finished: List[ServedTrajectory]) -> None:
        """Book a deadline-expired request (already retired by the
        scheduler) as a trajectory: whatever tokens it emitted, marked
        ``finish_reason="timeout"`` — an empty, fully-masked row when
        it never produced one."""
        self._clear_slot(req.slot)
        self.stats.finished += 1
        self.stats.timeouts += 1
        latency = (req.finish_time or time.monotonic()) - req.submit_time
        self._h_latency.observe(latency)
        n = len(req.tokens)
        finished.append(ServedTrajectory(
            request_id=req.request_id,
            prompt=req.prompt,
            tokens=np.asarray(req.tokens, np.int32),
            log_beta=np.asarray(req.log_beta, np.float32),
            versions=np.asarray(req.versions, np.int64),
            mask=np.ones((n,), np.float32),
            finish_reason="timeout",
            latency_s=latency,
            num_preemptions=req.num_preemptions,
        ))

    def _clear_slot(self, slot: Optional[int]) -> None:
        if slot is None:
            return
        self._active[slot] = False
        self._tables[slot] = 0
        self._pos[slot] = 0
        self._last_tok[slot] = 0

    # -- chunked ragged prefill ----------------------------------------------

    def _varlen_fn(self, t_pad: int, draft: bool = False):
        cache = self._draft_varlen_fns if draft else self._varlen_fns
        fn = cache.get(t_pad)
        if fn is None:
            fn = cache[t_pad] = self._make_varlen(t_pad, draft=draft)
        return fn

    def _make_varlen(self, t_pad: int, draft: bool = False):
        """One unified ragged dispatch: every slot contributes
        ``row_len[b]`` token rows starting at absolute position
        ``row_start[b]`` — a decode row is ``row_len == 1``, a prefill
        tile is ``row_len`` up to the chunk size, an idle/gated slot is
        ``row_len == 0``.  Verifier variant samples each slot's next
        token from the logits of its last live row; the draft variant
        only fills the draft pool (proposal rows must exist there for
        later speculative rounds) and discards logits."""
        bundle = self.draft.bundle if draft else self.bundle
        sample = self._sample
        kernel_mode = self._kernel_mode
        mesh = self.mesh

        def _fn(params, tokens, pages, tables, row_start, row_len, cap,
                slot_shard, key=None):
            out, pages = bundle.decode_step_paged_varlen(
                params, tokens, pages, tables, row_start, row_len, cap,
                kernel_mode=kernel_mode, mesh=mesh, slot_shard=slot_shard)
            if draft:
                return pages
            last = jnp.clip(row_len - 1, 0, t_pad - 1)
            logits = jnp.take_along_axis(
                out.logits, last[:, None, None], axis=1)[:, 0]
            tok, lp = sample(logits, key)
            return tok, lp, pages

        return jax.jit(_fn, donate_argnums=(2,))

    def _chunked_round(self, finished: List[ServedTrajectory]) -> bool:
        """One unified varlen round, or False when no prefill is pending
        (steady state: the caller falls through to the normal decode/
        speculative path, which this mode leaves untouched).

        Budgeting: every decode-eligible slot's single-token row is
        reserved out of ``dispatch_budget`` first — bounding the round's
        token count is only useful if in-flight requests keep emitting —
        and the remainder goes to prefill tiles of at most
        ``prefill_chunk`` rows, FIFO by admission order, with a one-row
        floor for the oldest ready tile so admission always progresses.

        Prefix-cache gating: an admission's pages are registered at
        admission but their rows land over future rounds, so a pending
        request whose shared (or COW-source) pages belong to another
        request still computing them waits until those rows land.  The
        gate is acyclic — a dependency always points at an *earlier*
        admission, so the oldest pending request is never gated — and a
        mid-prefill owner that aborts (preemption, deadline) preempts
        its gated dependents through the scheduler's ``_abort_prefill``.
        """
        pending = [r for r in self.scheduler.running if not r.prefill_done]
        if not pending:
            return False
        tr = self.tracer
        bs = self.block_size
        # Pages whose rows an in-flight prefill has not computed yet,
        # keyed to their unique computing owner (sharers only ever hold
        # such a page inside their own matched prefix, which is already
        # complete, so they never appear as owners).
        incomplete: Dict[Tuple[int, int], Request] = {}
        for r in pending:
            sh = r.shard or 0
            for j, b in enumerate(r.blocks):
                if b != RECLAIMED and (j + 1) * bs > r.num_prefilled:
                    incomplete[(sh, b)] = r

        def _gated(r: Request) -> bool:
            sh = r.shard or 0
            deps = list(r.blocks[:r.num_shared_full])
            if r.cow_src is not None:
                deps.append(r.cow_src[0])
            return any(incomplete.get((sh, b)) not in (None, r)
                       for b in deps)

        order = {id(r): i for i, r in
                 enumerate(self.scheduler._admission_order)}
        ready = sorted((r for r in pending if not _gated(r)),
                       key=lambda r: order[id(r)])
        decode_reqs = [r for r in self.scheduler.running if r.prefill_done]
        budget_left = self.dispatch_budget - len(decode_reqs)
        chunks: List[Tuple[Request, np.ndarray, int]] = []
        for r in ready:
            ids = self._committed_ids(r)
            left = int(ids.shape[0]) - r.num_prefilled
            n = min(self.prefill_chunk, left, budget_left)
            if n <= 0:
                if chunks:
                    continue
                n = 1    # floor: the oldest ready tile always advances
            budget_left -= n
            chunks.append((r, ids, n))
        # Deferred copy-on-write: a mid-page match is copied into the
        # request's own page right before its FIRST tile (cow_src is
        # cleared by the copy, so presence == not yet copied); the tile
        # then attends over the copied rows like any resident prefix.
        cow_items = [r for r, _, _ in chunks if r.cow_src is not None]
        if cow_items:
            n = len(cow_items)
            src = np.zeros((n,), np.int32)
            dst = np.zeros((n,), np.int32)
            rows = np.zeros((n,), np.int32)
            home = np.zeros((n,), np.int32)
            for i, r in enumerate(cow_items):
                src[i], rows[i] = r.cow_src
                dst[i] = r.blocks[r.num_shared_full]
                home[i] = r.shard or 0
            fn = self._cow_fn(n)
            args = (jnp.asarray(src), jnp.asarray(dst),
                    jnp.asarray(rows), jnp.asarray(home))
            self.pages = fn(self.pages, *args)
            if isinstance(self.draft, ModelDraft):
                self.draft.pages = fn(self.draft.pages, *args)
            for r in cow_items:
                self.allocator.release([r.cow_src[0]], r.shard or 0)
                r.cow_src = None
            self.stats.cow_copies += n
            if tr.enabled:
                tr.instant("cow_copy", tid="engine", n=n)
        t_max = max([n for _, _, n in chunks], default=1)
        t_pad = -(-t_max // 4) * 4     # pad for jit-cache reuse
        B = self.max_batch
        tokens = np.full((B, t_pad), PAD, np.int32)
        row_start = np.zeros((B,), np.int32)
        row_len = np.zeros((B,), np.int32)
        cap = np.zeros((B,), np.int32)
        for r in decode_reqs:
            s = r.slot
            tokens[s, 0] = self._last_tok[s]
            row_start[s] = self._pos[s]
            row_len[s] = 1
            cap[s] = len(r.blocks) * bs
        for r, ids, n in chunks:
            s = r.slot
            tokens[s, :n] = ids[r.num_prefilled:r.num_prefilled + n]
            row_start[s] = r.num_prefilled
            row_len[s] = n
            cap[s] = len(r.blocks) * bs
        n_tile_tokens = sum(n for _, _, n in chunks)
        fn = self._varlen_fn(t_pad)
        tokens_d = jnp.asarray(tokens)
        rs_d = jnp.asarray(row_start)
        rl_d = jnp.asarray(row_len)
        cap_d = jnp.asarray(cap)
        tables_d = self._dev("tables", self._tables)
        shard_d = self._dev("slot_shard", self._slot_shard)
        with tr.span("chunked_round", tid="engine",
                     decode=len(decode_reqs), tiles=len(chunks),
                     tokens=int(row_len.sum())), \
                self._ann("serve.chunked_round"):
            tok, lp, self.pages = fn(
                self.params, tokens_d, self.pages, tables_d,
                rs_d, rl_d, cap_d, shard_d, self._next_key())
        if isinstance(self.draft, ModelDraft):
            # Mirror the same rows into the draft pool (draft weights):
            # later speculative rounds read them as resident context.
            self.draft.pages = self._varlen_fn(t_pad, draft=True)(
                self.draft.params, tokens_d, self.draft.pages, tables_d,
                rs_d, rl_d, cap_d, shard_d)
        toks_np, lps_np = np.asarray(tok), np.asarray(lp)
        self.stats.prefill_dispatches += 1
        self.stats.prefill_tokens += n_tile_tokens
        if decode_reqs:
            self.stats.decode_steps += 1
            self.stats.occupancy_sum += float(len(decode_reqs))
        for r, ids, n in chunks:
            slot = r.slot
            r.num_prefilled += n
            self._pos[slot] = r.num_prefilled
            if r.num_prefilled >= int(ids.shape[0]):
                # Last chunk landed: the slot becomes decode-eligible
                # and the round's sampled token (from the final prompt
                # row's logits) is its first emission — unless the
                # request is resuming after preemption, whose pending
                # token was already recorded before the preemption.
                r.prefill_done = True
                self._active[slot] = True
                self.stats.prefills += 1
                if r.tokens:
                    self._last_tok[slot] = r.tokens[-1]
                else:
                    self._record(r, int(toks_np[slot]),
                                 float(lps_np[slot]), finished)
        for r in decode_reqs:
            slot = r.slot
            self._pos[slot] += 1
            self._record(r, int(toks_np[slot]), float(lps_np[slot]),
                         finished)
        return True

    # -- the decode loop -----------------------------------------------------

    def step(self) -> List[ServedTrajectory]:
        """One scheduling round + decode chunk (or speculative round);
        returns newly finished trajectories."""
        finished: List[ServedTrajectory] = []
        tr = self.tracer
        self._maybe_swap()
        self.stats.steps += 1
        if self.injector.active:
            # Straggler injection: a matching stall sleeps here, with
            # the deadline clock still running — exactly how a hung
            # slot turns into a timeout retirement.
            self.injector.stall("engine_step", at_step=self.stats.steps)
            for req in self.scheduler.running:
                self.injector.stall("engine_step",
                                    at_step=self.stats.steps,
                                    slot=int(req.slot))
        # Deadline sweep BEFORE scheduling: expired waiting requests
        # never get admitted, expired running ones free their slot and
        # pages (draft pool included — it shares the block tables) for
        # this round's admissions.
        for req in self.scheduler.expire():
            self._timeout_finish(req, finished)
        lookahead = self._spec_k_active or self.decode_chunk
        with tr.span("schedule", tid="engine"):
            admitted, _ = self.scheduler.schedule(lookahead=lookahead)
        self.stats.preemptions = self.scheduler.preemptions
        if admitted:
            now = time.monotonic()
            for req in admitted:
                self._h_queue_wait.observe(now - req.queued_time)
                req.admit_time = now
        for req in admitted:
            # Fresh occupant: the acceptance EMA of whoever held this
            # slot before says nothing about the new request.
            self._accept_ema[req.slot] = 1.0
        if self.chunked_prefill:
            # Admissions stream in as ragged tiles over the next rounds
            # (no prefill dispatch here): mark them pending and park the
            # write cursor at the first uncomputed row.
            for req in admitted:
                req.prefill_done = False
                self._pos[req.slot] = req.num_prefilled
        else:
            self._prefill_admitted(admitted, finished)
        # Rebuild slot state from the scheduler: preempted/retired slots
        # (their Request no longer knows its old index) go quiet, and
        # running rows pick up pages the extension pass just granted.
        # A mid-prefill request keeps its slot but is not decode-
        # eligible until its last chunk lands (prefill_done is always
        # True on the legacy path by this point).
        by_slot = {r.slot: r for r in self.scheduler.running}
        remaining = np.zeros((self.max_batch,), np.int32)
        for slot in range(self.max_batch):
            req = by_slot.get(slot)
            if req is None:
                self._clear_slot(slot)
            else:
                self._active[slot] = req.prefill_done
                self._slot_shard[slot] = req.shard or 0
                self._tables[slot] = self.allocator.padded_table(
                    req.blocks, self._tables.shape[1])
                remaining[slot] = req.max_new_tokens - len(req.tokens)
        if self.prefix_cache:
            self._assert_write_pages_private()
        if tr.enabled:
            # Counter tracks: load, pool occupancy (per shard), live
            # policy lag (publishes the engine hasn't swapped in yet).
            sched = self.scheduler
            tr.counter("serve_load", waiting=float(len(sched.waiting)),
                       running=float(len(sched.running)))
            alloc = self.allocator
            if getattr(alloc, "num_shards", 1) > 1:
                tr.counter("pool_free", **{
                    f"shard{s}": float(f)
                    for s, f in enumerate(alloc.free_by_shard())})
            else:
                tr.counter("pool_free", free=float(alloc.num_free))
            if self.store is not None:
                tr.counter("policy_lag",
                           lag=float(self.store.version - self.version))
        if self.chunked_prefill and self._chunked_round(finished):
            # A unified varlen round ran (prefill tiles + one decode
            # token per eligible slot); speculation and the multi-step
            # decode chunk resume once no prefill is pending.
            return finished
        if not self._active.any():
            return finished
        if self._spec_k_active:
            with tr.span("spec_round", tid="engine"):
                self._spec_round(finished)
            return finished
        with tr.span("decode", tid="engine", chunk=self.decode_chunk), \
                self._ann("serve.decode"):
            toks, lps, masks, self.pages = self._decode(
                self.params, jnp.asarray(self._last_tok), self.pages,
                self._dev("tables", self._tables), jnp.asarray(self._pos),
                self._dev("active", self._active),
                self._dev("remaining", remaining),
                self._dev("slot_shard", self._slot_shard),
                self._next_key())
            toks_np = np.asarray(toks)       # [chunk, B]
            lps_np = np.asarray(lps)
            masks_np = np.asarray(masks)
        self.stats.occupancy_sum += float(masks_np.sum())
        self.stats.decode_steps += self.decode_chunk
        for req in list(self.scheduler.running):
            slot = req.slot
            self._pos[slot] += int(masks_np[:, slot].sum())
            for t in range(self.decode_chunk):
                if not masks_np[t, slot]:
                    break
                self._record(req, int(toks_np[t, slot]),
                             float(lps_np[t, slot]), finished)
        return finished

    def _assert_write_pages_private(self) -> None:
        """Invariant guard: the page a slot's next decode write lands in
        must be exclusively owned (ref 1).  Shared pages are read-only;
        matched full pages sit strictly below the write position and a
        mid-page match was COW'd at prefill — a violation here means a
        refcount/COW bug, caught before it corrupts another request.

        Two chunked-prefill exemptions.  Mid-prefill requests are
        skipped outright: their registered-but-not-yet-complete pages
        may already be shared by a *gated* later admission (one blocked
        until exactly these rows land) — the gate in ``_chunked_round``
        is what keeps the sharer from reading early.  And a deferred
        COW reservation is allowed on a write page: until the
        dependent's first tile performs the copy, its ``cow_src`` ref
        keeps the owner's partial page above 1 — safe because the copy
        reads rows strictly below the owner's write offset (the match
        limit excludes the owner's last committed token, let alone its
        future writes).
        """
        cow_pending: Dict[Tuple[int, int], int] = {}
        for r in self.scheduler.running:
            if r.cow_src is not None:
                k = ((r.shard or 0), r.cow_src[0])
                cow_pending[k] = cow_pending.get(k, 0) + 1
        for req in self.scheduler.running:
            if not req.prefill_done:
                continue
            idx = int(self._pos[req.slot]) // self.block_size
            if idx >= len(req.blocks):
                continue
            page = req.blocks[idx]
            if page >= 0:
                refs = self.allocator.ref(page, req.shard or 0)
                expect = 1 + cow_pending.get(((req.shard or 0), page), 0)
                if refs != expect:
                    raise RuntimeError(
                        f"request {req.request_id}: decode write page "
                        f"{page} has refcount {refs} (expected {expect})"
                        f" — copy-on-write invariant violated")

    def _choose_k(self) -> int:
        """Per-round draft length.

        Non-adaptive: the configured ``speculate_k``.  Adaptive: each
        slot targets ``1 + round(ema * (k_max - 1))`` from its own
        acceptance EMA and the round runs the mean target over active
        slots — one dispatch serves the whole batch, so per-slot k is
        a compromise; the mean neither starves high-acceptance slots
        (max would overdraft the bad ones) nor throttles them to the
        worst slot (min).
        """
        k_max = self.speculate_k
        if not self.speculate_adaptive:
            return k_max
        act = self._active
        if not act.any():
            return k_max
        targets = np.clip(
            np.rint(1.0 + self._accept_ema[act] * (k_max - 1)), 1, k_max)
        return int(np.clip(np.rint(targets.mean()), 1, k_max))

    def _note_spec_round(self, accepted: int, n_active: int) -> None:
        """Track consecutive all-reject rounds; auto-disable the draft
        once `spec_disable_after` of them land in a row (output is
        unaffected — the verifier's corrections always emit — but a
        draft that never lands a token is pure overhead)."""
        if n_active <= 0:
            return
        if accepted > 0:
            self._all_reject_rounds = 0
            return
        self._all_reject_rounds += 1
        if (self._all_reject_rounds >= self.spec_disable_after
                and not self.spec_disabled):
            self.spec_disabled = True
            self.stats.spec_autodisables += 1
            self.metrics.counter("spec_autodisable_total").inc()
            if self.tracer.enabled:
                self.tracer.instant(
                    "spec_autodisable", tid="engine",
                    rounds=self._all_reject_rounds,
                    k=self.speculate_k)

    def _spec_round(self, finished: List[ServedTrajectory]) -> None:
        """One draft-then-verify round: k cheap draft steps, one
        multi-token verifier dispatch, accept/rollback by pos rewind."""
        tr = self.tracer
        k = self._choose_k()
        self._chosen_k_hist.record(k)
        cap = np.zeros((self.max_batch,), np.int32)
        for req in self.scheduler.running:
            cap[req.slot] = len(req.blocks) * self.block_size
        if isinstance(self.draft, ModelDraft):
            with tr.span("draft", tid="engine", k=k), \
                    self._ann("serve.draft"):
                draft_toks, draft_logits, self.draft.pages = \
                    self._draft_fn(k)(
                        self.draft.params, jnp.asarray(self._last_tok),
                        self.draft.pages,
                        self._dev("tables", self._tables),
                        jnp.asarray(self._pos),
                        self._dev("active", self._active),
                        self._dev("cap", cap),
                        self._dev("slot_shard", self._slot_shard),
                        self._next_key())
        else:
            prop_np = np.zeros((self.max_batch, k), np.int32)
            for req in self.scheduler.running:
                prop = np.asarray(
                    self.draft.fn(req, k), np.int32).reshape(-1)[:k]
                prop_np[req.slot, :prop.shape[0]] = prop
            draft_toks = jnp.asarray(prop_np)
            # One-hot proposal logits, built host-side (one transfer
            # instead of per-round device compare/where dispatches).
            vocab = self.bundle.cfg.vocab_size
            oh = np.full((self.max_batch, k, vocab), -1e9, np.float32)
            np.put_along_axis(oh, prop_np[..., None], 0.0, axis=-1)
            draft_logits = jnp.asarray(oh)
        with tr.span("verify", tid="engine", k=k), \
                self._ann("serve.verify"):
            toks, lps, n_acc, n_emit, self.pages = self._verify_fn(k)(
                self.params, jnp.asarray(self._last_tok), draft_toks,
                draft_logits, self.pages,
                self._dev("tables", self._tables),
                jnp.asarray(self._pos), self._dev("active", self._active),
                self._dev("cap", cap),
                self._dev("slot_shard", self._slot_shard),
                self._next_key())
            toks_np, lps_np, n_acc_np, n_emit_np = jax.device_get(
                (toks, lps, n_acc, n_emit))
        n_active = int(self._active.sum())
        self.stats.decode_steps += 1
        self.stats.occupancy_sum += float(n_active)
        self.stats.spec_rounds += 1
        self.stats.drafted_tokens += k * n_active
        accepted = int(n_acc_np[self._active].sum())
        self.stats.accepted_tokens += accepted
        self._note_spec_round(accepted, n_active)
        if tr.enabled:
            rejected = k * n_active - accepted
            if rejected:
                tr.instant("rollback", tid="engine", k=k,
                           rejected=rejected)
        if self.speculate_adaptive:
            # Acceptance EMA feeds the next round's adaptive k choice.
            a = self._accept_ema_alpha
            for slot in np.nonzero(self._active)[0]:
                rate = float(n_acc_np[slot]) / k
                self._accept_ema[slot] = (
                    (1.0 - a) * self._accept_ema[slot] + a * rate)
        lag = (None if self.draft.version is None
               else self.version - self.draft.version)
        for req in list(self.scheduler.running):
            slot = req.slot
            n_e = int(n_emit_np[slot])
            # Rollback = rewind: pos covers only the accepted prefix;
            # rejected rows are overwritten by the next round's writes.
            self._pos[slot] += n_e
            if lag is not None and n_e:
                self._draft_lag_hist.record(lag, n_e)
            for t in range(n_e):
                self._record(req, int(toks_np[slot, t]),
                             float(lps_np[slot, t]), finished)
                if req.state is not RequestState.RUNNING:
                    break    # EOS/budget retired it; drop the tail

    def run(self, max_steps: Optional[int] = None
            ) -> List[ServedTrajectory]:
        """Step until every submitted request finished (or max_steps)."""
        out: List[ServedTrajectory] = []
        steps = 0
        while self.has_work:
            out.extend(self.step())
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return out
