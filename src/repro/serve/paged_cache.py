"""Free-list block allocator + prefix cache for the pooled (paged) KV cache.

The device-side pool (``models.transformer.init_paged_cache``) is a
fixed set of ``num_blocks`` pages of ``block_size`` token rows each;
this module owns *which request holds which pages*.  Allocation pops
page ids off a free list and release pushes them back — freeing a
finished request is O(pages) pointer work with **zero cache copies**
(the rows are simply never referenced again; the next owner overwrites
them).

Page ids are plain ints; per-request block tables (ordered page lists)
live on the :class:`repro.serve.scheduler.Request`.  The table rows the
kernel sees must pad unused slots with an *in-range* id (0): the paged
attention index map fetches skipped pages too.  A negative table entry
(:data:`RECLAIMED`) marks a page released early by window reclamation —
``padded_table`` maps it to page 0 and the attention window mask hides
whatever garbage lives there.

**Prefix caching** (``prefix_cache=True``): pages become refcounted and
content-addressed.  A page's identity is a *chain hash* — blake2b over
its own token ids chained onto the previous page's hash and a salt
(policy version + arch identity), so a hash match certifies the entire
prefix up to and including that page.  :class:`PrefixIndex` maps

* chain hash → page id, for **full** pages (shared outright: a new
  admission's block table points at them, refcount bumped), and
* chain hash of the *preceding* pages → ``(page, token tuple)`` tail
  entries, for **partially matching** pages: the longest common token
  prefix is shared via copy-on-write (the engine copies the matched
  rows into a fresh page before appending its divergent suffix).

Release decrements refcounts; a registered page whose refcount hits
zero parks on an **evictable LRU** (content intact, hash entries live)
instead of the free list, so later admissions can still match it.
Allocation claims free pages first and evicts LRU cached pages only
under pressure — eviction drops the page's index entries.  Counting
``num_free = free + evictable`` keeps scheduler capacity math and the
"all pages returned" test invariants identical to the uncached
allocator.

**Sharded pools**: under a mesh, the pool's NB axis is partitioned over
the ``data`` axis and :class:`ShardedBlockAllocator` keeps one free
list *per shard*.  A request's pages all come from ONE shard (its home
shard — the scheduler picks it at admission), and the page ids handed
out are **shard-local** (``0 .. num_blocks/num_shards - 1``): they
index the shard's local pool slice, which is exactly what the
``shard_map``-dispatched kernels see.  Prefix indices are per-shard for
the same reason — a shared page is only addressable from its own
shard's pool slice, so the scheduler prefers placing an admission on
the shard holding its longest match.  Both allocator classes expose the
same shard-aware API; :class:`BlockAllocator` is the ``num_shards ==
1`` case where local and global ids coincide.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import (Deque, Dict, Iterable, List, Optional, Sequence, Tuple)

import numpy as np

from repro.obs.tracer import NULL_TRACER, Tracer

# Block-table sentinel: a page released early (window reclamation) but
# whose table position must survive so later pages keep their offsets.
RECLAIMED = -1

# At most this many divergent tails are indexed per chain position;
# beyond it new tails simply go unregistered (they still run, unshared).
_MAX_TAILS_PER_CHAIN = 8


class OutOfBlocks(RuntimeError):
    """Allocation request exceeds the free pool (caller should preempt)."""


# -- content addressing -------------------------------------------------------


def _digest(prev: bytes, tokens: Sequence[int]) -> bytes:
    h = hashlib.blake2b(prev, digest_size=16)
    h.update(np.asarray(tokens, np.int32).tobytes())
    return h.digest()


@dataclass(frozen=True)
class PrefixKey:
    """Content address of one request's committed token ids.

    ``chain[j]`` hashes pages ``0..j`` (salt-seeded, so a policy-weight
    swap or arch change invalidates every entry without a flush);
    ``pages[j]`` holds page ``j``'s token tuple and ``tail`` whatever
    ids spill past the last full page.  Built once per (version,
    length) by :func:`prefix_key`.
    """

    block_size: int
    root: bytes                         # H(salt): chain seed / empty-chain key
    chain: Tuple[bytes, ...]            # per full page, cumulative
    pages: Tuple[Tuple[int, ...], ...]  # token ids per full page
    tail: Tuple[int, ...]               # ids past the last full page

    def chain_before(self, j: int) -> bytes:
        """Index key for tails extending the first ``j`` full pages."""
        return self.root if j == 0 else self.chain[j - 1]


def prefix_key(ids: np.ndarray, block_size: int, salt: bytes) -> PrefixKey:
    ids = np.asarray(ids, np.int32)
    root = hashlib.blake2b(salt, digest_size=16).digest()
    n_full = len(ids) // block_size
    chain: List[bytes] = []
    pages: List[Tuple[int, ...]] = []
    prev = root
    for j in range(n_full):
        toks = tuple(int(t) for t in ids[j * block_size:(j + 1) * block_size])
        prev = _digest(prev, toks)
        chain.append(prev)
        pages.append(toks)
    tail = tuple(int(t) for t in ids[n_full * block_size:])
    return PrefixKey(block_size, root, tuple(chain), tuple(pages), tail)


def _common_prefix(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class PrefixIndex:
    """Hash → resident page map for one pool shard."""

    def __init__(self) -> None:
        self._full: Dict[bytes, int] = {}
        # chain-before key -> [(page, token tuple)], newest last
        self._tails: Dict[bytes, List[Tuple[int, Tuple[int, ...]]]] = {}
        # reverse map so eviction can drop a page's entries in O(entries)
        self._by_page: Dict[int, List[Tuple[str, bytes]]] = {}

    def __len__(self) -> int:
        return len(self._full) + sum(len(v) for v in self._tails.values())

    def register_full(self, page: int, chain_hash: bytes) -> None:
        if chain_hash in self._full:
            return              # first registration wins; content identical
        self._full[chain_hash] = page
        self._by_page.setdefault(page, []).append(("full", chain_hash))

    def register_tail(self, page: int, chain_before: bytes,
                      tokens: Tuple[int, ...]) -> None:
        if not tokens:
            return
        bucket = self._tails.setdefault(chain_before, [])
        if len(bucket) >= _MAX_TAILS_PER_CHAIN:
            return
        if any(p == page or t == tokens for p, t in bucket):
            return
        bucket.append((page, tokens))
        self._by_page.setdefault(page, []).append(("tail", chain_before))

    def drop_page(self, page: int) -> None:
        for kind, k in self._by_page.pop(page, []):
            if kind == "full":
                if self._full.get(k) == page:
                    del self._full[k]
            else:
                bucket = self._tails.get(k)
                if bucket is not None:
                    bucket[:] = [e for e in bucket if e[0] != page]
                    if not bucket:
                        del self._tails[k]

    def lookup_full(self, chain_hash: bytes) -> Optional[int]:
        return self._full.get(chain_hash)

    def lookup_tail(self, chain_before: bytes, tokens: Sequence[int],
                    budget: int) -> Tuple[Optional[int], int]:
        """Longest token-prefix tail match under ``chain_before``,
        capped at ``budget`` rows.  Returns ``(page, rows)``."""
        best_page, best_m = None, 0
        for page, toks in self._tails.get(chain_before, []):
            m = min(_common_prefix(tokens, toks), budget)
            if m > best_m:
                best_page, best_m = page, m
        return best_page, best_m


@dataclass
class PrefixMatch:
    """Resident-prefix match for one admission, on one shard."""

    matched_tokens: int = 0
    full_pages: List[int] = field(default_factory=list)  # share outright
    cow_page: Optional[int] = None      # partially matched source page
    cow_rows: int = 0                   # leading rows of cow_page to copy


class BlockAllocator:
    """Refcounted free-list allocator over ``num_blocks`` KV pages."""

    num_shards = 1

    def __init__(self, num_blocks: int, block_size: int, *,
                 prefix_cache: bool = False,
                 tracer: Tracer = NULL_TRACER,
                 shard_id: int = 0) -> None:
        if num_blocks < 1 or block_size < 1:
            raise ValueError(
                f"need positive pool, got {num_blocks}x{block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        self.tracer = tracer
        self.shard_id = shard_id
        # FIFO reuse spreads writes across the pool, which keeps stale
        # rows cold and makes use-after-free bugs loud in tests.
        self._free: Deque[int] = deque(range(num_blocks))
        self._refs: List[int] = [0] * num_blocks
        # zero-ref pages still registered in the index, LRU order
        # (oldest first); allocation evicts from here only after the
        # plain free list runs dry.
        self._evictable: "OrderedDict[int, None]" = OrderedDict()
        self._index = PrefixIndex() if prefix_cache else None
        self.evictions = 0

    # -- capacity -------------------------------------------------------------

    @property
    def num_free(self) -> int:
        """Allocatable pages: truly free + evictable cached."""
        return len(self._free) + len(self._evictable)

    @property
    def num_cached(self) -> int:
        """Zero-ref pages kept resident for prefix matching."""
        return len(self._evictable)

    @property
    def num_indexed(self) -> int:
        return 0 if self._index is None else len(self._index)

    @property
    def shard_num_blocks(self) -> int:
        """Pages per shard (= the whole pool when unsharded)."""
        return self.num_blocks

    def shard_free(self, shard: int = 0) -> int:
        return self.num_free

    def blocks_for(self, n_tokens: int) -> int:
        """Pages needed to hold `n_tokens` rows."""
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n: int, shard: int = 0) -> bool:
        return n <= self.num_free

    # -- allocate / share / release -------------------------------------------

    def allocate(self, n: int, shard: int = 0) -> List[int]:
        """Pop `n` page ids; raises :class:`OutOfBlocks` when short.

        Free pages go first; under pressure, least-recently-parked
        cached pages are evicted (their index entries dropped)."""
        if n > self.num_free:
            raise OutOfBlocks(
                f"asked for {n} pages, {self.num_free} free "
                f"(pool {self.num_blocks})")
        out: List[int] = []
        for _ in range(n):
            if self._free:
                b = self._free.popleft()
            else:
                b, _ = self._evictable.popitem(last=False)
                if self._index is not None:
                    self._index.drop_page(b)
                self.evictions += 1
                if self.tracer.enabled:
                    self.tracer.instant(
                        "cache_evict", tid="pool", page=b,
                        shard=self.shard_id)
            self._refs[b] = 1
            out.append(b)
        return out

    def share(self, page: int, shard: int = 0) -> int:
        """Add one reference to a resident page (reviving it from the
        evictable LRU if parked there).  Returns the page id."""
        self._check_page(page)
        if page in self._evictable:
            del self._evictable[page]
            assert self._refs[page] == 0
            self._refs[page] = 1
        elif self._refs[page] > 0:
            self._refs[page] += 1
        else:
            raise ValueError(f"page {page} is free; cannot share")
        return page

    def ref(self, page: int, shard: int = 0) -> int:
        self._check_page(page)
        return self._refs[page]

    def _check_page(self, b: int) -> None:
        if not (0 <= b < self.num_blocks):
            raise ValueError(
                f"page id {b} out of range [0, {self.num_blocks})")

    def release(self, blocks: Iterable[int], shard: int = 0) -> None:
        """Drop one reference per page (copy-free: no cache data moves).

        Double-frees and out-of-range ids raise — a silently corrupted
        free list would hand the same page to two requests."""
        for b in blocks:
            b = int(b)
            self._check_page(b)
            if self._refs[b] <= 0:
                raise ValueError(
                    f"double free of page {b} (refcount already 0)")
            self._refs[b] -= 1
            if self._refs[b] > 0:
                continue
            if self._index is not None and b in self._index._by_page:
                self._evictable[b] = None   # keep resident for matching
            else:
                self._free.append(b)

    # -- prefix index ---------------------------------------------------------

    def lookup(self, key: PrefixKey, limit: int,
               shard: int = 0) -> PrefixMatch:
        """Longest resident prefix of ``key``, at most ``limit`` tokens.

        Callers pass ``limit = len(ids) - 1`` so at least one token is
        always computed (the admission needs a logit to sample from).
        """
        m = PrefixMatch()
        if self._index is None or limit <= 0:
            return m
        bs = self.block_size
        for j in range(min(len(key.chain), limit // bs)):
            page = self._index.lookup_full(key.chain[j])
            if page is None:
                break
            m.full_pages.append(page)
        j = len(m.full_pages)
        if j < len(key.chain):
            next_tokens: Sequence[int] = key.pages[j]
        else:
            next_tokens = key.tail
        budget = min(limit - j * bs, bs)
        page, rows = self._index.lookup_tail(
            key.chain_before(j), next_tokens, budget)
        if page is not None and rows == bs:
            # The tail covers the whole page: share it outright, no COW.
            m.full_pages.append(page)
            j += 1
        elif page is not None:
            m.cow_page, m.cow_rows = page, rows
        m.matched_tokens = j * bs + m.cow_rows
        return m

    def register(self, key: PrefixKey, blocks: List[int],
                 n_matched_full: int, shard: int = 0) -> None:
        """Index an admission's *fresh* pages (matched ones already are).

        Every fresh full page registers under its chain hash and, so
        future admissions can diverge mid-page, also as a tail of the
        chain before it; a non-empty tail registers the page holding it.
        """
        if self._index is None:
            return
        for j in range(n_matched_full, len(key.chain)):
            self._index.register_full(blocks[j], key.chain[j])
            self._index.register_tail(
                blocks[j], key.chain_before(j), key.pages[j])
        if key.tail and len(key.chain) < len(blocks):
            self._index.register_tail(
                blocks[len(key.chain)], key.chain_before(len(key.chain)),
                key.tail)

    def unregister(self, pages: Iterable[int], shard: int = 0) -> None:
        """Drop the index entries of specific pages (their content stays
        put; references are untouched).  Used when a chunked prefill
        aborts mid-flight: the request's registered-but-never-computed
        pages must stop matching future admissions."""
        if self._index is None:
            return
        for b in pages:
            b = int(b)
            self._check_page(b)
            self._index.drop_page(b)
            # A page already parked on the evictable LRU with no index
            # entries left can never match again — free it outright.
            if b in self._evictable:
                del self._evictable[b]
                self._free.append(b)

    def flush(self, shard: Optional[int] = None) -> None:
        """Drop every index entry; evictable pages return to the free
        list.  (Unused on weight swaps — the version salt already
        invalidates stale entries — but handy for tests/tools.)"""
        if self._index is None:
            return
        for b in list(self._evictable):
            self._free.append(b)
        self._evictable.clear()
        self._index = PrefixIndex()

    # -- tables ---------------------------------------------------------------

    def padded_table(self, blocks: List[int], width: int) -> np.ndarray:
        """[width] int32 table row; unused slots and RECLAIMED entries
        pad with page 0 (the kernel's index map requires in-range ids
        everywhere; reclaimed positions are window-masked anyway)."""
        if len(blocks) > width:
            raise ValueError(
                f"request owns {len(blocks)} pages > table width {width}")
        row = np.zeros((width,), np.int32)
        row[: len(blocks)] = [b if b >= 0 else 0 for b in blocks]
        return row


class ShardedBlockAllocator:
    """Per-shard free lists over an NB-partitioned pool.

    ``num_blocks`` is the *total* pool; each of the ``num_shards``
    shards owns ``num_blocks / num_shards`` pages addressed by
    shard-local ids.  Placement (which shard a request lives on) is the
    scheduler's call; every allocate/release/lookup names the shard.
    """

    def __init__(self, num_blocks: int, block_size: int,
                 num_shards: int, *, prefix_cache: bool = False,
                 tracer: Tracer = NULL_TRACER) -> None:
        if num_shards < 1:
            raise ValueError(f"need >= 1 shard, got {num_shards}")
        if num_blocks % num_shards != 0:
            raise ValueError(
                f"num_blocks {num_blocks} must divide over "
                f"{num_shards} shards")
        self.num_shards = num_shards
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        self.tracer = tracer
        self._shards = [
            BlockAllocator(num_blocks // num_shards, block_size,
                           prefix_cache=prefix_cache, tracer=tracer,
                           shard_id=s)
            for s in range(num_shards)
        ]

    @property
    def num_free(self) -> int:
        return sum(s.num_free for s in self._shards)

    @property
    def num_cached(self) -> int:
        return sum(s.num_cached for s in self._shards)

    @property
    def evictions(self) -> int:
        return sum(s.evictions for s in self._shards)

    @property
    def shard_num_blocks(self) -> int:
        return self.num_blocks // self.num_shards

    def shard_free(self, shard: int = 0) -> int:
        return self._shards[shard].num_free

    def free_by_shard(self) -> List[int]:
        return [s.num_free for s in self._shards]

    def blocks_for(self, n_tokens: int) -> int:
        return self._shards[0].blocks_for(n_tokens)

    def can_allocate(self, n: int, shard: int = 0) -> bool:
        return self._shards[shard].can_allocate(n)

    def allocate(self, n: int, shard: int = 0) -> List[int]:
        """Pop `n` *shard-local* page ids off `shard`'s free list."""
        return self._shards[shard].allocate(n)

    def share(self, page: int, shard: int = 0) -> int:
        return self._shards[shard].share(page)

    def ref(self, page: int, shard: int = 0) -> int:
        return self._shards[shard].ref(page)

    def release(self, blocks: Iterable[int], shard: int = 0) -> None:
        self._shards[shard].release(blocks)

    def lookup(self, key: PrefixKey, limit: int,
               shard: int = 0) -> PrefixMatch:
        return self._shards[shard].lookup(key, limit)

    def register(self, key: PrefixKey, blocks: List[int],
                 n_matched_full: int, shard: int = 0) -> None:
        self._shards[shard].register(key, blocks, n_matched_full)

    def unregister(self, pages: Iterable[int], shard: int = 0) -> None:
        self._shards[shard].unregister(pages)

    def flush(self, shard: Optional[int] = None) -> None:
        for i, s in enumerate(self._shards):
            if shard is None or shard == i:
                s.flush()

    def padded_table(self, blocks: List[int], width: int) -> np.ndarray:
        return self._shards[0].padded_table(blocks, width)


def make_allocator(num_blocks: int, block_size: int,
                   num_shards: int = 1, *, prefix_cache: bool = False,
                   tracer: Tracer = NULL_TRACER):
    """Allocator for an ``num_shards``-way partitioned pool (1 = plain)."""
    if num_shards <= 1:
        return BlockAllocator(num_blocks, block_size,
                              prefix_cache=prefix_cache, tracer=tracer)
    return ShardedBlockAllocator(num_blocks, block_size, num_shards,
                                 prefix_cache=prefix_cache, tracer=tracer)
