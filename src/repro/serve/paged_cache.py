"""Free-list block allocator for the pooled (paged) KV cache.

The device-side pool (``models.transformer.init_paged_cache``) is a
fixed set of ``num_blocks`` pages of ``block_size`` token rows each;
this module owns *which request holds which pages*.  Allocation pops
page ids off a free list and release pushes them back — freeing a
finished request is O(pages) pointer work with **zero cache copies**
(the rows are simply never referenced again; the next owner overwrites
them).

Page ids are plain ints; per-request block tables (ordered page lists)
live on the :class:`repro.serve.scheduler.Request`.  The table rows the
kernel sees must pad unused slots with an *in-range* id (0): the paged
attention index map fetches skipped pages too.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Iterable, List

import numpy as np


class OutOfBlocks(RuntimeError):
    """Allocation request exceeds the free pool (caller should preempt)."""


class BlockAllocator:
    """FIFO free list over ``num_blocks`` fixed-size KV pages."""

    def __init__(self, num_blocks: int, block_size: int) -> None:
        if num_blocks < 1 or block_size < 1:
            raise ValueError(
                f"need positive pool, got {num_blocks}x{block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # FIFO reuse spreads writes across the pool, which keeps stale
        # rows cold and makes use-after-free bugs loud in tests.
        self._free: Deque[int] = deque(range(num_blocks))

    @property
    def num_free(self) -> int:
        return len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        """Pages needed to hold `n_tokens` rows."""
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def allocate(self, n: int) -> List[int]:
        """Pop `n` page ids; raises :class:`OutOfBlocks` when short."""
        if n > len(self._free):
            raise OutOfBlocks(
                f"asked for {n} pages, {len(self._free)} free "
                f"(pool {self.num_blocks})")
        return [self._free.popleft() for _ in range(n)]

    def release(self, blocks: Iterable[int]) -> None:
        """Return pages to the pool (copy-free: no cache data moves)."""
        for b in blocks:
            self._free.append(int(b))

    def padded_table(self, blocks: List[int], width: int) -> np.ndarray:
        """[width] int32 table row; unused slots pad with page 0 (the
        kernel's index map requires in-range ids everywhere)."""
        if len(blocks) > width:
            raise ValueError(
                f"request owns {len(blocks)} pages > table width {width}")
        row = np.zeros((width,), np.int32)
        row[: len(blocks)] = blocks
        return row
