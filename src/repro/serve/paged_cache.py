"""Free-list block allocator for the pooled (paged) KV cache.

The device-side pool (``models.transformer.init_paged_cache``) is a
fixed set of ``num_blocks`` pages of ``block_size`` token rows each;
this module owns *which request holds which pages*.  Allocation pops
page ids off a free list and release pushes them back — freeing a
finished request is O(pages) pointer work with **zero cache copies**
(the rows are simply never referenced again; the next owner overwrites
them).

Page ids are plain ints; per-request block tables (ordered page lists)
live on the :class:`repro.serve.scheduler.Request`.  The table rows the
kernel sees must pad unused slots with an *in-range* id (0): the paged
attention index map fetches skipped pages too.

**Sharded pools**: under a mesh, the pool's NB axis is partitioned over
the ``data`` axis and :class:`ShardedBlockAllocator` keeps one free
list *per shard*.  A request's pages all come from ONE shard (its home
shard — the scheduler picks it at admission), and the page ids handed
out are **shard-local** (``0 .. num_blocks/num_shards - 1``): they
index the shard's local pool slice, which is exactly what the
``shard_map``-dispatched kernels see.  Both allocator classes expose
the same shard-aware API; :class:`BlockAllocator` is the
``num_shards == 1`` case where local and global ids coincide.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Iterable, List, Optional

import numpy as np


class OutOfBlocks(RuntimeError):
    """Allocation request exceeds the free pool (caller should preempt)."""


class BlockAllocator:
    """FIFO free list over ``num_blocks`` fixed-size KV pages."""

    num_shards = 1

    def __init__(self, num_blocks: int, block_size: int) -> None:
        if num_blocks < 1 or block_size < 1:
            raise ValueError(
                f"need positive pool, got {num_blocks}x{block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # FIFO reuse spreads writes across the pool, which keeps stale
        # rows cold and makes use-after-free bugs loud in tests.
        self._free: Deque[int] = deque(range(num_blocks))

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def shard_num_blocks(self) -> int:
        """Pages per shard (= the whole pool when unsharded)."""
        return self.num_blocks

    def shard_free(self, shard: int = 0) -> int:
        return len(self._free)

    def blocks_for(self, n_tokens: int) -> int:
        """Pages needed to hold `n_tokens` rows."""
        return -(-n_tokens // self.block_size)

    def can_allocate(self, n: int, shard: int = 0) -> bool:
        return n <= len(self._free)

    def allocate(self, n: int, shard: int = 0) -> List[int]:
        """Pop `n` page ids; raises :class:`OutOfBlocks` when short."""
        if n > len(self._free):
            raise OutOfBlocks(
                f"asked for {n} pages, {len(self._free)} free "
                f"(pool {self.num_blocks})")
        return [self._free.popleft() for _ in range(n)]

    def release(self, blocks: Iterable[int], shard: int = 0) -> None:
        """Return pages to the pool (copy-free: no cache data moves)."""
        for b in blocks:
            self._free.append(int(b))

    def padded_table(self, blocks: List[int], width: int) -> np.ndarray:
        """[width] int32 table row; unused slots pad with page 0 (the
        kernel's index map requires in-range ids everywhere)."""
        if len(blocks) > width:
            raise ValueError(
                f"request owns {len(blocks)} pages > table width {width}")
        row = np.zeros((width,), np.int32)
        row[: len(blocks)] = blocks
        return row


class ShardedBlockAllocator:
    """Per-shard free lists over an NB-partitioned pool.

    ``num_blocks`` is the *total* pool; each of the ``num_shards``
    shards owns ``num_blocks / num_shards`` pages addressed by
    shard-local ids.  Placement (which shard a request lives on) is the
    scheduler's call; every allocate/release names the shard.
    """

    def __init__(self, num_blocks: int, block_size: int,
                 num_shards: int) -> None:
        if num_shards < 1:
            raise ValueError(f"need >= 1 shard, got {num_shards}")
        if num_blocks % num_shards != 0:
            raise ValueError(
                f"num_blocks {num_blocks} must divide over "
                f"{num_shards} shards")
        self.num_shards = num_shards
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._shards = [
            BlockAllocator(num_blocks // num_shards, block_size)
            for _ in range(num_shards)
        ]

    @property
    def num_free(self) -> int:
        return sum(s.num_free for s in self._shards)

    @property
    def shard_num_blocks(self) -> int:
        return self.num_blocks // self.num_shards

    def shard_free(self, shard: int = 0) -> int:
        return self._shards[shard].num_free

    def free_by_shard(self) -> List[int]:
        return [s.num_free for s in self._shards]

    def blocks_for(self, n_tokens: int) -> int:
        return self._shards[0].blocks_for(n_tokens)

    def can_allocate(self, n: int, shard: int = 0) -> bool:
        return self._shards[shard].can_allocate(n)

    def allocate(self, n: int, shard: int = 0) -> List[int]:
        """Pop `n` *shard-local* page ids off `shard`'s free list."""
        return self._shards[shard].allocate(n)

    def release(self, blocks: Iterable[int], shard: int = 0) -> None:
        self._shards[shard].release(blocks)

    def padded_table(self, blocks: List[int], width: int) -> np.ndarray:
        return self._shards[0].padded_table(blocks, width)


def make_allocator(num_blocks: int, block_size: int,
                   num_shards: int = 1):
    """Allocator for an ``num_shards``-way partitioned pool (1 = plain)."""
    if num_shards <= 1:
        return BlockAllocator(num_blocks, block_size)
    return ShardedBlockAllocator(num_blocks, block_size, num_shards)
