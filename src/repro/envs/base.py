"""Pure-JAX environment interface.

The paper's §5.1 study needs hundreds of parallel actors whose policies are
*different* (sampled from the policy buffer), running inside jit.  The
interface is therefore fully functional:

    env.reset(key)                  -> EnvState
    env.step(state, action, key)    -> (EnvState, Timestep)

``EnvState`` is env-specific (a pytree); ``Timestep`` is common.  Episode
truncation (time limits) and auto-reset are provided by ``wrap_autoreset``
so rollout collectors see an infinite stream, like gym vector envs.

All five environments are classic continuous-control tasks with smooth
dynamics, integrable by explicit Euler/RK at fixed dt, chosen to mirror
the "five MuJoCo environments" protocol of Fig. 3/4 while staying
CPU-jittable.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Timestep(NamedTuple):
    obs: jax.Array      # [obs_dim]
    reward: jax.Array   # scalar
    done: jax.Array     # scalar bool — episode ended THIS step (term|trunc)
    info_steps: jax.Array  # scalar int32 — steps elapsed in episode


class Env(NamedTuple):
    name: str
    obs_dim: int
    act_dim: int
    max_episode_steps: int
    reset: Callable[[jax.Array], Any]
    step: Callable[[Any, jax.Array, jax.Array], tuple]
    observe: Callable[[Any], jax.Array]


class AutoResetState(NamedTuple):
    inner: Any
    t: jax.Array  # steps elapsed


def wrap_autoreset(env: Env) -> Env:
    """Time-limit + auto-reset wrapper (gym-style vector semantics).

    On done (termination or hitting max_episode_steps) the state resets
    immediately; the returned `obs` is the first obs of the new episode
    and `done` is True so advantage estimators cut the bootstrap.
    """

    def reset(key):
        return AutoResetState(inner=env.reset(key), t=jnp.zeros((), jnp.int32))

    def step(state: AutoResetState, action, key):
        k_step, k_reset = jax.random.split(key)
        inner, ts = env.step(state.inner, action, k_step)
        t = state.t + 1
        truncated = t >= env.max_episode_steps
        done = jnp.logical_or(ts.done, truncated)

        fresh = env.reset(k_reset)
        inner = jax.tree.map(
            lambda new, old: jnp.where(done, new, old), fresh, inner
        )
        t = jnp.where(done, 0, t)
        obs = jnp.where(done, env.observe(inner), ts.obs)
        return (
            AutoResetState(inner=inner, t=t),
            Timestep(obs=obs, reward=ts.reward, done=done, info_steps=t),
        )

    def observe(state: AutoResetState):
        return env.observe(state.inner)

    return Env(
        name=env.name,
        obs_dim=env.obs_dim,
        act_dim=env.act_dim,
        max_episode_steps=env.max_episode_steps,
        reset=reset,
        step=step,
        observe=observe,
    )


def angle_normalize(x: jax.Array) -> jax.Array:
    return ((x + jnp.pi) % (2.0 * jnp.pi)) - jnp.pi
