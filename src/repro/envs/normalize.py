"""Running observation/reward normalization (CleanRL's NormalizeObservation
/ NormalizeReward, jit-compatible functional form).

The paper's Table 1 setup uses CleanRL defaults, which normalize
observations with running mean/variance (Welford) and scale rewards by a
running std of discounted returns.  State is an explicit pytree carried
by the rollout loop so everything stays inside jit and is shared across
the mixture actors (normalization statistics belong to the *environment*
stream, not to any one policy).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class RunningStat(NamedTuple):
    mean: jax.Array   # [D]
    var: jax.Array    # [D]
    count: jax.Array  # scalar


def stat_init(dim: int) -> RunningStat:
    return RunningStat(
        mean=jnp.zeros((dim,)),
        var=jnp.ones((dim,)),
        count=jnp.asarray(1e-4),
    )


def stat_update(stat: RunningStat, batch: jax.Array) -> RunningStat:
    """Parallel Welford update with a [N, D] batch."""
    b_mean = jnp.mean(batch, axis=0)
    b_var = jnp.var(batch, axis=0)
    b_count = jnp.asarray(batch.shape[0], jnp.float32)

    delta = b_mean - stat.mean
    tot = stat.count + b_count
    new_mean = stat.mean + delta * b_count / tot
    m_a = stat.var * stat.count
    m_b = b_var * b_count
    m2 = m_a + m_b + jnp.square(delta) * stat.count * b_count / tot
    return RunningStat(mean=new_mean, var=m2 / tot, count=tot)


def normalize(stat: RunningStat, x: jax.Array,
              clip: float = 10.0) -> jax.Array:
    y = (x - stat.mean) / jnp.sqrt(stat.var + 1e-8)
    return jnp.clip(y, -clip, clip)


class RewardNormState(NamedTuple):
    ret: jax.Array     # [N] running discounted returns per env stream
    stat: RunningStat  # scalar statistics over returns


def reward_norm_init(n_envs: int) -> RewardNormState:
    return RewardNormState(ret=jnp.zeros((n_envs,)), stat=stat_init(1))


def reward_norm_update(
    state: RewardNormState,
    rewards: jax.Array,   # [N]
    dones: jax.Array,     # [N]
    gamma: float = 0.99,
    clip: float = 10.0,
) -> Tuple[RewardNormState, jax.Array]:
    """Scale rewards by the running std of discounted returns."""
    ret = state.ret * gamma * (1.0 - dones.astype(jnp.float32)) + rewards
    stat = stat_update(state.stat, ret[:, None])
    scaled = jnp.clip(
        rewards / jnp.sqrt(stat.var[0] + 1e-8), -clip, clip)
    return RewardNormState(ret=ret, stat=stat), scaled
