"""Five pure-JAX continuous-control environments.

pendulum        1-act swing-up, dense cost           (obs 3)
cartpole_swingup 1-act cart + pole swing-up           (obs 5)
acrobot         1-act two-link underactuated swing-up (obs 6)
pointmass       2-act double integrator to random goal (obs 6)
reacher         2-act two-link arm to random target    (obs 8)

All dynamics are explicit-Euler at fixed dt with clipped torques, smooth
rewards, and bounded states — well-conditioned for policy-gradient
learning within a few hundred thousand steps on CPU.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.base import Env, Timestep, angle_normalize


# ---------------------------------------------------------------------------
# Pendulum swing-up
# ---------------------------------------------------------------------------


class PendulumState(NamedTuple):
    th: jax.Array
    thdot: jax.Array


def make_pendulum(max_steps: int = 200) -> Env:
    g, m, l, dt = 10.0, 1.0, 1.0, 0.05
    max_torque, max_speed = 2.0, 8.0

    def observe(s: PendulumState):
        return jnp.stack([jnp.cos(s.th), jnp.sin(s.th), s.thdot / max_speed])

    def reset(key):
        k1, k2 = jax.random.split(key)
        th = jax.random.uniform(k1, (), minval=-jnp.pi, maxval=jnp.pi)
        thdot = jax.random.uniform(k2, (), minval=-1.0, maxval=1.0)
        return PendulumState(th=th, thdot=thdot)

    def step(s: PendulumState, action, key):
        u = jnp.clip(action[0], -1.0, 1.0) * max_torque
        cost = (
            angle_normalize(s.th) ** 2
            + 0.1 * s.thdot**2
            + 0.001 * u**2
        )
        thdot = s.thdot + (
            3.0 * g / (2.0 * l) * jnp.sin(s.th)
            + 3.0 / (m * l**2) * u
        ) * dt
        thdot = jnp.clip(thdot, -max_speed, max_speed)
        th = s.th + thdot * dt
        ns = PendulumState(th=th, thdot=thdot)
        return ns, Timestep(
            obs=observe(ns),
            reward=-cost,
            done=jnp.zeros((), bool),
            info_steps=jnp.zeros((), jnp.int32),
        )

    return Env("pendulum", 3, 1, max_steps, reset, step, observe)


# ---------------------------------------------------------------------------
# CartPole swing-up (continuous force)
# ---------------------------------------------------------------------------


class CartPoleState(NamedTuple):
    x: jax.Array
    xdot: jax.Array
    th: jax.Array
    thdot: jax.Array


def make_cartpole_swingup(max_steps: int = 250) -> Env:
    g, mc, mp, l, dt = 9.8, 1.0, 0.1, 0.5, 0.02
    force_mag, x_lim = 10.0, 2.4

    def observe(s: CartPoleState):
        return jnp.stack(
            [s.x / x_lim, s.xdot / 5.0, jnp.cos(s.th), jnp.sin(s.th),
             s.thdot / 10.0]
        )

    def reset(key):
        k1, k2 = jax.random.split(key)
        th = jnp.pi + 0.1 * jax.random.normal(k1)   # hanging down
        x = 0.2 * jax.random.normal(k2)
        return CartPoleState(
            x=x, xdot=jnp.zeros(()), th=th, thdot=jnp.zeros(())
        )

    def step(s: CartPoleState, action, key):
        f = jnp.clip(action[0], -1.0, 1.0) * force_mag
        sin, cos = jnp.sin(s.th), jnp.cos(s.th)
        total_m = mc + mp
        tmp = (f + mp * l * s.thdot**2 * sin) / total_m
        thacc = (g * sin - cos * tmp) / (
            l * (4.0 / 3.0 - mp * cos**2 / total_m)
        )
        xacc = tmp - mp * l * thacc * cos / total_m
        x = s.x + dt * s.xdot
        xdot = jnp.clip(s.xdot + dt * xacc, -5.0, 5.0)
        th = s.th + dt * s.thdot
        thdot = jnp.clip(s.thdot + dt * thacc, -10.0, 10.0)
        ns = CartPoleState(x=x, xdot=xdot, th=th, thdot=thdot)
        # Upright bonus minus control / off-center penalty.
        reward = jnp.cos(th) - 0.05 * (x / x_lim) ** 2 - 0.001 * f**2
        done = jnp.abs(x) > x_lim
        return ns, Timestep(
            obs=observe(ns), reward=reward, done=done,
            info_steps=jnp.zeros((), jnp.int32),
        )

    return Env("cartpole_swingup", 5, 1, max_steps, reset, step, observe)


# ---------------------------------------------------------------------------
# Acrobot swing-up (continuous torque)
# ---------------------------------------------------------------------------


class AcrobotState(NamedTuple):
    th1: jax.Array
    th2: jax.Array
    dth1: jax.Array
    dth2: jax.Array


def make_acrobot(max_steps: int = 250) -> Env:
    m1 = m2 = 1.0
    l1 = 1.0
    lc1 = lc2 = 0.5
    i1 = i2 = 1.0
    g, dt, max_torque = 9.8, 0.05, 2.0

    def observe(s: AcrobotState):
        return jnp.stack(
            [jnp.cos(s.th1), jnp.sin(s.th1), jnp.cos(s.th2), jnp.sin(s.th2),
             s.dth1 / (4.0 * jnp.pi), s.dth2 / (9.0 * jnp.pi)]
        )

    def reset(key):
        vals = jax.random.uniform(key, (4,), minval=-0.1, maxval=0.1)
        return AcrobotState(
            th1=vals[0], th2=vals[1], dth1=vals[2], dth2=vals[3]
        )

    def step(s: AcrobotState, action, key):
        tau = jnp.clip(action[0], -1.0, 1.0) * max_torque
        d1 = (
            m1 * lc1**2
            + m2 * (l1**2 + lc2**2 + 2 * l1 * lc2 * jnp.cos(s.th2))
            + i1 + i2
        )
        d2 = m2 * (lc2**2 + l1 * lc2 * jnp.cos(s.th2)) + i2
        phi2 = m2 * lc2 * g * jnp.cos(s.th1 + s.th2 - jnp.pi / 2.0)
        phi1 = (
            -m2 * l1 * lc2 * s.dth2**2 * jnp.sin(s.th2)
            - 2 * m2 * l1 * lc2 * s.dth2 * s.dth1 * jnp.sin(s.th2)
            + (m1 * lc1 + m2 * l1) * g * jnp.cos(s.th1 - jnp.pi / 2.0)
            + phi2
        )
        ddth2 = (
            tau + d2 / d1 * phi1
            - m2 * l1 * lc2 * s.dth1**2 * jnp.sin(s.th2) - phi2
        ) / (m2 * lc2**2 + i2 - d2**2 / d1)
        ddth1 = -(d2 * ddth2 + phi1) / d1
        dth1 = jnp.clip(s.dth1 + dt * ddth1, -4 * jnp.pi, 4 * jnp.pi)
        dth2 = jnp.clip(s.dth2 + dt * ddth2, -9 * jnp.pi, 9 * jnp.pi)
        ns = AcrobotState(
            th1=angle_normalize(s.th1 + dt * dth1),
            th2=angle_normalize(s.th2 + dt * dth2),
            dth1=dth1,
            dth2=dth2,
        )
        # Tip height in [-2, 2]; dense shaping toward swing-up.
        height = -jnp.cos(ns.th1) - jnp.cos(ns.th1 + ns.th2)
        reward = 0.5 * height - 0.001 * tau**2
        return ns, Timestep(
            obs=observe(ns), reward=reward, done=jnp.zeros((), bool),
            info_steps=jnp.zeros((), jnp.int32),
        )

    return Env("acrobot", 6, 1, max_steps, reset, step, observe)


# ---------------------------------------------------------------------------
# Point-mass goal reaching (double integrator)
# ---------------------------------------------------------------------------


class PointMassState(NamedTuple):
    pos: jax.Array   # [2]
    vel: jax.Array   # [2]
    goal: jax.Array  # [2]


def make_pointmass(max_steps: int = 150) -> Env:
    dt, max_force, arena = 0.05, 1.0, 2.0

    def observe(s: PointMassState):
        return jnp.concatenate(
            [s.pos / arena, s.vel, (s.goal - s.pos) / arena]
        )

    def reset(key):
        k1, k2 = jax.random.split(key)
        pos = jax.random.uniform(k1, (2,), minval=-arena, maxval=arena)
        goal = jax.random.uniform(k2, (2,), minval=-arena, maxval=arena)
        return PointMassState(pos=pos, vel=jnp.zeros((2,)), goal=goal)

    def step(s: PointMassState, action, key):
        f = jnp.clip(action, -1.0, 1.0) * max_force
        vel = jnp.clip(s.vel + dt * f - 0.02 * s.vel, -2.0, 2.0)
        pos = jnp.clip(s.pos + dt * vel, -arena, arena)
        ns = PointMassState(pos=pos, vel=vel, goal=s.goal)
        dist = jnp.linalg.norm(s.goal - pos)
        reward = -dist - 0.01 * jnp.sum(f**2) + jnp.where(dist < 0.1, 1.0, 0.0)
        return ns, Timestep(
            obs=observe(ns), reward=reward, done=jnp.zeros((), bool),
            info_steps=jnp.zeros((), jnp.int32),
        )

    return Env("pointmass", 6, 2, max_steps, reset, step, observe)


# ---------------------------------------------------------------------------
# Two-link reacher
# ---------------------------------------------------------------------------


class ReacherState(NamedTuple):
    th: jax.Array      # [2]
    thdot: jax.Array   # [2]
    target: jax.Array  # [2]


def make_reacher(max_steps: int = 100) -> Env:
    l1, l2, dt, max_torque = 0.1, 0.11, 0.02, 1.0

    def _tip(th):
        x = l1 * jnp.cos(th[0]) + l2 * jnp.cos(th[0] + th[1])
        y = l1 * jnp.sin(th[0]) + l2 * jnp.sin(th[0] + th[1])
        return jnp.stack([x, y])

    def observe(s: ReacherState):
        return jnp.concatenate(
            [jnp.cos(s.th), jnp.sin(s.th), s.thdot / 10.0,
             (s.target - _tip(s.th)) * 5.0]
        )

    def reset(key):
        k1, k2, k3 = jax.random.split(key, 3)
        th = jax.random.uniform(k1, (2,), minval=-jnp.pi, maxval=jnp.pi)
        r = jax.random.uniform(k2, (), minval=0.05, maxval=l1 + l2 - 0.01)
        ang = jax.random.uniform(k3, (), minval=-jnp.pi, maxval=jnp.pi)
        target = r * jnp.stack([jnp.cos(ang), jnp.sin(ang)])
        return ReacherState(th=th, thdot=jnp.zeros((2,)), target=target)

    def step(s: ReacherState, action, key):
        tau = jnp.clip(action, -1.0, 1.0) * max_torque
        thdot = jnp.clip(s.thdot + dt * (tau * 40.0 - 1.0 * s.thdot),
                         -10.0, 10.0)
        th = s.th + dt * thdot
        ns = ReacherState(th=th, thdot=thdot, target=s.target)
        dist = jnp.linalg.norm(s.target - _tip(th))
        reward = -dist - 0.01 * jnp.sum(tau**2)
        return ns, Timestep(
            obs=observe(ns), reward=reward, done=jnp.zeros((), bool),
            info_steps=jnp.zeros((), jnp.int32),
        )

    return Env("reacher", 8, 2, max_steps, reset, step, observe)


ENV_MAKERS = {
    "pendulum": make_pendulum,
    "cartpole_swingup": make_cartpole_swingup,
    "acrobot": make_acrobot,
    "pointmass": make_pointmass,
    "reacher": make_reacher,
}


def make_env(name: str, **kwargs) -> Env:
    return ENV_MAKERS[name](**kwargs)
