from repro.envs.base import Env, Timestep, wrap_autoreset, angle_normalize
from repro.envs.normalize import (
    RunningStat,
    stat_init,
    stat_update,
    normalize,
    reward_norm_init,
    reward_norm_update,
)
from repro.envs.classic import (
    ENV_MAKERS,
    make_env,
    make_pendulum,
    make_cartpole_swingup,
    make_acrobot,
    make_pointmass,
    make_reacher,
)

__all__ = [
    "Env",
    "Timestep",
    "wrap_autoreset",
    "angle_normalize",
    "ENV_MAKERS",
    "make_env",
    "make_pendulum",
    "make_cartpole_swingup",
    "make_acrobot",
    "make_pointmass",
    "make_reacher",
]
