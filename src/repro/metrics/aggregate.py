"""rliable-style aggregate metrics (Agarwal et al., 2021).

The paper reports Median / IQM / Mean / Optimality Gap with stratified
bootstrap 95% CIs over (tasks x seeds) matrices of min-max normalized
returns (Figs. 3, 8, 10).  numpy host-side — these run on logged results,
not in jit.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np


def minmax_normalize(
    scores_by_alg: Dict[str, np.ndarray]
) -> Dict[str, np.ndarray]:
    """Per-task min-max normalization across ALL algorithms (paper §5.1:
    "use the maximum and minimum score obtained from all the algorithms").

    Each value is [n_tasks, n_seeds]; normalization is per task row.
    """
    algs = list(scores_by_alg)
    stacked = np.stack([scores_by_alg[a] for a in algs])  # [A, T, S]
    lo = stacked.min(axis=(0, 2), keepdims=True)
    hi = stacked.max(axis=(0, 2), keepdims=True)
    rng = np.where(hi - lo < 1e-12, 1.0, hi - lo)
    normed = (stacked - lo) / rng
    return {a: normed[i] for i, a in enumerate(algs)}


def iqm(scores: np.ndarray) -> float:
    """Interquartile mean over the flattened (task, seed) matrix."""
    x = np.sort(scores.reshape(-1))
    n = x.size
    lo, hi = int(np.floor(n * 0.25)), int(np.ceil(n * 0.75))
    return float(np.mean(x[lo:hi])) if hi > lo else float(np.mean(x))


def median(scores: np.ndarray) -> float:
    """Median of per-task mean scores (rliable convention)."""
    return float(np.median(scores.mean(axis=-1)))


def mean(scores: np.ndarray) -> float:
    return float(np.mean(scores))


def optimality_gap(scores: np.ndarray, gamma_thresh: float = 1.0) -> float:
    """Mean shortfall below the `gamma_thresh` performance level."""
    return float(np.mean(np.maximum(gamma_thresh - scores, 0.0)))


AGGREGATES: Dict[str, Callable[[np.ndarray], float]] = {
    "median": median,
    "iqm": iqm,
    "mean": mean,
    "optimality_gap": optimality_gap,
}


def stratified_bootstrap_ci(
    scores: np.ndarray,
    fn: Callable[[np.ndarray], float],
    n_boot: int = 2000,
    alpha: float = 0.05,
    seed: int = 0,
) -> Tuple[float, float, float]:
    """Percentile bootstrap CI, resampling seeds independently per task.

    `scores` is [n_tasks, n_seeds].  Returns (point, lo, hi).
    """
    rng = np.random.default_rng(seed)
    t, s = scores.shape
    stats = np.empty(n_boot)
    for b in range(n_boot):
        idx = rng.integers(0, s, size=(t, s))
        stats[b] = fn(np.take_along_axis(scores, idx, axis=1))
    lo = float(np.percentile(stats, 100 * alpha / 2))
    hi = float(np.percentile(stats, 100 * (1 - alpha / 2)))
    return fn(scores), lo, hi


def aggregate_metrics(
    scores_by_alg: Dict[str, np.ndarray],
    normalize: bool = True,
    n_boot: int = 2000,
    seed: int = 0,
) -> Dict[str, Dict[str, Tuple[float, float, float]]]:
    """Full Fig. 3-style table: per algorithm, per aggregate, (pt, lo, hi)."""
    if normalize:
        scores_by_alg = minmax_normalize(scores_by_alg)
    out: Dict[str, Dict[str, Tuple[float, float, float]]] = {}
    for alg, scores in scores_by_alg.items():
        out[alg] = {
            name: stratified_bootstrap_ci(scores, fn, n_boot=n_boot, seed=seed)
            for name, fn in AGGREGATES.items()
        }
    return out


def auc(curve: np.ndarray, axis: int = -1) -> np.ndarray:
    """Area under a (normalized-return vs step) curve — Fig. 4 bottom-right."""
    return np.trapezoid(curve, axis=axis) / max(curve.shape[axis] - 1, 1)
