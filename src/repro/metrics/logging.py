"""Minimal structured metric logging (CSV/JSONL writers for trainers).

No tensorboard/wandb offline — trainers append JSONL rows; benchmarks
read them back for curves.  Kept deliberately tiny and dependency-free.

Rows are written **atomically**: the full line is encoded first and
handed to an unbuffered binary handle as one ``write()``, so a trainer
crash mid-row never leaves a truncated JSONL line for the reader to
choke on.  The logger is a context manager and also closes on GC.

With ``registry=`` (an ``obs.MetricsRegistry``), :meth:`log_registry`
appends the registry's full ``snapshot()`` as one row — the logger is
then just a thin sink on the unified metrics path instead of a fourth
ad-hoc dict shape.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional


class MetricLogger:
    def __init__(self, path: Optional[str] = None, echo: bool = False,
                 registry: Any = None):
        self.path = path
        self.echo = echo
        self.registry = registry
        self._start = time.time()
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            # Unbuffered binary appends: each row is one write syscall,
            # atomic from the reader's point of view.
            self._fh = open(path, "ab", buffering=0)
        else:
            self._fh = None

    # -- context manager / GC hygiene ----------------------------------------

    def __enter__(self) -> "MetricLogger":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def __del__(self) -> None:
        try:
            self.close()
        except Exception:
            pass    # interpreter teardown: file may already be gone

    # -- writes ---------------------------------------------------------------

    def _write_row(self, row: Dict[str, Any]) -> None:
        if self._fh:
            self._fh.write((json.dumps(row) + "\n").encode("utf-8"))

    def log(self, step: int, **metrics: Any) -> None:
        row: Dict[str, Any] = {
            "step": step,
            "wall": round(time.time() - self._start, 3),
        }
        for k, v in metrics.items():
            try:
                row[k] = float(v)
            except (TypeError, ValueError):
                row[k] = v
        self._write_row(row)
        if self.echo:
            pretty = " ".join(
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in row.items() if k not in ("wall",)
            )
            print(pretty, flush=True)

    def log_registry(self, step: int, **extra: Any) -> Dict[str, Any]:
        """One row = the attached registry's full snapshot (+extras)."""
        if self.registry is None:
            raise ValueError("MetricLogger has no registry attached")
        row: Dict[str, Any] = {
            "step": step,
            "wall": round(time.time() - self._start, 3),
        }
        row.update(self.registry.snapshot())
        row.update(extra)
        self._write_row(row)
        return row

    def close(self) -> None:
        fh, self._fh = self._fh, None
        if fh:
            fh.close()


def read_jsonl(path: str):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
