"""Minimal structured metric logging (CSV/JSONL writers for trainers).

No tensorboard/wandb offline — trainers append JSONL rows; benchmarks
read them back for curves.  Kept deliberately tiny and dependency-free.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional


class MetricLogger:
    def __init__(self, path: Optional[str] = None, echo: bool = False):
        self.path = path
        self.echo = echo
        self._start = time.time()
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
            self._fh = open(path, "a")
        else:
            self._fh = None

    def log(self, step: int, **metrics: Any) -> None:
        row: Dict[str, Any] = {
            "step": step,
            "wall": round(time.time() - self._start, 3),
        }
        for k, v in metrics.items():
            try:
                row[k] = float(v)
            except (TypeError, ValueError):
                row[k] = v
        if self._fh:
            self._fh.write(json.dumps(row) + "\n")
            self._fh.flush()
        if self.echo:
            pretty = " ".join(
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in row.items() if k not in ("wall",)
            )
            print(pretty, flush=True)

    def close(self) -> None:
        if self._fh:
            self._fh.close()


def read_jsonl(path: str):
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
