from repro.metrics.aggregate import (
    iqm,
    median,
    mean,
    optimality_gap,
    aggregate_metrics,
    stratified_bootstrap_ci,
    minmax_normalize,
)
from repro.metrics.runtime_metrics import (
    LagHistogram,
    RuntimeQueueStats,
    collect_runtime_stats,
)

__all__ = [
    "iqm",
    "median",
    "mean",
    "optimality_gap",
    "aggregate_metrics",
    "stratified_bootstrap_ci",
    "minmax_normalize",
    "LagHistogram",
    "RuntimeQueueStats",
    "collect_runtime_stats",
]
