from repro.metrics.aggregate import (
    iqm,
    median,
    mean,
    optimality_gap,
    aggregate_metrics,
    stratified_bootstrap_ci,
    minmax_normalize,
)
from repro.metrics.logging import MetricLogger, read_jsonl
from repro.metrics.runtime_metrics import (
    LagHistogram,
    RuntimeQueueStats,
    collect_runtime_stats,
    collect_serve_stats,
    serve_latency_counts,
    serve_latency_stats,
)

__all__ = [
    "iqm",
    "median",
    "mean",
    "optimality_gap",
    "aggregate_metrics",
    "stratified_bootstrap_ci",
    "minmax_normalize",
    "LagHistogram",
    "MetricLogger",
    "RuntimeQueueStats",
    "collect_runtime_stats",
    "collect_serve_stats",
    "read_jsonl",
    "serve_latency_counts",
    "serve_latency_stats",
]
