from repro.metrics.aggregate import (
    iqm,
    median,
    mean,
    optimality_gap,
    aggregate_metrics,
    stratified_bootstrap_ci,
    minmax_normalize,
)

__all__ = [
    "iqm",
    "median",
    "mean",
    "optimality_gap",
    "aggregate_metrics",
    "stratified_bootstrap_ci",
    "minmax_normalize",
]
