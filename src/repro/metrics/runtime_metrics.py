"""Observability for the async actor-learner runtime.

Per-version lag histograms, queue depth, and admission-drop rates — the
paper's Fig. 1 "degree of asynchronicity" made measurable on a live run
instead of being a configuration constant.  Everything is host-side
Python (no jax), cheap enough to update on every queue operation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict


class LagHistogram:
    """Counter over integer policy lags (learner_version - behavior_version)."""

    def __init__(self) -> None:
        self._counts: Dict[int, int] = {}

    def record(self, lag: int, n: int = 1) -> None:
        lag = int(lag)
        self._counts[lag] = self._counts.get(lag, 0) + n

    def snapshot(self) -> Dict[int, int]:
        return dict(sorted(self._counts.items()))


@dataclass(frozen=True)
class RuntimeQueueStats:
    """One consistent snapshot of a TrajectoryQueue's counters."""

    depth: int
    puts: int
    admitted: int
    dropped: int
    downweighted: int
    admission_drop_rate: float
    drops_by_reason: Dict[str, int] = field(default_factory=dict)
    lag_histogram: Dict[int, int] = field(default_factory=dict)
    controller: str = ""
    downweights_by_reason: Dict[str, int] = field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        return {
            "depth": self.depth,
            "puts": self.puts,
            "admitted": self.admitted,
            "dropped": self.dropped,
            "downweighted": self.downweighted,
            "admission_drop_rate": self.admission_drop_rate,
            "drops_by_reason": dict(self.drops_by_reason),
            "lag_histogram": {
                str(k): v for k, v in self.lag_histogram.items()
            },
            "controller": self.controller,
            "downweights_by_reason": dict(self.downweights_by_reason),
        }


def collect_serve_stats(engine: Any) -> Dict[str, Any]:
    """JSON-ready view of a ServeEngine: decode/occupancy counters plus
    the paged-pool and scheduler state (the serve-side analogue of
    :func:`collect_runtime_stats`).

    Speculative runs additionally report the acceptance rate (accepted
    draft tokens / drafted; ``as_dict`` computes it), drafted-vs-emitted
    token counts, the draft slot's policy version, the
    **draft-version lag histogram**: per emitted token, how many
    publishes the draft policy lagged the verifier — the serve-side
    mirror of the runtime's behavior-policy lag histograms — and the
    **chosen-k histogram**: how many speculative rounds ran each draft
    length (constant at ``speculate_k`` unless ``speculate_adaptive``
    shrinks low-acceptance rounds).

    Sharded engines (``mesh``) add per-shard pool and placement
    counters: free pages and live decode slots by shard.

    Prefix-cached engines (``prefix_cache=True``) add the cache's
    effectiveness counters: admission hit rate, matched vs computed
    prefill tokens (the token-level hit rate), COW copies, resident
    zero-ref cached pages and LRU evictions; window-reclaiming engines
    report pages released behind the sliding window.

    Engines carrying an ``obs.MetricsRegistry`` (all of them, since
    the engine creates one by default) additionally report serve-time
    latency percentiles straight from the registry's histograms —
    TTFT (submit -> first token) plus its queue-wait vs
    prefill-compute decomposition, inter-token gap, admission
    queue-wait, end-to-end request latency and swap-to-first-stale-
    token — as ``{ttft,ttft_queue,ttft_prefill,inter_token,queue_wait,
    request_latency,swap_to_stale}_{count,mean_ms,p50_ms,p99_ms}``.
    Benchmarks source
    their timing columns from the same histograms, so benchmark
    numbers and live telemetry cannot disagree.
    """
    alloc = engine.allocator
    sched = engine.scheduler
    out = dict(engine.stats.as_dict())
    out.update({
        "policy_version": engine.version,
        "pool_blocks": alloc.num_blocks,
        "pool_blocks_free": alloc.num_free,
        "pool_utilization": (
            1.0 - alloc.num_free / alloc.num_blocks
            if alloc.num_blocks else 0.0
        ),
        "block_size": alloc.block_size,
        "waiting": len(sched.waiting),
        "running": len(sched.running),
        "speculate_k": getattr(engine, "speculate_k", 0),
    })
    draft = getattr(engine, "draft", None)
    if draft is not None:
        out["draft_version"] = draft.version
        out["draft_version_lag_histogram"] = {
            str(k): v
            for k, v in engine._draft_lag_hist.snapshot().items()
        }
        out["speculate_adaptive"] = getattr(
            engine, "speculate_adaptive", False)
        out["chosen_k_histogram"] = {
            str(k): v
            for k, v in engine._chosen_k_hist.snapshot().items()
        }
    if getattr(alloc, "num_shards", 1) > 1:
        out["num_shards"] = alloc.num_shards
        out["pool_free_by_shard"] = alloc.free_by_shard()
        out["live_slots_by_shard"] = sched._live_slots_by_shard()
    if getattr(alloc, "prefix_cache", False):
        matched = sched.prefix_matched_tokens
        computed = engine.stats.prefill_tokens
        out.update({
            "prefix_cache": True,
            "prefix_queries": sched.prefix_queries,
            "prefix_hits": sched.prefix_hits,
            "prefix_hit_rate": (
                sched.prefix_hits / sched.prefix_queries
                if sched.prefix_queries else 0.0),
            "prefix_matched_tokens": matched,
            "prefix_token_hit_rate": (
                matched / (matched + computed)
                if (matched + computed) else 0.0),
            "cached_pages": alloc.num_cached,
            "cache_evictions": alloc.evictions,
        })
    if getattr(engine, "_reclaim_window", None) is not None:
        out["reclaim_window"] = engine._reclaim_window
        out["reclaimed_window_pages"] = sched.reclaimed_pages
    out["spec_disabled"] = bool(getattr(engine, "spec_disabled", False))
    out["timeouts_by_state"] = dict(getattr(sched, "timeouts_by_state", {}))
    metrics = getattr(engine, "metrics", None)
    if metrics is not None:
        out.update(serve_latency_stats(metrics))
        resilience = collect_resilience_stats(
            metrics, store=getattr(engine, "store", None),
            injector=getattr(engine, "injector", None))
        if resilience:
            out["resilience"] = resilience
    return out


# Registry histogram name -> flat-key prefix in collect_serve_stats.
# ttft decomposes exactly into ttft_queue (submit -> the admission that
# produced the first token) + ttft_prefill (that admission -> first
# token): under chunked prefill the second term is what the dispatch
# budget bounds, so the split shows whether a slow first token is
# queueing or prompt compute.
SERVE_LATENCY_HISTOGRAMS = (
    ("serve_ttft_s", "ttft"),
    ("serve_ttft_queue_s", "ttft_queue"),
    ("serve_ttft_prefill_s", "ttft_prefill"),
    ("serve_inter_token_s", "inter_token"),
    ("serve_queue_wait_s", "queue_wait"),
    ("serve_request_latency_s", "request_latency"),
    ("serve_swap_to_stale_s", "swap_to_stale"),
)


def serve_latency_stats(metrics: Any,
                        starts: Any = None) -> Dict[str, Any]:
    """Flat latency keys (ms) from a registry's serve histograms.

    ``starts`` (a ``{hist_name: count}`` dict, e.g. captured before a
    benchmark run) restricts each histogram to observations made after
    that count — the windowed read benchmarks use on a registry shared
    across repeats.
    """
    out: Dict[str, Any] = {}
    for name, key in SERVE_LATENCY_HISTOGRAMS:
        h = metrics.histogram(name)
        s = h.summary(start=None if starts is None else starts.get(name))
        out[f"{key}_count"] = int(s["count"])
        out[f"{key}_mean_ms"] = s["mean"] * 1e3
        out[f"{key}_p50_ms"] = s["p50"] * 1e3
        out[f"{key}_p99_ms"] = s["p99"] * 1e3
    return out


def serve_latency_counts(metrics: Any) -> Dict[str, int]:
    """Current observation counts per serve histogram — pass back to
    :func:`serve_latency_stats` as ``starts`` for a windowed read."""
    return {name: metrics.histogram(name).count
            for name, _ in SERVE_LATENCY_HISTOGRAMS}


# Fault/recovery counters surfaced by collect_{runtime,serve}_stats —
# the names the resilience layer increments (repro.resilience plus the
# hooks in policy_store/queue/scheduler/engine/trainer).
RESILIENCE_COUNTERS = (
    "fault_injected_total",
    "watchdog_restart_total",
    "request_timeout_total",
    "publish_quarantined_total",
    "admission_fallback_total",
    "restart_admitted_total",
    "learner_nonfinite_total",
    "spec_autodisable_total",
)


def collect_resilience_stats(registry: Any, store: Any = None,
                             injector: Any = None) -> Dict[str, Any]:
    """Fault-injection and recovery counters as one JSON-ready block.

    Reads labelled counters via ``registry.counter_values`` (never
    ``snapshot()`` — this function runs *inside* snapshot producers),
    plus the store's quarantine ledger and the injector's fired-fault
    tally when available.
    """
    out: Dict[str, Any] = {}
    if registry is not None and hasattr(registry, "counter_values"):
        out["counters"] = registry.counter_values(*RESILIENCE_COUNTERS)
    if store is not None and hasattr(store, "quarantined_versions"):
        out["quarantined_versions"] = sorted(store.quarantined_versions())
    if injector is not None and getattr(injector, "active", False):
        out["faults_fired"] = dict(injector.fired_counts())
    return out


def collect_runtime_stats(store: Any, queue: Any) -> Dict[str, Any]:
    """Joined store+queue view, JSON-ready, for launchers and examples."""
    stats = queue.stats()
    hist = stats.lag_histogram
    total = sum(hist.values())
    mean_lag = (
        sum(k * v for k, v in hist.items()) / total if total else 0.0
    )
    out = {
        "policy_version": store.version,
        "retained_versions": store.retained_versions(),
        "queue": stats.as_dict(),
        "mean_lag": mean_lag,
        "max_lag": max(hist) if hist else 0,
        # The labelled-counter view of the same decisions, keyed by the
        # active controller (satisfies dashboards that join on the
        # queue_admission_total{controller,outcome,reason} counters).
        "admission": {
            "controller": stats.controller,
            "drops_by_reason": dict(stats.drops_by_reason),
            "downweights_by_reason": dict(stats.downweights_by_reason),
        },
    }
    counters_fn = getattr(queue, "admission_counters", None)
    if counters_fn is not None:
        out["admission"]["counters"] = counters_fn()
    resilience = collect_resilience_stats(
        getattr(queue, "registry", None), store=store,
        injector=getattr(queue, "injector", None))
    if resilience:
        out["resilience"] = resilience
    return out
