"""Architecture registry: the 10 assigned configs + the paper's own model.

``get_config(name)`` returns the FULL assigned config (dry-run only on
this host); ``reduced_config(name)`` returns the CPU-smoke variant of the
same family (<= 2 layers, d_model <= 512, <= 4 experts) used by tests and
the runnable examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.base import (
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    MoEConfig,
    SSMConfig,
)
from repro.configs.qwen2_5_14b import CONFIG as _qwen14b
from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.hymba_1_5b import CONFIG as _hymba
from repro.configs.granite_20b import CONFIG as _granite
from repro.configs.codeqwen1_5_7b import CONFIG as _codeqwen
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6
from repro.configs.qwen2_5_0_5b import CONFIG as _qwen05b

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _qwen14b, _paligemma, _gemma3, _hymba, _granite, _codeqwen,
        _whisper, _kimi, _llama4, _rwkv6,
    ]
}
# The paper's own model (not in the assigned pool, used by examples).
EXTRA_ARCHS: Dict[str, ModelConfig] = {_qwen05b.name: _qwen05b}


def list_archs() -> List[str]:
    return list(ARCHS)


def get_config(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name in EXTRA_ARCHS:
        return EXTRA_ARCHS[name]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


def reduced_config(name: str, vocab: int = 512) -> ModelConfig:
    """Family-preserving reduction: 2 layers, d_model<=256, <=4 experts.

    Keeps every structural feature live (GQA grouping, QKV bias, windows,
    MoE top-k + shared experts, SSM state size, prefix-LM, enc-dec) so the
    smoke test exercises the same code paths as the full config.
    """
    cfg = get_config(name)
    group = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    if cfg.attn_free:
        heads, kv = 2, 2
        d_model = 128  # rwkv requires d_model % 64 == 0
    else:
        heads = min(group, 8) if group > 1 else 2
        kv = max(1, heads // min(group, heads))
        d_model = 256
    changes = dict(
        n_layers=2,
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=kv,
        d_head=64,
        d_ff=256,
        vocab_size=vocab,
    )
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=128,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            capacity_factor=2.0,
        )
    if cfg.ssm is not None:
        changes["ssm"] = SSMConfig(
            state_dim=cfg.ssm.state_dim, conv_width=cfg.ssm.conv_width,
            expand=cfg.ssm.expand,
        )
    if cfg.sliding_window is not None:
        changes["sliding_window"] = 16
        changes["global_every"] = 2
    if cfg.vision_prefix_len > 0:
        changes["vision_prefix_len"] = 8
    if cfg.encoder_layers > 0:
        changes["encoder_layers"] = 2
        changes["encoder_seq_len"] = 16
    return cfg.replace(name=f"{cfg.name}-reduced", **changes)


__all__ = [
    "ARCHS",
    "EXTRA_ARCHS",
    "INPUT_SHAPES",
    "InputShape",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "list_archs",
    "get_config",
    "reduced_config",
]
