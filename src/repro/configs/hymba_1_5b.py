"""hymba-1.5b — hybrid-head: parallel attention + SSM per layer.
[arXiv:2411.13676]

Assigned: 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16.  Every layer fuses an attention branch and a Mamba branch
(mean of the two outputs, per the paper).  Most layers use sliding-window
attention; Hymba keeps 3 full-attention layers (first/middle/last) — we
approximate the pattern with ``global_every=16`` (layers 15 and 31 global)
since the layer scan expresses heterogeneity through the per-layer window
vector.  SWA + SSM makes the arch sub-quadratic => long_500k runs.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    arch_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32001,
    activation="swiglu",
    sliding_window=1024,
    global_every=16,
    hybrid_attn_ssm=True,
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    value_head=True,
    source="arXiv:2411.13676 (Hymba)",
)
