"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table scale).
[arXiv:2501.kimi2]

Assigned: 61L d_model=7168 64H (GQA kv=8) d_ff=2048(expert) vocab=163840,
MoE 384 experts top-8 (+1 shared).  ~1.04T total / ~32B active params —
the stress case for expert-parallel sharding and the dry-run's memory
analysis (optimizer state at this scale needs the full 512-chip multi-pod
mesh; see EXPERIMENTS.md §Dry-run).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=2048,                 # per-expert intermediate
    vocab_size=163840,
    rope_theta=50_000.0,
    activation="swiglu",
    moe=MoEConfig(
        n_experts=384,
        top_k=8,
        d_ff_expert=2048,
        n_shared_experts=1,
        capacity_factor=1.25,
    ),
    value_head=True,
    source="arXiv:2501.kimi2 (Kimi K2)",
)
