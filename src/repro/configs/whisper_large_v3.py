"""whisper-large-v3 — encoder-decoder ASR, conv frontend stubbed.
[arXiv:2212.04356]

Assigned: 32L d_model=1280 20H (kv=20 => MHA) d_ff=5120 vocab=51866.
The mel-spectrogram + conv subsampling frontend is the stubbed modality
input (``input_specs`` provides [B, 1500, 1280] frame embeddings); the
32-layer encoder and 32-layer decoder transformers are real.

Adaptation note (DESIGN.md §8): whisper's native decoder context is 448
tokens; the decode_32k shape exercises the same serve_step machinery with
a deeper cache (the assignment's input-shape suite is uniform across
archs).  long_500k is skipped — full attention (see DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    n_layers=32,           # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_head=64,
    d_ff=5120,
    vocab_size=51866,
    activation="gelu",
    encoder_layers=32,
    encoder_seq_len=1500,  # 30s audio -> 1500 post-conv frames
    value_head=True,
    source="arXiv:2212.04356 (Whisper); large-v3 card",
)
