"""granite-20b — code model with MQA.  [arXiv:2405.04324]

Assigned: 52L d_model=6144 48H (GQA kv=1 => MQA) d_ff=24576 vocab=49152.
d_ff = 4*d_model with a plain (non-gated) GELU MLP — the gpt_bigcode-style
block the 20B Granite code model actually uses (a gated swiglu at this
d_ff would be ~28B, off the nameplate); attention follows the llama-style
RoPE/RMSNorm conventions of the rest of the framework.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    arch_type="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_head=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=10_000.0,
    activation="gelu",
    value_head=True,
    source="arXiv:2405.04324 (Granite Code Models)",
)
