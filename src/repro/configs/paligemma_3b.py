"""paligemma-3b — SigLIP + gemma VLM, prefix-LM.  [arXiv:2407.07726]

Assigned: 18L d_model=2048 8H (GQA kv=1 => MQA) d_ff=16384 vocab=257216.
The SigLIP vision tower + projector input is the stubbed frontend
(``input_specs`` provides [B, 256, 1152] patch embeddings); the projector
linear and the gemma-2b language decoder are real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    arch_type="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16384,
    vocab_size=257216,
    rope_theta=10_000.0,
    activation="swiglu",      # gemma geglu ~ swiglu-class gated MLP
    tie_embeddings=True,
    vision_prefix_len=256,    # 224px / 14 patch -> 256 tokens
    prefix_lm=True,           # bidirectional prefix over image+prompt
    value_head=True,
    source="arXiv:2407.07726 (PaliGemma)",
)
