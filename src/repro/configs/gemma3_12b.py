"""gemma3-12b — dense GQA, 5:1 local:global interleave, 128k.
[hf:google/gemma-3-1b-pt]

Assigned: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
Sliding window 1024 on local layers; every 6th layer global — this is the
sub-quadratic pattern that qualifies gemma3 for the long_500k decode shape
(local layers bound their KV to the window; global-layer caches are
sequence-sharded, see repro.distributed).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    arch_type="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=15360,
    vocab_size=262144,
    rope_theta=1_000_000.0,
    activation="swiglu",
    tie_embeddings=True,
    logit_softcap=30.0,
    sliding_window=1024,
    global_every=6,           # 5 local : 1 global
    value_head=True,
    source="hf:google/gemma-3-1b-pt (family card, 12B shape)",
)
