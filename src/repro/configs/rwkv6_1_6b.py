"""rwkv6-1.6b — "Finch": attention-free, data-dependent decay.
[arXiv:2404.05892]

Assigned: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536.
O(1) decode state (the WKV matrix per head) — the canonical long_500k
architecture; decode cost is independent of context length.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # 2048 / 64 WKV heads (informational)
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    attn_free=True,
    activation="gelu",     # unused by the rwkv block (squared-relu inside)
    value_head=True,
    source="arXiv:2404.05892 (RWKV-6 Finch)",
)
