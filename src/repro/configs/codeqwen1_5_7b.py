"""codeqwen1.5-7b — qwen1.5-arch code model, full MHA.
[hf:Qwen/CodeQwen1.5-7B]

Assigned: 32L d_model=4096 32H (GQA kv=32 => MHA) d_ff=13440 vocab=92416.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    activation="swiglu",
    value_head=True,
    source="hf:Qwen/CodeQwen1.5-7B",
)
