"""llama4-scout-17b-a16e — MoE top-1 routing, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]

Assigned: 48L d_model=5120 40H (GQA kv=8) d_ff=8192(expert) vocab=202048,
MoE 16 experts top-1 (+ shared expert, llama4-style).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500_000.0,
    activation="swiglu",
    moe=MoEConfig(
        n_experts=16,
        top_k=1,
        d_ff_expert=8192,
        n_shared_experts=1,
        capacity_factor=1.25,
    ),
    value_head=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
