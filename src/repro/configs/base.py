"""Model/architecture configuration schema + the assigned input shapes.

Every assigned architecture instantiates ``ModelConfig`` in its own module
under ``repro.configs`` (one file per arch, citing its source), and a
``reduced()`` variant (<= 2 layers, d_model <= 512, <= 4 experts) for the
CPU smoke tests.  The FULL configs are exercised only through the
multi-pod dry-run (abstract lowering, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01  # load-balance loss coefficient
    group_size: int = 512          # tokens per dispatch group (perf knob:
                                   # dispatch memory = N*group*k*cf)


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16          # per-channel state (hymba: ssm_state=16)
    conv_width: int = 4
    expand: int = 2              # mamba inner expansion
    dt_rank: Optional[int] = None  # defaults to ceil(d_model/16)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str               # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False                # qwen-family
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    activation: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    logit_softcap: Optional[float] = None  # gemma-style final softcap
    # Sliding-window pattern: window size for "local" layers; a layer l is
    # global iff (l + 1) % global_every == 0 (gemma3's 5 local : 1 global).
    # sliding_window=None => all layers global full attention.
    sliding_window: Optional[int] = None
    global_every: int = 6
    # MoE / SSM / hybrid extensions.
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_ssm: bool = False          # hymba: parallel attn+SSM heads
    attn_free: bool = False                # rwkv6: no attention at all
    # Encoder-decoder (whisper): encoder consumes stubbed frame embeddings.
    encoder_layers: int = 0
    encoder_seq_len: int = 0               # e.g. 1500 mel frames
    # VLM (paligemma): prefix of stubbed patch embeddings, prefix-LM mask.
    vision_prefix_len: int = 0
    prefix_lm: bool = False
    # RL heads.
    value_head: bool = True
    # Citation for the assigned config (paper/model-card).
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else (
            self.d_model // self.n_heads
        )

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch can serve long_500k (harness long-decode rule)."""
        if self.attn_free:
            return True
        if self.hybrid_attn_ssm and self.sliding_window is not None:
            return True
        return self.sliding_window is not None

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def window_for_layer(self, layer: int) -> Optional[int]:
        """None => full/global attention at this layer."""
        if self.sliding_window is None:
            return None
        if (layer + 1) % self.global_every == 0:
            return None
        return self.sliding_window

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + heads)."""
        d, h, kv, dh, ff, v = (
            self.d_model, self.n_heads, self.n_kv_heads, self.head_dim,
            self.d_ff, self.vocab_size,
        )
        n_attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.qkv_bias:
            n_attn += (h + 2 * kv) * dh
        if self.activation == "swiglu":
            n_mlp_dense = 3 * d * ff
        else:
            n_mlp_dense = 2 * d * ff
        per_layer = 2 * d  # norms
        if self.attn_free:
            # rwkv6: time-mix (~4 d^2 per layer incl. decay MLPs) +
            # channel-mix (2*d*ff approximately, rwkv uses square relu ffn)
            per_layer += 4 * d * d + d * ff * 2
        elif self.hybrid_attn_ssm:
            inner = (self.ssm.expand if self.ssm else 2) * d
            per_layer += n_attn + n_mlp_dense + 2 * d * inner + inner * d
        else:
            per_layer += n_attn
            if self.moe is not None:
                m = self.moe
                per_layer += d * m.n_experts  # router
                per_layer += m.n_experts * 3 * d * m.d_ff_expert
                per_layer += m.n_shared_experts * 3 * d * m.d_ff_expert
            else:
                per_layer += n_mlp_dense
        total = self.n_layers * per_layer
        total += v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        if self.encoder_layers:
            enc_per = 2 * d + n_attn + n_mlp_dense
            total += self.encoder_layers * (enc_per + n_attn + d)  # + cross
        total += 2 * d  # final norm(s)
        if self.value_head:
            total += d + 1
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_like = self.replace(moe=None)
        base = dense_like.param_count() - self.n_layers * (
            3 * self.d_model * self.d_ff
        )
        active_ff = self.n_layers * (
            (m.top_k + m.n_shared_experts) * 3 * self.d_model * m.d_ff_expert
            + self.d_model * m.n_experts
        )
        return int(base + active_ff)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
