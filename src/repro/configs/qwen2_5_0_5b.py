"""qwen2.5-0.5b — the paper's OWN RLVR model (§5.2 / App. C.2).
[hf:Qwen/Qwen2.5-0.5B, arXiv:2412.15115]

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936, tied embeddings.
Not part of the assigned 10 — included because the paper trains it; the
RLVR example driver uses a reduced variant of exactly this family.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-0.5b",
    arch_type="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_head=64,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    activation="swiglu",
    tie_embeddings=True,
    value_head=True,
    source="hf:Qwen/Qwen2.5-0.5B (the paper's RLVR base model)",
)
