"""qwen2.5-14b — dense GQA decoder, QKV bias.  [hf:Qwen/Qwen2.5-0.5B]

Assigned: 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    arch_type="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,            # qwen-family attention bias
    rope_theta=1_000_000.0,
    activation="swiglu",
    value_head=True,
    source="hf:Qwen/Qwen2.5-0.5B (family card, 14B shape)",
)
