"""Paper hyper-parameter tables, as code (Table 1 and Table 2).

These are the *paper-faithful* values; the CPU-scaled runs in benchmarks/
override only the scale knobs (num envs, steps, episodes) and record the
overrides in EXPERIMENTS.md.
"""
from repro.train.trainer_rl import RLHyperparams
from repro.train.trainer_rlvr import RLVRHyperparams

# Table 1 — simulated-async MuJoCo setup (CleanRL defaults).
TABLE1_RL = RLHyperparams(
    algorithm="vaco",
    delta=0.2,                 # Clip Ratio / TV Threshold
    lr=3e-4,                   # + linear annealing (handled by trainer)
    gamma=0.99,
    num_minibatches=32,
    num_epochs=10,
    max_grad_norm=0.5,
    rho_bar=1.0,
    c_bar=1.0,
)
TABLE1_SCALE = dict(num_envs=500, num_steps=1000)  # paper-scale collection

# Table 2 — GSM8k RLVR setup.
TABLE2_RLVR = RLVRHyperparams(
    algorithm="grpo_vaco",
    clip_low=0.2,              # PPO-Clip Lower Ratio
    clip_high=0.272,           # PPO-Clip Higher Ratio (DAPO)
    delta=0.05,                # TV Threshold
    lr=1e-6,                   # paper LR on the 0.5B model
    prompts_per_minibatch=32,
    completions_per_prompt=8,
    max_new_tokens=512,        # Completion Length
    temperature=1.0,
)
TABLE2_SCALE = dict(total_episodes=65536, num_steps=256, prompt_length=512)
