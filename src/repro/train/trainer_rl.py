"""Classic-RL trainer: VACO vs PPO / PPO-KL / SPO / IMPALA (§5.1).

One jit-compiled ``train_phase`` per algorithm, following the paper's
protocol and Table 1 hyper-parameters:

    collect (mixture actors) -> estimate advantages ONCE (algorithm-
    specific) -> num_epochs x num_minibatches SGD -> publish policy.

Algorithm-specific advantage paths:
* ``vaco``    — V-trace realigned to pi_T (Eqs. 14-15), computed once per
                phase; TV-filtered loss (Alg. 1).
* ``ppo``     — GAE on the behavior data + clipped surrogate.
* ``ppo_kl``  — ppo + KL penalty coefficient (the Fig. 3 baselines).
* ``spo``     — GAE + squared-TV penalty, no clip (Xie et al., 2025).
* ``impala``  — V-trace RE-ESTIMATED against the current policy at every
                minibatch update (the costly path of Fig. 2 bottom).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.gae import gae, normalize_advantages
from repro.core.losses import (
    IMPALAConfig,
    PPOConfig,
    SPOConfig,
    VACOConfig,
    impala_total_loss,
    ppo_total_loss,
    spo_total_loss,
    vaco_total_loss,
)
from repro.core.vtrace import vtrace, vtrace_impala_pg_advantage
from repro.kernels import ops as kops
from repro.models.mlp_policy import policy_dist, value_fn
from repro.optim import (
    AdamWConfig,
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    linear_anneal,
)
from repro.rollout.env_rollout import RolloutBatch


@dataclass(frozen=True)
class RLHyperparams:
    """Table 1 defaults (CleanRL), scaled for CPU via the runner."""

    algorithm: str = "vaco"
    gamma: float = 0.99
    gae_lambda: float = 0.95
    vtrace_lambda: float = 1.0
    rho_bar: float = 1.0
    c_bar: float = 1.0
    delta: float = 0.2           # clip ratio / TV threshold
    kl_coef: float = 0.0         # ppo_kl
    spo_coef: float = 20.0
    entropy_coef: float = 0.0
    value_coef: float = 0.5
    lr: float = 3e-4
    max_grad_norm: float = 0.5
    num_epochs: int = 10
    num_minibatches: int = 32
    total_phases: int = 100      # for LR annealing
    normalize_adv: bool = True   # PPO-family minibatch normalization
    realign: bool = True         # Fig. 12 ablation: False => GAE advantages
                                 # on behavioral data + TV filter only


class RLTrainState(NamedTuple):
    params: Any
    opt_state: AdamWState
    phase: jax.Array   # int32 counter for LR annealing


def init_train_state(params: Any) -> RLTrainState:
    return RLTrainState(
        params=params,
        opt_state=adamw_init(params),
        phase=jnp.zeros((), jnp.int32),
    )


def _log_pi_and_entropy(params, obs, actions):
    dist = policy_dist(params, obs)
    return dist.log_prob(actions), dist.entropy()


def _phase_advantages(hp: RLHyperparams, params, batch: RolloutBatch):
    """Advantage/value-target estimation at phase start (once)."""
    values = value_fn(params, batch.obs)                      # [N, T]
    bootstrap = value_fn(params, batch.final_obs)             # [N]
    discounts = hp.gamma * (1.0 - batch.dones.astype(jnp.float32))

    if hp.algorithm == "vaco" and hp.realign:
        log_pi_T, _ = _log_pi_and_entropy(params, batch.obs, batch.actions)
        log_ratios = log_pi_T - batch.log_beta
        # kernels.ops dispatches reference (CPU/autodiff) vs the Pallas
        # TPU kernel per REPRO_KERNEL_MODE; realignment is once-per-phase
        # and consumed under stop_gradient, so the no-autodiff kernel
        # path is safe here.
        vs, advantages = kops.vtrace(
            jax.lax.stop_gradient(log_ratios), values, bootstrap,
            batch.rewards, discounts, rho_bar=hp.rho_bar, c_bar=hp.c_bar,
            lam=hp.vtrace_lambda,
        )
        return advantages, vs
    # PPO-family: GAE on the behavioral data.
    out = gae(values=values, bootstrap_value=bootstrap,
              rewards=batch.rewards, discounts=discounts,
              lam=hp.gae_lambda)
    return out.advantages, out.returns


def make_train_phase(
    hp: RLHyperparams,
) -> Callable[[RLTrainState, RolloutBatch, jax.Array],
              Tuple[RLTrainState, Dict[str, jax.Array]]]:
    """Build the jitted phase update for `hp.algorithm`."""
    opt_cfg = AdamWConfig(lr=hp.lr, eps=1e-5)
    lr_schedule = linear_anneal(hp.total_phases, floor=0.0)

    vaco_cfg = VACOConfig(delta=hp.delta, entropy_coef=hp.entropy_coef,
                          value_coef=hp.value_coef)
    ppo_cfg = PPOConfig(clip_low=hp.delta, clip_high=hp.delta,
                        kl_coef=hp.kl_coef if hp.algorithm == "ppo_kl"
                        else 0.0,
                        entropy_coef=hp.entropy_coef,
                        value_coef=hp.value_coef)
    spo_cfg = SPOConfig(penalty_coef=hp.spo_coef,
                        entropy_coef=hp.entropy_coef,
                        value_coef=hp.value_coef)
    impala_cfg = IMPALAConfig(entropy_coef=hp.entropy_coef,
                              value_coef=hp.value_coef,
                              rho_bar_pg=hp.rho_bar)

    def minibatch_loss(params, mb, full_batch):
        """mb: dict of flat [M, ...] slices."""
        log_pi, entropy = _log_pi_and_entropy(
            params, mb["obs"], mb["actions"])
        values = value_fn(params, mb["obs"])

        if hp.algorithm == "vaco":
            return vaco_total_loss(
                log_pi=log_pi, log_beta=mb["log_beta"],
                advantages=mb["advantages"] * mb["weight"], values=values,
                value_targets=mb["value_targets"], cfg=vaco_cfg,
            )
        if hp.algorithm in ("ppo", "ppo_kl"):
            adv = mb["advantages"]
            if hp.normalize_adv:
                adv = normalize_advantages(adv)
            return ppo_total_loss(
                log_pi=log_pi, log_beta=mb["log_beta"],
                advantages=adv * mb["weight"],
                values=values, value_targets=mb["value_targets"],
                entropy=entropy, cfg=ppo_cfg,
            )
        if hp.algorithm == "spo":
            adv = mb["advantages"]
            if hp.normalize_adv:
                adv = normalize_advantages(adv)
            adv = adv * mb["weight"]
            return spo_total_loss(
                log_pi=log_pi, log_beta=mb["log_beta"], advantages=adv,
                values=values, value_targets=mb["value_targets"],
                entropy=entropy, cfg=spo_cfg,
            )
        if hp.algorithm == "impala":
            # Re-estimate V-trace against the CURRENT policy on the full
            # batch (this is IMPALA's per-update realignment cost).
            full_values = value_fn(params, full_batch.obs)
            full_boot = value_fn(params, full_batch.final_obs)
            discounts = hp.gamma * (
                1.0 - full_batch.dones.astype(jnp.float32))
            full_log_pi, _ = _log_pi_and_entropy(
                params, full_batch.obs, full_batch.actions)
            log_ratios = jax.lax.stop_gradient(full_log_pi) - \
                full_batch.log_beta
            out = vtrace(
                log_ratios=log_ratios, values=full_values,
                bootstrap_value=full_boot, rewards=full_batch.rewards,
                discounts=discounts, rho_bar=hp.rho_bar, c_bar=hp.c_bar,
                lam=hp.vtrace_lambda,
            )
            pg_adv = vtrace_impala_pg_advantage(
                out, rewards=full_batch.rewards, discounts=discounts,
                values=full_values, bootstrap_value=full_boot,
                rho_bar_pg=hp.rho_bar, log_ratios=log_ratios,
            )
            flat = lambda x: x.reshape(-1, *x.shape[2:])
            idx = mb["flat_idx"]
            return impala_total_loss(
                log_pi=log_pi, log_beta=mb["log_beta"],
                pg_advantages=flat(pg_adv)[idx] * mb["weight"],
                values=values,
                value_targets=jax.lax.stop_gradient(flat(out.vs))[idx],
                entropy=entropy, cfg=impala_cfg,
            )
        raise ValueError(hp.algorithm)

    grad_fn = jax.value_and_grad(minibatch_loss, has_aux=True)

    def train_phase(state: RLTrainState, batch: RolloutBatch, key,
                    weight: float = 1.0):
        """One phase update.  `weight` scales the policy-gradient
        advantages — 1.0 normally; <1 when the runtime's admission policy
        downweighted the trajectory item instead of dropping it."""
        advantages, value_targets = _phase_advantages(
            hp, state.params, batch)
        advantages = jax.lax.stop_gradient(advantages)
        value_targets = jax.lax.stop_gradient(value_targets)

        n, t = batch.rewards.shape
        flat = lambda x: x.reshape(n * t, *x.shape[2:])
        data = {
            "obs": flat(batch.obs),
            "actions": flat(batch.actions),
            "log_beta": flat(batch.log_beta),
            "advantages": flat(advantages),
            "value_targets": flat(value_targets),
            "flat_idx": jnp.arange(n * t),
            "weight": jnp.full((n * t,), weight, jnp.float32),
        }
        mb_size = (n * t) // hp.num_minibatches
        lr_scale = lr_schedule(state.phase)

        def epoch_step(carry, key_e):
            params, opt_state = carry
            perm = jax.random.permutation(key_e, n * t)
            perm = perm[: mb_size * hp.num_minibatches].reshape(
                hp.num_minibatches, mb_size)

            def mb_step(carry, idx):
                params, opt_state = carry
                mb = {k: v[idx] for k, v in data.items()}
                (loss, aux), grads = grad_fn(params, mb, batch)
                grads, gnorm = clip_by_global_norm(
                    grads, hp.max_grad_norm)
                params, opt_state = adamw_update(
                    grads, opt_state, params, opt_cfg, lr_scale)
                aux = dict(aux, grad_norm=gnorm)
                return (params, opt_state), aux

            (params, opt_state), auxs = jax.lax.scan(
                mb_step, (params, opt_state), perm)
            return (params, opt_state), auxs

        keys = jax.random.split(key, hp.num_epochs)
        (params, opt_state), auxs = jax.lax.scan(
            epoch_step, (state.params, state.opt_state), keys)

        metrics = {k: jnp.mean(v) for k, v in auxs.items()}
        metrics["mean_reward"] = jnp.mean(batch.rewards)
        # Final-policy TV vs the behavior data (Fig. 11 diagnostic).
        log_pi, _ = _log_pi_and_entropy(params, batch.obs, batch.actions)
        metrics["final_tv"] = 0.5 * jnp.mean(
            jnp.abs(jnp.exp(log_pi - batch.log_beta) - 1.0))
        new_state = RLTrainState(
            params=params, opt_state=opt_state, phase=state.phase + 1)
        return new_state, metrics

    return jax.jit(train_phase)
